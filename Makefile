# Convenience targets (see README.md).  PYTHONPATH is set explicitly so
# the targets work without `pip install -e .`.
PY := PYTHONPATH=src python

.PHONY: test lint-analysis bench bench-smoke bench-sim bench-workloads \
        bench-experiments bench-faults bench-faults-full bench-synth \
        bench-synth-full bench-obs bench-obs-full bench-adaptive \
        bench-adaptive-full bench-compare bench-baselines examples

#: benches with a committed baseline under benchmarks/baselines/
BENCH_NAMES := sweep workload experiments fault synth obs adaptive

test:                 ## tier-1 verify
	$(PY) -m pytest -x -q

lint-analysis:        ## static verification gate (DESIGN.md §14)
	$(PY) -m repro.analysis --all-builtin -o results/diagnostics.json
	@command -v ruff >/dev/null 2>&1 && ruff check src \
		|| echo "ruff not installed; skipping style lint"

bench:                ## all paper figures, analytic model
	$(PY) -m benchmarks.run

bench-sim:            ## all paper figures, cycle-accurate simulator
	$(PY) -m benchmarks.run --sim

bench-smoke:          ## tiny batched-vs-looped sweep, < 60 s, bitwise-checked
	$(PY) -m benchmarks.sweep_bench --smoke

bench-workloads:      ## workload grid (topologies x substrates x workloads)
	$(PY) -m benchmarks.workload_bench   # -> results/workload_sweep.csv

bench-experiments:    ## mixed static+workload grid through repro.experiments
	$(PY) -m benchmarks.experiments_bench   # -> results/experiments_grid.csv

bench-faults:         ## fault-degradation smoke, < 60 s, CSV for CI
	$(PY) -m benchmarks.fault_bench --smoke   # -> results/fault_degradation.csv

bench-faults-full:    ## full degradation curves (Table III, N=36, k<=4)
	$(PY) -m benchmarks.fault_bench

bench-synth:          ## seeded mini topology search, < 60 s, Pareto CSV
	$(PY) -m benchmarks.synth_bench --smoke   # -> results/synth_pareto.csv

bench-synth-full:     ## full N=48 search (asserts FHT on front, 5x prefilter)
	$(PY) -m benchmarks.synth_bench

bench-obs:            ## observability smoke: link heatmap + phase trace, < 60 s
	$(PY) -m benchmarks.obs_bench --smoke   # -> results/link_load_*.csv, results/sweep_phases.trace.json

bench-obs-full:       ## full link-load heatmap grid (Table III, N=36)
	$(PY) -m benchmarks.obs_bench

bench-adaptive:       ## static-vs-adaptive routing smoke, < 60 s, CSV for CI
	$(PY) -m benchmarks.adaptive_bench --smoke   # -> results/adaptive_gain.csv

bench-adaptive-full:  ## full static-vs-adaptive gain grid (Table III, N=36)
	$(PY) -m benchmarks.adaptive_bench

bench-compare:        ## diff fresh results/BENCH_*.json vs committed baselines
	@for n in $(BENCH_NAMES); do \
	  if [ -f results/BENCH_$$n.json ]; then \
	    $(PY) -m repro.obs.bench compare \
	      benchmarks/baselines/BENCH_$$n.json results/BENCH_$$n.json \
	      --warn-only || exit $$?; \
	  fi; \
	done
	@echo "(gate hard with: python -m repro.obs.bench compare OLD NEW --fail-over 25)"

bench-baselines:      ## promote fresh smoke BENCH files to committed baselines
	cp results/BENCH_*.json benchmarks/baselines/

examples:             ## quickstart examples (experiment-API smoke)
	$(PY) examples/quickstart.py
	$(PY) examples/workload_quickstart.py
	$(PY) examples/synth_quickstart.py
	$(PY) examples/fault_quickstart.py
	$(PY) examples/obs_quickstart.py
	$(PY) examples/adaptive_quickstart.py
