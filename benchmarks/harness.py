"""Bench-facing wrapper over `repro.obs.bench` (DESIGN.md §16).

Every benchmark module builds a `BenchRun`, records its scalar metrics
(and optionally a traced/profiled extra pass), and calls `finish()` —
which assembles the versioned BENCH document (machine/JAX metadata,
span summaries, XLA profiles) and writes `results/BENCH_<name>.json`.
The committed baselines under `benchmarks/baselines/` are compared
against these in CI via `python -m repro.obs.bench compare`.

Conventions:

  * wall-clock metrics end in `_s` and are lower-is-better (the
    default); ratios like `warm_speedup` pass `direction="higher"`;
  * the traced/profiled pass happens OUTSIDE every timed section —
    profiling recompiles the executable (see `repro.obs.profile`) and
    tracing adds span bookkeeping, so neither may touch a timed region.
"""
from __future__ import annotations

import time
from contextlib import contextmanager

from repro.obs import bench as B
from repro.obs.profile import (clear_profiles, disable_profiling,
                               enable_profiling, get_profiles)
from repro.obs.trace import (clear_trace, disable_tracing, enable_tracing,
                             get_spans, span_summary, tracing_enabled)

from .common import RESULTS_DIR


class BenchRun:
    """Collects one benchmark's metrics and writes its BENCH json."""

    def __init__(self, name: str, mode: str = "full",
                 results_dir: str = RESULTS_DIR):
        self.name = name
        self.mode = mode
        self.results_dir = results_dir
        self._metrics: dict = {}
        self._directions: dict = {}
        self._spans: dict = {}
        self._profiles: list = []
        self._extra: dict = {}

    # ---- metrics -------------------------------------------------------
    def metric(self, name: str, value, direction: str = "lower"
               ) -> "BenchRun":
        self._metrics[name] = value
        if direction != "lower":
            self._directions[name] = direction
        return self

    def metrics(self, values: dict, direction: str = "lower"
                ) -> "BenchRun":
        for k, v in values.items():
            self.metric(k, v, direction)
        return self

    @contextmanager
    def timed(self, name: str, direction: str = "lower"):
        """`with run.timed("warm"):` records `warm_s` wall-clock."""
        t0 = time.perf_counter()
        yield
        self.metric(f"{name}_s", round(time.perf_counter() - t0, 4),
                    direction)

    def extra(self, **fields) -> "BenchRun":
        """Attach non-scalar payloads (grids, csv rows, notes)."""
        self._extra.update(fields)
        return self

    # ---- traced / profiled extra pass ---------------------------------
    def observed_pass(self, fn, *, profile: bool = True,
                      trace: bool = True):
        """Run `fn()` once with tracing/profiling enabled and absorb the
        span summary and XLA profiles into this run.  Call it AFTER the
        timed passes: the profile capture compiles a second executable
        and the spans add bookkeeping, so this pass is never timed."""
        was_tracing = tracing_enabled()
        if trace:
            clear_trace()
            enable_tracing()
        if profile:
            clear_profiles()
            enable_profiling()
        try:
            out = fn()
        finally:
            if profile:
                disable_profiling()
                self._profiles = get_profiles()
            if trace:
                self._spans = span_summary(get_spans())
                if not was_tracing:
                    disable_tracing()
        return out

    def device_host_split(self, total_key: str = "") -> dict:
        """Device-vs-host wall-clock split from the observed pass's
        spans: device time is the `sim.wait` total (the
        `block_until_ready` tail), host time is everything else."""
        device = self._spans.get("sim.wait", {}).get("total_s", 0.0)
        stack = self._spans.get("sim.stack", {}).get("total_s", 0.0)
        dispatch = self._spans.get("sim.dispatch", {}).get("total_s", 0.0)
        return dict(device_s=round(device, 4),
                    stack_s=round(stack, 4),
                    dispatch_s=round(dispatch, 4))

    # ---- emit ----------------------------------------------------------
    def finish(self) -> dict:
        doc = B.bench_doc(self.name, self._metrics,
                          directions=self._directions, mode=self.mode,
                          spans=self._spans, profiles=self._profiles,
                          extra=self._extra)
        B.write_bench(doc, self.results_dir)
        return doc
