"""Experiment-API benchmark: one mixed static+workload grid, one call.

    PYTHONPATH=src python -m benchmarks.experiments_bench [--smoke]

Exercises the whole declarative pipeline (DESIGN.md §10) the way the
paper's grids use it: a single `Experiment` mixing static patterns and
time-varying workloads over Table-III topologies x substrates, planned
into shape/phase buckets, executed in streamed chunks with progress
reporting, and written as a schema-stamped `ResultFrame` CSV
(results/experiments_grid.csv).  Reports plan shape, wall-clock split
(plan vs execute) and the engine's compile/reuse stats.
"""
from __future__ import annotations

import argparse
import os
import time
from functools import partial

import repro.experiments as X
import repro.workloads as W
from repro.core.simulator import SimConfig

from .common import RESULTS_DIR

SMOKE = dict(names=("mesh", "folded_torus", "folded_hexa_torus"),
             n=16, n_rates=3, cycles=360, warmup=120)
DEFAULT = dict(names=("mesh", "folded_torus", "hexamesh",
                      "folded_hexa_torus", "octamesh"),
               n=36, n_rates=5, cycles=1500, warmup=500)


def build_experiment(params: dict) -> X.Experiment:
    cfg = SimConfig(cycles=params["cycles"], warmup=params["warmup"])
    alt = W.Workload("alt:tornado-uniform",
                     partial(W.phase_alternating, repeats=1))
    traffics = ("uniform", "tornado", alt)
    return X.Experiment.grid(
        topologies=params["names"], sizes=[params["n"]],
        substrates=("organic", "glass"), traffics=traffics,
        roles=("hetero_cmi",), rates=X.SaturationGrid(params["n_rates"]),
        cfg=cfg, name="experiments_grid")


def bench(params: dict, chunk_size: int | None = None) -> dict:
    exp = build_experiment(params)
    engine = X.engine_for(exp.cfg)
    t0 = time.time()
    pl = X.plan(exp, engine)
    plan_s = time.time() - t0
    print(pl.describe())
    ticks: list = []
    t0 = time.time()
    frame = X.execute(pl, engine=engine, chunk_size=chunk_size,
                      progress=lambda done, total, key:
                      ticks.append((done, total)))
    exec_s = time.time() - t0
    frame.to_csv(os.path.join(RESULTS_DIR, "experiments_grid.csv"))
    static_rows = [r for r in frame.ok() if r["kind"] == "static"]
    wl_rows = [r for r in frame.ok() if r["kind"] == "workload"]
    out = dict(scenarios=len(exp), planned=pl.n_planned,
               buckets=len(pl.buckets), static_rows=len(static_rows),
               workload_rows=len(wl_rows),
               progress_ticks=len(ticks),
               plan_s=round(plan_s, 3), execute_s=round(exec_s, 3),
               engine_stats=dict(engine.stats))
    for k, v in out.items():
        print(f"{k}: {v}")

    from .harness import BenchRun
    run = BenchRun("experiments",
                   mode="smoke" if len(exp) <= 40 else "full")
    run.metrics(dict(plan_s=out["plan_s"], execute_s=out["execute_s"]))
    run.metric("scenarios", len(exp), direction="higher")
    run.metric("buckets", len(pl.buckets))
    run.metric("compiles", engine.stats["compiles"])
    run.extra(engine_stats=dict(engine.stats))
    run.finish()
    return out


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny grid (CI-sized, well under a minute)")
    ap.add_argument("--chunk-size", type=int, default=None,
                    help="stream buckets in chunks of this many cells")
    args = ap.parse_args(argv)
    bench(SMOKE if args.smoke else DEFAULT, chunk_size=args.chunk_size)


if __name__ == "__main__":
    main()
