"""Fault injection / graceful degradation benchmark (DESIGN.md §12).

    PYTHONPATH=src python -m benchmarks.fault_bench [--smoke|--full]

Measures how Table-III topologies degrade as links die: for each
(topology, substrate) cell at N=36 we draw seeded random fault sets of
k in {0, 1, 2, 4} failed links, re-route the surviving structure
(up*/down* on the masked edge list, via the structural-hash routing
cache) and sweep offered load to saturation.  Reported per cell:

  * absolute saturation throughput through the substrate wires (Tb/s,
    the §V-B cost model at the simulated plateau), and
  * zero-load latency in ns (cycle time is 1 ns at the paper's clock),

i.e. the two ends of the degradation curve in results/
fault_degradation.csv.  A second, smaller grid superimposes a serving
tenant on an LLM-training collective step (`workloads.mixed_tenant`)
and pushes the mixed schedule through the *same* fault masks — the
"serve traffic through dead links" scenario the paper never measures.

The whole grid is one declarative `Experiment`; degraded cells whose
fault set cannot be applied (e.g. the draw would disconnect the
survivors) are skipped by the sampler with a printed reason, never
silently dropped.
"""
from __future__ import annotations

import argparse
import os
import time

import numpy as np

import repro.experiments as X
import repro.workloads as W
from repro.configs import get_config
from repro.core import topology as T
from repro.core.simulator import SimConfig, zero_load_latency
from repro.faults import FaultError, sample_faults

from .common import RESULTS_DIR, write_csv

SUBSTRATES = ("organic", "glass")
#: mixed-tenant grid: the paper's headline pair + the hexagonal family
MIXED_NAMES = ("mesh", "torus", "hexamesh", "folded_hexa_torus")

SMOKE = dict(names=("mesh", "torus", "folded_hexa_torus"), n=16,
             substrates=("organic",), ks=(0, 2), n_rates=3,
             cycles=360, warmup=120, mixed_names=("folded_hexa_torus",),
             mixed_ks=(0, 2))
DEFAULT = dict(names="ALL", n=36, substrates=SUBSTRATES,
               ks=(0, 1, 2, 4), n_rates=5, cycles=1500, warmup=500,
               mixed_names=MIXED_NAMES, mixed_ks=(0, 1, 2, 4))
FULL = dict(names="ALL", n=64, substrates=SUBSTRATES, ks=(0, 1, 2, 4, 8),
            n_rates=6, cycles=2000, warmup=700,
            mixed_names=MIXED_NAMES, mixed_ks=(0, 2, 4, 8))


def fault_grid(names, n: int, substrates, ks, *, kind: str = "random",
               seed: int = 0):
    """[(name, substrate, k, FaultSet | None)] for every valid cell.

    k=0 carries `faults=None` so the pristine path is the untouched
    zero-fault code path (bitwise identical to a fault-free Scenario).
    Cells whose topology is invalid at N, or where the sampler cannot
    draw k survivable links, are dropped with a printed reason.
    """
    cells, dropped = [], []
    for name in names:
        if name in T.N_CONSTRAINTS and not T.N_CONSTRAINTS[name](n):
            dropped.append(f"{name}: unsupported N={n} "
                           f"(topology.N_CONSTRAINTS)")
            continue
        for substrate in substrates:
            topo = T.build(name, n, substrate=substrate)
            for k in ks:
                if k == 0:
                    cells.append((name, substrate, 0, None))
                    continue
                try:
                    fs = sample_faults(topo, k, kind, seed=seed)
                except FaultError as e:
                    dropped.append(f"{name}/{substrate}/k={k}: {e}")
                    continue
                cells.append((name, substrate, k, fs))
    for msg in dropped:
        print(f"[fault_bench] drop {msg}")
    return cells


def bench_faults(params: dict, arch: str = "qwen3_1_7b") -> list[dict]:
    cfg = SimConfig(cycles=params["cycles"], warmup=params["warmup"])
    names = params["names"]
    if names == "ALL":
        names = tuple(T.GENERATORS)
    rates = X.SaturationGrid(params["n_rates"])
    n = params["n"]

    cells = fault_grid(names, n, params["substrates"], params["ks"])
    scenarios = [
        X.Scenario(name, n, substrate, traffic="uniform", faults=fs,
                   rates=rates,
                   tags=(("k_failed", k), ("suite", "static")))
        for name, substrate, k, fs in cells]

    mixed = W.mixed_tenant(get_config(arch), serve_frac=0.3)
    mixed_cells = fault_grid(params["mixed_names"], n,
                             params["substrates"], params["mixed_ks"])
    scenarios += [
        X.Scenario(name, n, substrate, traffic=mixed, faults=fs,
                   rates=rates,
                   tags=(("k_failed", k), ("suite", "mixed")))
        for name, substrate, k, fs in mixed_cells]

    exp = X.Experiment(scenarios, cfg=cfg, name="fault_degradation")
    engine = X.engine_for(cfg)
    t0 = time.time()
    frame = X.run(exp, engine=engine)
    wall = time.time() - t0

    rows = []
    for i, row in enumerate(frame.rows):
        if row["status"] != "ok":
            continue
        ps = frame.planned[i]
        rows.append(dict(
            topology=row["topology"], n=row["n"],
            substrate=row["substrate"], suite=row["suite"],
            traffic=row["traffic"], k_failed=row["k_failed"],
            faults=row["faults"], failed_links=row["failed_links"],
            sim_saturation=round(row["sim_saturation"], 4),
            analytic_saturation=round(row["analytic_saturation"], 4),
            abs_throughput_gbps=round(row["abs_throughput_gbps"], 1),
            abs_throughput_tbps=round(
                row["abs_throughput_gbps"] / 1e3, 3),
            zero_load_ns=round(
                float(zero_load_latency(ps.routing, ps.traffic)), 2),
            latency_ns=round(row["latency_ns"], 2)))
    write_csv(os.path.join(RESULTS_DIR, "fault_degradation.csv"), rows)
    print(f"[fault_bench] {len(scenarios)} scenarios "
          f"({len(frame.ok())} ok) in {wall:.1f}s; "
          f"engine stats: {engine.stats}")
    _print_headline(rows, params["ks"])

    from .harness import BenchRun
    run = BenchRun("fault", mode="smoke" if params is SMOKE else "full")
    run.metrics(dict(wall_s=round(wall, 4)))
    run.metric("scenarios", len(scenarios), direction="higher")
    run.metric("ok_rows", len(frame.ok()), direction="higher")
    run.metric("compiles", engine.stats["compiles"])
    run.finish()
    return rows


def _print_headline(rows: list[dict], ks):
    """Static-uniform degradation: abs Tb/s retained vs k failed links."""
    stat = [r for r in rows if r["suite"] == "static"
            and r["substrate"] == "organic"]
    if not stat:
        return
    print("\nuniform-traffic degradation, organic "
          "(abs Tb/s at saturation; % of k=0 in parens):")
    names = sorted({r["topology"] for r in stat})
    print(f"  {'topology':20s} " + " ".join(f"{f'k={k}':>15s}"
                                            for k in ks))
    for name in names:
        by_k = {r["k_failed"]: r for r in stat if r["topology"] == name}
        if 0 not in by_k:
            continue
        base = by_k[0]["abs_throughput_tbps"]
        vals = []
        for k in ks:
            if k not in by_k:
                vals.append(f"{'—':>15s}")
                continue
            t = by_k[k]["abs_throughput_tbps"]
            vals.append(f"{t:7.2f} ({100 * t / max(base, 1e-9):4.0f}%)")
        print(f"  {name:20s} " + " ".join(vals))


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny grid (CI-sized, well under a minute)")
    ap.add_argument("--full", action="store_true",
                    help="all topologies at N=64, k up to 8 (slow)")
    ap.add_argument("--arch", default="qwen3_1_7b",
                    help="architecture for the mixed-tenant workload")
    args = ap.parse_args(argv)
    params = SMOKE if args.smoke else (FULL if args.full else DEFAULT)
    bench_faults(params, arch=args.arch)


if __name__ == "__main__":
    main()
