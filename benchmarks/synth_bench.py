"""Topology-synthesis benchmark: search the design space, report the
Pareto front -> results/synth_pareto.csv.

    PYTHONPATH=src python -m benchmarks.synth_bench [--smoke] [--seed S]

Runs the full DESIGN.md §11 pipeline (generate -> feasibility filter
-> analytic rank -> cycle-accurate verify -> Pareto) at the paper's
scale point (N=48, organic substrate) and reports the two headline
numbers the subsystem exists to produce:

  * whether `folded_hexa_torus` lands on (or within 5 % of) the Pareto
    front of the search's own candidate pool, and
  * the prefilter ratio — feasible candidates per cycle-accurate
    simulation (how much the analytic stage cut the simulation bill).

`--smoke` runs a seeded mini-search (N=16, one generation, short
simulations) that finishes well under 60 s for CI; it writes the same
CSV schema.
"""
from __future__ import annotations

import argparse
import os
import time

from repro.core.simulator import SimConfig
from repro.experiments import io as xio
from repro.synth import SearchConfig, run_search

from .common import RESULTS_DIR

SMOKE = SearchConfig(n=16, n_random=8, generations=1, offspring=8,
                     sim_top=4, n_rates=3,
                     cfg=SimConfig(cycles=360, warmup=120))
DEFAULT = SearchConfig(n=48, cfg=SimConfig(cycles=1500, warmup=500))


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="seeded mini-search (<60 s) for CI")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--out", default=os.path.join(RESULTS_DIR,
                                                  "synth_pareto.csv"))
    args = ap.parse_args()
    import dataclasses
    cfg = dataclasses.replace(SMOKE if args.smoke else DEFAULT,
                              seed=args.seed)

    t0 = time.time()
    res = run_search(cfg, progress=lambda g, G, s: print(
        f"[synth] generation {g}/{G}: {s['n_feasible']} feasible "
        f"of {s['n_generated']} generated", flush=True))
    wall = time.time() - t0

    xio.write_csv(args.out, res.rows())
    s = res.stats
    print(f"[synth] N={cfg.n} {cfg.substrate} seed={cfg.seed}: "
          f"{s['n_generated']} generated, {s['n_infeasible']} infeasible, "
          f"{s['n_duplicate']} duplicate, {s['n_feasible']} feasible, "
          f"{s['n_simulated']} simulated in {wall:.1f}s")
    print(f"[synth] prefilter ratio: {res.prefilter_ratio:.1f}x "
          f"(feasible / cycle-sim evaluations)")
    front = [c.topo.name for c in res.front()]
    print(f"[synth] Pareto front (abs Tb/s, zero-load ns, wire-mm): "
          f"{front}")
    fht = res.on_front("folded_hexa_torus", eps=0.0)
    fht5 = res.on_front("folded_hexa_torus", eps=0.05)
    print(f"[synth] folded_hexa_torus on front: {fht} "
          f"(within 5%: {fht5})")
    from .harness import BenchRun
    run = BenchRun("synth", mode="smoke" if args.smoke else "full")
    run.metrics(dict(wall_s=round(wall, 4)))
    run.metric("generated", s["n_generated"], direction="higher")
    run.metric("feasible", s["n_feasible"], direction="higher")
    run.metric("simulated", s["n_simulated"])
    run.metric("prefilter_ratio", round(res.prefilter_ratio, 2),
               direction="higher")
    run.metric("front_size", len(front), direction="higher")
    run.finish()

    if not args.smoke:
        assert fht5, "FHT fell off its own Pareto front — regression"
        assert res.prefilter_ratio >= 5.0, \
            f"prefilter ratio {res.prefilter_ratio:.1f}x < 5x"


if __name__ == "__main__":
    main()
