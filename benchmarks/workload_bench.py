"""Workload sweep benchmark — the headline time-varying result.

    PYTHONPATH=src python -m benchmarks.workload_bench [--smoke|--full]

Evaluates Table-III topologies on organic AND glass substrates under
three workload families (DESIGN.md §9):

  * an LLM-training collective workload derived from a sharded qwen3
    step (`repro.workloads.collective_workload` — TP all-reduce waves,
    FSDP gather/reduce-scatter, mapped onto chiplet positions),
  * a replayed Netrace-like region trace with ON/OFF memory bursts
    (`trace_workload("fluidanimate")`),
  * an adversarial tornado<->uniform phase alternation.

The whole (topology x substrate) x workload grid is ONE declarative
`Experiment` — workloads ride in the Scenarios' `traffic` field — run
through `repro.experiments.run` (DESIGN.md §10), which lowers it onto
batched `SweepEngine` programs (the engine `stats` record how many).
Results land in results/workload_sweep.csv (schema-stamped), one row
per (cell, phase) plus an ALL summary row per cell.
"""
from __future__ import annotations

import argparse
import os
import time
from functools import partial

import numpy as np

import repro.experiments as X
import repro.workloads as W
from repro.configs import get_config
from repro.core.simulator import SimConfig

from .common import RESULTS_DIR, write_csv

SUBSTRATES = ("organic", "glass")

SMOKE = dict(names=("mesh", "folded_torus", "folded_hexa_torus"),
             n=16, n_rates=3, cycles=360, warmup=120, roles="hetero_cmi")
DEFAULT = dict(names=("mesh", "folded_torus", "hexamesh",
                      "folded_hexa_torus"),
               n=36, n_rates=5, cycles=1500, warmup=500,
               roles="hetero_cmi")
# all Table-III topologies (invalid N-constraint cells are skipped by
# the planner, e.g. cluscross at odd grids)
FULL = dict(names="ALL", n=64, n_rates=6, cycles=2000, warmup=700,
            roles="hetero_cmi")


def workload_suite(arch: str = "qwen3_1_7b") -> list[W.Workload]:
    cfg = get_config(arch)
    return [
        W.Workload(f"collective:{cfg.name}",
                   partial(W.collective_workload, cfg)),
        W.Workload("trace:fluidanimate",
                   partial(W.trace_workload, trace="fluidanimate")),
        W.Workload("alt:tornado-uniform", W.phase_alternating),
    ]


def bench_workloads(params: dict, arch: str = "qwen3_1_7b") -> list[dict]:
    cfg = SimConfig(cycles=params["cycles"], warmup=params["warmup"])
    names = params["names"]
    if names == "ALL":
        from repro.core import topology as T
        names = tuple(T.GENERATORS)
    workloads = workload_suite(arch)
    exp = X.Experiment(
        [X.Scenario(name, params["n"], substrate, traffic=wl,
                    roles=params["roles"],
                    rates=X.SaturationGrid(params["n_rates"]))
         for name in names for substrate in SUBSTRATES
         for wl in workloads],
        cfg=cfg, name="workload_sweep")
    engine = X.engine_for(cfg)
    t0 = time.time()
    frame = X.run(exp, engine=engine)
    wall = time.time() - t0
    rows = []
    for i, row in enumerate(frame.rows):
        if row["status"] != "ok":
            continue
        res = frame.workload_result(i)
        # relative saturation is substrate-blind at these link lengths;
        # the substrate story is the absolute rate the wires sustain
        base = dict(topology=row["topology"], n=row["n"],
                    substrate=row["substrate"], workload=res["workload"],
                    sim_saturation=round(res["sim_saturation"], 4),
                    abs_throughput_gbps=round(row["abs_throughput_gbps"],
                                              1),
                    analytic_saturation=round(res["analytic_saturation"],
                                              4),
                    latency_at_sat=round(res["latency_at_sat"], 2))
        rows.append(dict(base, phase="ALL",
                         phase_cycles=int(res["phase_cycles"].sum()),
                         throughput=base["sim_saturation"],
                         latency=base["latency_at_sat"]))
        for k, label in enumerate(res["phase_labels"]):
            rows.append(dict(
                base, phase=label,
                phase_cycles=int(res["phase_cycles"][k]),
                throughput=round(float(res["throughput_ph"][k]), 4),
                latency=round(float(res["latency_ph"][k]), 2)))
    write_csv(os.path.join(RESULTS_DIR, "workload_sweep.csv"), rows)
    n_cells = len(names) * len(SUBSTRATES)
    print(f"[workload_bench] {n_cells} cells x {len(workloads)} "
          f"workloads in {wall:.1f}s; engine stats: {engine.stats}")
    _print_headline(rows)

    from .harness import BenchRun
    run = BenchRun("workload", mode="smoke" if params is SMOKE else "full")
    pf = [r["pad_fill"]["phase"] for r in frame.results if r is not None]
    run.metrics(dict(wall_s=round(wall, 4)))
    run.metric("cells", n_cells, direction="higher")
    run.metric("rows", len(rows), direction="higher")
    run.metric("pad_fill_phase", round(float(np.mean(pf)), 4)
               if pf else None, direction="higher")
    run.metric("compiles", engine.stats["compiles"])
    run.finish()
    return rows


def _print_headline(rows: list[dict]):
    """Collective-workload saturation by topology, organic vs glass."""
    coll = [r for r in rows if r["phase"] == "ALL"
            and r["workload"].startswith("collective:")]
    if not coll:
        return
    print("\nLLM-collective workload saturation "
          "(rel flits/node/cycle | abs Tb/s):")
    names = sorted({r["topology"] for r in coll})
    print(f"  {'topology':20s} " +
          " ".join(f"{s:>16s}" for s in SUBSTRATES))
    for name in names:
        cells = {r["substrate"]: r for r in coll if r["topology"] == name}
        vals = " ".join(
            f"{cells[s]['sim_saturation']:6.3f}|"
            f"{cells[s]['abs_throughput_gbps'] / 1e3:6.2f} Tb"
            if s in cells else f"{'—':>16s}" for s in SUBSTRATES)
        print(f"  {name:20s} {vals}")


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny grid (CI-sized, well under a minute)")
    ap.add_argument("--full", action="store_true",
                    help="10 topologies at N=64 (slow)")
    ap.add_argument("--arch", default="qwen3_1_7b",
                    help="architecture for the collective workload")
    args = ap.parse_args(argv)
    params = SMOKE if args.smoke else (FULL if args.full else DEFAULT)
    bench_workloads(params, arch=args.arch)


if __name__ == "__main__":
    main()
