"""Batched-vs-looped sweep wall-clock benchmark (DESIGN.md §6/§7).

    PYTHONPATH=src python -m benchmarks.sweep_bench [--smoke]

Measures the paper's core evaluation loop — K topologies x R injection
rates through the cycle simulator — two ways:

  * looped:  one compiled program per topology (the seed behaviour),
    driven by the primitive `run_batch`;
  * batched: the same grid described as a declarative `Experiment` and
    executed through `repro.experiments` (DESIGN.md §10), which lowers
    it onto a handful of padded `SweepEngine` programs.

The plan (routing, specs, rate grids) is resolved before the clock
starts for both paths, so cold times isolate compile + run cost.  The
two paths are checked bitwise-equal, counter for counter, before any
number is reported.  Results land in results/sweep_speedup.csv
(schema-stamped).  --smoke shrinks the grid so the whole benchmark
finishes well under a minute (the `make bench-smoke` target).
"""
from __future__ import annotations

import argparse
import os
import time

import numpy as np

import repro.experiments as X
from repro.core import simulator as sim
from repro.core.simulator import SimConfig, run_batch
from repro.obs.metrics import metrics

from .common import RESULTS_DIR, write_csv
from .harness import BenchRun

SMOKE = dict(names=("mesh", "folded_torus", "hexamesh",
                    "folded_hexa_torus"),
             n=16, n_rates=4, cycles=300, warmup=100)
FULL = dict(names=("mesh", "folded_torus", "hexamesh",
                   "folded_hexa_torus", "octamesh", "kite_medium"),
            n=36, n_rates=8, cycles=1500, warmup=500)


def _fresh_cache():
    """Clear the compiled-runner LRU so cold timings include compile."""
    sim._RUNNER_CACHE.clear()


def bench_speedup(smoke: bool = True) -> dict:
    params = SMOKE if smoke else FULL
    cfg = SimConfig(cycles=params["cycles"], warmup=params["warmup"])
    engine = X.engine_for(cfg)
    exp = X.Experiment(
        [X.Scenario(name, params["n"],
                    rates=X.SaturationGrid(params["n_rates"]))
         for name in params["names"]],
        cfg=cfg, name="sweep_bench")
    # resolve routing/specs/rates untimed; single_program mirrors the
    # seed bench's semantics (the whole grid as ONE compiled program)
    pl = X.plan(exp, engine, single_program=True)
    planned = sorted((ps for b in pl.buckets for ps in b.items),
                     key=lambda ps: ps.index)
    raw_keys = ("delivered", "offered_n", "accepted_n", "lat_sum")

    def looped():
        out = []
        for ps in planned:
            res = run_batch([ps.spec], ps.rates[None, :], cfg)[0]
            # same per-scenario tidy-row derivation the executor's
            # ResultFrame performs, so the timings compare like-for-like
            X.scenario_row(exp, ps, res)
            out.append(res)
        return out

    def batched():
        return X.execute(pl, engine=engine)   # few padded programs

    _fresh_cache()
    t0 = time.time()
    loop_res = looped()
    looped_cold = time.time() - t0
    t0 = time.time()
    looped()
    looped_warm = time.time() - t0

    _fresh_cache()
    t0 = time.time()
    frame = batched()
    batched_cold = time.time() - t0
    t0 = time.time()
    batched()
    batched_warm = time.time() - t0

    # warm host/device split (DESIGN.md §16): one extra WARM pass with
    # spans + XLA profiling on, never timed — device time is the
    # `sim.wait` total (block_until_ready), host time is the plan /
    # stack / dispatch orchestration around it.  This is the pass that
    # answers where the 0.82x-warm number's time actually goes.
    run = BenchRun("sweep", mode="smoke" if smoke else "full")
    frame2 = run.observed_pass(batched)
    split = run.device_host_split()
    warm_device = split["device_s"]
    warm_host = round(max(batched_warm - warm_device, 0.0), 4)

    # pad-waste accounting: per-scenario live-work fraction (from the
    # runner's pad_fill) and the engine's bucket fill (live rows /
    # padded rows), both straight off the observed pass
    pf = [r["pad_fill"]["state"] for r in frame2.results
          if r is not None]
    pad_fill = round(float(np.mean(pf)), 4) if pf else None
    bf = metrics.snapshot().get("sweep.bucket_fill")
    bucket_fill = round(bf["sum"] / bf["count"], 4) if bf else None

    equal = all(np.array_equal(a[k], frame.results[ps.index][k])
                for a, ps in zip(loop_res, planned) for k in raw_keys)
    out = dict(n_topologies=len(planned), n_rates=params["n_rates"],
               n=params["n"], cycles=params["cycles"],
               looped_cold_s=round(looped_cold, 3),
               looped_warm_s=round(looped_warm, 3),
               batched_cold_s=round(batched_cold, 3),
               batched_warm_s=round(batched_warm, 3),
               batched_warm_host_s=warm_host,
               batched_warm_device_s=warm_device,
               pad_fill_state=pad_fill, bucket_fill=bucket_fill,
               cold_speedup=round(looped_cold / max(batched_cold, 1e-9), 2),
               warm_speedup=round(looped_warm / max(batched_warm, 1e-9), 2),
               bitwise_equal=equal, mode="smoke" if smoke else "full")
    write_csv(os.path.join(RESULTS_DIR, "sweep_speedup.csv"), [out])

    run.metrics({k: v for k, v in out.items()
                 if isinstance(v, (int, float))
                 and not isinstance(v, bool)
                 and k not in ("cold_speedup", "warm_speedup")})
    run.metric("cold_speedup", out["cold_speedup"], direction="higher")
    run.metric("warm_speedup", out["warm_speedup"], direction="higher")
    run.metric("pad_fill_state", pad_fill, direction="higher")
    run.metric("bucket_fill", bucket_fill, direction="higher")
    run.extra(bitwise_equal=equal, csv_row=out)
    run.finish()
    return out


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny grid, finishes in well under 60 s")
    args = ap.parse_args(argv)
    out = bench_speedup(smoke=args.smoke)
    for k, v in out.items():
        print(f"{k}: {v}")
    if not out["bitwise_equal"]:
        raise SystemExit("batched results diverged from looped results")


if __name__ == "__main__":
    main()
