"""Batched-vs-looped sweep wall-clock benchmark (DESIGN.md §6/§7).

    PYTHONPATH=src python -m benchmarks.sweep_bench [--smoke]

Measures the paper's core evaluation loop — K topologies x R injection
rates through the cycle simulator — two ways:

  * looped:  one compiled program per topology (the seed behaviour),
  * batched: all topologies padded into ONE compiled program
             (`run_batch`, DESIGN.md §6).

Cold times include compilation (the dominant cost of the per-topology
loop); warm times re-run the cached executables.  Results land in
results/sweep_speedup.csv and the two paths are checked bitwise-equal
before any number is reported.  --smoke shrinks the grid so the whole
benchmark finishes well under a minute (the `make bench-smoke` target).
"""
from __future__ import annotations

import argparse
import os
import time

import numpy as np

from repro.core import simulator as sim
from repro.core import traffic as TR
from repro.core.routing import cached_routing
from repro.core.simulator import SimConfig, make_spec, run_batch

from .common import RESULTS_DIR, write_csv

SMOKE = dict(names=("mesh", "folded_torus", "hexamesh",
                    "folded_hexa_torus"),
             n=16, n_rates=4, cycles=300, warmup=100)
FULL = dict(names=("mesh", "folded_torus", "hexamesh",
                   "folded_hexa_torus", "octamesh", "kite_medium"),
            n=36, n_rates=8, cycles=1500, warmup=500)


def _specs_and_rates(params):
    specs, rate_rows = [], []
    for name in params["names"]:
        topo, routing = cached_routing(name, params["n"])
        tm = TR.PATTERNS["uniform"](topo)
        specs.append(make_spec(routing, tm))
        rate_rows.append(sim.saturation_rate_grid(
            routing.saturation_rate(tm), params["n_rates"]))
    return specs, np.stack(rate_rows).astype(np.float32)


def _fresh_cache():
    """Clear the compiled-runner cache so cold timings include compile."""
    sim._RUNNER_CACHE.clear()


def bench_speedup(smoke: bool = True) -> dict:
    params = SMOKE if smoke else FULL
    cfg = SimConfig(cycles=params["cycles"], warmup=params["warmup"])
    specs, rates = _specs_and_rates(params)
    raw_keys = ("delivered", "offered_n", "accepted_n", "lat_sum")

    def looped():
        return [run_batch([s], rates[i:i + 1], cfg)[0]
                for i, s in enumerate(specs)]

    def batched():
        return run_batch(specs, rates, cfg)   # ONE compiled program

    _fresh_cache()
    t0 = time.time()
    loop_res = looped()
    looped_cold = time.time() - t0
    t0 = time.time()
    looped()
    looped_warm = time.time() - t0

    _fresh_cache()
    t0 = time.time()
    batch_res = batched()
    batched_cold = time.time() - t0
    t0 = time.time()
    batched()
    batched_warm = time.time() - t0

    equal = all(np.array_equal(a[k], b[k])
                for a, b in zip(loop_res, batch_res) for k in raw_keys)
    out = dict(n_topologies=len(specs), n_rates=params["n_rates"],
               n=params["n"], cycles=params["cycles"],
               looped_cold_s=round(looped_cold, 3),
               looped_warm_s=round(looped_warm, 3),
               batched_cold_s=round(batched_cold, 3),
               batched_warm_s=round(batched_warm, 3),
               cold_speedup=round(looped_cold / max(batched_cold, 1e-9), 2),
               warm_speedup=round(looped_warm / max(batched_warm, 1e-9), 2),
               bitwise_equal=equal, mode="smoke" if smoke else "full")
    write_csv(os.path.join(RESULTS_DIR, "sweep_speedup.csv"), [out])
    return out


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny grid, finishes in well under 60 s")
    args = ap.parse_args(argv)
    out = bench_speedup(smoke=args.smoke)
    for k, v in out.items():
        print(f"{k}: {v}")
    if not out["bitwise_equal"]:
        raise SystemExit("batched results diverged from looped results")


if __name__ == "__main__":
    main()
