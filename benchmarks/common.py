"""Shared benchmark helpers: evaluate (topology, N, substrate, traffic)
cells analytically (channel-load bound + zero-load latency) or with the
cycle-accurate simulator.

Simulated evaluation goes through the batched sweep engine
(`repro.sweep.SweepEngine`, DESIGN.md §6): all cells of a figure are
padded into a handful of compiled programs instead of recompiling the
simulator per topology — the speedup is recorded by
`benchmarks/sweep_bench.py` in results/sweep_speedup.csv.
"""
from __future__ import annotations

import os
import time

import numpy as np

from repro.core import costmodel as cm
from repro.core import traffic as TR
from repro.core.routing import cached_routing
from repro.core.simulator import SimConfig, zero_load_latency
from repro.sweep.engine import SweepCase, SweepEngine

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "results")

# default benchmark sizes (--full sweeps the paper's 16..256 range finer)
SIZES = [16, 64, 144, 256]
SIZES_FULL = [16, 36, 64, 100, 144, 196, 256]

BENCH_SIM_CFG = SimConfig(cycles=2000, warmup=700)

_ENGINES: dict[SimConfig, SweepEngine] = {}


def engine_for(cfg: SimConfig = BENCH_SIM_CFG) -> SweepEngine:
    """One engine per SimConfig so all figures share executables."""
    if cfg not in _ENGINES:
        _ENGINES[cfg] = SweepEngine(cfg=cfg)
    return _ENGINES[cfg]


def _cell_row(case: SweepCase, sim_res: dict | None) -> dict:
    """Paper §V-B metrics for one cell; sim_res overrides the analytic
    saturation/latency when the cell was simulated."""
    topo, routing = cached_routing(case.name, case.n, case.substrate,
                                   case.area, case.roles)
    tm = TR.PATTERNS[case.pattern](topo)
    t_r = routing.saturation_rate(tm)
    lat = zero_load_latency(routing, tm)
    if sim_res is not None:
        t_r = sim_res["sim_saturation"]
        lat = sim_res["latency_at_sat"]
    _, hops, _ = routing.paths_channel_loads(tm)
    w = tm / max(tm.sum(), 1e-12)
    avg_hops = float((hops * w).sum())
    rep = cm.report(topo, t_r, avg_hops, lat)
    return dict(topology=case.name, n=case.n, substrate=case.substrate,
                pattern=case.pattern, area_mm2=case.area,
                rel_throughput=rep.rel_throughput,
                abs_throughput_gbps=rep.abs_throughput_gbps,
                latency_ns=rep.avg_latency_ns,
                chiplet_area_mm2=rep.area_mm2,
                phy_area_frac=rep.phy_area_fraction,
                power_w=rep.power_w, max_link_mm=rep.max_link_mm,
                radix=rep.radix, sim=sim_res is not None)


def evaluate_many(cells, use_sim: bool = False,
                  sim_cfg: SimConfig = BENCH_SIM_CFG,
                  n_rates: int = 6) -> list[dict | None]:
    """Evaluate many cells; simulated cells run through the batched
    sweep engine in few compiled programs.  cells: SweepCase or tuples
    accepted by SweepCase(*cell).  Invalid (N-constraint) cells -> None.
    """
    cases = [c if isinstance(c, SweepCase) else SweepCase(*c)
             for c in cells]
    sims: list = [None] * len(cases)
    if use_sim:
        sims = engine_for(sim_cfg).evaluate_cases(cases, n_rates=n_rates)
    return [_cell_row(case, sims[i]) if case.valid else None
            for i, case in enumerate(cases)]


def evaluate(name: str, n: int, substrate: str = "organic",
             pattern: str = "uniform", area: float = 74.0,
             roles: str = "homogeneous", use_sim: bool = False,
             sim_cfg: SimConfig = BENCH_SIM_CFG):
    """Single-cell convenience wrapper over `evaluate_many`."""
    return evaluate_many(
        [SweepCase(name, n, substrate, pattern, area, roles)],
        use_sim=use_sim, sim_cfg=sim_cfg)[0]


def write_csv(path: str, rows: list[dict]):
    os.makedirs(os.path.dirname(path), exist_ok=True)
    rows = [r for r in rows if r]
    if not rows:
        return
    cols = list(rows[0].keys())
    with open(path, "w") as f:
        f.write(",".join(cols) + "\n")
        for r in rows:
            f.write(",".join(str(r.get(c, "")) for c in cols) + "\n")
    print(f"[bench] wrote {path} ({len(rows)} rows)")


def timed(fn):
    t0 = time.time()
    out = fn()
    return out, (time.time() - t0) * 1e6
