"""Shared benchmark helpers, now a thin layer over `repro.experiments`.

The figure benches describe their grids as `Experiment`s of `Scenario`s
and run them through the declarative pipeline (DESIGN.md §10); this
module keeps the shared constants (sizes, the bench SimConfig), the
tidy-row -> legacy-row mapping, and the deprecated `evaluate_many` /
`evaluate` shims for code still written against the PR 1 API.

CSV output goes through `repro.experiments.io` (stable column order +
`schema_version` stamp) — `write_csv` forwards there.
"""
from __future__ import annotations

import os
import time
import warnings

import repro.experiments as X
from repro.core.simulator import SimConfig
from repro.experiments import io as xio
from repro.sweep.engine import SweepCase

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "results")

# default benchmark sizes (--full sweeps the paper's 16..256 range finer)
SIZES = [16, 64, 144, 256]
SIZES_FULL = [16, 36, 64, 100, 144, 196, 256]

BENCH_SIM_CFG = SimConfig(cycles=2000, warmup=700)


def run_cells(scenarios, use_sim: bool = False,
              sim_cfg: SimConfig = BENCH_SIM_CFG,
              name: str = "bench") -> X.ResultFrame:
    """Run a list of scenarios under the bench config; `use_sim=False`
    evaluates the analytic channel-load model (no simulation)."""
    exp = X.Experiment(scenarios, cfg=sim_cfg, name=name,
                       backend="sim" if use_sim else "analytic")
    return X.run(exp)


def legacy_row(row: dict) -> dict | None:
    """Map one tidy `ResultFrame` row to the PR 1 bench-row keys."""
    if row["status"] != "ok":
        return None
    return dict(topology=row["topology"], n=row["n"],
                substrate=row["substrate"], pattern=row["traffic"],
                area_mm2=row["area_mm2"],
                rel_throughput=row["rel_throughput"],
                abs_throughput_gbps=row["abs_throughput_gbps"],
                latency_ns=row["latency_ns"],
                chiplet_area_mm2=row["chiplet_area_mm2"],
                phy_area_frac=row["phy_area_frac"],
                power_w=row["power_w"], max_link_mm=row["max_link_mm"],
                radix=row["radix"], sim=row["backend"] == "sim")


def _cases_to_scenarios(cells, n_rates: int):
    cases = [c if isinstance(c, SweepCase) else SweepCase(*c)
             for c in cells]
    return [X.scenario_from_case(c, rates=X.SaturationGrid(n_rates))
            for c in cases]


def evaluate_many(cells, use_sim: bool = False,
                  sim_cfg: SimConfig = BENCH_SIM_CFG,
                  n_rates: int = 6) -> list[dict | None]:
    """DEPRECATED: build an `Experiment` and call
    `repro.experiments.run` (see README migration table).

    Forwards to the declarative pipeline; returns the legacy row dicts
    (None for invalid cells)."""
    warnings.warn(
        "benchmarks.common.evaluate_many is deprecated; build an "
        "Experiment of Scenarios and call repro.experiments.run",
        DeprecationWarning, stacklevel=2)
    frame = run_cells(_cases_to_scenarios(cells, n_rates),
                      use_sim=use_sim, sim_cfg=sim_cfg,
                      name="evaluate_many")
    return [legacy_row(r) for r in frame.rows]


def evaluate(name: str, n: int, substrate: str = "organic",
             pattern: str = "uniform", area: float = 74.0,
             roles: str = "homogeneous", use_sim: bool = False,
             sim_cfg: SimConfig = BENCH_SIM_CFG):
    """DEPRECATED single-cell wrapper: use `repro.experiments.run` on a
    one-Scenario Experiment."""
    warnings.warn(
        "benchmarks.common.evaluate is deprecated; run a one-Scenario "
        "Experiment through repro.experiments.run",
        DeprecationWarning, stacklevel=2)
    frame = run_cells(
        [X.Scenario(name, n, substrate, pattern, area, roles)],
        use_sim=use_sim, sim_cfg=sim_cfg, name="evaluate")
    return legacy_row(frame.rows[0])


def write_csv(path: str, rows: list[dict]):
    """Forwarder to the shared versioned writer (schema_version column,
    stable first-seen column order)."""
    xio.write_csv(path, rows)


def timed(fn):
    t0 = time.time()
    out = fn()
    return out, (time.time() - t0) * 1e6
