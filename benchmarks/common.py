"""Shared benchmark helpers: evaluate one (topology, N, substrate,
traffic) cell analytically (channel-load bound + zero-load latency) or
with the cycle-accurate simulator."""
from __future__ import annotations

import functools
import os
import time

import numpy as np

from repro.core import costmodel as cm
from repro.core import topology as T
from repro.core import traffic as TR
from repro.core.routing import build_routing
from repro.core.simulator import SimConfig, saturation_throughput, \
    zero_load_latency

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "results")

# default benchmark sizes (--full sweeps the paper's 16..256 range finer)
SIZES = [16, 64, 144, 256]
SIZES_FULL = [16, 36, 64, 100, 144, 196, 256]


@functools.lru_cache(maxsize=4096)
def _routing(name: str, n: int, substrate: str, area: float,
             roles: str, hex_region: bool = False):
    topo = T.build(name, n, substrate=substrate, chiplet_area_mm2=area,
                   roles_scheme=roles, hex_region=hex_region)
    return topo, build_routing(topo)


def evaluate(name: str, n: int, substrate: str = "organic",
             pattern: str = "uniform", area: float = 74.0,
             roles: str = "homogeneous", use_sim: bool = False,
             sim_cfg: SimConfig = SimConfig(cycles=2000, warmup=700)):
    """Returns a dict with the paper's §V-B metrics for one cell."""
    if name in T.N_CONSTRAINTS and not T.N_CONSTRAINTS[name](n):
        return None
    topo, routing = _routing(name, n, substrate, area, roles)
    tm = TR.PATTERNS[pattern](topo)
    t_r = routing.saturation_rate(tm)
    lat = zero_load_latency(routing, tm)
    sim_sat = None
    if use_sim:
        out = saturation_throughput(routing, tm, sim_cfg, n_rates=6)
        sim_sat = out["sim_saturation"]
        lat = out["latency_at_sat"]
        t_r = sim_sat
    _, hops, _ = routing.paths_channel_loads(tm)
    w = tm / max(tm.sum(), 1e-12)
    avg_hops = float((hops * w).sum())
    rep = cm.report(topo, t_r, avg_hops, lat)
    return dict(topology=name, n=n, substrate=substrate, pattern=pattern,
                area_mm2=area, rel_throughput=rep.rel_throughput,
                abs_throughput_gbps=rep.abs_throughput_gbps,
                latency_ns=rep.avg_latency_ns,
                chiplet_area_mm2=rep.area_mm2,
                phy_area_frac=rep.phy_area_fraction,
                power_w=rep.power_w, max_link_mm=rep.max_link_mm,
                radix=rep.radix, sim=use_sim)


def write_csv(path: str, rows: list[dict]):
    os.makedirs(os.path.dirname(path), exist_ok=True)
    rows = [r for r in rows if r]
    if not rows:
        return
    cols = list(rows[0].keys())
    with open(path, "w") as f:
        f.write(",".join(cols) + "\n")
        for r in rows:
            f.write(",".join(str(r.get(c, "")) for c in cols) + "\n")
    print(f"[bench] wrote {path} ({len(rows)} rows)")


def timed(fn):
    t0 = time.time()
    out = fn()
    return out, (time.time() - t0) * 1e6
