"""Observability benchmark (DESIGN.md §13): link-load heatmaps + traces.

    PYTHONPATH=src python -m benchmarks.obs_bench [--smoke]

Three artifacts, all from the flight recorder + span tracer:

  * results/link_load_heatmap.csv — per-directed-channel busy/util/
    stall/occupancy rows for every Table-III topology x substrate at
    N=36 under uniform traffic at the saturation plateau, plus
    results/link_load_summary.csv with the distribution stats
    (p50/p95/max channel load, Gini imbalance) per cell.  This is the
    paper's central mechanism made measurable: folding *spreads*
    channel load where Mesh/Torus concentrate it.
  * results/fault_link_load.csv — the same per-link view for the
    FoldedHexaTorus k=2 failed-links cell of results/
    fault_degradation.csv (same seeded draw), with the dead links as
    explicit `status="dead"` rows — showing where the surviving
    channels pick up the rerouted load.
  * results/sweep_phases.trace.json — a Chrome-trace/Perfetto span
    breakdown of one cold and one warm sweep over the same grid
    (plan -> chunk -> sweep.group -> sim.dispatch/sim.wait), with the
    compile-vs-run wall-clock split printed from the span tree.

The bench also *asserts* the flight-recorder conservation invariants
on every cell (sum(inj_node) == accepted_n, sum(eject_node) ==
delivered, sum(lat_hist) == delivered) — the telemetry cross-check of
the acceptance criteria.
"""
from __future__ import annotations

import argparse
import os
import time

import numpy as np

import repro.experiments as X
from repro.core import topology as T
from repro.core.simulator import SimConfig
from repro.faults import FaultError, sample_faults
import repro.workloads as W
from repro.obs import metrics
from repro.obs.report import write_link_reports, write_window_reports
from repro.obs.trace import (clear_trace, disable_tracing, enable_tracing,
                             get_spans, save_chrome_trace, trace)
from repro.sweep.engine import SweepEngine

from .common import RESULTS_DIR
from .harness import BenchRun

SUBSTRATES = ("organic", "glass")

SMOKE = dict(names=("mesh", "torus", "folded_hexa_torus"), n=16,
             substrates=("organic",), n_rates=3, cycles=360, warmup=120)
DEFAULT = dict(names="ALL", n=36, substrates=SUBSTRATES, n_rates=5,
               cycles=1500, warmup=500)


def _scenarios(params: dict):
    names = params["names"]
    if names == "ALL":
        names = tuple(T.GENERATORS)
    rates = X.SaturationGrid(params["n_rates"])
    n = params["n"]
    out = []
    for name in names:
        if name in T.N_CONSTRAINTS and not T.N_CONSTRAINTS[name](n):
            print(f"[obs_bench] drop {name}: unsupported N={n}")
            continue
        for substrate in params["substrates"]:
            out.append(X.Scenario(name, n, substrate, traffic="uniform",
                                  rates=rates))
    return out


def check_conservation(frame: X.ResultFrame) -> int:
    """Assert the exact flight-recorder invariants on every ok cell."""
    checked = 0
    for i, row in enumerate(frame.rows):
        res = frame.results[i]
        if row["status"] != "ok" or res is None:
            continue
        np.testing.assert_array_equal(
            res["inj_node"].sum(axis=1), res["accepted_n"],
            err_msg=f"{row['topology']}: inj_node vs accepted_n")
        np.testing.assert_array_equal(
            res["eject_node"].sum(axis=1), res["delivered"],
            err_msg=f"{row['topology']}: eject_node vs delivered")
        np.testing.assert_array_equal(
            res["lat_hist"].sum(axis=1), res["delivered"],
            err_msg=f"{row['topology']}: lat_hist vs delivered")
        checked += 1
    return checked


def _phase_split(spans) -> dict:
    """Wall-clock (ms) per span kind, compile vs run split included."""
    ms = lambda sel: sum(s.dur for s in spans if sel(s)) / 1e6
    return dict(
        plan=ms(lambda s: s.name == "experiment.plan"),
        stack=ms(lambda s: s.name == "sim.stack"),
        dispatch_cold=ms(lambda s: s.name == "sim.dispatch"
                         and s.args.get("cold")),
        dispatch_warm=ms(lambda s: s.name == "sim.dispatch"
                         and not s.args.get("cold")),
        wait=ms(lambda s: s.name == "sim.wait"),
        total=ms(lambda s: s.name == "experiment.execute")
        + ms(lambda s: s.name == "experiment.plan"))


def bench_obs(params: dict) -> None:
    cfg = SimConfig(cycles=params["cycles"], warmup=params["warmup"],
                    telemetry=True)
    scenarios = _scenarios(params)
    exp = X.Experiment(scenarios, cfg=cfg, name="link_load")
    engine = SweepEngine(cfg=cfg)

    enable_tracing()
    clear_trace()
    metrics.event("obs_bench.start", n=params["n"],
                  scenarios=len(scenarios))

    # ---- cold pass: compiles included ---------------------------------
    t0 = time.time()
    with trace("bench.cold_run", cat="bench"):
        frame = X.run(exp, engine=engine)
    cold_wall = time.time() - t0
    cold_spans = get_spans()

    # ---- warm pass: same grid, executables reused ---------------------
    clear_trace()
    t0 = time.time()
    with trace("bench.warm_run", cat="bench"):
        X.run(exp, engine=engine)
    warm_wall = time.time() - t0
    warm_spans = get_spans()

    # one Perfetto-loadable document holding both passes
    clear_trace()
    for s in cold_spans:
        s.set(run="cold")
    for s in warm_spans:
        s.set(run="warm")
    from repro.obs.trace import TRACER
    for s in cold_spans + warm_spans:
        TRACER._record(s)
    save_chrome_trace(
        os.path.join(RESULTS_DIR, "sweep_phases.trace.json"),
        metadata=dict(bench="obs_bench", n=params["n"],
                      scenarios=len(scenarios),
                      cold_wall_s=round(cold_wall, 3),
                      warm_wall_s=round(warm_wall, 3)))
    disable_tracing()

    checked = check_conservation(frame)
    print(f"[obs_bench] conservation exact on {checked} cells "
          f"(inj==accepted, eject==delivered, hist==delivered)")

    rows = frame.all_link_rows()
    summary = write_link_reports(
        os.path.join(RESULTS_DIR, "link_load_heatmap.csv"),
        os.path.join(RESULTS_DIR, "link_load_summary.csv"), rows)

    cold = _phase_split(cold_spans)
    warm = _phase_split(warm_spans)
    print(f"[obs_bench] cold pass {cold_wall:.2f}s "
          f"(compile-dispatch {cold['dispatch_cold'] / 1e3:.2f}s, "
          f"device wait {cold['wait'] / 1e3:.2f}s); "
          f"warm pass {warm_wall:.2f}s "
          f"(compile-dispatch {warm['dispatch_cold'] / 1e3:.2f}s, "
          f"device wait {warm['wait'] / 1e3:.2f}s)")
    print(f"[obs_bench] engine stats: {engine.stats}")

    _print_headline(summary)
    _fault_companion(params, cfg)
    drift_gini = _window_companion(params, cfg)

    # BENCH json (DESIGN.md §16): one extra warm pass with profiling on
    # captures the XLA cost/memory analysis; pad_fill rides on results
    run = BenchRun("obs", mode="smoke" if params is SMOKE else "full")
    frame3 = run.observed_pass(lambda: X.run(exp, engine=engine))
    pf = [r["pad_fill"]["state"] for r in frame3.results if r is not None]
    run.metrics(dict(cold_wall_s=round(cold_wall, 4),
                     warm_wall_s=round(warm_wall, 4),
                     cold_dispatch_s=round(cold["dispatch_cold"] / 1e3, 4),
                     cold_wait_s=round(cold["wait"] / 1e3, 4),
                     warm_wait_s=round(warm["wait"] / 1e3, 4)))
    run.metric("conservation_cells", checked, direction="higher")
    run.metric("pad_fill_state", round(float(np.mean(pf)), 4),
               direction="higher")
    if drift_gini is not None:
        run.metric("drift_gini_spread", drift_gini, direction="higher")
    run.extra(scenarios=len(scenarios), n=params["n"])
    run.finish()


def _window_companion(params: dict, cfg: SimConfig) -> float | None:
    """Time-windowed telemetry on a drifting-hotspot workload: the
    per-window heatmap/summary CSVs that make hotspot migration visible
    (DESIGN.md §16).  Returns the spread of per-window Gini (max - min)
    at the plateau rate — ~0 under steady uniform load, clearly positive
    while a hotspot drifts."""
    n = params["n"]
    wcfg = cfg._replace(telemetry_windows=6)
    wl = W.Workload("hotspot_drift",
                    lambda topo: W.hotspot_drift(topo, n_phases=6,
                                                 dwell=200))
    exp = X.Experiment(
        [X.Scenario("folded_hexa_torus", n, traffic=wl,
                    rates=X.SaturationGrid(params["n_rates"]))],
        cfg=wcfg, name="window_heatmap")
    frame = X.run(exp, engine=SweepEngine(cfg=wcfg))
    rows = frame.all_window_rows()
    if not rows:
        print("[obs_bench] window companion produced no rows")
        return None
    summary = write_window_reports(
        os.path.join(RESULTS_DIR, "window_heatmap.csv"),
        os.path.join(RESULTS_DIR, "window_summary.csv"), rows)
    ginis = [s["gini"] for s in summary]
    spread = round(max(ginis) - min(ginis), 4)
    print(f"[obs_bench] windowed drift companion: {len(summary)} windows, "
          f"gini {min(ginis):.3f}..{max(ginis):.3f} (spread {spread})")
    return spread


def _print_headline(summary: list[dict]) -> None:
    """Load-distribution table: the flatter the channel-load histogram
    (lower Gini / p95), the better folding does its job."""
    for substrate in sorted({s["substrate"] for s in summary}):
        rows = sorted((s for s in summary
                       if s["substrate"] == substrate),
                      key=lambda s: s["gini"])
        print(f"\nchannel-load distribution at saturation, {substrate} "
              f"(lower Gini = flatter load):")
        print(f"  {'topology':20s} {'links':>5s} {'p50':>7s} {'p95':>7s} "
              f"{'max':>7s} {'gini':>7s}")
        for s in rows:
            print(f"  {s['topology']:20s} {s['n_links']:5d} "
                  f"{s['util_p50']:7.3f} {s['util_p95']:7.3f} "
                  f"{s['util_max']:7.3f} {s['gini']:7.3f}")


def _fault_companion(params: dict, cfg: SimConfig) -> None:
    """Per-link telemetry for the FHT k=2 failed-links cell of
    results/fault_degradation.csv (same `sample_faults` seed)."""
    n = params["n"]
    topo = T.build("folded_hexa_torus", n)
    try:
        fs = sample_faults(topo, 2, "random", seed=0)
    except FaultError as e:
        print(f"[obs_bench] fault companion skipped: {e}")
        return
    rates = X.SaturationGrid(params["n_rates"])
    exp = X.Experiment(
        [X.Scenario("folded_hexa_torus", n, "organic", faults=None,
                    rates=rates, tags=(("k_failed", 0),)),
         X.Scenario("folded_hexa_torus", n, "organic", faults=fs,
                    rates=rates, tags=(("k_failed", 2),))],
        cfg=cfg, name="fault_link_load")
    frame = X.run(exp, engine=SweepEngine(cfg=cfg))
    check_conservation(frame)
    rows = frame.all_link_rows()
    frame.to_link_csv(os.path.join(RESULTS_DIR, "fault_link_load.csv"))
    dead = [r for r in rows if r["status"] == "dead"]
    ok2 = [r for r in rows if r["status"] == "ok"
           and r.get("k_failed") == 2]
    ok0 = [r for r in rows if r["status"] == "ok"
           and r.get("k_failed") == 0]
    hot0 = max(r["util"] for r in ok0) if ok0 else 0.0
    hot2 = max(r["util"] for r in ok2) if ok2 else 0.0
    print(f"[obs_bench] FHT k=2 companion: {len(dead)} dead directed "
          f"links ({fs.name}); hottest surviving channel util "
          f"{hot2:.3f} vs {hot0:.3f} pristine")


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny grid (CI-sized, well under a minute)")
    args = ap.parse_args(argv)
    bench_obs(SMOKE if args.smoke else DEFAULT)


if __name__ == "__main__":
    main()
