"""Roofline analysis over the dry-run artifacts (§g deliverable).

    PYTHONPATH=src python -m benchmarks.roofline [--dir results/dryrun]

Per (arch x shape x mesh) cell:
    compute term    = HLO_FLOPs_per_chip / 197 TFLOP/s (bf16, TPU v5e)
    memory term     = HLO_bytes_per_chip / 819 GB/s HBM
    collective term = collective_bytes_per_chip / 50 GB/s per ICI link
plus the dominant bottleneck, MODEL_FLOPS (6·N_active·D for training,
2·N_active·D for prefill, 2·N_active·B per decoded token), the
useful-FLOPs ratio MODEL_FLOPS / HLO_FLOPs, and — the paper bridge —
the same collective bytes costed under chiplet-ICI topologies (Mesh vs
FoldedHexaTorus) with the paper's link model.
"""
from __future__ import annotations

import argparse
import glob
import json
import os

import numpy as np

PEAK_FLOPS = 197e12         # bf16 per chip
HBM_BW = 819e9              # B/s
ICI_LINK_BW = 50e9          # B/s per link

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "results")


# ---------------------------------------------------------------------
# analytic MODEL_FLOPS
# ---------------------------------------------------------------------

def active_params(cfg) -> float:
    """Matmul parameters touched per token (active experts only)."""
    d, f, hd = cfg.d_model, cfg.d_ff, cfg.hd
    h, kv = cfg.n_heads, cfg.n_kv_heads
    per_layer = []
    for spec in cfg.layer_specs():
        p = 0.0
        if spec["kind"] == "attn":
            p += d * h * hd + 2 * d * kv * hd + h * hd * d
        elif spec["kind"] == "mla":
            dn, dr = cfg.mla_nope_dim, cfg.mla_rope_dim
            p += (d * cfg.q_lora_rank
                  + cfg.q_lora_rank * h * (dn + dr)
                  + d * cfg.kv_lora_rank
                  + cfg.kv_lora_rank * h * 2 * dn
                  + d * dr + h * dn * d)
        else:  # mamba
            din = cfg.ssm_expand * d
            nh = din // cfg.ssm_head_dim
            p += 2 * d * din + 2 * d * cfg.ssm_state + d * nh + din * d
        if spec["moe"]:
            p += d * cfg.n_experts + cfg.top_k * 3 * d * f
        elif spec["mlp"]:
            p += 3 * d * f
        per_layer.append(p)
    total = sum(per_layer)
    if cfg.arch_kind == "encdec":
        enc = cfg.n_enc_layers * (d * h * hd + 2 * d * kv * hd +
                                  h * hd * d + 3 * d * f)
        xattn = cfg.n_layers * (d * h * hd + 2 * d * kv * hd + h * hd * d)
        total += enc + xattn
    total += cfg.vocab * d          # lm head matmul
    return total


def model_flops(cfg, shape: dict, chips: int) -> float:
    n_act = active_params(cfg)
    b, t = shape["global_batch"], shape["seq_len"]
    if shape["mode"] == "train":
        return 6.0 * n_act * b * t / chips
    if shape["mode"] == "prefill":
        return 2.0 * n_act * b * t / chips
    return 2.0 * n_act * b / chips        # decode: one token per row


# ---------------------------------------------------------------------
# report
# ---------------------------------------------------------------------

def _bottleneck_hint(row) -> str:
    dom = row["dominant"]
    if dom == "collective":
        return ("reduce per-layer weight gathers (larger microbatch, "
                "2D-sharded activations) or overlap via async collectives")
    if dom == "memory":
        return ("fuse attention (flash kernel) / raise arithmetic "
                "intensity with bigger per-chip tiles")
    return ("compute-bound: reduce remat recompute or shrink padding "
            "waste; already near the MXU roof")


def analyze(dryrun_dir: str, chips_by_mesh=None, ici_sim: bool = False):
    """ici_sim=True costs the paper-bridge collectives with simulated
    (sweep-engine) saturation instead of the analytic bound."""
    from repro.configs import SHAPES, get_config
    from repro.core.collectives import build_ici_model

    chips_by_mesh = chips_by_mesh or {"16x16": 256, "2x16x16": 512}
    rows = []
    for path in sorted(glob.glob(os.path.join(dryrun_dir, "*.json"))):
        rec = json.load(open(path))
        if not rec.get("ok"):
            rows.append(dict(tag=rec["tag"], ok=False,
                             error=rec.get("error", "")[:100]))
            continue
        cfg = get_config(rec["arch"])
        shape = SHAPES[rec["shape"]]
        chips = chips_by_mesh[rec["mesh"]]
        ct = rec["flops_per_chip"] / PEAK_FLOPS
        mt = rec["bytes_accessed_per_chip"] / HBM_BW
        xt = rec["collective_bytes_per_chip"] / ICI_LINK_BW
        dom = max(("compute", ct), ("memory", mt),
                  ("collective", xt), key=lambda kv: kv[1])[0]
        mf = model_flops(cfg, shape, chips)
        step_s = max(ct, mt, xt)
        mfu = mf / PEAK_FLOPS / step_s if step_s > 0 else 0.0
        row = dict(
            tag=rec["tag"], arch=rec["arch"], shape=rec["shape"],
            mesh=rec["mesh"], ok=True,
            compute_s=ct, memory_s=mt, collective_s=xt,
            dominant=dom,
            model_flops_per_chip=mf,
            hlo_flops_per_chip=rec["flops_per_chip"],
            useful_flops_ratio=(mf / rec["flops_per_chip"]
                                if rec["flops_per_chip"] > 0 else 0.0),
            roofline_fraction=mfu,
            peak_gib=rec["peak_bytes_per_chip"] / 2 ** 30,
        )
        # paper bridge: same collective bytes on a 64-chiplet ICI package
        for topo in ("mesh", "folded_hexa_torus"):
            m = build_ici_model(topo, 64, "organic", use_sim=ici_sim)
            t = 0.0
            for kind, v in rec.get("collectives", {}).items():
                kk = kind.replace("-", "_")
                t += m.collective_time_s(kk, v["bytes"])
            row[f"coll_s_{topo}"] = t
        row["hint"] = _bottleneck_hint(row)
        rows.append(row)
    return rows


def to_markdown(rows) -> str:
    ok = [r for r in rows if r.get("ok")]
    lines = ["| arch | shape | mesh | compute_s | memory_s | collective_s"
             " | dominant | 6ND/HLO | roofline frac | peak GiB |",
             "|---|---|---|---|---|---|---|---|---|---|"]
    for r in sorted(ok, key=lambda r: (r["arch"], r["shape"], r["mesh"])):
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} "
            f"| {r['compute_s']:.3e} | {r['memory_s']:.3e} "
            f"| {r['collective_s']:.3e} | **{r['dominant']}** "
            f"| {r['useful_flops_ratio']:.2f} "
            f"| {r['roofline_fraction']:.3f} | {r['peak_gib']:.2f} |")
    return "\n".join(lines)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default=os.path.join(RESULTS_DIR, "dryrun"))
    ap.add_argument("--csv", default=os.path.join(RESULTS_DIR,
                                                  "roofline.csv"))
    ap.add_argument("--ici-sim", action="store_true",
                    help="cost the ICI bridge with simulated saturation "
                         "(batched sweep engine) instead of the analytic "
                         "bound")
    args = ap.parse_args(argv)
    rows = analyze(args.dir, ici_sim=args.ici_sim)
    ok = [r for r in rows if r.get("ok")]
    if ok:
        from repro.experiments import io as xio
        cols = [c for c in ok[0] if c != "hint"]
        xio.write_csv(args.csv,
                      [{c: r.get(c) for c in cols} for r in ok],
                      columns=cols)
    print(to_markdown(rows))
    bad = [r for r in rows if not r.get("ok")]
    for r in bad:
        print("FAILED CELL:", r["tag"], r.get("error"))
    return rows


if __name__ == "__main__":
    main()
