"""One benchmark per paper table/figure (see DESIGN.md §7).

Every grid-shaped figure is described as a `repro.experiments`
Experiment — a list of declarative Scenarios sharing one SimConfig —
and evaluated through the one `run` front door (DESIGN.md §10):
analytically by default, with the cycle-accurate simulator under
--sim.  CSVs are the experiment `ResultFrame`s (tidy rows, stable
columns, `schema_version` stamped)."""
from __future__ import annotations

import os
from functools import partial

import numpy as np

import repro.experiments as X
from repro.core import linkmodel as lm
from repro.core import topology as T
from repro.core import traffic as TR
from repro.core.collectives import build_ici_model

from .common import (RESULTS_DIR, SIZES, SIZES_FULL, run_cells,
                     write_csv)

PRINCIPLED = ["mesh", "folded_torus", "hexamesh", "folded_hexa_torus",
              "octamesh", "folded_octa_torus"]
ALL_TOPOLOGIES = list(T.GENERATORS)


def _figure_frame(scenarios, use_sim, name, csv):
    frame = run_cells(scenarios, use_sim=use_sim, name=name)
    frame.to_csv(os.path.join(RESULTS_DIR, csv))
    return frame


def fig2_linkmodel(sizes=None):
    """Fig. 2: data rate vs link length for all substrates."""
    rows = []
    for sub in ("organic", "glass", "passive_interposer"):
        for length in np.linspace(0, 75, 76):
            rows.append(dict(substrate=sub, length_mm=float(length),
                             rate_frac=float(lm.rate_fraction(length, sub)),
                             rate_gbps=float(lm.rate_gbps(length, sub))))
    write_csv(os.path.join(RESULTS_DIR, "fig2.csv"), rows)
    return rows[-1]["rate_frac"]


def fig4_principles(sizes=None, use_sim=False):
    """Fig. 4: principled topologies x 3 chiplet sizes, organic."""
    sizes = sizes or SIZES
    scens = [X.Scenario(name, n, "organic", "uniform", area=area)
             for area in (37.0, 74.0, 148.0)
             for name in PRINCIPLED
             for n in sizes]
    frame = _figure_frame(scens, use_sim, "fig4", "fig4.csv")
    # headline: FHT wins throughput at N=256, 74mm^2
    return frame.best("abs_throughput_gbps", n=max(sizes),
                      area_mm2=74.0)["topology"]


def table1_area(sizes=None):
    """Table I: chiplet area relative to Mesh."""
    frame = run_cells([X.Scenario(name, 64, "organic", area=area)
                       for area in (37.0, 74.0, 148.0)
                       for name in PRINCIPLED], name="table1")
    rows = []
    for area in (37.0, 74.0, 148.0):
        base = frame.select(topology="mesh",
                            area_mm2=area)[0]["chiplet_area_mm2"]
        for r in frame.select(area_mm2=area):
            rows.append(dict(topology=r["topology"], area_mm2=area,
                             chiplet_area_mm2=r["chiplet_area_mm2"],
                             rel_vs_mesh_pct=100 * (
                                 r["chiplet_area_mm2"] / base - 1)))
    write_csv(os.path.join(RESULTS_DIR, "table1.csv"), rows)
    fht74 = [r for r in rows if r["topology"] == "folded_hexa_torus"
             and r["area_mm2"] == 74.0][0]
    return fht74["rel_vs_mesh_pct"]


def table2_power(sizes=None):
    """Table II: power at saturation relative to Mesh (mean over sizes)."""
    sizes = sizes or SIZES
    frame = run_cells([X.Scenario(name, n, "organic", area=area)
                       for area in (37.0, 74.0, 148.0)
                       for name in PRINCIPLED for n in sizes],
                      name="table2")
    rows = []
    for area in (37.0, 74.0, 148.0):
        for name in PRINCIPLED:
            rels = [100 * (r["power_w"] /
                           frame.select(topology="mesh", n=r["n"],
                                        area_mm2=area)[0]["power_w"] - 1)
                    for r in frame.select(topology=name, area_mm2=area)]
            rows.append(dict(topology=name, area_mm2=area,
                             power_rel_mean_pct=float(np.mean(rels)),
                             power_rel_std_pct=float(np.std(rels))))
    write_csv(os.path.join(RESULTS_DIR, "table2.csv"), rows)
    return [r["power_rel_mean_pct"] for r in rows
            if r["topology"] == "folded_hexa_torus"][1]


def table3_properties(sizes=None):
    """Table III: measured diameter/radix/link-range for all topologies."""
    rows = []
    for name in ALL_TOPOLOGIES:
        for n in (64, 256):
            if name in T.N_CONSTRAINTS and not T.N_CONSTRAINTS[name](n):
                continue
            t = T.build(name, n)
            rows.append(dict(topology=name, n=n, diameter=t.diameter,
                             radix=t.radix,
                             max_link_range=int(t.link_ranges().max()),
                             max_link_mm=round(t.max_link_length_mm(), 1)))
    write_csv(os.path.join(RESULTS_DIR, "table3.csv"), rows)
    return len(rows)


def fig7_main(sizes=None, use_sim=False):
    """Fig. 7: all topologies x {homo,hetero} x {organic,glass}."""
    sizes = sizes or SIZES
    scens = [X.Scenario(name, n, substrate, pattern, roles=roles)
             for substrate in ("organic", "glass")
             for roles, pattern in (("homogeneous", "uniform"),
                                    ("hetero_cm", "hetero_mix"))
             for name in ALL_TOPOLOGIES
             for n in sizes]
    frame = _figure_frame(scens, use_sim, "fig7", "fig7.csv")
    best = {}
    for n in sizes:
        best[n] = frame.best("abs_throughput_gbps", n=n,
                             substrate="organic",
                             traffic="uniform")["topology"]
    return best


def fig8_patterns(sizes=None, use_sim=False):
    """Fig. 8: permutation / tornado / neighbor on glass, homogeneous."""
    sizes = sizes or SIZES
    scens = [X.Scenario(name, n, "glass", pattern)
             for pattern in ("permutation", "tornado", "neighbor")
             for name in ALL_TOPOLOGIES
             for n in sizes]
    frame = _figure_frame(scens, use_sim, "fig8", "fig8.csv")
    return len(frame.ok())


def fig10_traces(sizes=None, use_sim=False):
    """Fig. 10: synthetic Netrace-like traces, C/M/I placement, organic."""
    sizes = sizes or [64, 144]
    scens = []
    for profile in ("blackscholes", "fluidanimate"):
        for region in range(5):
            intensity = TR.TRACE_PROFILES[profile][region][0]
            tr = X.CustomTraffic(
                f"{profile}:r{region}",
                partial(lambda topo, p, r: TR.trace_region_traffic(
                    topo, p, r)[0], p=profile, r=region))
            for name in ("mesh", "folded_torus", "hexamesh",
                         "folded_hexa_torus", "kite_medium", "sid_mesh",
                         "double_butterfly", "octamesh"):
                for n in sizes:
                    scens.append(X.Scenario(
                        name, n, "organic", tr, roles="hetero_cmi",
                        tags=(("profile", profile), ("region", region),
                              ("intensity", intensity))))
    frame = _figure_frame(scens, use_sim, "fig10", "fig10.csv")
    return len(frame.ok())


def collectives_bridge(sizes=None):
    """Framework bridge: collective time under each ICI topology."""
    rows = []
    for name in ("mesh", "hexamesh", "folded_torus", "folded_hexa_torus"):
        for n in (64, 256):
            m = build_ici_model(name, n, "organic")
            for s in (2 ** 24, 2 ** 30):
                rows.append(dict(
                    topology=name, n=n, bytes=s,
                    allreduce_ms=1e3 * m.collective_time_s("all_reduce", s),
                    allgather_ms=1e3 * m.collective_time_s("all_gather", s),
                    b_eff_gbps=m.b_eff_gbps))
    write_csv(os.path.join(RESULTS_DIR, "collectives.csv"), rows)
    fht = [r for r in rows if r["topology"] == "folded_hexa_torus"
           and r["n"] == 64 and r["bytes"] == 2 ** 30][0]
    mesh = [r for r in rows if r["topology"] == "mesh"
            and r["n"] == 64 and r["bytes"] == 2 ** 30][0]
    return mesh["allreduce_ms"] / fht["allreduce_ms"]


def roofline_summary(sizes=None):
    """Framework roofline over the dry-run artifacts (if present)."""
    import glob
    from .roofline import analyze
    for d in ("results/dryrun_opt", "results/dryrun"):
        if glob.glob(os.path.join(d, "*.json")):
            rows = [r for r in analyze(d) if r.get("ok")]
            if not rows:
                continue
            best = max(rows, key=lambda r: r["roofline_fraction"])
            n_mem = sum(r["dominant"] == "memory" for r in rows)
            return (f"{len(rows)} cells ({d}); "
                    f"{n_mem} memory-bound; best fraction "
                    f"{best['roofline_fraction']:.3f} ({best['tag']})")
    return "no dry-run artifacts (run repro.launch.dryrun first)"


def sweep_speedup(sizes=None):
    """Batched-vs-looped simulator sweep wall-clock (DESIGN.md §6/§7)."""
    from .sweep_bench import bench_speedup
    out = bench_speedup(smoke=True)
    return (f"batched {out['batched_cold_s']:.1f}s vs looped "
            f"{out['looped_cold_s']:.1f}s cold "
            f"({out['cold_speedup']:.2f}x), bitwise_equal="
            f"{out['bitwise_equal']}")


BENCHES = {
    "fig2_linkmodel": fig2_linkmodel,
    "table3_properties": table3_properties,
    "table1_area": table1_area,
    "fig4_principles": fig4_principles,
    "table2_power": table2_power,
    "fig7_main": fig7_main,
    "fig8_patterns": fig8_patterns,
    "fig10_traces": fig10_traces,
    "collectives_bridge": collectives_bridge,
    "roofline_summary": roofline_summary,
    "sweep_speedup": sweep_speedup,
}
