"""Static-vs-adaptive routing gain grid (DESIGN.md §15).

    PYTHONPATH=src python -m benchmarks.adaptive_bench [--smoke|--full]

The question none of the paper's predecessors answer: does FHT's flat
channel-load distribution still translate to a throughput edge when
routing can route *around* congestion?  This bench runs Table-III
topologies x organic/glass x {uniform, hotspot_drift, bursty,
mixed-tenant} x {static, adaptive} at N=36 through ONE declarative
`Experiment` — the routing mode rides in `Scenario(routing=...)`, so
the planner splits the modes into their own compiled programs and the
engine batches everything else.

Results land in results/adaptive_gain.csv: one row per (topology,
substrate, workload) with both modes' saturation and the relative
adaptive gain.  The headline printout reports the hotspot-drift gains
— the drifting-hotspot schedule is where adaptivity should pay, and
where the mesh/torus family is expected to gain the most (FHT's static
load is already flat, so its gain is the interesting number).
"""
from __future__ import annotations

import argparse
import os
import time
from functools import partial

import numpy as np

import repro.experiments as X
import repro.workloads as W
from repro.configs import get_config
from repro.core.simulator import SimConfig

from .common import RESULTS_DIR, write_csv
from .harness import BenchRun

SUBSTRATES = ("organic", "glass")
ROUTINGS = ("static", "adaptive")

SMOKE = dict(names=("mesh", "torus", "folded_hexa_torus"), n=16,
             n_rates=4, cycles=400, warmup=150,
             workloads=("uniform", "hotspot_drift"))
DEFAULT = dict(names="ALL", n=36, n_rates=4, cycles=1000, warmup=300,
               workloads=("uniform", "hotspot_drift", "bursty",
                          "mixed_tenant"))
FULL = dict(names="ALL", n=36, n_rates=6, cycles=2000, warmup=700,
            workloads=("uniform", "hotspot_drift", "bursty",
                       "mixed_tenant"))


def traffic_suite(names, arch: str = "qwen3_1_7b") -> list:
    """Traffic sources by name: the uniform static pattern plus the
    time-varying workloads adaptivity is supposed to help with."""
    out = []
    for w in names:
        if w == "uniform":
            out.append("uniform")
        elif w == "hotspot_drift":
            out.append(W.Workload("hotspot_drift", partial(
                W.hotspot_drift, n_phases=4, dwell=250, seed=2)))
        elif w == "bursty":
            out.append(W.Workload("bursty", partial(
                W.bursty_uniform, on=20, off=60)))
        elif w == "mixed_tenant":
            out.append(W.mixed_tenant(get_config(arch)))
        else:
            raise KeyError(f"unknown workload {w!r}")
    return out


def bench_adaptive(params: dict, arch: str = "qwen3_1_7b") -> list[dict]:
    cfg = SimConfig(cycles=params["cycles"], warmup=params["warmup"])
    names = params["names"]
    if names == "ALL":
        from repro.core import topology as T
        names = tuple(T.GENERATORS)
    traffics = traffic_suite(params["workloads"], arch)
    exp = X.Experiment(
        [X.Scenario(name, params["n"], substrate, traffic=tr,
                    routing=routing,
                    rates=X.SaturationGrid(params["n_rates"]))
         for name in names for substrate in SUBSTRATES
         for tr in traffics for routing in ROUTINGS],
        cfg=cfg, name="adaptive_gain")
    engine = X.engine_for(cfg)
    t0 = time.time()
    frame = X.run(exp, engine=engine)
    wall = time.time() - t0

    # pair the (static, adaptive) rows — they are adjacent by
    # construction (routing is the innermost loop)
    rows = []
    for i in range(0, len(frame.rows), 2):
        st, ad = frame.rows[i], frame.rows[i + 1]
        if st["status"] != "ok" or ad["status"] != "ok":
            continue
        assert (st["routing"], ad["routing"]) == ROUTINGS
        s, a = st["sim_saturation"], ad["sim_saturation"]
        rows.append(dict(
            topology=st["topology"], n=st["n"],
            substrate=st["substrate"], workload=st["traffic"],
            analytic_saturation=round(st["analytic_saturation"], 4),
            static_saturation=round(s, 4),
            adaptive_saturation=round(a, 4),
            adaptive_gain=round(a / s - 1.0, 4) if s > 0 else "",
            static_latency_ns=round(st["latency_ns"], 2),
            adaptive_latency_ns=round(ad["latency_ns"], 2),
            abs_adaptive_gbps=round(ad["abs_throughput_gbps"], 1)))
    write_csv(os.path.join(RESULTS_DIR, "adaptive_gain.csv"), rows)
    print(f"[adaptive_bench] {len(rows)} cells "
          f"({len(names)} topologies x {len(SUBSTRATES)} substrates x "
          f"{len(traffics)} workloads) in {wall:.1f}s; "
          f"engine stats: {engine.stats}")
    _print_headline(rows)

    # BENCH json: warm observed pass for spans + XLA profiles; the gain
    # metrics guard the adaptive-routing win itself against regressions
    run = BenchRun("adaptive", mode="smoke" if params is SMOKE else "full")
    frame2 = run.observed_pass(lambda: X.run(exp, engine=engine))
    split = run.device_host_split()
    pf = [r["pad_fill"]["state"] for r in frame2.results if r is not None]
    hd = [r["adaptive_gain"] for r in rows
          if r["workload"] == "hotspot_drift"
          and isinstance(r["adaptive_gain"], float)]
    run.metrics(dict(cold_wall_s=round(wall, 4),
                     warm_device_s=split["device_s"],
                     warm_stack_s=split["stack_s"]))
    run.metric("cells", len(rows), direction="higher")
    run.metric("pad_fill_state", round(float(np.mean(pf)), 4)
               if pf else None, direction="higher")
    if hd:
        run.metric("drift_gain_mean", round(float(np.mean(hd)), 4),
                   direction="higher")
        run.metric("drift_gain_max", round(float(max(hd)), 4),
                   direction="higher")
    run.extra(workloads=list(params["workloads"]), n=params["n"])
    run.finish()
    return rows


def _print_headline(rows: list[dict]):
    """Hotspot-drift adaptive gain by topology (organic substrate)."""
    hd = [r for r in rows if r["workload"] == "hotspot_drift"
          and r["substrate"] == "organic"]
    if not hd:
        return
    print("\nhotspot-drift: static vs adaptive saturation "
          "(rel flits/node/cycle):")
    for r in sorted(hd, key=lambda r: -r["adaptive_gain"]):
        print(f"  {r['topology']:20s} static {r['static_saturation']:6.3f}"
              f"  adaptive {r['adaptive_saturation']:6.3f}"
              f"  gain {r['adaptive_gain']:+7.1%}")


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny grid (CI-sized, well under a minute)")
    ap.add_argument("--full", action="store_true",
                    help="Table III at N=36, long measurement windows")
    ap.add_argument("--arch", default="qwen3_1_7b",
                    help="architecture for the mixed-tenant workload")
    args = ap.parse_args(argv)
    params = SMOKE if args.smoke else (FULL if args.full else DEFAULT)
    bench_adaptive(params, arch=args.arch)


if __name__ == "__main__":
    main()
