"""Benchmark orchestrator — one function per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--full] [--sim] [--only NAME]

Prints ``name,us_per_call,derived`` CSV per bench; per-figure CSVs land
in results/.  Default mode uses the analytic channel-load model (the
cycle-accurate simulator cross-validates it in tests and via --sim).
"""
from __future__ import annotations

import argparse
import sys
import time


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="paper's full 16..256 size sweep")
    ap.add_argument("--sim", action="store_true",
                    help="cycle-accurate simulator instead of analytic")
    ap.add_argument("--only", default=None)
    ap.add_argument("--verify", action="store_true",
                    help="certify every benchmarked topology deadlock-"
                         "free (repro.analysis) before running figures")
    args = ap.parse_args(argv)

    if args.verify:
        # static preflight: a figure produced from an uncertified
        # routing is not worth the simulation time it costs
        from repro.analysis import analyze, builtin_names
        rep = analyze(names=builtin_names())
        print(f"# preflight: {rep.summary()}", file=sys.stderr)
        if not rep.ok:
            for d in rep.errors():
                print(f"# {d}", file=sys.stderr)
            sys.exit(1)

    from . import paper_benches as P
    sizes = P.SIZES_FULL if args.full else None

    print("name,us_per_call,derived")
    for name, fn in P.BENCHES.items():
        if args.only and args.only not in name:
            continue
        t0 = time.time()
        kw = {}
        if "sizes" in fn.__code__.co_varnames:
            kw["sizes"] = sizes
        if "use_sim" in fn.__code__.co_varnames and args.sim:
            kw["use_sim"] = True
        derived = fn(**kw)
        us = (time.time() - t0) * 1e6
        print(f"{name},{us:.0f},{derived}")
        sys.stdout.flush()


if __name__ == "__main__":
    main()
