"""Fault-injection layer tests (DESIGN.md §12): `FaultSet` lowering,
survivor-connectivity enforcement, seeded sampler determinism, and
traffic masking."""
import numpy as np
import pytest

import repro.faults as F
from repro.core import topology as T
from repro.core import traffic as TR
from repro.core.routing import routing_for


def _mesh16():
    return T.build("mesh", 16)


# ---------------------------------------------------------------------
# FaultSet construction / canonicalization
# ---------------------------------------------------------------------

def test_canonicalization_and_names():
    fs = F.FaultSet(links=((5, 1), (1, 5), (2, 3)), chiplets=(7, 7, 2))
    assert fs.links == ((1, 5), (2, 3))          # sorted, deduped, (lo, hi)
    assert fs.chiplets == (2, 7)
    assert fs.n_links == 2 and fs.n_chiplets == 2
    assert fs.name == "L1-5,2-3+C2,7"
    assert F.FaultSet().name == "none" and F.FaultSet().empty
    assert F.FaultSet(links=((0, 1),), name="custom").name == "custom"
    with pytest.raises(F.FaultError, match="self-loop"):
        F.FaultSet(links=((3, 3),))


def test_empty_apply_is_the_same_object():
    topo = _mesh16()
    fs = F.FaultSet()
    assert fs.apply(topo) is topo
    # ...so the pristine routing cache entry is shared bitwise
    assert routing_for(fs.apply(topo)) is routing_for(topo)


# ---------------------------------------------------------------------
# lowering onto a Topology
# ---------------------------------------------------------------------

def test_apply_removes_links_and_rebuilds_routing():
    topo = _mesh16()
    link = tuple(int(x) for x in np.asarray(topo.edges)[0])
    fs = F.FaultSet(links=(link,))
    deg = fs.apply(topo)
    assert len(deg.edges) == len(topo.edges) - 1
    assert deg.n == topo.n and deg.name == topo.name
    assert deg.structural_hash() != topo.structural_hash()
    r_deg, r_pri = routing_for(deg), routing_for(topo)
    assert r_deg is not r_pri                     # distinct cache entries
    u = TR.uniform(topo)
    assert r_deg.saturation_rate(u) <= r_pri.saturation_rate(u) + 1e-12


def test_unknown_link_and_bad_chiplet_are_errors():
    topo = _mesh16()
    with pytest.raises(F.FaultError, match="not links of this topology"):
        F.FaultSet(links=((0, 15),)).apply(topo)
    with pytest.raises(F.FaultError, match="out of range"):
        F.FaultSet(chiplets=(16,)).apply(topo)


def test_disconnecting_set_rejected_with_island_sizes():
    topo = _mesh16()
    e = np.sort(np.asarray(topo.edges), axis=1)
    cut = tuple(tuple(int(x) for x in lk) for lk in e[(e == 0).any(1)])
    with pytest.raises(F.DisconnectedFaultError,
                       match=r"islands of sizes \[15, 1\]"):
        F.FaultSet(links=cut).apply(topo)
    assert not F.surviving_connected(topo, F.FaultSet(links=cut))
    # the same cut is fine if chiplet 0 is itself dead: isolating a dead
    # chiplet is what dying means, not a partition of the survivors
    fs = F.FaultSet(links=cut, chiplets=(0,))
    deg = fs.apply(topo)
    assert not (np.asarray(deg.edges) == 0).any()
    assert F.surviving_connected(topo, fs)


def test_dead_chiplet_drops_all_its_links():
    topo = _mesh16()
    fs = F.FaultSet(chiplets=(5,))
    deg = fs.apply(topo)
    assert not (np.asarray(deg.edges) == 5).any()
    d = np.asarray(topo.edges)
    assert len(deg.edges) == len(d) - int((d == 5).any(1).sum())


# ---------------------------------------------------------------------
# traffic masking
# ---------------------------------------------------------------------

def test_mask_traffic_zeroes_and_renormalizes():
    topo = _mesh16()
    u = TR.uniform(topo)
    fs = F.FaultSet(chiplets=(3, 8))
    m = fs.mask_traffic(u)
    assert (m[[3, 8], :] == 0).all() and (m[:, [3, 8]] == 0).all()
    alive = fs.alive(16)
    np.testing.assert_allclose(m[alive].sum(1), 1.0)
    # link-only fault sets leave traffic untouched — same object
    only_links = F.FaultSet(links=(tuple(
        int(x) for x in np.asarray(topo.edges)[0]),))
    assert only_links.mask_traffic(u) is u


def test_mask_schedule_masks_every_phase():
    import repro.workloads as W
    topo = _mesh16()
    sched = W.phase_alternating(topo, phase_cycles=50, repeats=1)
    fs = F.FaultSet(chiplets=(2,))
    masked = fs.mask_schedule(sched)
    assert len(masked.phases) == len(sched.phases)
    for p in masked.phases:
        m = np.asarray(p.traffic)
        assert (m[2, :] == 0).all() and (m[:, 2] == 0).all()
    assert fs.mask_schedule(sched) is not sched
    assert F.FaultSet(links=((0, 1),)).mask_schedule(sched) is sched


# ---------------------------------------------------------------------
# samplers
# ---------------------------------------------------------------------

@pytest.mark.parametrize("kind", ["random", "correlated", "chiplets"])
def test_samplers_deterministic_and_survivable(kind):
    topo = T.build("folded_hexa_torus", 36)
    a = F.sample_faults(topo, 3, kind, seed=7)
    b = F.sample_faults(topo, 3, kind, seed=7)
    assert a == b                                # same draw, same seed
    assert (a.n_links if kind != "chiplets" else a.n_chiplets) == 3
    a.apply(topo)                                # survivable by default
    draws = {F.sample_faults(topo, 3, kind, seed=s) for s in range(6)}
    assert len(draws) > 1                        # seed actually matters


def test_correlated_faults_are_spatially_tight():
    topo = T.build("mesh", 64)
    blast = F.sample_faults(topo, 5, "correlated", seed=1)
    rand = F.sample_faults(topo, 5, "random", seed=1)
    pmm = topo.pos_mm()

    def spread(fs):
        mids = np.array([(pmm[a] + pmm[b]) / 2 for a, b in fs.links])
        return np.linalg.norm(mids - mids.mean(0), axis=1).max()

    assert spread(blast) < spread(rand)


def test_adversarial_faults_hurt_most():
    topo = T.build("folded_hexa_torus", 16)
    u = TR.uniform(topo)
    pristine = routing_for(topo).saturation_rate(u)
    worst = F.sample_faults(topo, 2, "adversarial")
    assert worst == F.sample_faults(topo, 2, "adversarial")  # no seed
    sat_worst = routing_for(worst.apply(topo)).saturation_rate(u)
    assert sat_worst < pristine
    # the greedy draw targets loaded links: its first victim is a
    # maximally-loaded channel of the pristine routing
    loads, _, _ = routing_for(topo).paths_channel_loads(u)
    r = routing_for(topo)
    link_load = {}
    for c in range(len(loads)):
        a, b = int(r.ch_src[c]), int(r.ch_dst[c])
        lk = (min(a, b), max(a, b))
        link_load[lk] = link_load.get(lk, 0.0) + float(loads[c])
    first = F.sample_faults(topo, 1, "adversarial").links[0]
    assert link_load[first] == pytest.approx(max(link_load.values()))


def test_sampler_errors():
    topo = _mesh16()
    with pytest.raises(KeyError, match="unknown fault kind"):
        F.sample_faults(topo, 1, "nonesuch")
    with pytest.raises(F.FaultError, match="survivable"):
        F.sample_faults(topo, len(topo.edges), "random")
    assert F.sample_faults(topo, 0, "random").empty


# ---------------------------------------------------------------------
# adaptive routing x faults (DESIGN.md §15): the productive-ports mask
# is built from the DEGRADED structure, so adaptive selection can never
# name a dead port
# ---------------------------------------------------------------------

def test_adaptive_mask_never_names_dead_ports():
    from hypothesis import given, settings
    from hypothesis import strategies as st
    from repro.core.routing import productive_ports

    @given(seed=st.integers(0, 5_000), k=st.integers(1, 2))
    @settings(max_examples=10, deadline=None)
    def prop(seed, k):
        topo = T.build("mesh", 36)
        try:
            fs = F.sample_faults(topo, k, "random", seed=seed)
            degraded = fs.apply(topo)
        except F.FaultError:
            return
        r = routing_for(degraded)
        prod = productive_ports(r)
        dead = {tuple(sorted(lk)) for lk in fs.links}
        assert dead, "sampler produced no link faults"
        for d, u, p in np.argwhere(prod):
            c = int(r.out_ch[u, p])
            assert c >= 0, "productive port without a declared channel"
            hop = tuple(sorted((int(r.ch_src[c]), int(r.ch_dst[c]))))
            assert hop not in dead, \
                f"adaptive mask names dead link {hop} at (d={d}, u={u})"

    prop()


def test_adaptive_simulates_through_faults():
    """Adaptive mode on a degraded topology delivers traffic (the mask
    and escape table both come from the surviving structure)."""
    from repro.core.simulator import SimConfig, make_spec, run_batch
    topo = T.build("mesh", 36)
    fs = F.sample_faults(topo, 2, "random", seed=7)
    r = routing_for(fs.apply(topo))
    spec = make_spec(r, fs.mask_traffic(TR.uniform(topo)))
    cfg = SimConfig(cycles=300, warmup=100, routing="adaptive")
    res = run_batch([spec], np.array([[0.1, 0.4]], np.float32), cfg)[0]
    assert (np.asarray(res["delivered"]) > 0).all()
