"""Paper validation: Table III structural properties, Table I area,
Fig. 2 link-rate anchors, and generator invariants (hypothesis)."""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import topology as T
from repro.core import linkmodel as lm
from repro.core import costmodel as cm
from repro.core import placement as pl

BENCH_NS = [16, 36, 64, 100, 144, 196, 256]


# ---------------------------------------------------------------------
# Table III — diameter / radix / link-range
# ---------------------------------------------------------------------

@pytest.mark.parametrize("r", [2, 3, 4, 5, 6, 7, 8])
def test_folded_hexa_torus_diameter_formula(r):
    """Paper: diameter(FHT) = sqrt(12N-3)/6 + 1/2 (exact at hex N)."""
    n = 3 * r * r + 3 * r + 1
    t = T.build("folded_hexa_torus", n, hex_region=True)
    expected = np.sqrt(12 * n - 3) / 6 + 0.5
    assert t.diameter == round(expected)
    assert t.radix == 6
    assert t.link_ranges().max() == 1


@pytest.mark.parametrize("r", [2, 3, 4, 5, 6])
def test_hexamesh_diameter_formula(r):
    """Paper: diameter(HexaMesh) = sqrt(12N-3)/3 - 1."""
    n = 3 * r * r + 3 * r + 1
    t = T.build("hexamesh", n, hex_region=True)
    assert t.diameter == round(np.sqrt(12 * n - 3) / 3 - 1)
    assert t.radix == 6
    assert t.link_ranges().max() == 0


@pytest.mark.parametrize("n", [16, 64, 256])
def test_mesh_and_folded_torus_diameters(n):
    s = int(np.sqrt(n))
    assert T.build("mesh", n).diameter == 2 * s - 2
    assert T.build("folded_torus", n).diameter == 2 * (s // 2)
    assert T.build("torus", n).diameter == 2 * (s // 2)


@pytest.mark.parametrize("n", [16, 64, 256])
def test_radix_table(n):
    expect = {"mesh": 4, "torus": 4, "folded_torus": 4, "hexamesh": 6,
              "folded_hexa_torus": 6, "octamesh": 8, "folded_octa_torus": 8,
              "honeycomb_mesh": 3, "honeycomb_torus": 3,
              "kite_medium": 4, "kite_large": 4, "sid_mesh": 4,
              "cluscross_v1": 4, "cluscross_v2": 4}
    for name, r in expect.items():
        t = T.build(name, n)
        assert t.radix == r, (name, n, t.radix)


@pytest.mark.parametrize("n", [16, 64, 256])
def test_flattened_butterfly(n):
    t = T.build("flattened_butterfly", n)
    s = int(np.sqrt(n))
    assert t.diameter == 2
    assert t.radix == 2 * (s - 1)


def test_hypercube_diameter():
    for n in (16, 64, 256):
        t = T.build("hypercube", n)
        assert t.diameter == int(np.log2(n))


@pytest.mark.parametrize("name", sorted(T.GENERATORS))
@pytest.mark.parametrize("n", [16, 64])
def test_all_connected_and_ranges(name, n):
    if name in T.N_CONSTRAINTS and not T.N_CONSTRAINTS[name](n):
        pytest.skip("N constraint")
    t = T.build(name, n)
    assert t.is_connected()
    # link-range: folded topologies must be exactly <= 1 except octa diag
    if name == "folded_torus":
        assert t.link_ranges().max() == 1
    if name == "folded_hexa_torus":
        assert t.link_ranges().max() == 1


def test_folded_halves_diameter():
    """Principle 1+2: folding roughly halves the diameter."""
    for n in (64, 144, 256):
        assert T.build("folded_torus", n).diameter <= \
            T.build("mesh", n).diameter / 2 + 1
        assert T.build("folded_hexa_torus", n).diameter <= \
            T.build("hexamesh", n).diameter / 2 + 2


# ---------------------------------------------------------------------
# Table I — area; §V-C PHY fractions
# ---------------------------------------------------------------------

def test_table1_area_overheads():
    """Radix-6 vs Mesh chiplet area: +4.34/2.27/1.16 % at 37/74/148 mm^2
    and PHY fractions 4.54 % (radix 4) / 6.66 % (radix 6)."""
    for area, pct in ((37.0, 4.34), (74.0, 2.27), (148.0, 1.16)):
        mesh = T.build("mesh", 64, chiplet_area_mm2=area)
        fht = T.build("folded_hexa_torus", 64, chiplet_area_mm2=area)
        rel = (cm.chiplet_area_mm2(fht) / cm.chiplet_area_mm2(mesh) - 1)
        assert abs(rel * 100 - pct) < 0.02, (area, rel * 100, pct)
    mesh74 = T.build("mesh", 64)
    fht74 = T.build("folded_hexa_torus", 64)
    assert abs(cm.phy_area_fraction(mesh74) * 100 - 4.54) < 0.02
    assert abs(cm.phy_area_fraction(fht74) * 100 - 6.66) < 0.02


# ---------------------------------------------------------------------
# Fig. 2 — link rate anchors
# ---------------------------------------------------------------------

def test_fig2_anchors():
    # range-1 band (74 mm^2): 17.5-24.7 mm
    assert lm.rate_fraction(17.5, "glass") >= 0.99
    assert lm.rate_fraction(24.7, "glass") >= 0.99
    assert 0.88 <= lm.rate_fraction(24.7, "organic") <= 0.97
    # range-2 worst case 37.2 mm
    assert abs(lm.rate_fraction(37.2, "organic") - 0.47) < 0.02
    assert abs(lm.rate_fraction(37.2, "glass") - 0.66) < 0.02
    # hard 70 mm limit
    assert lm.rate_fraction(71.0, "organic") == 0.0
    assert lm.rate_fraction(71.0, "glass") == 0.0
    # passive interposer collapses past 4 mm
    assert lm.rate_fraction(4.0, "passive_interposer") == 1.0
    assert lm.rate_fraction(10.0, "passive_interposer") <= 0.15


def test_long_link_topologies_die_at_256():
    """§V-C: Torus/ClusCross/HoneycombTorus/FlattenedButterfly exceed
    70 mm at N=256 -> zero absolute throughput."""
    for name in ("torus", "cluscross_v1", "honeycomb_torus",
                 "flattened_butterfly"):
        t = T.build(name, 256)
        assert t.max_link_length_mm() > lm.MAX_LINK_LENGTH_MM
        assert cm.absolute_throughput_gbps(t, 1.0) == 0.0
    for name in ("mesh", "folded_hexa_torus", "folded_torus", "hexamesh"):
        t = T.build(name, 256)
        assert cm.absolute_throughput_gbps(t, 0.1) > 0.0


# ---------------------------------------------------------------------
# hypothesis invariants
# ---------------------------------------------------------------------

@given(k=st.integers(min_value=2, max_value=40))
@settings(max_examples=25, deadline=None)
def test_fold_chain_is_single_cycle(k):
    """fold_chain turns a k-chain into a single ring (degree 2, k edges,
    connected) with diameter floor(k/2)."""
    import networkx as nx
    edges = T.fold_chain(list(range(k)))
    g = nx.Graph(edges)
    if k == 2:
        assert g.number_of_edges() == 1
        return
    assert g.number_of_edges() == k
    assert all(d == 2 for _, d in g.degree())
    assert nx.is_connected(g)
    assert nx.diameter(g) == k // 2


@given(n=st.sampled_from([16, 36, 64, 100]),
       name=st.sampled_from(sorted(T.GENERATORS)))
@settings(max_examples=30, deadline=None)
def test_generator_invariants(n, name):
    if name in T.N_CONSTRAINTS and not T.N_CONSTRAINTS[name](n):
        return
    t = T.build(name, n)
    assert t.n == n
    assert t.is_connected()
    assert (t.edges[:, 0] != t.edges[:, 1]).all()
    # undirected edges unique
    key = t.edges[:, 0].astype(np.int64) * n + t.edges[:, 1]
    assert len(np.unique(key)) == len(key)
    # roles partition the chiplets
    roles = pl.assign_roles(t.pos, "hetero_cm")
    assert set(np.unique(roles)) <= {"C", "M"}
    assert (roles == "M").sum() > 0


def test_hop_latency_cycles_scalar_and_array_agree():
    """Satellite: hop_latency_cycles must accept both call shapes and
    give a python int for scalars that matches the array path."""
    lengths = [0.0, 5.0, 17.5, 24.7, 37.2, 69.9]
    for sub in ("organic", "glass"):
        arr = lm.hop_latency_cycles(np.asarray(lengths), sub)
        assert arr.dtype == np.int64 and arr.shape == (len(lengths),)
        for x, want in zip(lengths, arr):
            got = lm.hop_latency_cycles(x, sub)
            assert isinstance(got, int) and not isinstance(got, np.integer)
            assert got == int(want)
    # 0-d arrays count as scalars too
    assert isinstance(lm.hop_latency_cycles(np.float64(20.0), "organic"),
                      int)
    # longer wire -> never fewer cycles
    arr = lm.hop_latency_cycles(np.linspace(0, 70, 141), "organic")
    assert (np.diff(arr) >= 0).all()
