"""Sweep-engine tests (DESIGN.md §6): padding invariance — a padded
batch of heterogeneous topologies must be bitwise-equal to the
single-spec simulator path — plus executable-cache reuse and the
rate-grid plumbing."""
import numpy as np
import pytest

from repro.core import simulator as sim
from repro.core import topology as T, traffic as TR
from repro.core.routing import build_routing
from repro.core.simulator import (SimConfig, make_spec, run_batch,
                                  simulate)
from repro.sweep.engine import SweepCase, SweepEngine
from repro.sweep.padding import PadShape, stack_specs

CFG = SimConfig(cycles=300, warmup=100)
RAW = ("delivered", "offered_n", "accepted_n", "lat_sum")

# deliberately heterogeneous: different N, radix/ports, channel counts
HETERO = [("mesh", 16), ("folded_hexa_torus", 36), ("honeycomb_mesh", 16),
          ("octamesh", 25)]


@pytest.fixture(scope="module")
def hetero_specs():
    specs = []
    for name, n in HETERO:
        r = build_routing(T.build(name, n))
        specs.append(make_spec(r, TR.uniform(r.topo)))
    return specs


def test_stack_specs_shapes(hetero_specs):
    batch, shape = stack_specs(hetero_specs)
    s = len(hetero_specs)
    assert shape == PadShape.of(hetero_specs)
    assert batch.table.shape == (s, shape.n, shape.n, shape.p + 1)
    assert batch.ch_src.shape == (s, shape.c)
    # padded nodes must be inert: no injection weight, no routes
    for i, spec in enumerate(hetero_specs):
        assert (batch.inj_weight[i, spec.n:] == 0).all()
        assert (batch.table[i, :, spec.n:, :] == -1).all()
        assert int(batch.pi[i]) == spec.p + 1


def test_pad_shape_must_cover(hetero_specs):
    from repro.sweep.padding import pad_spec
    small = PadShape(n=4, p=2, c=4, d=2)
    with pytest.raises(ValueError):
        pad_spec(hetero_specs[0], small)


def test_batched_bitwise_equals_single_spec(hetero_specs):
    """The acceptance property: >=4 topologies x >=4 rates through ONE
    batched compiled program, bitwise-equal per spec to the single-spec
    path."""
    rates = np.array([0.05, 0.15, 0.3, 0.6], np.float32)
    batched = run_batch(hetero_specs, rates, CFG)      # one program
    for spec, b in zip(hetero_specs, batched):
        single = run_batch([spec], rates[None, :], CFG)[0]
        for k in RAW:
            np.testing.assert_array_equal(single[k], b[k], err_msg=k)
        # derived floats come from identical ints -> identical too
        np.testing.assert_array_equal(single["throughput"],
                                      b["throughput"])
        np.testing.assert_array_equal(single["latency"], b["latency"])


def test_engine_bucketing_matches_and_reuses(hetero_specs):
    rates = np.array([0.05, 0.2, 0.5], np.float32)
    eng = SweepEngine(cfg=CFG)
    res1 = eng.run_specs(hetero_specs, rates)
    for spec, r in zip(hetero_specs, res1):
        single = run_batch([spec], rates[None, :], CFG)[0]
        for k in RAW:
            np.testing.assert_array_equal(single[k], r[k], err_msg=k)
    # a second sweep over the same shapes must not compile anything new
    compiles_before = eng.stats["compiles"]
    eng.run_specs(hetero_specs, rates)
    assert eng.stats["compiles"] == compiles_before


def test_engine_single_program_mode(hetero_specs):
    rates = np.array([0.1, 0.4], np.float32)
    eng = SweepEngine(cfg=CFG)
    res = eng.run_specs(hetero_specs, rates, single_program=True)
    assert eng.stats["groups"] == 1
    for spec, r in zip(hetero_specs, res):
        single = run_batch([spec], rates[None, :], CFG)[0]
        np.testing.assert_array_equal(single["delivered"], r["delivered"])


def test_run_batch_per_spec_rates(hetero_specs):
    """[S, R] rate rows pair each spec with its own grid."""
    specs = hetero_specs[:2]
    rates = np.array([[0.05, 0.2], [0.1, 0.3]], np.float32)
    out = run_batch(specs, rates, CFG)
    for i, spec in enumerate(specs):
        single = run_batch([spec], rates[i:i + 1], CFG)[0]
        np.testing.assert_array_equal(single["delivered"],
                                      out[i]["delivered"])
    with pytest.raises(ValueError):
        run_batch(specs, np.zeros((3, 2), np.float32), CFG)


def test_simulate_is_a_batch_of_one():
    topo = T.build("folded_hexa_torus", 16)
    r = build_routing(topo)
    u = TR.uniform(topo)
    rates = [0.05, 0.3]
    res = simulate(r, u, rates, CFG)
    spec = make_spec(r, u)
    raw = run_batch([spec], np.asarray(rates, np.float32)[None, :], CFG)[0]
    np.testing.assert_array_equal(res["throughput"], raw["throughput"])
    np.testing.assert_array_equal(res["latency"], raw["latency"])


def test_evaluate_cases_matches_saturation_throughput():
    """Engine case evaluation reports the same saturation as the
    single-spec `saturation_throughput` helper."""
    from repro.core.simulator import saturation_throughput
    cases = [SweepCase("mesh", 16), SweepCase("folded_hexa_torus", 16),
             SweepCase("hypercube", 15)]          # last one invalid
    eng = SweepEngine(cfg=CFG)
    out = eng.evaluate_cases(cases, n_rates=4)
    assert out[2] is None
    for case, res in zip(cases[:2], out[:2]):
        routing, tm = case.build()
        want = saturation_throughput(routing, tm, CFG, n_rates=4)
        assert res["sim_saturation"] == want["sim_saturation"]
        assert res["latency_at_sat"] == want["latency_at_sat"]


def test_alloc_pallas_interpret_matches_jnp():
    """The Pallas netstep allocator (interpret mode on CPU) drives the
    batched simulator to the same counters as the jnp oracle."""
    r = build_routing(T.build("mesh", 16))
    spec = make_spec(r, TR.uniform(r.topo))
    rates = np.array([0.1, 0.4], np.float32)[None, :]
    tiny = SimConfig(cycles=60, warmup=20)
    ref = run_batch([spec], rates, tiny)
    got = run_batch([spec], rates, tiny._replace(alloc="pallas"))
    for k in RAW:
        np.testing.assert_array_equal(ref[0][k], got[0][k], err_msg=k)


def test_runner_cache_lru_eviction_does_not_change_results():
    """Bounding the compiled-runner cache only costs recompiles: with a
    1-entry LRU, alternating two padded shapes evicts on every switch
    yet reproduces the unbounded-cache counters bitwise, and the
    hit/miss/eviction counters account for the traffic."""
    tiny = SimConfig(cycles=80, warmup=20)
    rates = np.array([0.1, 0.3], np.float32)
    specs = []
    for name, n in (("mesh", 16), ("folded_hexa_torus", 36)):
        r = build_routing(T.build(name, n))
        specs.append(make_spec(r, TR.uniform(r.topo)))
    want = [run_batch([s], rates[None, :], tiny)[0] for s in specs]

    old_max = sim.runner_cache_info()["max_size"]
    sim._RUNNER_CACHE.clear()
    before = sim.runner_cache_info()
    try:
        sim.set_runner_cache_limit(1)
        got = []
        for _ in range(2):
            for s in specs:                 # A, B, A, B -> evict each time
                got.append(run_batch([s], rates[None, :], tiny)[0])
        info = sim.runner_cache_info()
        assert info["size"] == 1 and info["max_size"] == 1
        assert info["misses"] - before["misses"] == 4
        assert info["evictions"] - before["evictions"] == 3
        assert info["hits"] == before["hits"]
    finally:
        sim.set_runner_cache_limit(old_max)
    for g, w in zip(got, want + want):
        for k in RAW:
            np.testing.assert_array_equal(g[k], w[k], err_msg=k)
    # the survivor (last-run shape) is still cached: re-run is a hit
    h0 = sim.runner_cache_info()["hits"]
    run_batch([specs[1]], rates[None, :], tiny)
    assert sim.runner_cache_info()["hits"] == h0 + 1


def test_hash_rng_invariant_to_padding():
    """The injection hash depends only on (seed, t, node, stream)."""
    import jax.numpy as jnp
    a = sim._node_bits(7, 13, jnp.arange(16), 1)
    b = sim._node_bits(7, 13, jnp.arange(64), 1)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b)[:16])
    # and distinct streams / cycles decorrelate
    c = sim._node_bits(7, 13, jnp.arange(16), 2)
    d = sim._node_bits(7, 14, jnp.arange(16), 1)
    assert not np.array_equal(np.asarray(a), np.asarray(c))
    assert not np.array_equal(np.asarray(a), np.asarray(d))


# ---------------------------------------------------------------------
# adaptive routing through the batched engine (DESIGN.md §15)
# ---------------------------------------------------------------------

ACFG = CFG._replace(routing="adaptive")


def test_adaptive_batched_bitwise_equals_single_spec(hetero_specs):
    """Padding invariance holds for the adaptive branch too: the
    batched program delivers the same counters as each single-spec run."""
    rates = np.array([0.05, 0.2, 0.5], np.float32)
    batched = run_batch(hetero_specs, rates, ACFG)
    for spec, b in zip(hetero_specs, batched):
        single = run_batch([spec], rates[None, :], ACFG)[0]
        for k in RAW:
            np.testing.assert_array_equal(single[k], b[k], err_msg=k)


def test_adaptive_fat_pad_invariant(hetero_specs):
    """Fat-padding every axis (nodes, ports, channels, ring depth) does
    not change a single adaptive counter: the productive-ports mask's
    pad region is all-False, so adaptive selection never sees pad
    lanes."""
    specs = hetero_specs[:2]
    rates = np.array([0.1, 0.4], np.float32)
    tight = run_batch(specs, rates, ACFG)
    shape = PadShape.of(specs)
    fat = PadShape(n=shape.n + 7, p=shape.p + 2, c=shape.c + 19,
                   d=shape.d + 3)
    padded = run_batch(specs, rates, ACFG, pad_shape=fat)
    for a, b in zip(tight, padded):
        for k in RAW:
            np.testing.assert_array_equal(a[k], b[k], err_msg=k)


def test_static_fat_pad_invariant_with_prod_leaf(hetero_specs):
    """The new `prod` BatchSpec leaf must not disturb the static path's
    fat-pad invariance (it is dead code under routing='static')."""
    specs = hetero_specs[:2]
    rates = np.array([0.1, 0.4], np.float32)
    tight = run_batch(specs, rates, CFG)
    shape = PadShape.of(specs)
    fat = PadShape(n=shape.n + 5, p=shape.p + 1, c=shape.c + 9,
                   d=shape.d + 2)
    padded = run_batch(specs, rates, CFG, pad_shape=fat)
    for a, b in zip(tight, padded):
        for k in RAW:
            np.testing.assert_array_equal(a[k], b[k], err_msg=k)


def test_prod_leaf_padding_contract(hetero_specs):
    """Stacked productive-ports masks: real region matches each spec's
    own mask, pad region is all-False."""
    batch, shape = stack_specs(hetero_specs)
    for i, spec in enumerate(hetero_specs):
        pr = batch.prod[i]
        assert pr.shape == (shape.n, shape.n, shape.p)
        np.testing.assert_array_equal(
            pr[:spec.n, :spec.n, :spec.p], spec.prod)
        assert not pr[spec.n:].any()
        assert not pr[:, spec.n:].any()
        assert not pr[:, :, spec.p:].any()


def test_engine_cfg_override_routes_adaptively(hetero_specs):
    """`run_specs(..., cfg=...)` runs the override config; the engine's
    own default stays intact (per-scenario routing, DESIGN.md §15)."""
    specs = hetero_specs[:2]
    rates = np.array([0.1, 0.4], np.float32)
    eng = SweepEngine(cfg=CFG)
    via_engine = eng.run_specs(specs, rates, cfg=ACFG)
    direct = run_batch(specs, rates, ACFG)
    for a, b in zip(direct, via_engine):
        for k in RAW:
            np.testing.assert_array_equal(a[k], b[k], err_msg=k)
    # and without the override the engine still runs static
    static = eng.run_specs(specs, rates)
    single = run_batch([specs[0]], rates[None, :], CFG)[0]
    np.testing.assert_array_equal(static[0]["delivered"],
                                  single["delivered"])
