"""Benchmark harness + profiling tests (DESIGN.md §16).

Covers the three new observability pieces end to end:

  * `repro.obs.bench` — BENCH document schema, write/load round-trip,
    the metric-by-metric `compare` (direction-aware regression
    detection) and the CLI's exit-code contract;
  * `repro.obs.profile` — opt-in XLA cost/memory capture through
    `run_batch`, keyed once per compiled runner;
  * `benchmarks.harness.BenchRun` — the bench-facing recorder (timed
    sections, observed pass, BENCH emission).
"""
import json

import numpy as np
import pytest

from repro.core import topology as T
from repro.core import traffic as TR
from repro.core.routing import build_routing
from repro.core.simulator import SimConfig, make_spec, run_batch
from repro.obs import bench as B
from repro.obs.profile import (clear_profiles, disable_profiling,
                               enable_profiling, get_profiles,
                               profiling_enabled)

CFG = SimConfig(cycles=120, warmup=40)


@pytest.fixture(autouse=True)
def _profiling_off():
    disable_profiling()
    clear_profiles()
    yield
    disable_profiling()
    clear_profiles()


@pytest.fixture(scope="module")
def spec():
    r = build_routing(T.build("mesh", 16))
    return make_spec(r, TR.uniform(r.topo))


# ---------------------------------------------------------------------
# BENCH documents
# ---------------------------------------------------------------------

def _doc(name="t", **metrics):
    metrics = metrics or dict(warm_s=1.0, speedup=2.0)
    return B.bench_doc(name, metrics,
                       directions={"speedup": "higher"}
                       if "speedup" in metrics else None)


def test_bench_doc_schema_and_metadata():
    doc = _doc()
    assert doc["bench_schema_version"] == B.BENCH_SCHEMA_VERSION
    assert doc["machine"]["jax"] and doc["machine"]["backend"]
    assert doc["metrics"] == dict(warm_s=1.0, speedup=2.0)
    assert doc["directions"] == dict(speedup="higher")


def test_bench_doc_rejects_nonscalar_metrics():
    with pytest.raises(TypeError, match="non-scalar"):
        B.bench_doc("t", dict(rows=[1, 2]))
    with pytest.raises(ValueError, match="lower.*higher"):
        B.bench_doc("t", dict(x=1.0), directions=dict(x="up"))


def test_bench_write_load_roundtrip(tmp_path):
    path = B.write_bench(_doc(), results_dir=str(tmp_path))
    assert path.endswith("BENCH_t.json")
    doc = B.load_bench(path)
    assert doc["metrics"]["warm_s"] == 1.0


def test_bench_load_rejects_wrong_schema(tmp_path):
    p = tmp_path / "BENCH_x.json"
    p.write_text(json.dumps(dict(bench_schema_version=999, name="x",
                                 metrics={})))
    with pytest.raises(ValueError, match="bench_schema_version"):
        B.load_bench(str(p))


# ---------------------------------------------------------------------
# compare: direction-aware regression detection
# ---------------------------------------------------------------------

def test_compare_detects_regressions_both_directions():
    old = _doc(warm_s=1.0, speedup=2.0)
    new = _doc(warm_s=1.5, speedup=1.0)     # slower AND less speedup
    by = {r["metric"]: r for r in B.compare(old, new, 25.0)}
    assert by["warm_s"]["status"] == "regressed"      # lower-is-better
    assert by["speedup"]["status"] == "regressed"     # higher-is-better
    assert by["warm_s"]["delta_pct"] == 50.0
    assert by["speedup"]["delta_pct"] == -50.0


def test_compare_improvements_and_threshold():
    old = _doc(warm_s=1.0, speedup=2.0)
    fast = _doc(warm_s=0.5, speedup=3.0)
    by = {r["metric"]: r for r in B.compare(old, fast, 25.0)}
    assert by["warm_s"]["status"] == "improved"
    assert by["speedup"]["status"] == "improved"
    wiggle = _doc(warm_s=1.1, speedup=1.9)  # within 25%
    assert all(r["status"] == "ok" for r in B.compare(old, wiggle, 25.0))
    # same docs, tighter threshold -> regression
    by = {r["metric"]: r for r in B.compare(old, wiggle, 5.0)}
    assert by["warm_s"]["status"] == "regressed"


def test_compare_new_and_removed_metrics():
    old = B.bench_doc("t", dict(a=1.0, gone=2.0))
    new = B.bench_doc("t", dict(a=1.0, fresh=3.0))
    by = {r["metric"]: r for r in B.compare(old, new)}
    assert by["gone"]["status"] == "removed"
    assert by["fresh"]["status"] == "new"
    assert by["a"]["status"] == "ok"


def test_compare_zero_and_none_values():
    old = B.bench_doc("t", dict(z=0.0, n=None))
    new = B.bench_doc("t", dict(z=0.0, n=1.0))
    by = {r["metric"]: r for r in B.compare(old, new)}
    assert by["z"]["status"] == "ok"        # 0 -> 0 is no change
    assert by["n"]["status"] == "new"       # None baseline: informative


# ---------------------------------------------------------------------
# CLI exit codes
# ---------------------------------------------------------------------

def _write(tmp_path, name, **metrics):
    doc = B.bench_doc(name, metrics,
                      directions={k: "higher" for k in metrics
                                  if k == "speedup"})
    return B.write_bench(doc, results_dir=str(tmp_path))


def test_cli_compare_ok_and_regression(tmp_path):
    old = _write(tmp_path / "a", "s", warm_s=1.0)
    new_ok = _write(tmp_path / "b", "s", warm_s=1.05)
    new_bad = _write(tmp_path / "c", "s", warm_s=3.0)
    assert B.main(["compare", old, new_ok]) == 0
    assert B.main(["compare", old, new_bad]) == 1
    assert B.main(["compare", old, new_bad, "--warn-only"]) == 0
    assert B.main(["compare", old, new_bad,
                   "--fail-over", "500"]) == 0


def test_cli_compare_missing_file(tmp_path):
    old = _write(tmp_path, "s", warm_s=1.0)
    assert B.main(["compare", old, str(tmp_path / "nope.json")]) == 2


def test_cli_unknown_subcommand():
    assert B.main(["frobnicate"]) == 2
    assert B.main([]) == 2


# ---------------------------------------------------------------------
# profiling through run_batch
# ---------------------------------------------------------------------

def test_profile_disabled_by_default(spec):
    run_batch([spec], [0.1], CFG)
    assert not profiling_enabled()
    assert get_profiles() == []


def test_profile_capture_and_key_caching(spec):
    enable_profiling()
    run_batch([spec], [0.1], CFG)
    run_batch([spec], [0.1], CFG)           # same runner: no second key
    profs = get_profiles()
    assert len(profs) == 1
    p = profs[0]
    assert p["compile_s"] > 0
    assert p["flops"] and p["flops"] > 0
    assert p["bytes_accessed"] and p["bytes_accessed"] > 0
    assert p["temp_bytes"] is not None and p["temp_bytes"] > 0
    assert p["argument_bytes"] is not None
    # a different SimConfig is a different executable -> second profile
    run_batch([spec], [0.1], CFG._replace(telemetry=True))
    assert len(get_profiles()) == 2


def test_profile_results_unchanged(spec):
    """Profiling is a pure observer: counters match bitwise."""
    plain = run_batch([spec], [0.1, 0.3], CFG)[0]
    enable_profiling()
    profiled = run_batch([spec], [0.1, 0.3], CFG)[0]
    for k in ("delivered", "offered_n", "accepted_n", "lat_sum"):
        np.testing.assert_array_equal(plain[k], profiled[k], err_msg=k)


# ---------------------------------------------------------------------
# BenchRun recorder
# ---------------------------------------------------------------------

def test_bench_run_records_and_emits(tmp_path, spec):
    from benchmarks.harness import BenchRun
    run = BenchRun("unit", mode="smoke", results_dir=str(tmp_path))
    with run.timed("work"):
        pass
    run.metric("cells", 3, direction="higher")
    out = run.observed_pass(lambda: run_batch([spec], [0.1], CFG))
    assert out[0]["pad_fill"]["state"] == 1.0
    doc = run.finish()
    assert doc["metrics"]["work_s"] >= 0
    assert doc["spans"].get("sim.dispatch", {}).get("count") == 1
    assert doc["profiles"] and doc["profiles"][0]["flops"] > 0
    loaded = B.load_bench(str(tmp_path / "BENCH_unit.json"))
    assert loaded["directions"] == dict(cells="higher")
    split = run.device_host_split()
    assert set(split) == {"device_s", "stack_s", "dispatch_s"}
