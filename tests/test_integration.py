"""Integration tests: Pallas kernels wired into the model forward
(interpret mode), the data pipeline, the training driver end-to-end with
checkpoint resume, and the collective-model bridge."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.data import SyntheticLMData
from repro.models import Model, unbox


def test_flash_kernel_in_model_forward():
    """use_flash_kernel routes attention through the Pallas kernel and
    matches the dense path (T=128 tile minimum)."""
    cfg = get_config("qwen3_1_7b", smoke=True)
    m_ref = Model(cfg)
    params, _ = unbox(m_ref.init(jax.random.PRNGKey(0)))
    rng = np.random.default_rng(0)
    batch = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab, (1, 128)),
                                   jnp.int32)}
    logits_ref, _ = jax.jit(m_ref.logits_fn)(params, batch)
    m_k = Model(dataclasses.replace(cfg, use_flash_kernel=True))
    logits_k, _ = jax.jit(m_k.logits_fn)(params, batch)
    np.testing.assert_allclose(np.asarray(logits_k, np.float32),
                               np.asarray(logits_ref, np.float32),
                               atol=0.08, rtol=0.08)


def test_ssd_kernel_in_model_forward():
    """use_ssd_kernel routes the mamba core through the Pallas kernel."""
    cfg = get_config("mamba2_1_3b", smoke=True)
    m_ref = Model(cfg)
    params, _ = unbox(m_ref.init(jax.random.PRNGKey(0)))
    rng = np.random.default_rng(1)
    batch = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab, (2, 16)),
                                   jnp.int32)}
    logits_ref, _ = jax.jit(m_ref.logits_fn)(params, batch)
    m_k = Model(dataclasses.replace(cfg, use_ssd_kernel=True, ssm_chunk=8))
    logits_k, _ = jax.jit(m_k.logits_fn)(params, batch)
    np.testing.assert_allclose(np.asarray(logits_k, np.float32),
                               np.asarray(logits_ref, np.float32),
                               atol=0.05, rtol=0.05)


def test_data_pipeline_determinism_and_sharding():
    d1 = SyntheticLMData(vocab=128, seq_len=16, global_batch=8, seed=3)
    d2 = SyntheticLMData(vocab=128, seq_len=16, global_batch=8, seed=3)
    b1, b2 = d1.batch(5), d2.batch(5)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    assert not np.array_equal(d1.batch(6)["tokens"], b1["tokens"])
    # labels are next-token shifted
    np.testing.assert_array_equal(b1["labels"][:, :-1], b1["tokens"][:, 1:])
    # per-host slices differ and tile the global batch
    h0 = SyntheticLMData(vocab=128, seq_len=16, global_batch=8,
                         n_hosts=2, host_index=0, seed=3)
    h1 = SyntheticLMData(vocab=128, seq_len=16, global_batch=8,
                         n_hosts=2, host_index=1, seed=3)
    assert h0.batch(0)["tokens"].shape == (4, 16)
    assert not np.array_equal(h0.batch(0)["tokens"], h1.batch(0)["tokens"])


def test_train_driver_end_to_end(tmp_path):
    """Full driver: train, checkpoint, resume — loss continues down."""
    from repro.launch.train import main
    ck = str(tmp_path / "ck")
    losses = main(["--arch", "qwen3-1.7b", "--smoke", "--steps", "8",
                   "--batch", "4", "--seq", "32", "--ckpt-dir", ck,
                   "--ckpt-every", "4", "--log-every", "100"])
    assert len(losses) == 8
    # resume picks up from the saved step
    losses2 = main(["--arch", "qwen3-1.7b", "--smoke", "--steps", "12",
                    "--batch", "4", "--seq", "32", "--ckpt-dir", ck,
                    "--ckpt-every", "100", "--log-every", "100"])
    assert len(losses2) == 4            # resumed at step 8
    assert all(np.isfinite(losses + losses2))


def test_collective_model_orderings():
    """FHT beats Mesh for every collective kind and payload."""
    from repro.core.collectives import build_ici_model
    fht = build_ici_model("folded_hexa_torus", 64, "organic")
    mesh = build_ici_model("mesh", 64, "organic")
    for kind in ("all_reduce", "all_gather", "reduce_scatter",
                 "all_to_all"):
        for size in (2 ** 20, 2 ** 30):
            assert fht.collective_time_s(kind, size) < \
                mesh.collective_time_s(kind, size)


def test_serve_driver_runs():
    from repro.launch.serve import main
    toks = main(["--arch", "mamba2-1.3b", "--smoke", "--batch", "2",
                 "--prompt-len", "16", "--gen", "4"])
    assert toks.shape == (2, 5)
