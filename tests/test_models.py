"""Per-arch smoke tests (reduced configs, CPU): one forward/train step,
shape + finiteness asserts, prefill/decode consistency with the full
forward, and training-loss descent on a tiny model."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, get_config
from repro.models import Model, unbox
from repro.models.model import DecodeDims
from repro.launch import steps as St
from repro.optim import adamw_init


def make_batch(cfg, b=2, t=16, seed=0):
    rng = np.random.default_rng(seed)
    batch = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab, (b, t)),
                                   jnp.int32),
             "labels": jnp.asarray(rng.integers(0, cfg.vocab, (b, t)),
                                   jnp.int32)}
    if cfg.arch_kind == "encdec":
        batch["frames"] = jnp.asarray(
            rng.normal(0, 0.02, (b, t, cfg.d_model)), jnp.float32)
    return batch


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_forward(arch):
    cfg = get_config(arch, smoke=True)
    m = Model(cfg)
    params, _ = unbox(m.init(jax.random.PRNGKey(0)))
    batch = make_batch(cfg)
    logits, aux = jax.jit(m.logits_fn)(params, batch)
    assert logits.shape == (2, 16, cfg.vocab)
    assert bool(jnp.isfinite(logits).all())
    loss = jax.jit(m.loss_fn)(params, batch)
    assert bool(jnp.isfinite(loss))


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_train_step(arch):
    cfg = get_config(arch, smoke=True)
    m = Model(cfg)
    params, _ = unbox(m.init(jax.random.PRNGKey(0)))
    step = St.make_train_step(m, St.TrainConfig())
    opt = adamw_init(params)
    batch = make_batch(cfg)
    p2, opt2, metrics = jax.jit(step)(params, opt, batch)
    assert bool(jnp.isfinite(metrics["loss"]))
    assert bool(jnp.isfinite(metrics["grad_norm"]))
    # parameters actually moved
    moved = any(
        float(jnp.abs(a - b).max()) > 0
        for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(p2)))
    assert moved


@pytest.mark.parametrize("arch", ARCHS)
def test_decode_matches_full_forward(arch):
    """prefill(t[:-1]) + decode(t[-1]) == full forward's last logits."""
    cfg = get_config(arch, smoke=True)
    if cfg.window:   # ring-cache windows change the attended set slightly
        cfg = type(cfg)(**{**cfg.__dict__, "window": 64})
    m = Model(cfg)
    params, _ = unbox(m.init(jax.random.PRNGKey(0)))
    # t-1 = 16 is a multiple of the smoke ssm_chunk (8) and collides with
    # no cache dimension of the smoke configs, so the seq-pad below is safe
    b, t = 2, 17
    batch = make_batch(cfg, b=b, t=t)
    full_logits, _ = jax.jit(m.logits_fn)(params, batch)

    pre = {k: (v[:, :t - 1] if v.ndim == 2 else v)
           for k, v in batch.items() if k != "labels"}
    _, caches = jax.jit(m.prefill)(params, pre)
    # widen each self-attention cache ring by one slot for the new token
    def pad_seq(c, path_hint):
        return c

    def widen(tree):
        def f(a):
            if a.ndim == 4 and a.shape[1] == t - 1:      # [B,S,KV,hd]
                return jnp.pad(a, ((0, 0), (0, 1), (0, 0), (0, 0)))
            if a.ndim == 5 and a.shape[2] == t - 1:      # [L,B,S,KV,hd]
                return jnp.pad(a, ((0, 0), (0, 0), (0, 1), (0, 0), (0, 0)))
            if a.ndim == 3 and a.shape[1] == t - 1:      # MLA [B,S,r]
                return jnp.pad(a, ((0, 0), (0, 1), (0, 0)))
            if a.ndim == 4 and a.shape[2] == t - 1:      # MLA [L,B,S,r]
                return jnp.pad(a, ((0, 0), (0, 0), (0, 1), (0, 0)))
            return a
        return jax.tree.map(f, tree)

    caches = widen(caches)
    tok = batch["tokens"][:, t - 1:t]
    dec_logits, _ = jax.jit(m.decode_step)(params, caches, tok,
                                           jnp.int32(t - 1))
    got = dec_logits[:, 0]
    want = full_logits[:, -1]
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=0.08, atol=0.08)


def test_loss_decreases():
    cfg = get_config("qwen3-1.7b", smoke=True)
    m = Model(cfg)
    params, _ = unbox(m.init(jax.random.PRNGKey(0)))
    tcfg = St.TrainConfig(total_steps=50, warmup_steps=2)
    step = jax.jit(St.make_train_step(m, tcfg))
    opt = adamw_init(params)
    batch = make_batch(cfg, b=4, t=32, seed=1)
    losses = []
    for _ in range(12):
        params, opt, metrics = step(params, opt, batch)
        losses.append(float(metrics["loss"]))
    assert losses[-1] < losses[0] - 0.5, losses


def test_microbatched_grads_match_full_batch():
    """Gradient accumulation is mathematically a mean over microbatches."""
    cfg = get_config("qwen3-1.7b", smoke=True)
    m = Model(cfg)
    params, _ = unbox(m.init(jax.random.PRNGKey(0)))
    batch = make_batch(cfg, b=4, t=16)
    s1 = jax.jit(St.make_train_step(m, St.TrainConfig(microbatches=1)))
    s2 = jax.jit(St.make_train_step(m, St.TrainConfig(microbatches=2)))
    opt = adamw_init(params)
    _, _, m1 = s1(params, opt, batch)
    _, _, m2 = s2(params, opt, batch)
    assert float(m1["loss"]) == pytest.approx(float(m2["loss"]), rel=2e-2)


def test_pattern_grouping():
    cfg = get_config("jamba-v0.1-52b")
    pat, n_rep, tail = cfg.pattern()
    assert len(pat) == 8 and n_rep == 4 and not tail
    kinds = [p["kind"] for p in pat]
    assert kinds.count("attn") == 1 and kinds.count("mamba") == 7
    assert sum(p["moe"] for p in pat) == 4
    cfg = get_config("gemma3-1b")
    pat, n_rep, tail = cfg.pattern()
    assert len(pat) == 6 and n_rep == 4 and len(tail) == 2
    assert sum(1 for p in pat if p["window"] == 0) == 1   # 5 local:1 global
