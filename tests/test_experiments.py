"""Declarative experiment API tests (DESIGN.md §10).

The acceptance property: `repro.experiments.run` reproduces the legacy
case-level paths BITWISE — a mixed static+workload Experiment over
Table-III topologies yields metrics identical to `evaluate_cases` /
`evaluate_workload_cases` on the same grid, and both are pinned to the
independent single-spec oracle (`saturation_throughput` / single-spec
`run_batch`) so the equality is not vacuous.  Plus: planning semantics
(validation, bucketing, rate policies), chunked/progress/partial-
failure execution, the versioned writers, the analytic-vs-simulated
saturation cross-check, and the deprecation contracts of the legacy
entry points.
"""
import warnings

import numpy as np
import pytest

import repro.experiments as X
import repro.workloads as W
from repro.core import topology as T
from repro.core.simulator import (SimConfig, run_batch,
                                  saturation_throughput)
from repro.sweep.engine import SweepCase, SweepEngine

CFG = SimConfig(cycles=300, warmup=100)
RAW = ("delivered", "offered_n", "accepted_n", "lat_sum")

STATIC_CASES = [SweepCase("mesh", 16), SweepCase("folded_hexa_torus", 16),
                SweepCase("hexamesh", 16), SweepCase("hypercube", 15)]

WORKLOADS = [W.Workload("alt", lambda t: W.phase_alternating(
                 t, phase_cycles=60, repeats=1)),
             W.Workload("trace", lambda t: W.trace_workload(
                 t, "blackscholes", region_cycles=40))]


def _quiet_legacy(fn, *args, **kw):
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", DeprecationWarning)
        return fn(*args, **kw)


# ---------------------------------------------------------------------
# acceptance: bitwise reproduction of the legacy paths
# ---------------------------------------------------------------------

def test_mixed_experiment_bitwise_equals_legacy_paths():
    """THE acceptance criterion: one mixed static+workload Experiment
    == evaluate_cases + evaluate_workload_cases on the same grid, and
    both == the single-spec oracle."""
    eng = SweepEngine(cfg=CFG)
    static_scens = [X.scenario_from_case(c, rates=X.SaturationGrid(4))
                    for c in STATIC_CASES]
    wl_cases = [SweepCase("mesh", 16, roles="hetero_cmi"),
                SweepCase("folded_hexa_torus", 16, "glass",
                          roles="hetero_cmi")]
    wl_scens = [X.scenario_from_case(c, traffic=wl,
                                     rates=X.SaturationGrid(3))
                for c in wl_cases for wl in WORKLOADS]
    exp = X.Experiment(static_scens + wl_scens, cfg=CFG, name="mixed")
    frame = X.run(exp, engine=eng)

    legacy_static = _quiet_legacy(eng.evaluate_cases, STATIC_CASES,
                                  n_rates=4)
    legacy_wl = _quiet_legacy(eng.evaluate_workload_cases, wl_cases,
                              WORKLOADS, n_rates=3)

    ns = len(static_scens)
    for i, case in enumerate(STATIC_CASES):
        got, want = frame.case_result(i), legacy_static[i]
        if not case.valid:
            assert got is None and want is None
            assert frame.rows[i]["status"] == "invalid"
            continue
        assert got["sim_saturation"] == want["sim_saturation"]
        assert got["analytic_saturation"] == want["analytic_saturation"]
        assert got["latency_at_sat"] == want["latency_at_sat"]
        for k in RAW:
            np.testing.assert_array_equal(got["sweep"][k],
                                          want["sweep"][k], err_msg=k)
        # ...and the independent oracle agrees (equality is not vacuous)
        routing, tm = case.build()
        oracle = saturation_throughput(routing, tm, CFG, n_rates=4)
        assert got["sim_saturation"] == oracle["sim_saturation"]
        assert got["latency_at_sat"] == oracle["latency_at_sat"]
    for j in range(len(wl_scens)):
        got = frame.workload_result(ns + j)
        want = legacy_wl[j]
        assert got["sim_saturation"] == want["sim_saturation"]
        assert got["workload"] == want["workload"]
        assert got["phase_labels"] == want["phase_labels"]
        np.testing.assert_array_equal(got["phase_cycles"],
                                      want["phase_cycles"])
        np.testing.assert_array_equal(got["throughput_ph"],
                                      want["throughput_ph"])
        for k in RAW:
            np.testing.assert_array_equal(got["sweep"][k],
                                          want["sweep"][k], err_msg=k)


def test_workload_scenario_bitwise_equals_single_spec_oracle():
    """A workload scenario's sweep == the raw run_batch single-spec
    path fed the identical fitted schedule + rate grid."""
    scen = X.Scenario("mesh", 16, roles="hetero_cmi",
                      traffic=WORKLOADS[0], rates=X.SaturationGrid(3))
    frame = X.run(X.Experiment([scen], cfg=CFG), engine=SweepEngine(
        cfg=CFG))
    ps = frame.planned[0]
    single = run_batch([ps.spec], ps.rates[None, :], CFG,
                       schedules=[ps.sched_spec])[0]
    for k in RAW + ("delivered_ph", "lat_sum_ph"):
        np.testing.assert_array_equal(single[k], frame.results[0][k],
                                      err_msg=k)


# ---------------------------------------------------------------------
# satellite: analytic-vs-simulated saturation cross-check (Table III)
# ---------------------------------------------------------------------

def test_saturation_crosscheck_all_table3_topologies():
    """For every Table-III topology at n=16, the simulated saturation
    from a SaturationGrid scenario lands within tolerance of the
    analytic `paths_channel_loads` bound (the bound is an upper bound;
    the sim plateau must reach a sane fraction of it)."""
    names = [n for n in T.GENERATORS
             if X.Scenario(n, 16).valid]
    assert len(names) >= 15          # the Table-III roster
    exp = X.Experiment([X.Scenario(name, 16,
                                   rates=X.SaturationGrid(4))
                        for name in names],
                       cfg=CFG, name="crosscheck")
    frame = X.run(exp)
    for i, name in enumerate(names):
        row = frame.rows[i]
        assert row["status"] == "ok", name
        analytic = row["analytic_saturation"]
        routing = frame.planned[i].routing
        # the frame's analytic bound IS the channel-load bound
        assert analytic == pytest.approx(
            routing.saturation_rate(frame.planned[i].traffic))
        assert row["sim_saturation"] <= 1.15 * analytic, name
        assert row["sim_saturation"] >= 0.30 * analytic, name


# ---------------------------------------------------------------------
# planning semantics
# ---------------------------------------------------------------------

def test_plan_validates_and_buckets():
    exp = X.Experiment(
        [X.Scenario("mesh", 16),                       # static
         X.Scenario("folded_hexa_torus", 16),          # static, same R
         X.Scenario("hypercube", 15),                  # invalid
         X.Scenario("mesh", 16, traffic=WORKLOADS[0]),
         X.Scenario("mesh", 16, rates=X.ExplicitRates((0.1, 0.2)))],
        cfg=CFG)
    pl = X.plan(exp)
    assert pl.n_planned == 4
    assert [i for i, _ in pl.skipped] == [2]
    kinds = sorted(b.key.kind for b in pl.buckets)
    assert "workload" in kinds and "static" in kinds
    # the explicit-rate scenario has R=2, so it cannot share a bucket
    rs = sorted(b.key.n_rates for b in pl.buckets)
    assert 2 in rs
    assert "skip #2" in pl.describe()
    # workload buckets carry a padded phase axis
    wl = [b for b in pl.buckets if b.key.kind == "workload"][0]
    assert wl.key.k_pad >= wl.items[0].sched_spec.k


def test_single_program_plan_merges_buckets_bitwise():
    """single_program=True coalesces same-(kind, R) buckets into one
    compiled program without changing any counter."""
    exp = X.Experiment([X.Scenario("mesh", 16,
                                   rates=X.SaturationGrid(3)),
                        X.Scenario("folded_hexa_torus", 16,
                                   rates=X.SaturationGrid(3))], cfg=CFG)
    eng = SweepEngine(cfg=CFG)
    base = X.run(exp, engine=eng)
    assert len(X.plan(exp, eng).buckets) == 2    # P4 vs P6 shapes
    pl = X.plan(exp, eng, single_program=True)
    assert len(pl.buckets) == 1 and pl.single_program
    one = X.execute(pl, engine=eng)
    for a, b in zip(base.results, one.results):
        for k in RAW:
            np.testing.assert_array_equal(a[k], b[k], err_msg=k)


def test_rate_policies():
    grid = X.SaturationGrid(5).resolve(0.4)
    assert len(grid) == 5 and grid[-1] <= 1.0
    ex = X.ExplicitRates((0.3, 0.1))
    np.testing.assert_allclose(ex.resolve(123.0), [0.3, 0.1])
    assert "0.3" in ex.describe()
    with pytest.raises(ValueError):
        X.ExplicitRates(())
    with pytest.raises(KeyError):
        X.plan(X.Experiment([X.Scenario("mesh", 16,
                                        traffic="nonesuch")], cfg=CFG))
    # a bare topo -> matrix callable is a usage error with a clear fix
    from repro.core import traffic as TR
    with pytest.raises(TypeError, match="CustomTraffic"):
        X.plan(X.Experiment([X.Scenario("mesh", 16,
                                        traffic=TR.uniform)], cfg=CFG))


def test_analytic_backend_rows_match_sim_identity():
    """Analytic backend: no simulation, rows carry the channel-load
    bound and zero-load latency through the same cost model."""
    exp = X.Experiment([X.Scenario("mesh", 16),
                        X.Scenario("hypercube", 15)],
                       cfg=CFG, backend="analytic")
    frame = X.run(exp)
    assert frame.results[0] is None          # nothing simulated
    row = frame.rows[0]
    assert row["sim_saturation"] is None
    assert row["rel_throughput"] == pytest.approx(
        row["analytic_saturation"])
    assert row["abs_throughput_gbps"] > 0
    assert frame.rows[1]["status"] == "invalid"


# ---------------------------------------------------------------------
# execution: chunking, progress, partial-failure isolation
# ---------------------------------------------------------------------

def test_chunked_execution_bitwise_and_progress():
    exp = X.Experiment([X.Scenario(n, 16, rates=X.SaturationGrid(3))
                        for n in ("mesh", "folded_hexa_torus",
                                  "hexamesh", "honeycomb_mesh")],
                       cfg=CFG)
    eng = SweepEngine(cfg=CFG)
    whole = X.run(exp, engine=eng)
    ticks = []
    chunked = X.run(exp, engine=eng, chunk_size=1,
                    progress=lambda done, total, key:
                    ticks.append((done, total)))
    for a, b in zip(whole.results, chunked.results):
        for k in RAW:
            np.testing.assert_array_equal(a[k], b[k], err_msg=k)
    assert ticks[-1][0] == ticks[-1][1] == 4
    assert len(ticks) == 4               # one tick per 1-scenario chunk


class _FailingEngine(SweepEngine):
    """Raises for any chunk containing the poisoned topology size."""
    poison_n: int = 0

    def run_specs(self, specs, rates, single_program=False, cfg=None):
        if any(s.n == self.poison_n for s in specs):
            raise RuntimeError("injected failure")
        return super().run_specs(specs, rates, single_program, cfg=cfg)


def test_partial_failure_isolation():
    eng = _FailingEngine(cfg=CFG)
    eng.poison_n = 36
    exp = X.Experiment([X.Scenario("mesh", 16),
                        X.Scenario("mesh", 36),      # poisoned
                        X.Scenario("folded_hexa_torus", 16)],
                       cfg=CFG)
    with pytest.raises(RuntimeError):
        X.run(exp, engine=eng)                       # default: raise
    frame = X.run(exp, engine=eng, chunk_size=1, on_error="skip")
    statuses = [r["status"] for r in frame.rows]
    assert statuses == ["ok", "failed", "ok"]
    assert "injected failure" in frame.rows[1]["error"]
    assert frame.errors and frame.errors[0][0] == 1
    assert frame.results[0] is not None
    # ok scenarios are bitwise-unaffected by their failed neighbour
    clean = X.run(X.Experiment([X.Scenario("mesh", 16)], cfg=CFG),
                  engine=SweepEngine(cfg=CFG))
    for k in RAW:
        np.testing.assert_array_equal(frame.results[0][k],
                                      clean.results[0][k], err_msg=k)


# ---------------------------------------------------------------------
# deprecation contracts
# ---------------------------------------------------------------------

def test_legacy_entry_points_warn_and_work():
    eng = SweepEngine(cfg=CFG)
    cases = [SweepCase("mesh", 16)]
    with pytest.warns(DeprecationWarning, match="evaluate_cases"):
        out = eng.evaluate_cases(cases, n_rates=3)
    assert out[0]["sim_saturation"] > 0
    with pytest.warns(DeprecationWarning,
                      match="evaluate_workload_cases"):
        grid = eng.evaluate_workload_cases(cases, WORKLOADS[:1],
                                           n_rates=3)
    assert grid[0]["phase_cycles"].sum() == CFG.cycles - CFG.warmup
    from benchmarks.common import evaluate_many
    with pytest.warns(DeprecationWarning, match="evaluate_many"):
        rows = evaluate_many([("mesh", 16)], sim_cfg=CFG)
    assert rows[0]["topology"] == "mesh" and not rows[0]["sim"]


# ---------------------------------------------------------------------
# versioned writers + frame plumbing
# ---------------------------------------------------------------------

def test_write_csv_schema_and_stable_columns(tmp_path):
    path = str(tmp_path / "out.csv")
    rows = [dict(b=1, a=2), None, dict(a=3, b=4, c=5)]
    cols = X.write_csv(path, rows)
    assert cols == ["schema_version", "b", "a", "c"]
    lines = open(path).read().splitlines()
    assert lines[0] == "schema_version,b,a,c"
    assert lines[1] == f"{X.SCHEMA_VERSION},1,2,"
    assert len(lines) == 3                   # None row dropped
    # cells containing commas/quotes are RFC-4180 quoted, not split
    X.write_csv(path, [dict(r="rates(0.1,0.2)", q='say "hi"')])
    body = open(path).read().splitlines()[1]
    assert body == f'{X.SCHEMA_VERSION},"rates(0.1,0.2)","say ""hi"""'


def test_write_json_roundtrip(tmp_path):
    path = str(tmp_path / "out.json")
    X.write_json(path, [dict(x=np.float32(1.5),
                             y=np.arange(3))], meta=dict(tag="t"))
    doc = X.read_json(path)
    assert doc["schema_version"] == X.SCHEMA_VERSION
    assert doc["tag"] == "t"
    assert doc["rows"][0] == {"x": 1.5, "y": [0, 1, 2]}


def test_frame_csv_and_selects(tmp_path):
    exp = X.Experiment([X.Scenario("mesh", 16,
                                   tags=(("flavour", "plain"),)),
                        X.Scenario("hypercube", 15)],
                       cfg=CFG, backend="analytic")
    frame = X.run(exp)
    assert frame.columns[:3] == ("experiment", "backend", "status")
    assert "flavour" in frame.columns
    path = str(tmp_path / "frame.csv")
    frame.to_csv(path)
    lines = open(path).read().splitlines()
    assert lines[0].startswith("schema_version,experiment")
    assert len(lines) == 2                   # invalid row excluded
    frame.to_csv(path, include_failures=True)
    assert len(open(path).read().splitlines()) == 3
    assert frame.select(topology="mesh")[0]["flavour"] == "plain"
    assert len(frame) == 2 and len(list(iter(frame))) == 2
    # tags may not shadow reserved result columns
    with pytest.raises(ValueError, match="reserved"):
        X.Scenario("mesh", 16, tags=(("status", "phase1"),))


# ---------------------------------------------------------------------
# fault injection through the pipeline (DESIGN.md §12)
# ---------------------------------------------------------------------

def test_empty_faultset_bitwise_identical_to_no_faults():
    """Regression: `faults=FaultSet()` must be byte-for-byte the
    no-faults path — same routing cache entry, same sweep counters —
    for static AND workload traffic."""
    import repro.faults as F
    eng = SweepEngine(cfg=CFG)
    mk = lambda fs: [
        X.Scenario("mesh", 16, faults=fs, rates=X.SaturationGrid(3)),
        X.Scenario("folded_hexa_torus", 16, faults=fs,
                   traffic=WORKLOADS[0], rates=X.SaturationGrid(3))]
    base = X.run(X.Experiment(mk(None), cfg=CFG), engine=eng)
    empty = X.run(X.Experiment(mk(F.FaultSet()), cfg=CFG), engine=eng)
    for i in range(2):
        assert empty.planned[i].routing is base.planned[i].routing
        assert empty.planned[i].topo is base.planned[i].topo
        for k in RAW:
            np.testing.assert_array_equal(empty.results[i][k],
                                          base.results[i][k], err_msg=k)
        assert empty.rows[i]["faults"] == "none"
        assert empty.rows[i]["failed_links"] == 0


def test_degraded_scenarios_flow_through_pipeline():
    """Link/chiplet fault sets run in the same padded batches; columns
    report the fault identity; disconnecting sets are skipped with an
    actionable reason, not crashed on."""
    import repro.faults as F
    topo = T.build("folded_hexa_torus", 16)
    fs = F.sample_faults(topo, 2, "random", seed=0)
    chip = F.sample_faults(topo, 1, "chiplets", seed=0)
    e = np.sort(np.asarray(T.build("mesh", 16).edges), axis=1)
    cut = F.FaultSet(links=tuple(
        tuple(int(x) for x in lk) for lk in e[(e == 0).any(1)]))
    exp = X.Experiment(
        [X.Scenario("folded_hexa_torus", 16, rates=X.SaturationGrid(3)),
         X.Scenario("folded_hexa_torus", 16, faults=fs,
                    rates=X.SaturationGrid(3)),
         X.Scenario("folded_hexa_torus", 16, faults=chip,
                    rates=X.SaturationGrid(3)),
         X.Scenario("mesh", 16, faults=cut,
                    rates=X.SaturationGrid(3))], cfg=CFG)
    pl = X.plan(exp)
    assert pl.n_planned == 3
    assert len(pl.skipped) == 1
    i, reason = pl.skipped[0]
    assert i == 3 and "fault set rejected" in reason \
        and "islands" in reason
    frame = X.run(exp)
    assert [r["status"] for r in frame.rows] == ["ok", "ok", "ok",
                                                 "invalid"]
    pristine, degraded, dead_chip = frame.rows[:3]
    assert degraded["faults"] == fs.name
    assert degraded["failed_links"] == 2 and degraded["failed_chiplets"] == 0
    assert dead_chip["failed_chiplets"] == 1
    assert degraded["sim_saturation"] <= pristine["sim_saturation"] + 1e-9
    # the degraded cell routed a genuinely different structure
    assert frame.planned[1].routing is not frame.planned[0].routing
    assert len(frame.planned[1].topo.edges) == \
        len(frame.planned[0].topo.edges) - 2
    # dead chiplet neither injects nor receives in the resolved traffic
    dead = chip.chiplets[0]
    assert frame.planned[2].traffic[dead].sum() == 0
    assert frame.planned[2].traffic[:, dead].sum() == 0
    # scenario labels and Scenario.degraded reflect the fault identity
    assert exp.scenarios[1].degraded and not exp.scenarios[0].degraded
    assert fs.name in exp.scenarios[1].label


def test_workload_scenario_with_chiplet_faults_masks_every_phase():
    """A schedule run under chiplet faults carries masked phases and the
    whole (degraded topo, masked schedule) pair stays bitwise equal to
    the single-spec oracle."""
    import repro.faults as F
    from repro.core.simulator import run_batch
    topo = T.build("mesh", 16)
    chip = F.sample_faults(topo, 1, "chiplets", seed=3)
    scen = X.Scenario("mesh", 16, traffic=WORKLOADS[0], faults=chip,
                      rates=X.SaturationGrid(3))
    frame = X.run(X.Experiment([scen], cfg=CFG),
                  engine=SweepEngine(cfg=CFG))
    assert frame.rows[0]["status"] == "ok"
    ps = frame.planned[0]
    dead = chip.chiplets[0]
    for p in ps.schedule.phases:
        m = np.asarray(p.traffic)
        assert m[dead].sum() == 0 and m[:, dead].sum() == 0
    single = run_batch([ps.spec], ps.rates[None, :], CFG,
                       schedules=[ps.sched_spec])[0]
    for k in RAW:
        np.testing.assert_array_equal(single[k], frame.results[0][k],
                                      err_msg=k)


def test_scenario_faults_type_error():
    with pytest.raises(TypeError, match="FaultSet"):
        X.Scenario("mesh", 16, faults=[(0, 1)])


# ---------------------------------------------------------------------
# per-scenario routing modes (DESIGN.md §15)
# ---------------------------------------------------------------------

def test_scenario_routing_validation():
    X.Scenario("mesh", 16, routing="adaptive")
    X.Scenario("mesh", 16, routing=None)
    with pytest.raises(ValueError, match="routing"):
        X.Scenario("mesh", 16, routing="wild")
    s = X.Scenario("mesh", 16)
    assert s.effective_routing(CFG) == "static"
    assert s.effective_routing(CFG._replace(routing="adaptive")) \
        == "adaptive"
    so = X.Scenario("mesh", 16, routing="adaptive")
    assert so.effective_routing(CFG) == "adaptive"


def test_plan_buckets_split_by_routing():
    """Static and adaptive scenarios of the same shape land in
    different buckets (different compiled programs), and the bucket key
    carries the effective mode."""
    exp = X.Experiment(
        [X.Scenario("mesh", 16, rates=X.ExplicitRates((0.1, 0.3))),
         X.Scenario("mesh", 16, rates=X.ExplicitRates((0.1, 0.3)),
                    routing="adaptive")], cfg=CFG)
    pl = X.plan(exp)
    keys = sorted(b.key.routing for b in pl.buckets)
    assert keys == ["adaptive", "static"]
    # single_program mode must NOT merge across routing modes
    pl2 = X.plan(exp, single_program=True)
    assert len(pl2.buckets) == 2


def test_execute_routing_override_matches_direct():
    """A routing="adaptive" scenario produces exactly the counters of a
    direct adaptive run; the static sibling stays on the engine default."""
    rates = (0.1, 0.4)
    exp = X.Experiment(
        [X.Scenario("mesh", 16, rates=X.ExplicitRates(rates)),
         X.Scenario("mesh", 16, rates=X.ExplicitRates(rates),
                    routing="adaptive")], cfg=CFG)
    frame = X.run(exp)
    assert [r["routing"] for r in frame.rows] == ["static", "adaptive"]
    from repro.core.routing import cached_routing
    from repro.core import traffic as TR
    from repro.core.simulator import make_spec
    topo, routing = cached_routing("mesh", 16, "organic", 74.0,
                                   "homogeneous")
    spec = make_spec(routing, TR.uniform(topo))
    rr = np.asarray(rates, np.float32)[None, :]
    for i, mode in enumerate(("static", "adaptive")):
        direct = run_batch([spec], rr, CFG._replace(routing=mode))[0]
        got = frame.results[i]
        for k in RAW:
            np.testing.assert_array_equal(
                np.asarray(got[k]), np.asarray(direct[k]),
                err_msg=f"{mode}/{k}")


def test_saturation_grid_routing_headroom():
    """SaturationGrid resolves a wider ceiling for adaptive scenarios;
    explicit headroom pins it for both modes."""
    from repro.core.simulator import saturation_rate_grid
    g = X.SaturationGrid(n_rates=5)
    np.testing.assert_array_equal(
        g.resolve(0.3), saturation_rate_grid(0.3, 5))
    ad = g.resolve(0.3, routing="adaptive")
    assert ad[-1] > g.resolve(0.3)[-1]
    pinned = X.SaturationGrid(n_rates=5, headroom=2.5)
    np.testing.assert_array_equal(
        pinned.resolve(0.3, routing="static"),
        pinned.resolve(0.3, routing="adaptive"))
    assert "x2.5" in pinned.describe()


def test_routing_column_in_frame_csv(tmp_path):
    exp = X.Experiment(
        [X.Scenario("mesh", 16, rates=X.ExplicitRates((0.1,)),
                    routing="adaptive")], cfg=CFG)
    frame = X.run(exp)
    p = tmp_path / "out.csv"
    frame.to_csv(str(p))
    head = p.read_text().splitlines()
    assert "routing" in head[0].split(",")
    i = head[0].split(",").index("routing")
    assert head[1].split(",")[i] == "adaptive"
