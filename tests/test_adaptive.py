"""Adaptive-routing subsystem facade (repro.adaptive, DESIGN.md §15)."""
import numpy as np

import repro.adaptive as A
from repro.core import topology as T, traffic as TR
from repro.core.routing import build_routing
from repro.core.simulator import SimConfig

CFG = SimConfig(cycles=300, warmup=100)


def test_adaptive_config_derivation():
    cfg = A.adaptive_config()
    assert cfg.routing == "adaptive" and cfg.n_vcs >= 2
    base = SimConfig(n_vcs=1, cycles=50)
    up = A.adaptive_config(base)
    assert up.n_vcs == 2 and up.cycles == 50
    pinned = A.adaptive_config(base, n_vcs=6)
    assert pinned.n_vcs == 6


def test_facade_reexports():
    r = build_routing(T.build("mesh", 16))
    prod = A.productive_ports(r)
    assert prod.shape == (16, 16, r.max_ports)
    diags, n = A.check_escape(r)
    assert diags == [] and n > 0
    assert A.routing_headroom("adaptive") == A.ADAPTIVE_HEADROOM
    assert A.routing_headroom("static") == A.STATIC_HEADROOM


def test_compare_saturation_reports_both_modes():
    r = build_routing(T.build("mesh", 16))
    out = A.compare_saturation(r, TR.uniform(r.topo), CFG, n_rates=4)
    assert out["static"] > 0 and out["adaptive"] > 0
    assert out["gain"] == out["adaptive"] / out["static"] - 1.0
    assert out["analytic"] > 0
    # the two sweeps really ran different grids (adaptive headroom)
    sg = out["static_sweep"]["sweep"]["rate"]
    ag = out["adaptive_sweep"]["sweep"]["rate"]
    assert ag[-1] > sg[-1]
