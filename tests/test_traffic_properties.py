"""Traffic-pattern property tests (satellites of the workload PR).

Every `PATTERNS` entry must return a matrix whose rows are destination
distributions — summing to exactly 1 (active source) or 0 (inert
source) — with a zero diagonal and no negative entries, for grid,
brick-wall, and hex-spiral placements on both substrates.  Plus the
`random_permutation` derangement regression: a seed sweep must never
produce a fixed point (the seed code's pairwise-swap repair could)."""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import topology as T
from repro.core import traffic as TR

# (generator name, hex_region) triples covering the three placement
# families: rectangular grid, brick-wall, hex spiral
PLACEMENTS = [("mesh", False), ("folded_hexa_torus", False),
              ("folded_hexa_torus", True)]


def _build(placement, n, substrate):
    name, hex_region = placement
    return T.build(name, n, substrate=substrate,
                   roles_scheme="hetero_cmi", hex_region=hex_region)


@given(pattern=st.sampled_from(sorted(TR.PATTERNS)),
       placement=st.sampled_from(PLACEMENTS),
       substrate=st.sampled_from(["organic", "glass"]),
       n=st.sampled_from([12, 16, 24, 36]))
@settings(max_examples=60, deadline=None)
def test_patterns_rows_are_distributions(pattern, placement, substrate, n):
    topo = _build(placement, n, substrate)
    m = TR.PATTERNS[pattern](topo)
    assert m.shape == (n, n)
    assert (m >= 0).all()
    assert np.abs(np.diag(m)).max() == 0.0
    rows = m.sum(axis=1)
    active = rows > 0
    assert np.allclose(rows[active], 1.0, atol=1e-12)
    assert (rows[~active] == 0).all()
    # at least someone injects
    assert active.any()


@given(n=st.sampled_from([2, 3, 4, 5, 9, 16, 25, 36]),
       seed=st.integers(min_value=0, max_value=10_000))
@settings(max_examples=120, deadline=None)
def test_random_permutation_is_derangement(n, seed):
    """Seed-sweep regression: no fixed points, hence no silently-inert
    all-zero rows — every source sends exactly one unit of traffic."""
    topo = T.build("mesh", n)
    m = TR.random_permutation(topo, seed=seed)
    assert np.abs(np.diag(m)).max() == 0.0
    np.testing.assert_allclose(m.sum(axis=1), 1.0)
    # one-hot rows onto distinct destinations (a permutation)
    assert ((m == 0) | (m == 1)).all()
    np.testing.assert_allclose(m.sum(axis=0), 1.0)


def test_random_permutation_cyclic_fallback_path():
    """The fallback must itself be a derangement for tiny n where
    rejection sampling is most likely to exhaust its draws."""
    for n in (2, 3):
        for seed in range(200):
            m = TR.random_permutation(T.build("mesh", n), seed=seed)
            assert np.abs(np.diag(m)).max() == 0.0
            np.testing.assert_allclose(m.sum(axis=1), 1.0)


def test_region_traffic_matches_legacy_trace_regions():
    """`region_traffic` must reproduce what `trace_region_traffic`
    (still used by fig10) derives from the same profile entry."""
    topo = T.build("folded_hexa_torus", 16, roles_scheme="hetero_cmi")
    for profile in TR.TRACE_PROFILES:
        for region in range(len(TR.TRACE_PROFILES[profile])):
            want, intensity = TR.trace_region_traffic(topo, profile,
                                                      region)
            _, mem_frac = TR.TRACE_PROFILES[profile][region]
            np.testing.assert_array_equal(
                want, TR.region_traffic(topo, mem_frac))
            assert intensity == TR.TRACE_PROFILES[profile][region][0]
