"""Observability tests (DESIGN.md §13).

The acceptance properties of the telemetry layer:

  * **off is bitwise free** — `SimConfig(telemetry=True)` must not
    change a single shared counter vs the telemetry-off run, on static,
    workload AND fault-degraded scenarios (the flight recorder is a
    pure observer);
  * **conservation** — the per-node/per-link counters reconcile exactly
    with the aggregate counters the simulator already reports
    (sum(inj_node) == accepted_n, sum(eject_node) == delivered,
    sum(lat_hist) == delivered);
  * **padding-invariant** — telemetry sliced from a larger padded batch
    is bitwise-equal to the tight run, and never names a sacrificial or
    padded slot.

Plus unit coverage of the host half: tracer semantics, Chrome-trace
export, the metrics registry, the executor's backwards-compatible
progress callback, and the engine's eviction-proof compile accounting.
"""
import json

import numpy as np
import pytest

import repro.experiments as X
import repro.faults as F
import repro.workloads as W
from repro.core import topology as T
from repro.core import traffic as TR
from repro.core.routing import build_routing
from repro.core.simulator import (LAT_HIST_BINS, TELEMETRY_KEYS,
                                  TELEMETRY_WINDOW_KEYS, SimConfig,
                                  make_spec, run_batch,
                                  telemetry_window_cycles)
from repro.obs.metrics import (MetricsRegistry, cache_counters,
                               metrics as METRICS)
from repro.obs.report import gini, link_load_summary, window_summary
from repro.obs.trace import (Tracer, clear_trace, disable_tracing,
                             enable_tracing, get_spans, span_summary,
                             trace)
from repro.sweep.engine import SweepEngine
from repro.sweep.padding import PadShape

CFG = SimConfig(cycles=300, warmup=100)
TCFG = CFG._replace(telemetry=True)
MEAS = CFG.cycles - CFG.warmup
RAW = ("delivered", "offered_n", "accepted_n", "lat_sum")
RATES = np.array([0.05, 0.2, 0.5], np.float32)

HETERO = [("mesh", 16), ("folded_hexa_torus", 36)]


@pytest.fixture(scope="module")
def specs():
    out = []
    for name, n in HETERO:
        r = build_routing(T.build(name, n))
        out.append(make_spec(r, TR.uniform(r.topo)))
    return out


@pytest.fixture(autouse=True)
def _tracing_off():
    """Every test starts and ends with the process tracer disabled."""
    disable_tracing()
    clear_trace()
    yield
    disable_tracing()
    clear_trace()


# ---------------------------------------------------------------------
# flight recorder: bitwise-off, conservation, padding
# ---------------------------------------------------------------------

def test_telemetry_off_bitwise_identical_static(specs):
    """Turning the recorder on must not perturb any shared counter."""
    off = run_batch(specs, RATES, CFG)
    on = run_batch(specs, RATES, TCFG)
    for o, t in zip(off, on):
        for k in RAW:
            np.testing.assert_array_equal(o[k], t[k], err_msg=k)
        np.testing.assert_array_equal(o["throughput"], t["throughput"])
        np.testing.assert_array_equal(o["latency"], t["latency"])
        assert all(k in t for k in TELEMETRY_KEYS)
        assert not any(k in o for k in TELEMETRY_KEYS)


def test_telemetry_off_bitwise_identical_workload():
    topo = T.build("folded_hexa_torus", 16)
    r = build_routing(topo)
    sched = W.phase_alternating(topo, phase_cycles=60, repeats=1).fit(MEAS)
    spec = make_spec(r, sched.mean_traffic())
    eng_off = SweepEngine(cfg=CFG)
    eng_on = SweepEngine(cfg=TCFG)
    off = eng_off.run_workloads([spec], [sched], RATES)[0]
    on = eng_on.run_workloads([spec], [sched], RATES)[0]
    for k in RAW + ("delivered_ph", "lat_sum_ph"):
        np.testing.assert_array_equal(off[k], on[k], err_msg=k)
    assert "link_busy" in on and "link_busy" not in off


def test_telemetry_off_bitwise_identical_faults():
    topo = T.build("folded_hexa_torus", 36)
    fs = F.sample_faults(topo, 2, "random", seed=0)
    mk = lambda cfg: X.Experiment(
        [X.Scenario("folded_hexa_torus", 36, faults=fs,
                    rates=X.ExplicitRates((0.1, 0.3)))], cfg=cfg)
    off = X.run(mk(CFG), engine=SweepEngine(cfg=CFG))
    on = X.run(mk(TCFG), engine=SweepEngine(cfg=TCFG))
    for k in RAW:
        np.testing.assert_array_equal(off.results[0][k], on.results[0][k],
                                      err_msg=k)


def test_telemetry_conservation(specs):
    """Flight counters reconcile EXACTLY with the aggregate counters."""
    out = run_batch(specs, RATES, TCFG)
    for spec, res in zip(specs, out):
        np.testing.assert_array_equal(res["inj_node"].sum(axis=1),
                                      res["accepted_n"])
        np.testing.assert_array_equal(res["eject_node"].sum(axis=1),
                                      res["delivered"])
        np.testing.assert_array_equal(res["lat_hist"].sum(axis=1),
                                      res["delivered"])
        # each delivered flit traversed >= 1 link; busy counts them all
        assert (res["link_busy"].sum(axis=1) >= res["delivered"]).all()
        util = res["link_util"]
        assert (util >= 0).all() and (util <= 1).all()
        assert (res["link_stall"] >= 0).all()
        assert res["lat_hist"].shape == (len(RATES), LAT_HIST_BINS)


def test_telemetry_padding_invariant(specs):
    """Telemetry sliced from a fat padded batch == the tight batch, and
    its leaves are sized to the spec's own (c, n) — pad slots and the
    sacrificial row can never leak into a report."""
    tight = run_batch(specs, RATES, TCFG)
    shape = PadShape.of(specs)
    fat = PadShape(n=shape.n + 7, p=shape.p + 2, c=shape.c + 19,
                   d=shape.d + 3)
    padded = run_batch(specs, RATES, TCFG, pad_shape=fat)
    for spec, a, b in zip(specs, tight, padded):
        for k in TELEMETRY_KEYS:
            np.testing.assert_array_equal(a[k], b[k], err_msg=k)
        assert b["link_busy"].shape == (len(RATES), spec.c)
        assert b["inj_node"].shape == (len(RATES), spec.n)
        assert b["link_occ_sum"].shape[:2] == (len(RATES), spec.c)


def test_link_rows_and_frame_columns(tmp_path):
    """Tidy per-link rows cover exactly the routed channels, tidy rows
    gain the distribution columns, and the CSV writers round-trip."""
    exp = X.Experiment([X.Scenario("mesh", 16,
                                   rates=X.ExplicitRates((0.1, 0.4))),
                        X.Scenario("folded_hexa_torus", 16,
                                   rates=X.ExplicitRates((0.1, 0.4)))],
                       cfg=TCFG, name="obs_unit")
    frame = X.run(exp, engine=SweepEngine(cfg=TCFG))
    for i in range(2):
        rows = frame.link_rows(i)
        routing = frame.planned[i].routing
        assert len(rows) == len(routing.ch_src)          # all ok, no dead
        assert all(r["status"] == "ok" for r in rows)
        assert {r["channel"] for r in rows} == set(range(len(rows)))
        srcs = {(r["src"], r["dst"]) for r in rows}
        want = {(int(s), int(d)) for s, d in
                zip(routing.ch_src, routing.ch_dst)}
        assert srcs == want
        assert frame.rows[i]["link_gini"] is not None
        assert 0.0 <= frame.rows[i]["link_gini"] <= 1.0
        assert frame.rows[i]["link_util_max"] >= \
            frame.rows[i]["link_util_p95"]
    path = str(tmp_path / "links.csv")
    frame.to_link_csv(path)
    header = open(path).readline().strip().split(",")
    assert header[0] == "schema_version" and "util" in header
    # summary distribution stats per topology cell
    summary = link_load_summary(frame.all_link_rows())
    assert len(summary) == 2
    for s in summary:
        assert s["n_dead"] == 0 and s["util_max"] >= s["util_p95"]


def test_link_rows_report_dead_links():
    topo = T.build("folded_hexa_torus", 36)
    fs = F.sample_faults(topo, 2, "random", seed=0)
    exp = X.Experiment([X.Scenario("folded_hexa_torus", 36, faults=fs,
                                   rates=X.ExplicitRates((0.1, 0.3)))],
                       cfg=TCFG)
    frame = X.run(exp, engine=SweepEngine(cfg=TCFG))
    rows = frame.link_rows(0)
    dead = [r for r in rows if r["status"] == "dead"]
    ok = [r for r in rows if r["status"] == "ok"]
    assert len(dead) == 2 * fs.n_links          # both directions
    assert {(r["src"], r["dst"]) for r in dead} == \
        {(u, v) for a, b in fs.links for u, v in ((a, b), (b, a))}
    assert all(r["busy"] == 0 and r["channel"] == -1 for r in dead)
    # surviving channels are the degraded routing's channels
    assert len(ok) == len(frame.planned[0].routing.ch_src)
    # no dead link appears among the surviving directed channels
    assert not ({(r["src"], r["dst"]) for r in ok}
                & {(r["src"], r["dst"]) for r in dead})


def test_link_rows_require_telemetry():
    exp = X.Experiment([X.Scenario("mesh", 16,
                                   rates=X.ExplicitRates((0.1,)))],
                       cfg=CFG)
    frame = X.run(exp, engine=SweepEngine(cfg=CFG))
    with pytest.raises(ValueError, match="telemetry"):
        frame.link_rows(0)


def test_gini():
    assert gini([1, 1, 1, 1]) == pytest.approx(0.0)
    assert gini([0, 0, 0, 8]) == pytest.approx(0.75)
    assert gini([]) == 0.0
    assert gini([0.0, 0.0]) == 0.0


# ---------------------------------------------------------------------
# host half: tracer + metrics
# ---------------------------------------------------------------------

def test_tracer_records_spans_and_attrs():
    tr = Tracer()
    with tr.trace("outer", cat="test", a=1):
        with tr.trace("inner") as sp:
            sp.set(cold=True)
    assert not tr.spans()                      # disabled: nothing kept
    tr.enable()
    with tr.trace("outer", cat="test", a=1):
        with tr.trace("inner") as sp:
            sp.set(cold=True)
    spans = tr.spans()
    assert [s.name for s in spans] == ["inner", "outer"]  # close order
    inner, outer = spans
    assert inner.args["cold"] is True and outer.args["a"] == 1
    assert outer.dur >= inner.dur >= 0
    assert outer.ts <= inner.ts <= inner.ts + inner.dur \
        <= outer.ts + outer.dur


def test_tracer_records_exceptions():
    tr = Tracer()
    tr.enable()
    with pytest.raises(RuntimeError):
        with tr.trace("boom"):
            raise RuntimeError("x")
    (sp,) = tr.spans()
    assert sp.args["error"] == "RuntimeError"


def test_chrome_trace_export(tmp_path):
    tr = Tracer()
    tr.enable()
    with tr.trace("phase", cat="test", shape="(1, 2)"):
        pass
    path = str(tmp_path / "trace.json")
    n = tr.save_chrome_trace(path, metadata=dict(run="unit"))
    assert n == 1
    doc = json.load(open(path))
    (ev,) = doc["traceEvents"]
    assert ev["ph"] == "X" and ev["name"] == "phase"
    assert ev["args"]["shape"] == "(1, 2)"
    assert doc["metadata"]["run"] == "unit"


def test_simulator_emits_spans_when_tracing(specs):
    enable_tracing()
    run_batch(specs[:1], RATES, CFG)
    names = [s.name for s in get_spans()]
    assert "sim.stack" in names and "sim.dispatch" in names \
        and "sim.wait" in names
    disp = [s for s in get_spans() if s.name == "sim.dispatch"]
    assert all("cold" in s.args for s in disp)


def test_metrics_registry(tmp_path):
    m = MetricsRegistry()
    m.inc("a")
    m.inc("a", 2)
    assert m.get("a") == 3
    m.observe("lat", 1.0)
    m.observe("lat", 3.0)
    snap = m.snapshot()
    assert snap["a"] == 3
    assert snap["lat"] == dict(count=2, sum=4.0, min=1.0, max=3.0)
    assert "cache.runner.misses" in snap        # absorbed LRU counters
    sink = str(tmp_path / "events.jsonl")
    m.set_sink(sink)
    m.event("chunk_failed", reason="boom", n=2)
    m.event("other")
    assert [e["reason"] for e in m.events("chunk_failed")] == ["boom"]
    lines = [json.loads(x) for x in open(sink)]
    assert len(lines) == 2 and lines[0]["event"] == "chunk_failed"
    out = str(tmp_path / "log.jsonl")
    assert m.save_jsonl(out) == 2
    m.reset()
    assert m.get("a") == 0 and not m.events()


def test_cache_counters_monotonic():
    before = cache_counters()
    r = build_routing(T.build("mesh", 16))
    run_batch([make_spec(r, TR.uniform(r.topo))],
              np.array([0.1], np.float32), CFG)
    after = cache_counters()
    for k in ("cache.runner.misses", "cache.runner.hits",
              "cache.routing.misses"):
        assert after[k] >= before[k]


# ---------------------------------------------------------------------
# executor + engine plumbing
# ---------------------------------------------------------------------

def test_progress_callback_three_and_four_arg():
    exp = X.Experiment([X.Scenario("mesh", 16, rates=X.SaturationGrid(3)),
                        X.Scenario("folded_hexa_torus", 16,
                                   rates=X.SaturationGrid(3))], cfg=CFG)
    eng = SweepEngine(cfg=CFG)
    legacy, rich = [], []
    X.run(exp, engine=eng, chunk_size=1,
          progress=lambda done, total, key: legacy.append((done, total)))
    X.run(exp, engine=eng, chunk_size=1,
          progress=lambda done, total, key, info:
          rich.append((done, total, info)))
    assert [x[:2] for x in legacy] == [x[:2] for x in rich]
    for _, _, info in rich:
        assert info["status"] == "ok" and info["scenarios"] == 1
        assert info["elapsed_s"] >= 0 and info["compiled"] >= 0
    # warm second run: the engine reused its executables
    assert sum(info["compiled"] for _, _, info in rich) == 0


class _FailingEngine(SweepEngine):
    poison_n: int = 0

    def run_specs(self, specs, rates, single_program=False, cfg=None):
        if any(s.n == self.poison_n for s in specs):
            raise RuntimeError("injected failure")
        return super().run_specs(specs, rates, single_program, cfg=cfg)


def test_failed_chunk_logs_metrics_event():
    eng = _FailingEngine(cfg=CFG)
    eng.poison_n = 36
    exp = X.Experiment([X.Scenario("mesh", 16),
                        X.Scenario("mesh", 36)], cfg=CFG,
                       name="obs_fail_unit")
    n0 = len(METRICS.events("execute.chunk_failed"))
    infos = []
    frame = X.run(exp, engine=eng, chunk_size=1, on_error="skip",
                  progress=lambda d, t, k, info: infos.append(info))
    assert [r["status"] for r in frame.rows] == ["ok", "failed"]
    evs = METRICS.events("execute.chunk_failed")[n0:]
    assert len(evs) == 1
    assert evs[0]["experiment"] == "obs_fail_unit"
    assert "injected failure" in evs[0]["reason"]
    assert evs[0]["indices"] == [1]
    assert [i["status"] for i in infos] == ["ok", "failed"]


def test_engine_compile_stats_survive_evictions():
    """Satellite regression: compile accounting is a monotonic
    miss-delta, so an LRU eviction between groups cannot make the
    engine report fewer (or negative) compiles."""
    from repro.core import simulator as sim
    tiny = SimConfig(cycles=80, warmup=20)
    rates = np.array([0.1, 0.3], np.float32)
    specs = []
    for name, n in HETERO:
        r = build_routing(T.build(name, n))
        specs.append(make_spec(r, TR.uniform(r.topo)))
    old_max = sim.runner_cache_info()["max_size"]
    sim._RUNNER_CACHE.clear()
    eng = SweepEngine(cfg=tiny, bucket=False)
    try:
        sim.set_runner_cache_limit(1)   # every group evicts the other
        eng.run_specs(specs, rates)     # 2 shapes -> 2 compiles
        assert eng.stats["compiles"] == 2
        eng.run_specs(specs, rates)     # both cold again (evicted)
        assert eng.stats["compiles"] == 4
        assert eng.stats["reuses"] == 0
    finally:
        sim.set_runner_cache_limit(old_max)


def test_engine_emits_sweep_group_spans(specs):
    enable_tracing()
    clear_trace()
    SweepEngine(cfg=CFG).run_specs(specs, RATES)
    groups = [s for s in get_spans() if s.name == "sweep.group"]
    assert groups and all(s.args["kind"] == "static" for s in groups)


def test_experiment_pipeline_emits_plan_execute_spans():
    enable_tracing()
    clear_trace()
    exp = X.Experiment([X.Scenario("mesh", 16,
                                   rates=X.ExplicitRates((0.1,)))],
                       cfg=CFG)
    X.run(exp, engine=SweepEngine(cfg=CFG))
    names = [s.name for s in get_spans()]
    for want in ("experiment.plan", "experiment.execute",
                 "execute.chunk", "sweep.group", "sim.dispatch"):
        assert want in names, want


# ---------------------------------------------------------------------
# windowed flight recorder (DESIGN.md §16)
# ---------------------------------------------------------------------

WCFG = TCFG._replace(telemetry_windows=5)
WKEYS_RAW = ("link_busy_w", "link_stall_w", "link_occ_w",
             "inj_node_w", "eject_node_w")
#: (windowed key, aggregate it must sum to over the window axis)
WSUM = (("link_busy_w", "link_busy"), ("link_stall_w", "link_stall"),
        ("link_occ_w", "link_occ_sum"), ("inj_node_w", "inj_node"),
        ("eject_node_w", "eject_node"))


def test_windowed_off_by_default(specs):
    """telemetry_windows=0 leaves results without any windowed key, and
    enabling it perturbs no aggregate counter (it only *bins*)."""
    plain = run_batch(specs, RATES, TCFG)
    windowed = run_batch(specs, RATES, WCFG)
    for p, w in zip(plain, windowed):
        assert not any(k in p for k in TELEMETRY_WINDOW_KEYS)
        assert all(k in w for k in TELEMETRY_WINDOW_KEYS)
        for k in RAW + TELEMETRY_KEYS:
            np.testing.assert_array_equal(p[k], w[k], err_msg=k)


@pytest.mark.parametrize("routing", ["static", "adaptive"])
def test_windowed_conservation(specs, routing):
    """Each windowed tensor sums over its window axis EXACTLY to the
    aggregate counter, in both routing modes."""
    cfg = WCFG._replace(routing=routing)
    for res in run_batch(specs, RATES, cfg):
        for wk, ak in WSUM:
            np.testing.assert_array_equal(
                res[wk].sum(axis=1), res[ak],
                err_msg=f"{routing}: {wk} vs {ak}")
        wc = res["window_cycles"]
        assert wc.sum() == MEAS and len(wc) == 5
        util = res["link_util_w"]
        assert (util >= 0).all() and (util <= 1).all()


def test_windowed_conservation_workload():
    """Windowed counters reconcile on the phase-schedule path too."""
    topo = T.build("folded_hexa_torus", 16)
    r = build_routing(topo)
    sched = W.phase_alternating(topo, phase_cycles=60, repeats=1).fit(MEAS)
    spec = make_spec(r, sched.mean_traffic())
    res = SweepEngine(cfg=WCFG).run_workloads([spec], [sched], RATES)[0]
    for wk, ak in WSUM:
        np.testing.assert_array_equal(res[wk].sum(axis=1), res[ak],
                                      err_msg=wk)
    # and the two decompositions of accepted agree: windows vs phases
    np.testing.assert_array_equal(
        res["inj_node_w"].sum(axis=(1, 2)),
        res["accepted_ph"].sum(axis=1))


@pytest.mark.parametrize("routing", ["static", "adaptive"])
def test_windowed_padding_invariant(specs, routing):
    """Windowed telemetry sliced from a FAT padded batch is bitwise
    equal to the tight batch, in both routing modes (the fat-pad
    regression test of the acceptance criteria)."""
    cfg = WCFG._replace(routing=routing)
    tight = run_batch(specs, RATES, cfg)
    shape = PadShape.of(specs)
    fat = PadShape(n=shape.n + 7, p=shape.p + 2, c=shape.c + 19,
                   d=shape.d + 3)
    padded = run_batch(specs, RATES, cfg, pad_shape=fat)
    for spec, a, b in zip(specs, tight, padded):
        for k in WKEYS_RAW + ("link_util_w", "window_cycles"):
            np.testing.assert_array_equal(a[k], b[k],
                                          err_msg=f"{routing}: {k}")
        W_ = cfg.telemetry_windows
        assert b["link_busy_w"].shape == (len(RATES), W_, spec.c)
        assert b["inj_node_w"].shape == (len(RATES), W_, spec.n)


def test_window_validation_errors(specs):
    with pytest.raises(ValueError, match="telemetry=True"):
        run_batch(specs, RATES, CFG._replace(telemetry_windows=4))
    with pytest.raises(ValueError, match="exceeds the measured"):
        run_batch(specs, RATES,
                  TCFG._replace(telemetry_windows=MEAS + 1))
    with pytest.raises(ValueError):
        run_batch(specs, RATES, TCFG._replace(telemetry_windows=-1))


def test_telemetry_window_cycles_partition():
    """The host-side window grid partitions the measured span exactly,
    even when W does not divide it."""
    cfg = SimConfig(cycles=307, warmup=100, telemetry=True,
                    telemetry_windows=6)
    wc = telemetry_window_cycles(cfg)
    assert wc.sum() == 207 and len(wc) == 6
    assert wc.min() >= 207 // 6 and wc.max() <= 207 // 6 + 1
    with pytest.raises(ValueError):
        telemetry_window_cycles(cfg._replace(telemetry_windows=0))


def test_window_rows_summary_and_csv(tmp_path):
    """Tidy per-(window, link) rows + time-heatmap CSV round-trip, and
    the per-window summary tracks a drifting hotspot's imbalance."""
    wl = W.Workload("hotspot_drift",
                    lambda topo: W.hotspot_drift(topo, n_phases=5,
                                                 dwell=40))
    exp = X.Experiment(
        [X.Scenario("folded_hexa_torus", 16, traffic=wl,
                    rates=X.ExplicitRates((0.1, 0.3)))],
        cfg=WCFG, name="win")
    frame = X.run(exp, engine=SweepEngine(cfg=WCFG))
    rows = frame.window_rows(0)
    spec = frame.planned[0].spec
    W_ = WCFG.telemetry_windows
    assert len(rows) == W_ * spec.c
    # the window grid tiles the measured span
    starts = sorted({r["t_start"] for r in rows})
    ends = sorted({r["t_end"] for r in rows})
    assert starts[0] == 0 and ends[-1] == MEAS
    assert starts[1:] == ends[:-1]
    # summary: one row per window, busy total conserved vs link rows
    summ = window_summary(rows)
    assert [s["window"] for s in summ] == list(range(W_))
    assert sum(s["busy_total"] for s in summ) == \
        sum(r["busy"] for r in rows)
    path = tmp_path / "win.csv"
    frame.to_window_csv(str(path))
    header = path.read_text().splitlines()[0].split(",")
    assert header[0] == "schema_version"
    from repro.obs.flight import WINDOW_COLUMNS
    assert list(WINDOW_COLUMNS) == header[1:1 + len(WINDOW_COLUMNS)]


def test_window_rows_require_windowed_telemetry(specs):
    from repro.obs.flight import window_rows
    exp = X.Experiment([X.Scenario("mesh", 16,
                                   rates=X.ExplicitRates((0.1,)))],
                       cfg=TCFG)
    frame = X.run(exp, engine=SweepEngine(cfg=TCFG))
    with pytest.raises(ValueError, match="windowed telemetry"):
        window_rows(frame.planned[0], frame.results[0])


# ---------------------------------------------------------------------
# pad-waste accounting (DESIGN.md §16)
# ---------------------------------------------------------------------

def test_pad_fill_on_results(specs):
    """Every result carries its live-work fraction; padding fatter
    shrinks it, and a tight single-spec batch is fill 1.0."""
    tight = run_batch([specs[0]], RATES, CFG)[0]
    assert tight["pad_fill"] == dict(state=1.0, chan=1.0, depth=1.0,
                                     phase=1.0)
    both = run_batch(specs, RATES, CFG)
    shape = PadShape.of(specs)
    for spec, res in zip(specs, both):
        pf = res["pad_fill"]
        assert 0 < pf["state"] <= 1.0 and pf["chan"] == spec.c / shape.c
        assert pf["phase"] == 1.0
    fat = PadShape(n=shape.n + 7, p=shape.p + 2, c=shape.c + 19,
                   d=shape.d + 3)
    fatter = run_batch(specs, RATES, CFG, pad_shape=fat)
    for res, fres in zip(both, fatter):
        assert fres["pad_fill"]["state"] < res["pad_fill"]["state"]


def test_pad_fill_in_frame_rows():
    """Tidy ResultFrame rows surface the pad-fill columns (schema v6)."""
    exp = X.Experiment(
        [X.Scenario(name, 16, rates=X.ExplicitRates((0.1,)))
         for name in ("mesh", "folded_hexa_torus")],
        cfg=CFG)
    frame = X.run(exp, engine=SweepEngine(cfg=CFG))
    for row in frame.ok():
        assert 0 < row["pad_fill_state"] <= 1.0
        assert 0 < row["pad_fill_chan"] <= 1.0
        assert row["pad_fill_phase"] == 1.0
    assert any(r["pad_fill_chan"] < 1.0 for r in frame.ok())


def test_sweep_group_span_reports_bucket_fill(specs):
    enable_tracing()
    clear_trace()
    SweepEngine(cfg=CFG, s_round=4).run_specs(specs, RATES)
    groups = [s for s in get_spans() if s.name == "sweep.group"]
    assert groups
    for sp in groups:
        assert sp.args["s_live"] <= sp.args["s_pad"]
        assert sp.args["r_live"] <= sp.args["r_pad"]
    disp = [s for s in get_spans() if s.name == "sim.dispatch"]
    assert disp and all("fill_state" in s.args for s in disp)


# ---------------------------------------------------------------------
# metrics sink isolation (DESIGN.md §16 satellite)
# ---------------------------------------------------------------------

def test_metrics_buffered_sink_flush_and_close(tmp_path):
    reg = MetricsRegistry()
    sink = tmp_path / "ev.jsonl"
    reg.set_sink(str(sink), buffered=True)
    reg.event("a", x=1)
    reg.event("b", x=2)
    assert not sink.exists() or sink.read_text() == ""
    assert reg.flush() == 2
    assert len(sink.read_text().splitlines()) == 2
    reg.event("c")
    reg.close_sink()
    lines = [json.loads(ln) for ln in sink.read_text().splitlines()]
    assert [e["event"] for e in lines] == ["a", "b", "c"]
    reg.event("after_close")          # no sink: memory only
    assert len(sink.read_text().splitlines()) == 3


def test_metrics_reset_detaches_sink(tmp_path):
    """reset() flushes + detaches the sink, so a later run cannot leak
    events into a file an earlier test attached."""
    reg = MetricsRegistry()
    sink = tmp_path / "run1.jsonl"
    reg.set_sink(str(sink), buffered=True)
    reg.inc("n")
    reg.event("run1.ev")
    reg.reset()
    assert [json.loads(ln)["event"]
            for ln in sink.read_text().splitlines()] == ["run1.ev"]
    assert reg.get("n") == 0 and reg.events() == []
    reg.event("run2.ev")              # post-reset events stay in memory
    assert len(sink.read_text().splitlines()) == 1


def test_metrics_sink_switch_flushes_old(tmp_path):
    reg = MetricsRegistry()
    a, b = tmp_path / "a.jsonl", tmp_path / "b.jsonl"
    reg.set_sink(str(a), buffered=True)
    reg.event("one")
    reg.set_sink(str(b))              # unbuffered from here
    assert len(a.read_text().splitlines()) == 1
    reg.event("two")
    assert json.loads(b.read_text())["event"] == "two"


# ---------------------------------------------------------------------
# tracer edge cases (DESIGN.md §16 satellite)
# ---------------------------------------------------------------------

def test_tracer_empty_export(tmp_path):
    t = Tracer()
    t.enable()
    assert t.chrome_events() == []
    path = tmp_path / "empty.trace.json"
    assert t.save_chrome_trace(str(path)) == 0
    doc = json.loads(path.read_text())
    assert doc["traceEvents"] == []


def test_tracer_concurrent_threads():
    import threading
    t = Tracer()
    t.enable()

    def worker(i):
        for j in range(20):
            with t.trace(f"w{i}", cat="thr", j=j):
                pass

    threads = [threading.Thread(target=worker, args=(i,))
               for i in range(4)]
    for th in threads:
        th.start()
    for th in threads:
        th.join()
    spans = t.spans()
    assert len(spans) == 80           # no span lost to a race
    # every span carries its recording thread's id (ids may be recycled
    # once a thread exits, so count per worker, not distinct tids)
    assert all(s.tid for s in spans)
    by_name = span_summary(spans)
    assert all(by_name[f"w{i}"]["count"] == 20 for i in range(4))
    for i in range(4):
        tids = {s.tid for s in spans if s.name == f"w{i}"}
        assert len(tids) == 1         # one worker -> one tid


def test_nested_span_parent_attribution():
    """Chrome events come out start-sorted with parents before children
    (spans RECORD innermost-first; export must not)."""
    t = Tracer()
    t.enable()
    with t.trace("parent", cat="t"):
        with t.trace("child", cat="t"):
            with t.trace("grandchild", cat="t"):
                pass
    assert [s.name for s in t.spans()] == ["grandchild", "child",
                                           "parent"]
    ev = t.chrome_events()
    assert [e["name"] for e in ev] == ["parent", "child", "grandchild"]
    p, c, g = ev
    assert p["ts"] <= c["ts"] <= g["ts"]
    assert p["ts"] + p["dur"] >= c["ts"] + c["dur"] \
        >= g["ts"] + g["dur"]


def test_span_summary_aggregates():
    t = Tracer()
    t.enable()
    for _ in range(3):
        with t.trace("x"):
            pass
    with t.trace("y"):
        pass
    summ = span_summary(t.spans())
    assert summ["x"]["count"] == 3 and summ["y"]["count"] == 1
    assert summ["x"]["total_s"] >= summ["x"]["max_s"] >= 0
