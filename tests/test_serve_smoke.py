"""Tier-1 smoke for the serving driver: `launch/serve.py --smoke`
prefills and decodes end to end with config-consistent output shapes."""
import numpy as np
import pytest

from repro.configs import get_config
from repro.launch import serve


@pytest.mark.parametrize("arch", ["qwen3-1.7b", "mamba2-1.3b"])
def test_serve_smoke_decodes(arch):
    b, gen = 2, 4
    toks = serve.main(["--smoke", "--arch", arch, "--batch", str(b),
                       "--prompt-len", "8", "--gen", str(gen)])
    out = np.asarray(toks)
    # one token from the prefill argmax + gen decode steps
    assert out.shape == (b, gen + 1)
    assert out.dtype == np.int32
    cfg = get_config(arch, smoke=True)
    assert (out >= 0).all() and (out < cfg.vocab).all()


def test_serve_smoke_deterministic_in_seed():
    argv = ["--smoke", "--arch", "qwen3-1.7b", "--batch", "2",
            "--prompt-len", "8", "--gen", "3", "--seed", "11"]
    a = np.asarray(serve.main(argv))
    b = np.asarray(serve.main(argv))
    np.testing.assert_array_equal(a, b)
