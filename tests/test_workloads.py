"""Workload-engine tests (DESIGN.md §9).

The acceptance property: a phase schedule with a single uniform phase
reproduces the static-traffic simulator counters BITWISE — the workload
path is a strict generalization of the static path.  Plus: padding
invariance of the phase pointer (spec-, rate- and phase-axis padding),
ON/OFF burst semantics, the collective/trace/synthetic generators, and
the engine's workloads x topologies batching."""
import numpy as np
import pytest

import repro.workloads as W
from repro.configs import get_config
from repro.core import topology as T, traffic as TR
from repro.core.collectives import (collective_flow, mesh_axis_groups,
                                    mesh_coords)
from repro.core.routing import build_routing
from repro.core.simulator import (SimConfig, make_sched_spec, make_spec,
                                  phase_measured_cycles, run_batch)
from repro.sweep.engine import SweepCase, SweepEngine

CFG = SimConfig(cycles=300, warmup=100)
RAW = ("delivered", "offered_n", "accepted_n", "lat_sum")


@pytest.fixture(scope="module")
def fht16():
    return build_routing(T.build("folded_hexa_torus", 16))


@pytest.fixture(scope="module")
def mesh16():
    return build_routing(T.build("mesh", 16))


# ---------------------------------------------------------------------
# acceptance: static equivalence + padding invariance
# ---------------------------------------------------------------------

def test_single_uniform_phase_bitwise_equals_static(fht16):
    """THE acceptance criterion: one uniform unit-intensity phase ==
    the static simulator, counter for counter, bit for bit."""
    u = TR.uniform(fht16.topo)
    spec = make_spec(fht16, u)
    rates = np.array([0.05, 0.2, 0.6], np.float32)[None, :]
    static = run_batch([spec], rates, CFG)[0]
    sched = W.static_schedule(u, CFG.cycles).compile()
    wl = run_batch([spec], rates, CFG, schedules=[sched])[0]
    for k in RAW:
        np.testing.assert_array_equal(static[k], wl[k], err_msg=k)
    np.testing.assert_array_equal(static["throughput"], wl["throughput"])
    np.testing.assert_array_equal(static["latency"], wl["latency"])
    # the single phase carries all delivery
    np.testing.assert_array_equal(wl["delivered_ph"][:, 0],
                                  wl["delivered"])


def test_workload_batch_padding_invariance(fht16, mesh16):
    """Heterogeneous (spec, schedule) pairs padded into one program are
    bitwise-equal to each pair run alone — phase pointer, per-phase
    counters and all."""
    pairs = []
    for r in (mesh16, fht16):
        u, t = TR.uniform(r.topo), TR.tornado(r.topo)
        sched = W.Schedule([W.Phase(u, 1.0, 120),
                            W.Phase(t, 0.8, 180, 10, 30)]).compile()
        pairs.append((make_spec(r, u), sched))
    # a third pair with a different phase count forces K padding
    r = build_routing(T.build("honeycomb_mesh", 16))
    u = TR.uniform(r.topo)
    pairs.append((make_spec(r, u),
                  W.static_schedule(u, CFG.cycles).compile()))
    specs = [p[0] for p in pairs]
    scheds = [p[1] for p in pairs]
    rates = np.array([0.1, 0.4], np.float32)
    batched = run_batch(specs, rates, CFG, schedules=scheds)
    for (spec, sched), b in zip(pairs, batched):
        single = run_batch([spec], rates[None, :], CFG,
                           schedules=[sched])[0]
        for k in RAW + ("delivered_ph", "offered_ph", "accepted_ph",
                        "lat_sum_ph"):
            np.testing.assert_array_equal(single[k], b[k], err_msg=k)


def test_phase_axis_padding_is_inert(fht16):
    u, t = TR.uniform(fht16.topo), TR.tornado(fht16.topo)
    spec = make_spec(fht16, u)
    sched = W.Schedule([W.Phase(u, 1.0, 150),
                        W.Phase(t, 0.5, 150)]).compile()
    rates = np.array([0.3], np.float32)[None, :]
    a = run_batch([spec], rates, CFG, schedules=[sched])[0]
    b = run_batch([spec], rates, CFG, schedules=[sched], k_pad=7)[0]
    for k in RAW + ("delivered_ph", "lat_sum_ph"):
        np.testing.assert_array_equal(a[k], b[k], err_msg=k)


# ---------------------------------------------------------------------
# phase semantics
# ---------------------------------------------------------------------

def test_phase_counters_partition_totals(fht16):
    u, t = TR.uniform(fht16.topo), TR.tornado(fht16.topo)
    spec = make_spec(fht16, u)
    sched = W.Schedule([W.Phase(u, 1.0, 100), W.Phase(t, 0.7, 100),
                        W.Phase(u, 0.4, 100)]).compile()
    res = run_batch([spec], np.array([[0.2, 0.8]], np.float32), CFG,
                    schedules=[sched])[0]
    for ph_key, tot_key in (("delivered_ph", "delivered"),
                            ("offered_ph", "offered_n"),
                            ("accepted_ph", "accepted_n"),
                            ("lat_sum_ph", "lat_sum")):
        np.testing.assert_array_equal(res[ph_key].sum(axis=1),
                                      res[tot_key], err_msg=ph_key)
    assert phase_measured_cycles(sched, CFG).sum() == \
        CFG.cycles - CFG.warmup


def test_zero_intensity_phase_offers_nothing(fht16):
    u = TR.uniform(fht16.topo)
    spec = make_spec(fht16, u)
    sched = W.Schedule([W.Phase(u, 1.0, 150),
                        W.Phase(u, 0.0, 150)]).compile()
    res = run_batch([spec], np.array([[0.5]], np.float32), CFG,
                    schedules=[sched])[0]
    assert res["offered_ph"][0, 0] > 0
    assert res["offered_ph"][0, 1] == 0     # exact: gain 0 gates injection
    assert res["accepted_ph"][0, 1] == 0


def test_burst_modulation_preserves_mean_and_bursts(fht16):
    """ON/OFF modulation: same mean offered load as unmodulated, but
    injection happens only inside ON windows."""
    u = TR.uniform(fht16.topo)
    spec = make_spec(fht16, u)
    cfg = SimConfig(cycles=1200, warmup=200)
    rate = np.array([[0.2]], np.float32)
    plain = run_batch([spec], rate, cfg, schedules=[
        W.static_schedule(u, cfg.cycles).compile()])[0]
    burst = run_batch([spec], rate, cfg, schedules=[W.Schedule(
        [W.Phase(u, 1.0, cfg.cycles, burst_on=20, burst_off=20)]
    ).compile()])[0]
    assert burst["offered_n"][0] == pytest.approx(plain["offered_n"][0],
                                                  rel=0.15)
    # extreme bursts (gain > 1 inside ON) must cap at the rate ceiling:
    # offered can never exceed one flit per node per ON cycle
    assert burst["offered_n"][0] <= spec.n * (cfg.cycles - cfg.warmup)


def test_schedule_replays_cyclically(fht16):
    """A schedule shorter than the simulation wraps: phase 0 of the
    second replay sees the same traffic as the first."""
    u = TR.uniform(fht16.topo)
    spec = make_spec(fht16, u)
    short = W.Schedule([W.Phase(u, 1.0, 90), W.Phase(u, 0.0, 30)])
    res = run_batch([spec], np.array([[0.3]], np.float32), CFG,
                    schedules=[short.compile()])[0]
    cyc = phase_measured_cycles(short.compile(), CFG)
    assert cyc.sum() == CFG.cycles - CFG.warmup
    assert cyc[0] > 90        # phase 0 measured across >1 replay
    assert res["offered_ph"][0, 0] > 0


def test_schedule_fit_is_exact():
    topo = T.build("mesh", 16)
    s = W.phase_alternating(topo, phase_cycles=333, repeats=1)
    for target in (200, 1000, 777):
        f = s.fit(target)
        assert f.total_cycles == target
        assert len(f.phases) == len(s.phases)
    # many 1-cycle phases: the negative rounding residual exceeds any
    # single phase's slack and must be spread across phases
    u = TR.uniform(topo)
    tiny = W.Schedule([W.Phase(u, 1.0, 1) for _ in range(10)])
    f = tiny.fit(15)
    assert f.total_cycles == 15
    assert min(p.duration for p in f.phases) >= 1
    with pytest.raises(ValueError):
        tiny.fit(9)


# ---------------------------------------------------------------------
# generators
# ---------------------------------------------------------------------

def test_mesh_groups_partition_and_are_contiguous():
    topo = T.build("mesh", 16)
    shape = {"data": 4, "model": 4}
    coords = mesh_coords(topo, shape)
    assert sorted(coords) == ["data", "model"]
    for axis in shape:
        groups = mesh_axis_groups(topo, shape, axis)
        flat = sorted(i for g in groups for i in g)
        assert flat == list(range(16))          # partition
        assert all(len(g) == 4 for g in groups)
    # model groups are physically contiguous runs along x
    for g in mesh_axis_groups(topo, shape, "model"):
        ys = topo.pos[g, 1]
        assert np.ptp(ys) == 0
        assert (np.diff(topo.pos[g, 0]) > 0).all()


def test_collective_flow_conserves_payload():
    groups = [[0, 1, 2, 3], [4, 5, 6, 7]]
    for kind, factor in (("all_reduce", 2 * 3 / 4), ("all_gather", 3 / 4),
                         ("reduce_scatter", 3 / 4),
                         ("collective_permute", 1.0), ("all_to_all", 3 / 4)):
        m = collective_flow(8, kind, groups, 100.0)
        assert m.shape == (8, 8) and (np.diag(m) == 0).all()
        np.testing.assert_allclose(m.sum(axis=1), 100.0 * factor)
    with pytest.raises(KeyError):
        collective_flow(8, "broadcastish", groups, 1.0)


def test_collective_workload_phases(fht16):
    cfg = get_config("qwen3_1_7b")
    sched = W.collective_workload(cfg, fht16.topo, step_cycles=800)
    labels = [p.label for p in sched.phases]
    assert labels == ["fsdp_gather", "fwd_tp", "bwd_tp", "grad_reduce"]
    assert max(p.intensity for p in sched.phases) == 1.0
    for p in sched.phases:
        m = np.asarray(p.traffic)
        assert (m >= 0).all() and np.abs(np.diag(m)).max() == 0
        assert p.duration >= 1
    # MoE archs add the all-to-all phase
    moe = W.collective_workload(get_config("qwen3_moe_235b_a22b"),
                                fht16.topo)
    assert "moe_a2a" in [p.label for p in moe.phases]
    # and the whole thing simulates
    spec = make_spec(fht16, sched.mean_traffic())
    res = run_batch([spec], np.array([[0.3]], np.float32), CFG,
                    schedules=[sched.fit(CFG.cycles - CFG.warmup)
                               .compile()])[0]
    assert res["delivered"][0] > 0


def test_trace_roundtrip_and_workload(tmp_path, fht16):
    tr = W.builtin_traces(region_cycles=100)["fluidanimate"]
    path = str(tmp_path / "t.json")
    tr.save(path)
    tr2 = W.load_trace(path)
    assert tr2.name == tr.name and tr2.regions == tr.regions
    topo = T.build("folded_hexa_torus", 16, roles_scheme="hetero_cmi")
    sched = W.trace_workload(topo, path)
    assert len(sched.phases) == 5
    assert sched.phases[0].burst_on == 25    # fluidanimate memory waves
    # intensities come straight from the legacy profile
    legacy = [i for i, _ in TR.TRACE_PROFILES["fluidanimate"]]
    assert [p.intensity for p in sched.phases] == legacy


def test_synthetic_generators(fht16):
    topo = fht16.topo
    alt = W.phase_alternating(topo, repeats=1)
    assert [p.label for p in alt.phases] == ["tornado", "uniform"]
    hot = W.hotspot_drift(topo, n_phases=3, seed=1)
    assert len(hot.phases) == 3
    for p in hot.phases:
        assert np.abs(np.diag(p.traffic)).max() == 0
    assert W.bursty_uniform(topo).phases[0].burst_off == 60


# ---------------------------------------------------------------------
# engine batching
# ---------------------------------------------------------------------

def test_engine_run_workloads_matches_singles(fht16, mesh16):
    rates = np.array([0.1, 0.35], np.float32)
    specs, scheds = [], []
    for r in (mesh16, fht16):
        u = TR.uniform(r.topo)
        specs.append(make_spec(r, u))
        scheds.append(W.phase_alternating(r.topo, phase_cycles=100,
                                          repeats=1).compile())
    eng = SweepEngine(cfg=CFG)
    out = eng.run_workloads(specs, scheds, rates)
    for spec, sched, got in zip(specs, scheds, out):
        single = run_batch([spec], rates[None, :], CFG,
                           schedules=[sched])[0]
        for k in RAW + ("delivered_ph", "lat_sum_ph"):
            np.testing.assert_array_equal(single[k], got[k], err_msg=k)
        np.testing.assert_array_equal(single["phase_cycles"],
                                      got["phase_cycles"])
    # same shapes again -> no new compilation
    before = eng.stats["compiles"]
    eng.run_workloads(specs, scheds, rates)
    assert eng.stats["compiles"] == before


def test_engine_workload_cases_grid():
    cases = [SweepCase("mesh", 16, roles="hetero_cmi"),
             SweepCase("hypercube", 15),     # invalid N
             SweepCase("folded_hexa_torus", 16, "glass",
                       roles="hetero_cmi")]
    workloads = [W.Workload("alt", lambda t: W.phase_alternating(
                     t, phase_cycles=60, repeats=1)),
                 W.Workload("trace", lambda t: W.trace_workload(
                     t, "blackscholes", region_cycles=40))]
    eng = SweepEngine(cfg=CFG)
    grid = eng.evaluate_workload_cases(cases, workloads, n_rates=3)
    assert len(grid) == 6
    assert grid[2] is None and grid[3] is None
    for row in (grid[0], grid[1], grid[4], grid[5]):
        assert row["sim_saturation"] > 0
        assert len(row["phase_labels"]) == len(row["throughput_ph"])
        # fitted: one replay covers the measurement window exactly
        assert row["phase_cycles"].sum() == CFG.cycles - CFG.warmup
    assert grid[0]["workload"].startswith("alt")
    assert grid[1]["workload"].startswith("trace:")
