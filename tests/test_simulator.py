"""Cycle-accurate simulator tests: conservation, plateau behaviour,
consistency with the analytic channel-load bound."""
import numpy as np
import pytest

from repro.core import topology as T, traffic as TR
from repro.core.routing import build_routing
from repro.core.simulator import SimConfig, simulate, \
    saturation_throughput, zero_load_latency

CFG = SimConfig(cycles=1500, warmup=500)


@pytest.fixture(scope="module")
def fht16():
    topo = T.build("folded_hexa_torus", 16)
    return build_routing(topo)


def test_low_load_delivery(fht16):
    """At 10 % of saturation, delivered == offered (no loss)."""
    u = TR.uniform(fht16.topo)
    res = simulate(fht16, u, [0.05], CFG)
    assert res["throughput"][0] >= 0.85 * res["offered"][0]


def test_throughput_plateaus(fht16):
    u = TR.uniform(fht16.topo)
    res = simulate(fht16, u, [0.2, 0.5, 0.9, 1.0], CFG)
    thr = res["throughput"]
    # monotone-ish up to plateau; last two within 15 %
    assert thr[1] > thr[0]
    assert abs(thr[3] - thr[2]) < 0.15 * max(thr[2], 1e-6)


def test_sim_below_analytic_bound(fht16):
    """The analytic channel-load rate is an upper bound for the sim."""
    u = TR.uniform(fht16.topo)
    out = saturation_throughput(fht16, u, CFG, n_rates=5)
    assert out["sim_saturation"] <= out["analytic_saturation"] * 1.1
    assert out["sim_saturation"] >= 0.4 * out["analytic_saturation"]


def test_latency_grows_with_load(fht16):
    u = TR.uniform(fht16.topo)
    res = simulate(fht16, u, [0.1, 1.0], CFG)
    assert res["latency"][1] > res["latency"][0]


def test_zero_load_latency_close_to_sim(fht16):
    """Sim latency at very low load ~ analytic zero-load latency."""
    u = TR.uniform(fht16.topo)
    zl = zero_load_latency(fht16, u)
    res = simulate(fht16, u, [0.02], CFG)
    assert res["latency"][0] == pytest.approx(zl, rel=0.35)


def test_mesh_vs_fht_simulated():
    """Fig. 4: FHT sustains higher simulated throughput than Mesh.

    (N=16 is the paper's smallest, tightest-margin point — Fig. 7 even
    shows other topologies edging FHT there; we assert strictly higher.)"""
    out = {}
    for name in ("mesh", "folded_hexa_torus"):
        r = build_routing(T.build(name, 16))
        out[name] = saturation_throughput(
            r, TR.uniform(r.topo), CFG, n_rates=5)["sim_saturation"]
    assert out["folded_hexa_torus"] > 1.05 * out["mesh"]


def test_hetero_traffic_runs():
    topo = T.build("folded_hexa_torus", 16, roles_scheme="hetero_cm")
    r = build_routing(topo)
    m = TR.hetero_mix(topo)
    res = simulate(r, m, [0.2], CFG)
    assert res["throughput"][0] > 0
