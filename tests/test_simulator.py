"""Cycle-accurate simulator tests: conservation, plateau behaviour,
consistency with the analytic channel-load bound."""
import numpy as np
import pytest

from repro.core import topology as T, traffic as TR
from repro.core.routing import build_routing
from repro.core.simulator import SimConfig, simulate, \
    saturation_throughput, zero_load_latency

CFG = SimConfig(cycles=1500, warmup=500)


@pytest.fixture(scope="module")
def fht16():
    topo = T.build("folded_hexa_torus", 16)
    return build_routing(topo)


def test_low_load_delivery(fht16):
    """At 10 % of saturation, delivered == offered (no loss)."""
    u = TR.uniform(fht16.topo)
    res = simulate(fht16, u, [0.05], CFG)
    assert res["throughput"][0] >= 0.85 * res["offered"][0]


def test_throughput_plateaus(fht16):
    u = TR.uniform(fht16.topo)
    res = simulate(fht16, u, [0.2, 0.5, 0.9, 1.0], CFG)
    thr = res["throughput"]
    # monotone-ish up to plateau; last two within 15 %
    assert thr[1] > thr[0]
    assert abs(thr[3] - thr[2]) < 0.15 * max(thr[2], 1e-6)


def test_sim_below_analytic_bound(fht16):
    """The analytic channel-load rate is an upper bound for the sim."""
    u = TR.uniform(fht16.topo)
    out = saturation_throughput(fht16, u, CFG, n_rates=5)
    assert out["sim_saturation"] <= out["analytic_saturation"] * 1.1
    assert out["sim_saturation"] >= 0.4 * out["analytic_saturation"]


def test_latency_grows_with_load(fht16):
    u = TR.uniform(fht16.topo)
    res = simulate(fht16, u, [0.1, 1.0], CFG)
    assert res["latency"][1] > res["latency"][0]


def test_zero_load_latency_close_to_sim(fht16):
    """Sim latency at very low load ~ analytic zero-load latency."""
    u = TR.uniform(fht16.topo)
    zl = zero_load_latency(fht16, u)
    res = simulate(fht16, u, [0.02], CFG)
    assert res["latency"][0] == pytest.approx(zl, rel=0.35)


def test_mesh_vs_fht_simulated():
    """Fig. 4: FHT sustains higher simulated throughput than Mesh.

    (N=16 is the paper's smallest, tightest-margin point — Fig. 7 even
    shows other topologies edging FHT there; we assert strictly higher.)"""
    out = {}
    for name in ("mesh", "folded_hexa_torus"):
        r = build_routing(T.build(name, 16))
        out[name] = saturation_throughput(
            r, TR.uniform(r.topo), CFG, n_rates=5)["sim_saturation"]
    assert out["folded_hexa_torus"] > 1.05 * out["mesh"]


def test_hetero_traffic_runs():
    topo = T.build("folded_hexa_torus", 16, roles_scheme="hetero_cm")
    r = build_routing(topo)
    m = TR.hetero_mix(topo)
    res = simulate(r, m, [0.2], CFG)
    assert res["throughput"][0] > 0


# ---------------------------------------------------------------------
# bitwise pin: routing="static" vs the pre-adaptive simulator
# (DESIGN.md §15).  The counters below were captured from the simulator
# BEFORE the adaptive-routing branch existed; any drift in the static
# path — across plain, workload, telemetry-on and faulted configs —
# fails here with the exact counter that moved.
# ---------------------------------------------------------------------

_PIN_CFG = SimConfig(cycles=300, warmup=100)
_PIN_RAW = ("delivered", "offered_n", "accepted_n", "lat_sum")
_PIN_RATES = np.array([0.05, 0.2, 0.6], np.float32)

GOLDEN = {
    'static:mesh16': {'delivered': [165, 669, 1119], 'offered_n': [161, 653, 1948], 'accepted_n': [161, 653, 1186], 'lat_sum': [3497, 14991, 64092]},
    'static:fht36': {'delivered': [359, 1424, 3536], 'offered_n': [363, 1442, 4393], 'accepted_n': [363, 1442, 3690], 'lat_sum': [7406, 29483, 122185]},
    'workload:fht16_drift': {'delivered': [156, 621, 705], 'offered_n': [155, 634, 1874], 'accepted_n': [155, 618, 718], 'lat_sum': [2171, 26593, 57100], 'delivered_ph': [0, 73, 83, 0, 304, 317, 0, 281, 424]},
    'telemetry:fht16': {'delivered': [163, 654, 1950], 'offered_n': [161, 653, 1948], 'accepted_n': [161, 653, 1935], 'lat_sum': [2240, 9071, 32384]},
    'telemetry:fht16:tel': {'link_busy': 4787, 'link_stall': 929, 'inj_node': 2749, 'eject_node': 2767},
    'faulted:mesh16_k2': {'delivered': [164, 666, 964], 'offered_n': [161, 653, 1948], 'accepted_n': [161, 653, 990], 'lat_sum': [3695, 16056, 66809]},
}


def _pin_check(tag, res, keys=_PIN_RAW):
    for k in keys:
        got = [int(x) for x in np.asarray(res[k]).ravel()]
        assert got == GOLDEN[tag][k], f"{tag}/{k}: {got} != {GOLDEN[tag][k]}"


def test_static_pin_plain():
    from repro.core.simulator import make_spec, run_batch
    mesh = build_routing(T.build("mesh", 16))
    fht = build_routing(T.build("folded_hexa_torus", 36))
    specs = [make_spec(mesh, TR.uniform(mesh.topo)),
             make_spec(fht, TR.uniform(fht.topo))]
    res = run_batch(specs, _PIN_RATES, _PIN_CFG)
    _pin_check("static:mesh16", res[0])
    _pin_check("static:fht36", res[1])


def test_static_pin_workload():
    import repro.workloads as W
    from repro.core.simulator import make_spec, run_batch
    fht16 = build_routing(T.build("folded_hexa_torus", 16))
    sched = W.hotspot_drift(fht16.topo, n_phases=3, dwell=100,
                            seed=1).fit(_PIN_CFG.cycles).compile()
    spec = make_spec(fht16, TR.uniform(fht16.topo))
    res = run_batch([spec], _PIN_RATES[None, :], _PIN_CFG,
                    schedules=[sched])[0]
    _pin_check("workload:fht16_drift", res, _PIN_RAW + ("delivered_ph",))


def test_static_pin_telemetry():
    from repro.core.simulator import make_spec, run_batch
    fht16 = build_routing(T.build("folded_hexa_torus", 16))
    spec = make_spec(fht16, TR.uniform(fht16.topo))
    res = run_batch([spec], _PIN_RATES[None, :],
                    _PIN_CFG._replace(telemetry=True))[0]
    _pin_check("telemetry:fht16", res)
    for k, want in GOLDEN["telemetry:fht16:tel"].items():
        assert int(np.asarray(res[k]).sum()) == want, k
    # the new escape/adaptive split is a pure host-side view of occ_sum
    occ = np.asarray(res["link_occ_sum"])
    assert np.array_equal(np.asarray(res["link_occ_escape"]),
                          occ[:, :, 0])
    assert np.array_equal(np.asarray(res["link_occ_adaptive"]),
                          occ[:, :, 1:].sum(axis=-1))


def test_static_pin_faulted():
    import repro.faults as F
    from repro.core.simulator import make_spec, run_batch
    mesh = build_routing(T.build("mesh", 16))
    fs = F.sample_faults(mesh.topo, 2, "random", seed=3)
    rdeg = build_routing(fs.apply(mesh.topo))
    spec = make_spec(rdeg, fs.mask_traffic(TR.uniform(mesh.topo)))
    res = run_batch([spec], _PIN_RATES[None, :], _PIN_CFG)[0]
    _pin_check("faulted:mesh16_k2", res)


# ---------------------------------------------------------------------
# adaptive mode (DESIGN.md §15)
# ---------------------------------------------------------------------

def test_adaptive_runs_and_conserves():
    """Adaptive mode delivers traffic and obeys flit conservation."""
    from repro.core.simulator import make_spec, run_batch
    r = build_routing(T.build("mesh", 16))
    spec = make_spec(r, TR.uniform(r.topo))
    cfg = _PIN_CFG._replace(routing="adaptive")
    res = run_batch([spec], _PIN_RATES[None, :], cfg)[0]
    d = np.asarray(res["delivered"])
    a = np.asarray(res["accepted_n"])
    o = np.asarray(res["offered_n"])
    assert (d > 0).all()
    # conservation up to warmup in-flight drain: the measured window can
    # deliver flits accepted during warmup, but never more than the
    # network could plausibly hold (node buffers at every node)
    slack = spec.n * cfg.n_vcs * cfg.buf_depth
    assert (d <= a + slack).all()
    assert (a <= o).all()            # acceptance never exceeds offers


def test_adaptive_rejects_single_vc():
    from repro.core.simulator import make_spec, run_batch
    r = build_routing(T.build("mesh", 16))
    spec = make_spec(r, TR.uniform(r.topo))
    cfg = _PIN_CFG._replace(routing="adaptive", n_vcs=1)
    with pytest.raises(ValueError, match="n_vcs"):
        run_batch([spec], _PIN_RATES[None, :], cfg)


def test_unknown_routing_mode_rejected():
    from repro.core.simulator import make_spec, run_batch
    r = build_routing(T.build("mesh", 16))
    spec = make_spec(r, TR.uniform(r.topo))
    with pytest.raises(ValueError, match="routing"):
        run_batch([spec], _PIN_RATES[None, :],
                  _PIN_CFG._replace(routing="exotic"))


def test_rate_grid_headroom():
    """Satellite regression: adaptive grids extend past the analytic
    bound, static grids are bitwise-unchanged from the historical 2x."""
    from repro.core.simulator import (ADAPTIVE_HEADROOM, STATIC_HEADROOM,
                                      routing_headroom,
                                      saturation_rate_grid)
    analytic = 0.31
    legacy = np.linspace(max(analytic * 0.25, 1e-3),
                         min(1.0, 2.0 * analytic), 8)
    assert np.array_equal(saturation_rate_grid(analytic), legacy)
    assert np.array_equal(
        saturation_rate_grid(analytic, headroom=STATIC_HEADROOM), legacy)
    ad = saturation_rate_grid(analytic, headroom=ADAPTIVE_HEADROOM)
    assert ad[-1] > analytic and ad[-1] > legacy[-1]
    assert routing_headroom("adaptive") == ADAPTIVE_HEADROOM
    assert routing_headroom("static") == STATIC_HEADROOM
    # the ceiling still clips at 1.0 flits/node/cycle
    assert saturation_rate_grid(0.9, headroom=3.0)[-1] == 1.0


def test_adaptive_beats_static_on_hotspot_drift():
    """The headline claim (ISSUE acceptance): minimal-adaptive routing
    outruns static table routing on the drifting-hotspot schedule for
    the mesh family."""
    import repro.workloads as W
    from repro.core.simulator import make_spec, run_batch
    cfg = SimConfig(cycles=1000, warmup=300)
    r = build_routing(T.build("mesh", 36))
    spec = make_spec(r, TR.uniform(r.topo))
    sched = W.hotspot_drift(r.topo, n_phases=4, dwell=250,
                            seed=2).fit(cfg.cycles).compile()
    rr = np.linspace(0.05, 0.9, 8).astype(np.float32)[None, :]
    st = run_batch([spec], rr, cfg, schedules=[sched])[0]
    ad = run_batch([spec], rr, cfg._replace(routing="adaptive"),
                   schedules=[sched])[0]
    s = float(np.max(np.asarray(st["throughput"])))
    a = float(np.max(np.asarray(ad["throughput"])))
    assert a > 1.05 * s, f"adaptive {a:.4f} should beat static {s:.4f}"
