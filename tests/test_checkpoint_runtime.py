"""Checkpoint (atomicity, retention, async, elastic restore) and
fault-tolerance primitive tests."""
import os
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import (AsyncCheckpointer, latest_step,
                              restore_checkpoint, save_checkpoint)
from repro.runtime import StepWatchdog, Heartbeat, elastic_batch, retry


def _tree(seed=0):
    rng = np.random.default_rng(seed)
    return {"w": jnp.asarray(rng.normal(size=(8, 4)), jnp.float32),
            "opt": {"m": jnp.zeros((8, 4)), "step": jnp.int32(7)},
            "blocks": [jnp.ones((2, 3)), jnp.arange(5)]}


def test_roundtrip(tmp_path):
    d = str(tmp_path / "ck")
    t = _tree()
    save_checkpoint(d, 10, t)
    assert latest_step(d) == 10
    got = restore_checkpoint(d, 10, jax.tree.map(jnp.zeros_like, t))
    for a, b in zip(jax.tree.leaves(t), jax.tree.leaves(got)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_retention_and_latest(tmp_path):
    d = str(tmp_path / "ck")
    t = _tree()
    for s in (1, 2, 3, 4, 5):
        save_checkpoint(d, s, t, keep=2)
    steps = sorted(x for x in os.listdir(d) if x.startswith("step_"))
    assert len(steps) == 2
    assert latest_step(d) == 5


def test_async_checkpointer(tmp_path):
    d = str(tmp_path / "ck")
    ck = AsyncCheckpointer(d)
    t = _tree()
    ck.save(3, t)
    ck.wait()
    assert latest_step(d) == 3
    got = restore_checkpoint(d, 3, jax.tree.map(jnp.zeros_like, t))
    np.testing.assert_array_equal(np.asarray(got["w"]),
                                  np.asarray(t["w"]))


def test_elastic_restore_new_sharding(tmp_path):
    """Restore under a (trivially) different mesh placement."""
    from jax.sharding import NamedSharding, PartitionSpec as P
    d = str(tmp_path / "ck")
    t = _tree()
    save_checkpoint(d, 1, t)
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    shards = jax.tree.map(
        lambda x: NamedSharding(mesh, P(*([None] * jnp.ndim(x)))), t)
    got = restore_checkpoint(d, 1, t, shardings=shards)
    assert got["w"].sharding.mesh.shape["data"] == 1
    np.testing.assert_array_equal(np.asarray(got["w"]), np.asarray(t["w"]))


def test_tmp_dirs_not_trusted(tmp_path):
    d = str(tmp_path / "ck")
    t = _tree()
    save_checkpoint(d, 1, t)
    os.makedirs(os.path.join(d, "step_00000099.tmp0"))
    assert latest_step(d) == 1


def test_watchdog_flags_straggler():
    wd = StepWatchdog(window=16, factor=2.0)
    for _ in range(10):
        assert not wd.observe(1.0)
    assert wd.observe(5.0)
    assert wd.flagged == 1


def test_heartbeat(tmp_path):
    p = str(tmp_path / "hb.json")
    hb = Heartbeat(p, interval_s=100)
    hb.beat({"step": 5})
    import json
    with open(p) as f:
        data = json.load(f)
    assert data["step"] == 5
    hb.stop()


def test_elastic_batch():
    per, scale = elastic_batch(256, 16)
    assert per == 16 and scale == 1.0
    per, scale = elastic_batch(256, 12)   # lost 4 hosts
    assert per == 22 and scale == pytest.approx(264 / 256)


def test_retry():
    calls = []

    def flaky():
        calls.append(1)
        if len(calls) < 3:
            raise RuntimeError("transient")
        return "ok"

    assert retry(flaky, retries=4, backoff_s=0.01)() == "ok"
    assert len(calls) == 3
