"""Checkpoint (atomicity, retention, async, elastic restore) and
fault-tolerance primitive tests."""
import os
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import (AsyncCheckpointer, latest_step,
                              restore_checkpoint, save_checkpoint)
from repro.runtime import StepWatchdog, Heartbeat, elastic_batch, retry


def _tree(seed=0):
    rng = np.random.default_rng(seed)
    return {"w": jnp.asarray(rng.normal(size=(8, 4)), jnp.float32),
            "opt": {"m": jnp.zeros((8, 4)), "step": jnp.int32(7)},
            "blocks": [jnp.ones((2, 3)), jnp.arange(5)]}


def test_roundtrip(tmp_path):
    d = str(tmp_path / "ck")
    t = _tree()
    save_checkpoint(d, 10, t)
    assert latest_step(d) == 10
    got = restore_checkpoint(d, 10, jax.tree.map(jnp.zeros_like, t))
    for a, b in zip(jax.tree.leaves(t), jax.tree.leaves(got)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_retention_and_latest(tmp_path):
    d = str(tmp_path / "ck")
    t = _tree()
    for s in (1, 2, 3, 4, 5):
        save_checkpoint(d, s, t, keep=2)
    steps = sorted(x for x in os.listdir(d) if x.startswith("step_"))
    assert len(steps) == 2
    assert latest_step(d) == 5


def test_async_checkpointer(tmp_path):
    d = str(tmp_path / "ck")
    ck = AsyncCheckpointer(d)
    t = _tree()
    ck.save(3, t)
    ck.wait()
    assert latest_step(d) == 3
    got = restore_checkpoint(d, 3, jax.tree.map(jnp.zeros_like, t))
    np.testing.assert_array_equal(np.asarray(got["w"]),
                                  np.asarray(t["w"]))


def test_elastic_restore_new_sharding(tmp_path):
    """Restore under a (trivially) different mesh placement."""
    from jax.sharding import NamedSharding, PartitionSpec as P
    d = str(tmp_path / "ck")
    t = _tree()
    save_checkpoint(d, 1, t)
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    shards = jax.tree.map(
        lambda x: NamedSharding(mesh, P(*([None] * jnp.ndim(x)))), t)
    got = restore_checkpoint(d, 1, t, shardings=shards)
    assert got["w"].sharding.mesh.shape["data"] == 1
    np.testing.assert_array_equal(np.asarray(got["w"]), np.asarray(t["w"]))


def test_tmp_dirs_not_trusted(tmp_path):
    d = str(tmp_path / "ck")
    t = _tree()
    save_checkpoint(d, 1, t)
    os.makedirs(os.path.join(d, "step_00000099.tmp0"))
    assert latest_step(d) == 1


def test_watchdog_flags_straggler():
    wd = StepWatchdog(window=16, factor=2.0)
    for _ in range(10):
        assert not wd.observe(1.0)
    assert wd.observe(5.0)
    assert wd.flagged == 1


def test_heartbeat(tmp_path):
    p = str(tmp_path / "hb.json")
    hb = Heartbeat(p, interval_s=100)
    hb.beat({"step": 5})
    import json
    with open(p) as f:
        data = json.load(f)
    assert data["step"] == 5
    hb.stop()


def test_elastic_batch():
    per, scale = elastic_batch(256, 16)
    assert per == 16 and scale == 1.0
    per, scale = elastic_batch(256, 12)   # lost 4 hosts
    assert per == 22 and scale == pytest.approx(264 / 256)


def test_retry():
    calls = []

    def flaky():
        calls.append(1)
        if len(calls) < 3:
            raise RuntimeError("transient")
        return "ok"

    assert retry(flaky, retries=4, backoff_s=0.01)() == "ok"
    assert len(calls) == 3


def test_retry_backoff_sequence(monkeypatch):
    """Delays follow exact exponential doubling from backoff_s, one
    sleep per failed attempt, none after the final raise."""
    from repro.runtime import fault as rf
    slept = []
    monkeypatch.setattr(rf.time, "sleep", slept.append)
    calls = []

    def always_fails():
        calls.append(1)
        raise OSError("transient")

    with pytest.raises(OSError):
        retry(always_fails, retries=3, backoff_s=0.5)()
    assert calls == [1, 1, 1, 1]              # initial + 3 retries
    assert slept == [0.5, 1.0, 2.0]           # no sleep after last raise


def test_retry_exception_filtering(monkeypatch):
    """Exceptions outside `on` propagate immediately: no retry, no
    sleep."""
    from repro.runtime import fault as rf
    slept = []
    monkeypatch.setattr(rf.time, "sleep", slept.append)
    calls = []

    def wrong_kind():
        calls.append(1)
        raise ValueError("a bug, not a transient")

    with pytest.raises(ValueError):
        retry(wrong_kind, retries=5, backoff_s=0.1)()
    assert calls == [1] and slept == []
    # ...and a custom `on` widens the net
    calls.clear()

    def flaky_value():
        calls.append(1)
        if len(calls) < 2:
            raise ValueError("transient here")
        return "ok"

    assert retry(flaky_value, retries=2, backoff_s=0.1,
                 on=(ValueError,))() == "ok"
    assert slept == [0.1]


def test_watchdog_factor_boundary():
    """Flagging is strict: step == factor x median is NOT slow, just
    above is; and nothing is flagged before 8 observations."""
    warm = StepWatchdog(window=16, factor=2.5)
    for _ in range(7):
        assert not warm.observe(100.0)        # < 8 samples: never slow
    assert warm.flagged == 0

    wd = StepWatchdog(window=16, factor=2.5)
    for _ in range(8):
        wd.observe(1.0)                       # window: 8 x 1.0, median 1.0
    assert not wd.observe(2.5)                # exactly factor x median
    assert wd.flagged == 0
    assert wd.observe(2.5 + 1e-9)             # just above
    assert wd.flagged == 1


def test_watchdog_uses_rolling_window():
    """Old samples age out of the deque: a regime change re-baselines
    the median instead of flagging forever."""
    wd = StepWatchdog(window=8, factor=2.0)
    for _ in range(8):
        wd.observe(1.0)
    assert wd.observe(10.0)                   # slow vs the 1.0 regime
    for _ in range(8):
        wd.observe(10.0)                      # window now all 10.0
    assert not wd.observe(10.0)               # re-baselined


def test_heartbeat_lifecycle_and_atomicity(tmp_path):
    p = str(tmp_path / "sub" / "hb.json")
    hb = Heartbeat(p, interval_s=100)
    assert hb.start() is hb                   # chainable; beats at start
    import json
    with open(p) as f:
        data = json.load(f)
    assert data["pid"] == os.getpid() and data["time"] <= time.time()
    hb.beat({"step": 12})
    with open(p) as f:
        assert json.load(f)["step"] == 12
    assert not os.path.exists(p + ".tmp")     # atomic tmp+replace
    hb.stop()
    hb._thread.join(timeout=5)
    assert not hb._thread.is_alive()
