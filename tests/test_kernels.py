"""Pallas kernel validation (interpret mode on CPU): shape/dtype sweeps
vs the pure-jnp oracles, plus allocation invariants for netstep."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.kernels.flash_attention import ops as fa_ops
from repro.kernels.flash_attention.ref import attention_ref
from repro.kernels.flash_attention.flash_attention import \
    flash_attention_bhsd
from repro.kernels.ssd_scan.ssd_scan import ssd_scan_pallas
from repro.kernels.ssd_scan.ref import ssd_ref, ssd_naive
from repro.kernels.netstep.netstep import netstep_pallas
from repro.kernels.netstep.ref import netstep_ref
from repro.models.ssm import ssd_chunked_core


# ---------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------

@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("tq,tk,causal,window", [
    (128, 128, True, None),
    (256, 256, True, None),
    (128, 256, False, None),
    (256, 256, True, 128),
    (128, 128, True, 64),
])
def test_flash_attention_matches_ref(tq, tk, causal, window, dtype):
    rng = np.random.default_rng(0)
    bh, hd = 3, 128
    q = jnp.asarray(rng.normal(0, 1, (bh, tq, hd)), dtype)
    k = jnp.asarray(rng.normal(0, 1, (bh, tk, hd)), dtype)
    v = jnp.asarray(rng.normal(0, 1, (bh, tk, hd)), dtype)
    got = flash_attention_bhsd(q, k, v, causal=causal, window=window,
                               interpret=True)
    want = attention_ref(q, k, v, causal=causal, window=window)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               atol=tol, rtol=tol)


def test_flash_attention_gqa_wrapper():
    rng = np.random.default_rng(1)
    b, t, h, kv, hd = 2, 128, 4, 2, 128
    q = jnp.asarray(rng.normal(0, 1, (b, t, h, hd)), jnp.float32)
    k = jnp.asarray(rng.normal(0, 1, (b, t, kv, hd)), jnp.float32)
    v = jnp.asarray(rng.normal(0, 1, (b, t, kv, hd)), jnp.float32)
    got = fa_ops.flash_attention(q, k, v, causal=True)
    # oracle via broadcast + ref
    kr = jnp.repeat(k, h // kv, 2)
    vr = jnp.repeat(v, h // kv, 2)
    qb = q.transpose(0, 2, 1, 3).reshape(b * h, t, hd)
    kb = kr.transpose(0, 2, 1, 3).reshape(b * h, t, hd)
    vb = vr.transpose(0, 2, 1, 3).reshape(b * h, t, hd)
    want = attention_ref(qb, kb, vb, causal=True) \
        .reshape(b, h, t, hd).transpose(0, 2, 1, 3)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=3e-5, rtol=3e-5)


# ---------------------------------------------------------------------
# SSD scan
# ---------------------------------------------------------------------

def _ssd_inputs(rng, b, t, h, p, n, dtype=jnp.float32):
    return (jnp.asarray(rng.normal(0, 1, (b, t, h, p)), dtype),
            jnp.asarray(rng.uniform(0.05, 0.9, (b, t, h)), jnp.float32),
            -jnp.asarray(rng.uniform(0.3, 2.0, (h,)), jnp.float32),
            jnp.asarray(rng.normal(0, 1, (b, t, n)), dtype),
            jnp.asarray(rng.normal(0, 1, (b, t, n)), dtype))


def test_ssd_chunked_core_matches_naive():
    rng = np.random.default_rng(2)
    x, dt, a, bm, cm = _ssd_inputs(rng, 2, 32, 3, 4, 5)
    for chunk in (4, 8, 16, 32):
        y, s = ssd_chunked_core(x, dt, a, bm, cm, chunk)
        yn, sn = ssd_naive(x, dt, a, bm, cm)
        np.testing.assert_allclose(np.asarray(y), np.asarray(yn),
                                   atol=2e-5, rtol=2e-4)
        np.testing.assert_allclose(np.asarray(s), np.asarray(sn),
                                   atol=2e-5, rtol=2e-4)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("b,t,h,p,n,chunk", [
    (2, 64, 4, 8, 16, 16),
    (1, 128, 2, 16, 8, 32),
    (3, 32, 8, 4, 4, 8),
])
def test_ssd_kernel_matches_ref(b, t, h, p, n, chunk, dtype):
    rng = np.random.default_rng(3)
    x, dt, a, bm, cm = _ssd_inputs(rng, b, t, h, p, n, dtype)
    y, s = ssd_scan_pallas(x, dt, a, bm, cm, chunk=chunk, interpret=True)
    yr, sr = ssd_ref(x, dt, a, bm, cm, chunk)
    tol = 3e-2 if dtype == jnp.bfloat16 else 1e-4
    np.testing.assert_allclose(np.asarray(y, np.float32),
                               np.asarray(yr, np.float32),
                               atol=tol, rtol=tol)
    np.testing.assert_allclose(np.asarray(s), np.asarray(sr),
                               atol=tol, rtol=tol)


@given(seed=st.integers(0, 1000))
@settings(max_examples=8, deadline=None)
def test_ssd_kernel_property(seed):
    rng = np.random.default_rng(seed)
    b = int(rng.integers(1, 3))
    nc = int(rng.integers(1, 4))
    chunk = int(rng.choice([4, 8]))
    h, p, n = (int(rng.integers(1, 5)), int(rng.choice([4, 8])),
               int(rng.choice([4, 8])))
    x, dt, a, bm, cm = _ssd_inputs(rng, b, nc * chunk, h, p, n)
    y, s = ssd_scan_pallas(x, dt, a, bm, cm, chunk=chunk, interpret=True)
    yn, sn = ssd_naive(x, dt, a, bm, cm)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yn),
                               atol=1e-4, rtol=1e-3)


# ---------------------------------------------------------------------
# netstep (paper hot loop)
# ---------------------------------------------------------------------

def _alloc_inputs(rng, n, pi, v):
    op_slot = rng.integers(-1, pi, (n, pi, v)).astype(np.int32)
    eligible = (rng.uniform(size=(n, pi, v)) < 0.5) & (op_slot >= 0)
    return jnp.asarray(op_slot), jnp.asarray(eligible)


@pytest.mark.parametrize("n,pi,v", [(16, 5, 4), (100, 7, 4), (64, 31, 2)])
def test_netstep_matches_ref(n, pi, v):
    rng = np.random.default_rng(4)
    op_slot, eligible = _alloc_inputs(rng, n, pi, v)
    for rr in (0, 3, 11):
        got = netstep_pallas(op_slot, eligible, rr, interpret=True)
        want = netstep_ref(op_slot, eligible, rr)
        for g, w in zip(got, want):
            np.testing.assert_array_equal(np.asarray(g), np.asarray(w))


@given(seed=st.integers(0, 10_000))
@settings(max_examples=20, deadline=None)
def test_netstep_allocation_invariants(seed):
    rng = np.random.default_rng(seed)
    n, pi, v = int(rng.integers(4, 40)), int(rng.integers(2, 9)), 4
    op_slot, eligible = _alloc_inputs(rng, n, pi, v)
    win, vc, req = netstep_pallas(op_slot, eligible, 2, interpret=True)
    win = np.asarray(win)
    # at most one winning VC per input port
    assert (win.sum(axis=2) <= 1).all()
    # winners were eligible
    assert (win <= np.asarray(eligible)).all()
    # at most one winner per (router, output slot)
    slots = np.asarray(op_slot)
    for o in range(pi):
        cnt = ((slots == o) & win).sum(axis=(1, 2))
        assert (cnt <= 1).all()
