"""Exercise the distributed code paths on a trivial 1x1 mesh (CPU):
shard_map MoE (both variants) vs the dropless ragged oracle, sequence
parallelism, and the distributed decode attention."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.launch.mesh import make_host_mesh
from repro.launch import steps as St
from repro.models import Model, unbox


def _ctx():
    return St.build_ctx(make_host_mesh())


def _batch(cfg, b=2, t=16, seed=0):
    rng = np.random.default_rng(seed)
    return {"tokens": jnp.asarray(rng.integers(0, cfg.vocab, (b, t)),
                                  jnp.int32),
            "labels": jnp.asarray(rng.integers(0, cfg.vocab, (b, t)),
                                  jnp.int32)}


@pytest.mark.parametrize("arch", ["qwen3_moe_235b_a22b", "grok_1_314b",
                                  "jamba_v0_1_52b"])
def test_moe_ep_matches_ragged(arch):
    """With ample capacity the shard_map EP path equals dropless ragged."""
    cfg = dataclasses.replace(get_config(arch, smoke=True),
                              capacity_factor=8.0)
    m_ref = Model(cfg)
    params, _ = unbox(m_ref.init(jax.random.PRNGKey(0)))
    batch = _batch(cfg)
    ctx = _ctx()
    m_ep = Model(cfg, ctx=ctx)
    with ctx.mesh:
        loss_ep = jax.jit(m_ep.loss_fn)(params, batch)
    loss_ref = jax.jit(m_ref.loss_fn)(params, batch)
    assert float(abs(loss_ep - loss_ref)) < 2e-2, (float(loss_ep),
                                                   float(loss_ref))


def test_moe_stationary_used_for_small_batches():
    """Tiny token counts route through moe_ep_stationary (decode path)."""
    cfg = dataclasses.replace(get_config("grok_1_314b", smoke=True),
                              capacity_factor=8.0)
    ctx = _ctx()
    m = Model(cfg, ctx=ctx)
    m_ref = Model(cfg)
    params, _ = unbox(m_ref.init(jax.random.PRNGKey(0)))
    batch = _batch(cfg, b=2, t=8)   # 16 tokens << 2048 -> stationary
    with ctx.mesh:
        l1 = jax.jit(m.loss_fn)(params, batch)
    l2 = jax.jit(m_ref.loss_fn)(params, batch)
    assert float(abs(l1 - l2)) < 2e-2


def test_seq_parallel_matches_reference():
    """seq_parallel=True must not change the math (1x1 mesh)."""
    cfg = get_config("starcoder2_3b", smoke=True)
    ctx = _ctx()
    m_ref = Model(cfg)
    params, _ = unbox(m_ref.init(jax.random.PRNGKey(0)))
    batch = _batch(cfg)
    m_sp = Model(dataclasses.replace(cfg, n_heads=3, n_kv_heads=3,
                                     head_dim=16, d_model=48, d_ff=96),
                 ctx=ctx)
    # rebuild reference with the same (seq-parallel-triggering) dims
    cfg2 = m_sp.cfg
    assert cfg2.seq_parallel is False or True  # documented via ctx below
    m_ref2 = Model(dataclasses.replace(cfg2, seq_parallel=False))
    params2, _ = unbox(m_ref2.init(jax.random.PRNGKey(1)))
    with ctx.mesh:
        l_sp = jax.jit(m_sp.loss_fn)(params2, batch)
    l_ref = jax.jit(m_ref2.loss_fn)(params2, batch)
    assert float(abs(l_sp - l_ref)) < 1e-2


def test_distributed_decode_attention_matches():
    """decode_attention_dist == dense decode on a 1-shard mesh."""
    from repro.models import layers as L
    cfg = get_config("qwen3_1_7b", smoke=True)
    ctx = _ctx()
    rng = np.random.default_rng(3)
    b, s, kv, hd, h = 2, 8, 2, 16, 4
    q = jnp.asarray(rng.normal(0, 1, (b, 1, h, hd)), jnp.float32)
    kn = jnp.asarray(rng.normal(0, 1, (b, 1, kv, hd)), jnp.float32)
    vn = jnp.asarray(rng.normal(0, 1, (b, 1, kv, hd)), jnp.float32)
    ck = jnp.asarray(rng.normal(0, 1, (b, s, kv, hd)), jnp.float32)
    cv = jnp.asarray(rng.normal(0, 1, (b, s, kv, hd)), jnp.float32)
    pos = 5
    with ctx.mesh:
        out, (ck2, cv2) = L.decode_attention_dist(
            None, q, kn, vn, (ck, cv), pos, cfg, ctx)
    # reference: update cache then dense softmax attention
    ck_r = ck.at[:, pos].set(kn[:, 0])
    cv_r = cv.at[:, pos].set(vn[:, 0])
    kr = jnp.repeat(ck_r, h // kv, 2)
    vr = jnp.repeat(cv_r, h // kv, 2)
    sc = jnp.einsum("bqhd,bshd->bhqs", q, kr) / np.sqrt(hd)
    w = jax.nn.softmax(sc, -1)
    ref = jnp.einsum("bhqs,bshd->bqhd", w, vr)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=2e-5, rtol=2e-5)
    np.testing.assert_allclose(np.asarray(ck2), np.asarray(ck_r))
