"""Test configuration.

If `hypothesis` is installed (declared in pyproject.toml / the test
extra) the property tests use it as written.  This container-friendly
fallback keeps the suite collectable and the property tests *running* —
deterministically, with a fixed seed and the declared `max_examples`
budget — when the package is absent, instead of failing at import."""
from __future__ import annotations

import sys
import types

try:
    import hypothesis  # noqa: F401
except ModuleNotFoundError:
    import numpy as np

    class _Strategy:
        def __init__(self, draw):
            self.draw = draw

    def integers(min_value=0, max_value=10):
        return _Strategy(
            lambda rng: int(rng.integers(min_value, max_value + 1)))

    def sampled_from(elements):
        seq = list(elements)
        return _Strategy(lambda rng: seq[int(rng.integers(0, len(seq)))])

    def booleans():
        return _Strategy(lambda rng: bool(rng.integers(0, 2)))

    def floats(min_value=0.0, max_value=1.0, **_kw):
        return _Strategy(
            lambda rng: float(rng.uniform(min_value, max_value)))

    def settings(max_examples=10, deadline=None, **_kw):
        def deco(fn):
            fn._fallback_max_examples = max_examples
            return fn
        return deco

    def given(**strategies):
        def deco(fn):
            n_examples = getattr(fn, "_fallback_max_examples", 10)

            # zero-arg wrapper: pytest must not mistake the strategy
            # parameters for fixtures (so no functools.wraps, which
            # copies the wrapped signature via __wrapped__)
            def wrapper():
                rng = np.random.default_rng(0)
                for _ in range(n_examples):
                    draws = {k: s.draw(rng) for k, s in strategies.items()}
                    fn(**draws)
            wrapper.__name__ = fn.__name__
            wrapper.__doc__ = fn.__doc__
            return wrapper
        return deco

    mod = types.ModuleType("hypothesis")
    mod.__doc__ = "deterministic fallback shim (see tests/conftest.py)"
    st_mod = types.ModuleType("hypothesis.strategies")
    for f in (integers, sampled_from, booleans, floats):
        setattr(st_mod, f.__name__, f)
    mod.given, mod.settings, mod.strategies = given, settings, st_mod
    sys.modules["hypothesis"] = mod
    sys.modules["hypothesis.strategies"] = st_mod
