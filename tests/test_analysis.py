"""Static verification layer (DESIGN.md §14): diagnostics engine,
exhaustive routing certification, design-principle lint, JAX hazards.

The certification grid here is the acceptance bar: every Table III
topology on both substrates (and fault-degraded variants) must come
back deadlock-free with a full reachability certificate, a
deliberately-cyclic routing must yield a *real* CDG-cycle witness, and
the seeded int32-overflow / pad-slot-write configs must be flagged.
"""
from __future__ import annotations

import dataclasses
import warnings

import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

import repro.analysis as A
from repro.analysis.diagnostics import CODES, Report, diag
from repro.analysis.jaxpr_hazards import (check_dtype_promotions,
                                          check_host_sync,
                                          check_overflow,
                                          check_padding_contract,
                                          check_recompiles, iter_eqns)
from repro.analysis.routing_verify import (certify_routing, check_acyclic,
                                           dependency_edges,
                                           find_cdg_cycle)
from repro.core import topology as T
from repro.core import traffic as tr
from repro.core.routing import (Routing, dependency_graph_is_acyclic,
                                routing_for)
from repro.core.simulator import SimConfig, make_spec
from repro.sweep.padding import PadShape, stack_specs

CFG = SimConfig(cycles=120, warmup=40)


# ---------------------------------------------------------------------
# diagnostics engine
# ---------------------------------------------------------------------

def test_diagnostic_defaults_and_witness():
    d = diag("RT001", "cycle found", target="x", cycle=[1, 2, 3])
    assert d.severity == "error" and d.slug == "cdg-cycle"
    assert d.label == "RT001 cdg-cycle"
    assert d.witness_dict() == {"cycle": [1, 2, 3]}
    assert "RT001" in str(d) and "[x]" in str(d)
    with pytest.raises(KeyError):
        diag("ZZ999", "no such code")
    with pytest.raises(ValueError):
        diag("RT001", "bad sev", severity="fatal")


def test_code_registry_families():
    for code, (slug, sev, desc) in CODES.items():
        assert code[:2] in ("RT", "DP", "JX", "FT", "EX")
        assert sev in ("error", "warning", "info") and slug and desc
    # routing violations are errors; design principles are warnings
    # (Table III deliberately violates them)
    assert all(CODES[c][1] == "error" for c in CODES if c[:2] == "RT")
    assert all(CODES[c][1] == "warning" for c in CODES if c[:2] == "DP")


def test_report_gate_and_summary(tmp_path):
    rep = Report([diag("DP001", "w1"), diag("RT001", "e1")])
    rep.record("routing", "t1")
    assert not rep.ok and rep.gate() == 1
    assert rep.gate(fail_on="warning") == 1
    assert len(rep.errors()) == 1 and len(rep.warnings()) == 1
    assert rep.counts() == {"DP001": 1, "RT001": 1}
    assert "1 error(s)" in rep.summary()
    out = tmp_path / "diag.json"
    rep.to_json(str(out))
    import json
    doc = json.loads(out.read_text())
    assert doc["kind"] == "diagnostics" and doc["n_errors"] == 1
    assert doc["rows"][1]["code"] == "RT001"
    clean = Report()
    assert clean.ok and clean.gate() == 0


# ---------------------------------------------------------------------
# routing verifier: certification grid (the acceptance bar)
# ---------------------------------------------------------------------

@pytest.mark.parametrize("substrate", ["organic", "glass"])
def test_all_table3_topologies_certify_deadlock_free(substrate):
    """Exhaustive certification: every builtin at N=36, both substrates."""
    for name in sorted(T.GENERATORS):
        n = T.nearest_valid_n(name, 36)
        r = routing_for(T.build(name, n, substrate=substrate),
                        certify=True)
        cert = r.cert
        assert cert is not None and cert.ok, \
            f"{name}/{substrate}: {[str(d) for d in cert.diagnostics]}"
        assert cert.acyclic and cert.complete and cert.declared
        # every ordered pair of a connected pristine topology is checked
        assert cert.n_pairs_checked == n * (n - 1)
        assert cert.n_dep_edges > 0 and cert.max_hops_seen >= 1


@pytest.mark.parametrize("name", ["folded_hexa_torus", "mesh", "torus",
                                  "hexamesh"])
def test_fault_variants_certify(name):
    """Fault masks k<=2: degraded routings stay certified; pairs
    involving dead chiplets are exempt by construction."""
    from repro.faults import apply_variant, iter_fault_variants
    topo = T.build(name, 36)
    labels = []
    for label, fs in iter_fault_variants(topo, kmax=2,
                                         kinds=("random", "chiplets")):
        degraded = apply_variant(topo, fs)
        cert = routing_for(degraded, certify=True).cert
        assert cert.ok, f"{name}[{label}]: {cert.diagnostics}"
        labels.append(label)
        if label.startswith("chiplets"):
            k = int(label.split(":")[1][1:])
            live = 36 - k
            assert cert.n_pairs_checked == live * (live - 1)
    assert "pristine" in labels and len(labels) >= 3


def _ring_cyclic_routing(n: int) -> Routing:
    """A deliberately-cyclic routing: n-ring, everything forwarded
    clockwise with no turn prohibition — the textbook deadlock."""
    pos = np.stack([np.cos(np.linspace(0, 2 * np.pi, n, endpoint=False)),
                    np.sin(np.linspace(0, 2 * np.pi, n,
                                       endpoint=False))], axis=1) * 10
    edges = np.array([(i, (i + 1) % n) for i in range(n)])
    topo = T.make_topology(f"ring{n}", pos, edges)
    # one clockwise channel per node; port 0 at src, in_port 0 at dst
    ch_src = np.arange(n)
    ch_dst = (ch_src + 1) % n
    table = np.full((n, n, 2), -1, np.int16)
    for d in range(n):
        for v in range(n):
            table[d, v, :] = Routing.EJECT if v == d else 0
    return Routing(
        topo=topo, ch_src=ch_src, ch_dst=ch_dst,
        ch_len_mm=np.ones(n), ch_out_port=np.zeros(n, np.int64),
        ch_in_port=np.zeros(n, np.int64),
        out_ch=np.arange(n).reshape(n, 1),
        in_ch=((np.arange(n) - 1) % n).reshape(n, 1),
        n_ports=np.ones(n, np.int64), table=table,
        prohibited_turns=0, total_turns=n)


@settings(max_examples=8, deadline=None)
@given(n=st.integers(min_value=3, max_value=9))
def test_cyclic_routing_witness_is_a_real_cdg_cycle(n):
    """The RT001 witness must be an actual cycle of dependency edges."""
    r = _ring_cyclic_routing(n)
    diags = check_acyclic(r)
    assert len(diags) == 1 and diags[0].code == "RT001"
    w = diags[0].witness_dict()
    cycle = w["cycle"]
    assert len(cycle) >= 2
    edge_set = {tuple(e) for e in dependency_edges(r).tolist()}
    for a, b in zip(cycle, cycle[1:] + cycle[:1]):
        assert (a, b) in edge_set, f"witness edge {(a, b)} not in CDG"
    cert = certify_routing(r)
    assert not cert.ok and not cert.acyclic
    # the ring routing delivers (clockwise all the way), so only the
    # cycle check fails
    assert cert.complete and cert.declared


def test_broken_table_yields_unreachable_and_undeclared():
    r = routing_for(T.build("mesh", 16))
    table = r.table.copy()
    # dead-end pair (3 -> 0): no out port at the injection column
    table[0, 3, r.max_ports] = -1
    bad = dataclasses.replace(r, table=table, cert=None)
    cert = certify_routing(bad)
    assert not cert.ok and not cert.complete
    rt2 = [d for d in cert.diagnostics if d.code == "RT002"]
    assert rt2 and rt2[0].witness_dict()["pair"] == (3, 0)
    # undeclared channel: route to a port with no channel behind it
    table2 = r.table.copy()
    p_bad = int(r.n_ports[5])           # first virtual port at node 5
    if p_bad < r.max_ports:
        table2[0, 5, 0] = p_bad
        bad2 = dataclasses.replace(r, table=table2, cert=None)
        cert2 = certify_routing(bad2)
        assert any(d.code == "RT003" for d in cert2.diagnostics)


def test_livelock_detected_as_rt004():
    # bounce a packet for dst 15 between nodes 0 and 1 forever
    r = routing_for(T.build("mesh", 16))
    c01 = int(np.flatnonzero((r.ch_src == 0) & (r.ch_dst == 1))[0])
    c10 = int(np.flatnonzero((r.ch_src == 1) & (r.ch_dst == 0))[0])
    table = r.table.copy()
    dst = 15
    table[dst, 0, r.max_ports] = r.ch_out_port[c01]        # inject 0->1
    table[dst, 1, r.ch_in_port[c01]] = r.ch_out_port[c10]  # 1 -> 0
    table[dst, 0, r.ch_in_port[c10]] = r.ch_out_port[c01]  # 0 -> 1
    bad = dataclasses.replace(r, table=table, cert=None)
    cert = certify_routing(bad)
    assert not cert.ok and not cert.complete
    d = [x for x in cert.diagnostics if x.code == "RT004"]
    assert d and d[0].witness_dict()["pair"] == (0, dst)


def test_certificate_cached_with_routing():
    from repro.core.routing import routing_cache_clear
    routing_cache_clear()
    topo = T.build("folded_hexa_torus", 16)
    r1 = routing_for(topo)              # plain: no certificate yet
    assert r1.cert is None
    r2 = routing_for(topo, certify=True)
    assert r2 is r1 and r2.cert is not None and r2.cert.ok
    r3 = routing_for(topo, certify=True)  # cached, not re-verified
    assert r3.cert is r2.cert


def test_deprecated_bool_shim_still_works():
    r = routing_for(T.build("folded_hexa_torus", 16))
    with warnings.catch_warnings(record=True) as w:
        warnings.simplefilter("always")
        assert dependency_graph_is_acyclic(r) is True
        assert any(issubclass(x.category, DeprecationWarning) for x in w)
    assert dependency_graph_is_acyclic.__doc__.startswith("Deprecated")
    with warnings.catch_warnings():
        warnings.simplefilter("ignore")
        assert dependency_graph_is_acyclic(_ring_cyclic_routing(5)) \
            is False


# ---------------------------------------------------------------------
# design-principle lint (byte-identical to the legacy prefilter)
# ---------------------------------------------------------------------

def test_principle_messages_match_legacy_strings():
    from repro.synth.feasibility import FeasibilityCriteria, check
    crit = FeasibilityCriteria(max_radix=3, max_wire_cost_mm=1.0)
    topo = T.build("torus", 36)
    legacy = check(topo, crit)
    diags = A.diagnose(topo, crit)
    assert [d.message for d in diags] == legacy
    assert legacy[0] == "link-range 4 > 1 (Principle 2)"
    codes = [d.code for d in diags]
    assert codes == sorted(codes)       # DP001..DP005 in check order
    assert all(d.severity == "warning" for d in diags)


def test_rate_floor_diagnostic_on_glass_vs_organic():
    crit = A.FeasibilityCriteria(min_rate_fraction=0.95)
    topo_o = T.build("torus", 36, substrate="organic")
    dp2 = [d for d in A.diagnose(topo_o, crit) if d.code == "DP002"]
    assert dp2 and "organic rate floor 0.95" in dp2[0].message
    w = dp2[0].witness_dict()
    assert w["max_link_mm"] > w["cap_mm"]


def test_n_constraint_lint_matches_planner_string():
    assert A.check_n_constraint("mesh", 36) == []
    diags = A.check_n_constraint("hypercube", 36)
    assert diags[0].code == "DP006"
    assert diags[0].message == \
        "hypercube does not support N=36 (topology.N_CONSTRAINTS)"


def test_valid_n_and_nearest():
    assert T.valid_n("mesh", 17) and T.valid_n("hypercube", 32)
    assert not T.valid_n("hypercube", 36)
    assert T.nearest_valid_n("hypercube", 36) == 32
    assert T.nearest_valid_n("cluscross_v1", 36) == 36
    assert T.nearest_valid_n("mesh", 36) == 36


def test_synth_rejection_ledger_carries_codes():
    from repro.synth.search import SearchConfig, SearchState
    st_ = SearchState(config=SearchConfig(n=36, substrate="organic"))
    assert not st_.admit(T.build("torus", 36), origin="registry")
    rej = st_.rejected[0]
    assert rej["reasons"] == ["link-range 4 > 1 (Principle 2)"]
    assert rej["diag_codes"] == ["DP001"]


# ---------------------------------------------------------------------
# planner / frame diag_code plumbing
# ---------------------------------------------------------------------

def test_plan_skip_codes_and_frame_column():
    import repro.experiments as X
    exp = X.Experiment([X.Scenario("mesh", 16),
                        X.Scenario("hypercube", 15)], cfg=CFG,
                       backend="analytic")
    pl = X.plan(exp)
    # legacy 2-tuple shape is pinned; codes ride in skip_codes
    i, reason = pl.skipped[0]
    assert i == 1 and reason == \
        "hypercube does not support N=15 (topology.N_CONSTRAINTS)"
    assert pl.skip_codes == {1: "DP006"}
    frame = X.run(exp)
    assert frame.rows[1]["status"] == "invalid"
    assert frame.rows[1]["diag_code"] == "DP006"
    assert frame.rows[0]["diag_code"] in ("", None)
    assert "diag_code" in frame.columns


def test_fault_rejected_skip_code():
    import repro.experiments as X
    import repro.faults as F
    e = np.sort(np.asarray(T.build("mesh", 16).edges), axis=1)
    cut = F.FaultSet(links=tuple(
        tuple(int(x) for x in lk) for lk in e[(e == 0).any(1)]))
    pl = X.plan(X.Experiment(
        [X.Scenario("mesh", 16, faults=cut)], cfg=CFG,
        backend="analytic"))
    assert pl.skip_codes == {0: "FT001"}


def test_schema_v6():
    from repro.experiments.io import SCHEMA_VERSION
    assert SCHEMA_VERSION == 6


# ---------------------------------------------------------------------
# jaxpr hazards
# ---------------------------------------------------------------------

def test_seeded_int32_overflow_flagged():
    """The acceptance-criterion seeded config: long run overflows the
    summed-latency counter; the default config is clean."""
    hot = check_overflow(36, 4, SimConfig(cycles=50_000, warmup=1000))
    assert any(d.code == "JX001" for d in hot)
    lat = [d for d in hot if d.witness_dict()["counter"] == "lat_node"]
    assert lat and lat[0].severity == "error"
    assert lat[0].witness_dict()["bound"] >= 2 ** 31
    assert check_overflow(36, 4, SimConfig()) == []


def test_telemetry_counters_bounded_too():
    from repro.analysis.jaxpr_hazards import counter_bounds
    b = counter_bounds(36, 4, SimConfig(telemetry=True))
    assert "tel_occ" in b and "tel_hist" in b
    assert all(v < 2 ** 31 for v in b.values())


def test_seeded_pad_slot_write_flagged():
    """Corrupting a padded lane (the acceptance-criterion seed) must
    produce a JX002 with a concrete (spec, leaf, index) witness."""
    specs = [make_spec(routing_for(T.build(nm, n)),
                       tr.uniform(T.build(nm, n)))
             for nm, n in (("folded_hexa_torus", 36), ("mesh", 16))]
    batch, shape = stack_specs(specs)
    assert check_padding_contract(batch, specs) == []   # clean batch
    # seed 1: a padded out_ch points at a real channel -> a pad lane
    # could scatter a flit onto spec 1's live channel rows
    bad = batch._replace(out_ch=batch.out_ch.copy())
    bad.out_ch[1, specs[1].n + 1, 0] = 3
    d = check_padding_contract(bad, specs)
    assert d and all(x.code == "JX002" for x in d)
    w = d[0].witness_dict()
    assert w["spec"] == 1 and w["leaf"] == "out_ch"
    assert w["value"] == 3
    # seed 2: nonzero injection weight in the padded node tail -> pad
    # nodes would inject real flits
    bad2 = batch._replace(inj_weight=batch.inj_weight.copy())
    bad2.inj_weight[1, specs[1].n] = 0.5
    d2 = check_padding_contract(bad2, specs)
    assert any(x.witness_dict()["leaf"] == "inj_weight" for x in d2)


def test_out_of_range_declared_channel_flagged():
    spec = make_spec(routing_for(T.build("mesh", 16)),
                     tr.uniform(T.build("mesh", 16)))
    batch, _ = stack_specs([spec])
    bad = batch._replace(out_ch=batch.out_ch.copy())
    live = np.argwhere(bad.out_ch[0] >= 0)[0]
    bad.out_ch[0, live[0], live[1]] = spec.c + 5   # beyond this spec's C
    d = check_padding_contract(bad, [spec])
    assert any(x.code == "JX002" for x in d)


def test_recompile_hazard_reported_with_bucketing_hint():
    shapes = [PadShape(16, 4, 48, 4), PadShape(36, 4, 120, 4),
              PadShape(16, 4, 48, 4)]
    assert check_recompiles([shapes[0], shapes[0]]) == []
    d = check_recompiles(shapes, bucketed=[PadShape(40, 4, 128, 4)] * 3)
    assert d[0].code == "JX003"
    assert "2 distinct padded shapes" in d[0].message
    assert "reduce this to 1" in d[0].message
    assert d[0].witness_dict()["n_shapes"] == 2


def test_traced_step_is_clean_and_walker_finds_seeded_hazards():
    import jax
    import jax.numpy as jnp
    from repro.core.simulator import trace_batch
    topo = T.build("mesh", 16)
    spec = make_spec(routing_for(topo), tr.uniform(topo))
    jaxpr, shape, batch = trace_batch([spec], [0.1, 0.2], CFG)
    assert shape.n == 16
    assert len(list(iter_eqns(jaxpr))) > 50      # walker descends scan
    assert check_host_sync(jaxpr) == []
    assert check_dtype_promotions(jaxpr) == []
    # seeded host callback is found inside nested jaxprs
    def noisy(x):
        jax.debug.print("x={x}", x=x)
        return x * 2
    j2 = jax.make_jaxpr(jax.jit(noisy))(jnp.float32(1.0))
    hs = check_host_sync(j2)
    assert hs and hs[0].code == "JX004"
    # seeded 64-bit promotion is found
    try:
        from jax.experimental import enable_x64
        with enable_x64():
            j3 = jax.make_jaxpr(
                lambda x: x.astype(jnp.float64) + 1.0)(
                    np.float32(1.0))
        dp = check_dtype_promotions(j3)
        assert any(d.code == "JX005" for d in dp)
    except ImportError:
        pass


def test_analyze_batch_front_door():
    from repro.analysis.jaxpr_hazards import analyze_batch
    topos = [T.build("folded_hexa_torus", 16), T.build("mesh", 16)]
    specs = [make_spec(routing_for(t), tr.uniform(t)) for t in topos]
    rep = analyze_batch(specs, [0.1], CFG)
    assert rep.ok                        # no errors on the real path
    assert ("padding", "batch[2]") in rep.analyzed
    assert any(kind == "recompile" for kind, _ in rep.analyzed)


# ---------------------------------------------------------------------
# engine front door + CLI
# ---------------------------------------------------------------------

def test_analyze_front_door_and_metrics():
    from repro.obs.metrics import metrics
    before = metrics.with_prefix("analysis.").get("analysis.certified", 0)
    rep = A.analyze(names=["folded_hexa_torus", "hypercube"], n=36,
                    substrates=("organic",), fault_kmax=1)
    assert rep.ok
    # hypercube at 36 is linted DP006 and analyzed at 32 instead
    assert [d.code for d in rep if d.code == "DP006"] == ["DP006"]
    assert any("hypercube/n32" in lbl for _, lbl in rep.analyzed)
    after = metrics.with_prefix("analysis.")
    assert after["analysis.certified"] > before


def test_cli_all_builtin_gate(tmp_path, capsys):
    """The acceptance criterion: `--all-builtin` certifies every Table
    III topology on both substrates with zero error diagnostics."""
    from repro.analysis.__main__ import main
    out = tmp_path / "diagnostics.json"
    rc = main(["--all-builtin", "-n", "36", "-q", "-o", str(out)])
    assert rc == 0
    text = capsys.readouterr().out
    assert "0 error(s)" in text
    import json
    doc = json.loads(out.read_text())
    assert doc["n_errors"] == 0
    # 19 builtins x 2 substrates, principles + >=1 routing cert each
    routings = [a for a in doc["analyzed"] if a[0] == "routing"]
    assert len(routings) >= 2 * len(T.GENERATORS)


def test_cli_fails_on_warning_threshold(capsys):
    from repro.analysis.__main__ import main
    rc = main(["torus", "-n", "36", "--substrate", "organic", "-q",
               "--fail-on", "warning"])
    assert rc == 1                       # DP001 link-range warning
    rc2 = main(["torus", "-n", "36", "--substrate", "organic", "-q"])
    assert rc2 == 0                      # warnings pass the error gate


# ---------------------------------------------------------------------
# RT005: escape certification for minimal-adaptive routing (§15)
# ---------------------------------------------------------------------

def test_rt005_registered():
    slug, sev, desc = CODES["RT005"]
    assert slug == "escape-unsafe" and sev == "error"


@pytest.mark.parametrize("name", ["folded_hexa_torus", "mesh", "torus",
                                  "hexamesh"])
def test_escape_certified_on_builtins(name):
    """Every Table III family certifies RT005-clean: the productive-
    ports mask is non-trivial and every adaptive choice keeps a
    deliverable escape."""
    from repro.analysis.routing_verify import check_escape
    r = routing_for(T.build(name, 36))
    diags, n_choices = check_escape(r)
    assert diags == [] and n_choices > 0
    cert = certify_routing(r)
    assert cert.ok and cert.escape_safe
    assert cert.n_adaptive_choices == n_choices


def test_productive_ports_structure():
    """Mask semantics: all-False on the diagonal, every True entry is a
    strictly minimal, escape-safe declared channel."""
    import scipy.sparse.csgraph as csg
    from repro.core.routing import productive_ports
    r = routing_for(T.build("folded_hexa_torus", 16))
    prod = productive_ports(r)
    n, P = r.topo.n, r.max_ports
    assert prod.shape == (n, n, P) and prod.dtype == bool
    assert not prod[np.arange(n), np.arange(n)].any()
    hops = csg.shortest_path(r.topo.adjacency(), unweighted=True)
    for d, u, p in np.argwhere(prod):
        c = int(r.out_ch[u, p])
        assert c >= 0
        w = int(r.ch_dst[c])
        assert hops[w, d] + 1 == hops[u, d]
        q = int(r.ch_in_port[c])
        assert w == d or r.table[d, w, q] >= 0
    # the mask is non-trivial; a (dst, node) MAY legitimately have no
    # escape-safe minimal port (up*/down* escape routes are not always
    # minimal) — those states simply ride the escape class
    assert prod.any()
    assert prod.sum() >= n * (n - 1) // 2


def test_rt005_flags_escape_unsafe_mask():
    """Hand-poisoning the productive-ports mask with a non-minimal (or
    escape-losing) entry must yield an RT005 witness naming it."""
    from repro.core.routing import productive_ports
    r = routing_for(T.build("mesh", 16))
    prod = productive_ports(r).copy()
    # add a port that walks AWAY from the destination: node 0's port to
    # node 1 while routing to node 1's far side... pick (d=0, u=0+1 hop)
    # any declared port at node 5 that is not already productive for d=0
    cand = [(5, p) for p in range(r.max_ports)
            if r.out_ch[5, p] >= 0 and not prod[0, 5, p]]
    assert cand, "mesh node 5 should have a non-minimal port for dst 0"
    u, p = cand[0]
    poisoned = dataclasses.replace(r, cert=None)
    poisoned.prod = prod
    prod[0, u, p] = True
    from repro.analysis.routing_verify import check_escape
    diags, _ = check_escape(poisoned)
    assert diags and all(d.code == "RT005" for d in diags)
    w = diags[0].witness_dict()
    assert w["choice"][0] == 0 and w["choice"][1] == u
    cert = certify_routing(poisoned)
    assert not cert.ok and not cert.escape_safe


@given(seed=st.integers(0, 10_000))
@settings(max_examples=12, deadline=None)
def test_escape_property_random_graphs(seed):
    """Satellite property (ISSUE #9): on random connected degree-
    bounded topologies the escape-class CDG is acyclic and every
    (src, dst) pair stays reachable when the adaptive function is in
    play — i.e. RT005 + RT002/RT004 certify clean."""
    import networkx as nx
    rng = np.random.default_rng(seed)
    n = int(rng.integers(8, 24))
    g = nx.gnm_random_graph(n, int(n * 1.8), seed=seed)
    if not nx.is_connected(g):
        g = nx.compose(g, nx.path_graph(n))
    edges = np.array(sorted(tuple(sorted(e)) for e in g.edges()),
                     dtype=np.int32)
    pos = rng.uniform(0, np.sqrt(n), size=(n, 2))
    topo = T.Topology(name="rand", n=n, pos=pos, edges=edges,
                      substrate="organic", chiplet_area_mm2=74.0)
    cert = certify_routing(routing_for(topo))
    assert cert.ok, [str(d) for d in cert.diagnostics]
    assert cert.escape_safe and cert.n_adaptive_choices > 0
    assert cert.n_pairs_checked == n * (n - 1)


@given(seed=st.integers(0, 5_000), k=st.integers(1, 2))
@settings(max_examples=10, deadline=None)
def test_escape_property_faulted(seed, k):
    """Same property under sampled fault masks k<=2 on a Table III
    topology: the degraded routing (and its productive-ports mask)
    still certifies RT005-clean."""
    from repro.faults import FaultError, sample_faults
    topo = T.build("folded_hexa_torus", 36)
    try:
        fs = sample_faults(topo, k, "random", seed=seed)
        degraded = fs.apply(topo)
    except FaultError:
        return                          # disconnecting mask: resampled
    cert = certify_routing(routing_for(degraded))
    assert cert.ok and cert.escape_safe, \
        [str(d) for d in cert.diagnostics]
