"""Topology synthesis engine (DESIGN.md §11): design-space generators,
feasibility filter, Pareto utilities, the seeded search (acceptance:
FHT on its own Pareto front, >=5x analytic prefilter), custom-topology
registry/validation hardening, and the structural-hash routing cache.
"""
import dataclasses

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

import repro.experiments as X
import repro.synth as S
from repro.core import costmodel as cm
from repro.core import routing as R
from repro.core import topology as T
from repro.core.routing import (build_routing, cached_routing,
                                dependency_graph_is_acyclic, routing_for,
                                routing_cache_info)
from repro.core.simulator import SimConfig


# =====================================================================
# build-time validation hardening (satellite 1)
# =====================================================================

POS3 = np.array([[0.0, 0.0], [1.0, 0.0], [2.0, 0.0]])


def test_make_topology_rejects_self_loops():
    with pytest.raises(ValueError, match="self-loop"):
        T.make_topology("bad", POS3, [(0, 1), (1, 2), (2, 2)])


def test_make_topology_rejects_duplicate_edges():
    with pytest.raises(ValueError, match="duplicate edge"):
        T.make_topology("bad", POS3, [(0, 1), (1, 2), (2, 1)])


def test_make_topology_rejects_disconnected():
    pos = np.array([[0.0, 0], [1, 0], [2, 0], [3, 0]])
    with pytest.raises(ValueError, match="disconnected"):
        T.make_topology("bad", pos, [(0, 1), (2, 3)])


def test_make_topology_rejects_out_of_range():
    with pytest.raises(ValueError, match="out of range"):
        T.make_topology("bad", POS3, [(0, 1), (1, 3)])


def test_build_validates_registered_generators():
    T.register_topology(
        "bad_gen", lambda n: ("bad_gen", POS3[:n],
                              [(i, i) for i in range(n)]), overwrite=True)
    try:
        with pytest.raises(ValueError, match="self-loop"):
            T.build("bad_gen", 3)
    finally:
        T.unregister_topology("bad_gen")


def test_build_rejects_generator_node_count_mismatch():
    pos25 = np.stack([np.arange(25.0) % 5, np.arange(25.0) // 5], axis=-1)
    ring25 = [(i, (i + 1) % 25) for i in range(25)]
    T.register_topology("wrong_n", lambda n: ("wrong_n", pos25, ring25),
                        overwrite=True)
    try:
        with pytest.raises(ValueError, match="25 positions"):
            T.build("wrong_n", 16)
    finally:
        T.unregister_topology("wrong_n")


def test_register_topology_guards():
    with pytest.raises(ValueError, match="built-in"):
        T.register_topology("mesh", lambda n: None)
    T.register_topology("reg_guard_demo", lambda n: None, overwrite=True)
    try:
        with pytest.raises(ValueError, match="already registered"):
            T.register_topology("reg_guard_demo", lambda n: None)
    finally:
        T.unregister_topology("reg_guard_demo")


def test_registered_generator_resolves_through_build_and_experiments():
    def gen(n):
        base = T.build("mesh", n)
        return ("wrapped_mesh", base.pos, base.edges)
    T.register_topology("wrapped_mesh", gen, overwrite=True)
    try:
        topo = T.build("wrapped_mesh", 16)
        assert topo.structural_hash() == T.build("mesh", 16).structural_hash()
        frame = X.run(X.Experiment([X.Scenario("wrapped_mesh", 16)],
                                   backend="analytic"))
        assert frame.rows[0]["status"] == "ok"
        assert frame.rows[0]["topology"] == "wrapped_mesh"
    finally:
        T.unregister_topology("wrapped_mesh")


# =====================================================================
# structural-hash routing cache (satellite 2)
# =====================================================================

def test_structural_hash_ignores_name_and_edge_order():
    a = T.build("mesh", 16)
    b = dataclasses.replace(a, name="renamed",
                            edges=a.edges[::-1].copy())
    assert a.structural_hash() == b.structural_hash()
    c = T.build("folded_torus", 16)
    assert a.structural_hash() != c.structural_hash()


def test_cached_routing_no_collision_for_reregistered_name():
    """The old (name, n, substrate) key served stale routing when a
    custom name was re-registered with a different structure."""
    T.register_topology("clash", lambda n: T.build("mesh", n),
                        overwrite=True)
    try:
        t1, r1 = cached_routing("clash", 16)
        T.register_topology("clash", lambda n: T.build("folded_torus", n),
                            overwrite=True)
        t2, r2 = cached_routing("clash", 16)
        assert t1.structural_hash() != t2.structural_hash()
        assert r1.n_channels != r2.n_channels or \
            not np.array_equal(r1.table, r2.table)
    finally:
        T.unregister_topology("clash")


def test_routing_cache_shares_entries_across_names():
    info0 = routing_cache_info()
    base = T.build("mesh", 20)
    alias = dataclasses.replace(base, name="mesh_alias")
    r1 = routing_for(base)
    r2 = routing_for(alias)
    assert r1 is r2                      # same structure, one entry
    info1 = routing_cache_info()
    assert info1["hits"] >= info0["hits"] + 1
    assert set(info1) >= {"size", "max_size", "hits", "misses",
                          "evictions"}


# =====================================================================
# design space (synth.space)
# =====================================================================

def test_fold_mask_recovers_table_iii_points():
    """Mesh / FoldedTorus / HexaMesh / FHT are fold-mask points."""
    pairs = [(("grid", ("path", "path")), "mesh"),
             (("grid", ("folded", "folded")), "folded_torus"),
             (("brick", ("path", "path", "path")), "hexamesh"),
             (("brick", ("folded", "folded", "folded")),
              "folded_hexa_torus")]
    for (family, modes), name in pairs:
        fm = S.fold_mask_topology(48, family, modes)
        assert fm.structural_hash() == T.build(name, 48).structural_hash()


def test_fold_mask_variants_enumerate_and_validate():
    variants = S.fold_mask_variants(16, families=("grid",))
    assert len(variants) == 9            # 3 modes ^ 2 axes
    assert len({t.structural_hash() for t in variants}) == 9
    for t in variants:
        assert t.is_connected()


@pytest.mark.parametrize("seed", [0, 7, 123])
def test_random_geometric_invariants(seed):
    t = S.random_geometric(24, seed, max_degree=5, max_range=1)
    assert t is not None
    assert t.is_connected()
    assert t.degrees().max() <= 5
    assert t.link_ranges().max() <= 1
    again = S.random_geometric(24, seed, max_degree=5, max_range=1)
    assert t.structural_hash() == again.structural_hash()  # deterministic


def test_candidate_pairs_match_link_ranges_convention():
    """Generation and the feasibility filter must share ONE link-range
    convention: every admitted pair, built as an edge, must satisfy
    the same Topology.link_ranges budget it was admitted under."""
    t = S.random_geometric(24, 5, family="brick", max_degree=6,
                           max_range=1)
    assert t.link_ranges().max() <= 1
    pairs = S.candidate_pairs(t.pos, max_range=0)
    adj_only = T.make_topology("adj", t.pos, pairs)
    assert adj_only.link_ranges().max() == 0


def test_perturb_preserves_invariants():
    base = S.random_geometric(16, 3, max_degree=5, max_range=1)
    child = S.perturb(base, seed=11, max_degree=5, max_range=1)
    assert child is not None
    assert child.structural_hash() != base.structural_hash()
    assert child.is_connected()
    assert child.degrees().max() <= 5
    assert child.link_ranges().max() <= 1


# =====================================================================
# feasibility filter (the three design principles)
# =====================================================================

def test_feasibility_accepts_fht_rejects_torus_wraps():
    crit = S.FeasibilityCriteria()
    assert S.check(T.build("folded_hexa_torus", 48), crit) == []
    reasons = S.check(T.build("torus", 48), crit)
    assert any("link-range" in r for r in reasons)


def test_feasibility_radix_and_wire_budget():
    crit = S.FeasibilityCriteria(max_radix=4)
    reasons = S.check(T.build("octamesh", 48), crit)
    assert any("radix" in r for r in reasons)
    assert cm.wire_cost_mm(T.build("mesh", 16)) > 0


def test_max_feasible_link_monotone_in_rate_floor():
    for sub in ("organic", "glass"):
        l_lo = S.max_feasible_link_mm(sub, 0.9)
        l_hi = S.max_feasible_link_mm(sub, 0.25)
        assert 0 < l_lo < l_hi <= 70.0
    # glass holds rate longer than organic (Fig. 2)
    assert S.max_feasible_link_mm("glass", 0.9) > \
        S.max_feasible_link_mm("organic", 0.9)


# =====================================================================
# Pareto utilities
# =====================================================================

def test_pareto_mask_basics():
    #               thr(max)  lat(min)  wire(min)
    pts = np.array([[10.0,     5.0,     100.0],    # front
                    [12.0,     6.0,     120.0],    # front (best thr)
                    [9.0,      7.0,     140.0],    # beaten >5% everywhere
                    [9.9,      5.2,     104.0],    # within 5% of 0
                    [1.0,      50.0,    500.0]])   # far dominated
    mx = (True, False, False)
    mask = S.pareto_mask(pts, mx)
    assert mask.tolist() == [True, True, False, False, False]
    eps = S.pareto_mask(pts, mx, eps=0.05)
    assert eps.tolist() == [True, True, False, True, False]
    assert S.pareto_front(pts, mx).tolist() == [0, 1]


def test_pareto_mask_nan_rows_excluded():
    pts = np.array([[1.0, 1.0], [np.nan, 1.0]])
    mask = S.pareto_mask(pts, (True, False))
    assert mask.tolist() == [True, False]


# =====================================================================
# deadlock-freedom over the search space (satellite 3)
# =====================================================================

@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 10_000),
       n=st.sampled_from([12, 16, 18, 24]),
       max_degree=st.integers(3, 6))
def test_routing_is_deadlock_free_on_random_topologies(seed, n,
                                                       max_degree):
    """The search space relies on build_routing being deadlock-free and
    complete on ANY connected degree-bounded topology: the channel
    dependency graph must be acyclic and every pair reachable."""
    topo = S.random_geometric(n, seed, max_degree=max_degree, max_range=1)
    if topo is None:                     # degree bound too tight to span
        return
    r = build_routing(topo)
    assert dependency_graph_is_acyclic(r)
    hops = r.restricted_hops()           # raises on dead ends / livelock
    off = ~np.eye(n, dtype=bool)
    assert (hops[off] >= 1).all()
    assert hops.max() <= 4 * n


# =====================================================================
# custom topologies through the experiment pipeline
# =====================================================================

def test_scenario_accepts_topology_object_bitwise_vs_name():
    cfg = SimConfig(cycles=240, warmup=80)
    topo = T.build("mesh", 16)
    frame = X.run(X.Experiment(
        [X.Scenario("mesh", 16, rates=X.ExplicitRates((0.1, 0.3))),
         X.Scenario(topo, 16, rates=X.ExplicitRates((0.1, 0.3)))],
        cfg=cfg, name="obj_vs_name"))
    a, b = frame.results
    np.testing.assert_array_equal(a["throughput"], b["throughput"])
    np.testing.assert_array_equal(a["latency"], b["latency"])
    assert frame.rows[1]["topology"] == "mesh"


def test_scenario_accepts_generator_callable():
    frame = X.run(X.Experiment(
        [X.Scenario(lambda n: T.build("folded_torus", n), 16)],
        backend="analytic"))
    row = frame.rows[0]
    assert row["status"] == "ok" and row["radix"] == 4


def test_scenario_topology_object_applies_roles_scheme():
    """A non-default roles scheme must bind to Topology-object scenarios
    exactly as it does to registry names (the result row reports it)."""
    exp = X.Experiment([X.Scenario(T.build("mesh", 16), 16,
                                   roles="hetero_cm",
                                   traffic="hetero_mix")],
                       backend="analytic")
    ps = X.plan(exp).buckets[0].items[0]
    assert (ps.topo.roles == "M").any()
    # same traffic matrix as the registry-name path
    name_ps = X.plan(X.Experiment(
        [X.Scenario("mesh", 16, roles="hetero_cm",
                    traffic="hetero_mix")],
        backend="analytic")).buckets[0].items[0]
    np.testing.assert_array_equal(ps.traffic, name_ps.traffic)


def test_scenario_topology_n_mismatch_raises():
    with pytest.raises(ValueError, match="n=25 != topology n=16"):
        X.plan(X.Experiment([X.Scenario(T.build("mesh", 16), 25)],
                            backend="analytic"))


# =====================================================================
# the search driver: acceptance criteria
# =====================================================================

ACCEPT_CFG = S.SearchConfig(
    n=48, substrate="organic", seed=0,
    n_random=16, generations=2, offspring=10, sim_top=3, n_rates=3,
    cfg=SimConfig(cycles=700, warmup=250))


@pytest.fixture(scope="module")
def accept_result():
    return S.run_search(ACCEPT_CFG)


def test_search_fht_on_own_pareto_front(accept_result):
    """Acceptance: seeded search at N=48 (organic) places FHT on (or
    within 5 % of) the Pareto front of its own candidate pool."""
    res = accept_result
    assert any(c.topo.name == "folded_hexa_torus" for c in res.simulated)
    assert res.on_front("folded_hexa_torus", eps=0.05)


def test_search_prefilter_cuts_sims_5x(accept_result):
    """Acceptance: the analytic prefilter cuts cycle-sim evaluations by
    >= 5x vs simulating every feasible candidate."""
    res = accept_result
    assert res.stats["n_simulated"] >= 1
    assert res.prefilter_ratio >= 5.0


def test_search_pool_and_front_sanity(accept_result):
    res = accept_result
    s = res.stats
    assert s["n_generated"] == s["n_feasible"] + s["n_infeasible"] + \
        s["n_duplicate"]
    assert s["n_feasible"] >= 50         # the space is genuinely explored
    origins = {c.origin for c in res.state.pool}
    assert {"registry", "fold_mask", "random", "perturb"} <= origins
    front = res.front()
    assert front                          # non-empty
    for c in res.simulated:
        assert c.sim is not None and "sim_saturation" in c.sim
        assert S.check(c.topo, ACCEPT_CFG.criteria) == []   # all feasible
    rows = res.rows()
    assert len(rows) == len(res.state.pool) + len(res.state.rejected)
    assert any(r["status"] == "infeasible" for r in rows)


def test_search_state_json_roundtrip_and_resume(tmp_path):
    """Pause after generation 1, serialize, resume: identical pool to
    an uninterrupted run (per-generation PRNG keys)."""
    cfg = S.SearchConfig(n=16, n_random=6, generations=2, offspring=6,
                         sim_top=2, n_rates=2,
                         cfg=SimConfig(cycles=240, warmup=80))
    # pause_after == generations must also skip stage-2 simulation
    at_end = S.run_search(cfg, pause_after=cfg.generations)
    assert at_end.frame is None and at_end.simulated == []
    assert at_end.state.generation == cfg.generations
    paused = S.run_search(cfg, pause_after=1)
    assert paused.frame is None and paused.simulated == []
    path = str(tmp_path / "state.json")
    paused.state.to_json(path)
    loaded = S.SearchState.from_json(path)
    assert loaded.config == cfg
    assert loaded.generation == 1
    resumed = S.run_search(state=loaded)
    full = S.run_search(cfg)
    names = lambda r: sorted(c.topo.name for c in r.state.pool)
    hashes = lambda r: sorted(c.topo.structural_hash()
                              for c in r.state.pool)
    assert names(resumed) == names(full)
    assert hashes(resumed) == hashes(full)
    assert resumed.stats["n_generated"] == full.stats["n_generated"]
    assert sorted(c.topo.name for c in resumed.front()) == \
        sorted(c.topo.name for c in full.front())
