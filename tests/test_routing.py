"""Routing-layer tests: deadlock freedom, shortest paths, channel loads."""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import topology as T
from repro.core import traffic as TR
from repro.core.routing import build_routing, dependency_graph_is_acyclic


@pytest.mark.parametrize("name", ["mesh", "folded_torus", "hexamesh",
                                  "folded_hexa_torus", "octamesh",
                                  "honeycomb_mesh", "kite_medium",
                                  "sid_mesh"])
def test_deadlock_free(name):
    topo = T.build(name, 36)
    r = build_routing(topo)
    assert dependency_graph_is_acyclic(r)


@pytest.mark.parametrize("name", ["mesh", "hexamesh", "folded_hexa_torus"])
def test_paths_shortest_on_mesh_family(name):
    """Up*/down* with a central root preserves shortest paths on the
    mesh/hex families (stretch 1.0)."""
    topo = T.build(name, 64)
    r = build_routing(topo)
    hops = r.restricted_hops()
    assert hops.max() == topo.diameter


def test_all_pairs_reachable_all_topologies():
    for name in sorted(T.GENERATORS):
        if name in T.N_CONSTRAINTS and not T.N_CONSTRAINTS[name](16):
            continue
        topo = T.build(name, 16)
        r = build_routing(topo)
        u = TR.uniform(topo)
        loads, hops, lat = r.paths_channel_loads(u)   # raises on dead end
        off = ~np.eye(16, dtype=bool)
        assert (hops[off] >= 1).all()
        assert loads.sum() > 0


def test_channel_load_conservation():
    """Sum of channel loads == expected total hops per injected packet."""
    topo = T.build("folded_hexa_torus", 36)
    r = build_routing(topo)
    u = TR.uniform(topo)
    loads, hops, _ = r.paths_channel_loads(u)
    expected = (u * hops).sum()
    assert np.isclose(loads.sum(), expected, rtol=1e-9)


def test_saturation_ordering_matches_paper():
    """Fig. 4/7: FHT > HexaMesh > Mesh in relative saturation throughput
    under uniform traffic.  (FoldedTorus is excluded: our single-class
    turn-prohibition routing underutilizes its wrap rings — the paper's
    BookSim setup datelines them with VCs; divergence documented in
    EXPERIMENTS.md §Paper-validation.)"""
    sats = {}
    for name in ("mesh", "hexamesh", "folded_hexa_torus"):
        topo = T.build(name, 64)
        r = build_routing(topo)
        sats[name] = r.saturation_rate(TR.uniform(topo))
    assert sats["folded_hexa_torus"] > sats["hexamesh"]
    assert sats["hexamesh"] > sats["mesh"]


def test_latency_ordering_matches_paper():
    """Latency is primarily determined by diameter (§IV): FHT latency
    beats Mesh/HexaMesh."""
    from repro.core.simulator import zero_load_latency
    lats = {}
    for name in ("mesh", "hexamesh", "folded_hexa_torus"):
        topo = T.build(name, 64)
        r = build_routing(topo)
        lats[name] = zero_load_latency(r, TR.uniform(topo))
    assert lats["folded_hexa_torus"] < lats["hexamesh"] < lats["mesh"]


@given(seed=st.integers(0, 10_000))
@settings(max_examples=15, deadline=None)
def test_routing_on_random_connected_graphs(seed):
    """Property: on arbitrary connected graphs the routing is complete
    (every pair reachable via the table) and deadlock-free."""
    import networkx as nx
    rng = np.random.default_rng(seed)
    n = int(rng.integers(8, 24))
    g = nx.gnm_random_graph(n, int(n * 1.8), seed=seed)
    if not nx.is_connected(g):
        g = nx.compose(g, nx.path_graph(n))
    edges = np.array(sorted(tuple(sorted(e)) for e in g.edges()),
                     dtype=np.int32)
    pos = rng.uniform(0, np.sqrt(n), size=(n, 2))
    topo = T.Topology(name="rand", n=n, pos=pos, edges=edges,
                      substrate="organic", chiplet_area_mm2=74.0)
    r = build_routing(topo)
    u = np.ones((n, n))
    np.fill_diagonal(u, 0)
    u /= u.sum(1, keepdims=True)
    loads, hops, _ = r.paths_channel_loads(u)
    assert dependency_graph_is_acyclic(r)


def test_traffic_patterns_are_distributions():
    topo = T.build("folded_hexa_torus", 36, roles_scheme="hetero_cm")
    for name, fn in TR.PATTERNS.items():
        m = fn(topo)
        assert m.shape == (36, 36)
        assert np.all(np.diag(m) == 0)
        rows = m.sum(1)
        active = rows > 0
        assert np.allclose(rows[active], 1.0)
