"""Routing-layer tests: deadlock freedom, shortest paths, channel loads."""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import topology as T
from repro.core import traffic as TR
from repro.core.routing import build_routing, dependency_graph_is_acyclic


@pytest.mark.parametrize("name", ["mesh", "folded_torus", "hexamesh",
                                  "folded_hexa_torus", "octamesh",
                                  "honeycomb_mesh", "kite_medium",
                                  "sid_mesh"])
def test_deadlock_free(name):
    topo = T.build(name, 36)
    r = build_routing(topo)
    assert dependency_graph_is_acyclic(r)


@pytest.mark.parametrize("name", ["mesh", "hexamesh", "folded_hexa_torus"])
def test_paths_shortest_on_mesh_family(name):
    """Up*/down* with a central root preserves shortest paths on the
    mesh/hex families (stretch 1.0)."""
    topo = T.build(name, 64)
    r = build_routing(topo)
    hops = r.restricted_hops()
    assert hops.max() == topo.diameter


def test_all_pairs_reachable_all_topologies():
    for name in sorted(T.GENERATORS):
        if name in T.N_CONSTRAINTS and not T.N_CONSTRAINTS[name](16):
            continue
        topo = T.build(name, 16)
        r = build_routing(topo)
        u = TR.uniform(topo)
        loads, hops, lat = r.paths_channel_loads(u)   # raises on dead end
        off = ~np.eye(16, dtype=bool)
        assert (hops[off] >= 1).all()
        assert loads.sum() > 0


def test_channel_load_conservation():
    """Sum of channel loads == expected total hops per injected packet."""
    topo = T.build("folded_hexa_torus", 36)
    r = build_routing(topo)
    u = TR.uniform(topo)
    loads, hops, _ = r.paths_channel_loads(u)
    expected = (u * hops).sum()
    assert np.isclose(loads.sum(), expected, rtol=1e-9)


def test_saturation_ordering_matches_paper():
    """Fig. 4/7: FHT > HexaMesh > Mesh in relative saturation throughput
    under uniform traffic.  (FoldedTorus is excluded: our single-class
    turn-prohibition routing underutilizes its wrap rings — the paper's
    BookSim setup datelines them with VCs; divergence documented in
    EXPERIMENTS.md §Paper-validation.)"""
    sats = {}
    for name in ("mesh", "hexamesh", "folded_hexa_torus"):
        topo = T.build(name, 64)
        r = build_routing(topo)
        sats[name] = r.saturation_rate(TR.uniform(topo))
    assert sats["folded_hexa_torus"] > sats["hexamesh"]
    assert sats["hexamesh"] > sats["mesh"]


def test_latency_ordering_matches_paper():
    """Latency is primarily determined by diameter (§IV): FHT latency
    beats Mesh/HexaMesh."""
    from repro.core.simulator import zero_load_latency
    lats = {}
    for name in ("mesh", "hexamesh", "folded_hexa_torus"):
        topo = T.build(name, 64)
        r = build_routing(topo)
        lats[name] = zero_load_latency(r, TR.uniform(topo))
    assert lats["folded_hexa_torus"] < lats["hexamesh"] < lats["mesh"]


@given(seed=st.integers(0, 10_000))
@settings(max_examples=15, deadline=None)
def test_routing_on_random_connected_graphs(seed):
    """Property: on arbitrary connected graphs the routing is complete
    (every pair reachable via the table) and deadlock-free."""
    import networkx as nx
    rng = np.random.default_rng(seed)
    n = int(rng.integers(8, 24))
    g = nx.gnm_random_graph(n, int(n * 1.8), seed=seed)
    if not nx.is_connected(g):
        g = nx.compose(g, nx.path_graph(n))
    edges = np.array(sorted(tuple(sorted(e)) for e in g.edges()),
                     dtype=np.int32)
    pos = rng.uniform(0, np.sqrt(n), size=(n, 2))
    topo = T.Topology(name="rand", n=n, pos=pos, edges=edges,
                      substrate="organic", chiplet_area_mm2=74.0)
    r = build_routing(topo)
    u = np.ones((n, n))
    np.fill_diagonal(u, 0)
    u /= u.sum(1, keepdims=True)
    loads, hops, _ = r.paths_channel_loads(u)
    assert dependency_graph_is_acyclic(r)


def test_traffic_patterns_are_distributions():
    topo = T.build("folded_hexa_torus", 36, roles_scheme="hetero_cm")
    for name, fn in TR.PATTERNS.items():
        m = fn(topo)
        assert m.shape == (36, 36)
        assert np.all(np.diag(m) == 0)
        rows = m.sum(1)
        active = rows > 0
        assert np.allclose(rows[active], 1.0)


# ---------------------------------------------------------------------
# fault-masked routing properties (DESIGN.md §12)
# ---------------------------------------------------------------------

_FAULT_TOPOS = ("mesh", "torus", "hexamesh", "folded_hexa_torus",
                "honeycomb_mesh", "kite_medium")


@given(name=st.sampled_from(_FAULT_TOPOS), k=st.integers(1, 4),
       seed=st.integers(0, 1000))
@settings(max_examples=40, deadline=None)
def test_fault_masked_routing_stays_deadlock_free_and_complete(name, k,
                                                               seed):
    """Property: any survivable random link-fault draw leaves routing
    deadlock-free (acyclic CDG) and fully reachable (every pair routed
    without a dead end)."""
    import repro.faults as F
    topo = T.build(name, 16)
    try:
        fs = F.sample_faults(topo, k, "random", seed=seed)
    except F.FaultError:
        return                       # fewer than k survivable faults
    deg = fs.apply(topo)
    r = build_routing(deg)
    assert dependency_graph_is_acyclic(r)
    loads, hops, _ = r.paths_channel_loads(TR.uniform(deg))
    off = ~np.eye(deg.n, dtype=bool)
    assert (hops[off] >= 1).all()
    assert loads.sum() > 0


@given(name=st.sampled_from(_FAULT_TOPOS), k=st.integers(1, 3),
       seed=st.integers(0, 1000))
@settings(max_examples=25, deadline=None)
def test_chiplet_fault_routing_reaches_all_survivors(name, k, seed):
    """Property: with k dead chiplets, routing on the degraded topology
    is deadlock-free and reaches every surviving pair; dead chiplets
    neither inject nor receive in the masked traffic."""
    import repro.faults as F
    topo = T.build(name, 16)
    try:
        fs = F.sample_faults(topo, k, "chiplets", seed=seed)
    except F.FaultError:
        return
    deg = fs.apply(topo)
    r = build_routing(deg)
    assert dependency_graph_is_acyclic(r)
    tm = fs.mask_traffic(TR.uniform(topo))
    alive = fs.alive(topo.n)
    assert tm[~alive].sum() == 0 and tm[:, ~alive].sum() == 0
    loads, hops, _ = r.paths_channel_loads(tm)    # raises on dead end
    pair = np.outer(alive, alive) & ~np.eye(topo.n, dtype=bool)
    assert (hops[pair] >= 1).all()
    assert loads.sum() > 0


def test_disconnecting_fault_sets_are_rejected():
    """A fault set that partitions the survivors is a clear error at
    apply time, and the planner-facing probe agrees."""
    import repro.faults as F
    topo = T.build("mesh", 16)
    e = np.sort(np.asarray(topo.edges), axis=1)
    cut = tuple(tuple(int(x) for x in lk) for lk in e[(e == 0).any(1)])
    with pytest.raises(F.DisconnectedFaultError, match="islands"):
        F.FaultSet(links=cut).apply(topo)
    assert not F.surviving_connected(topo, F.FaultSet(links=cut))
