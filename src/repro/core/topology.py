"""ICI topology generators — all 17 topologies of paper Table III.

Every generator returns a `Topology`: chiplet centre positions (pitch
units), an undirected edge list, and derived properties (radix, diameter,
link lengths in mm, link-range).

The *folded* topologies are built with a single primitive, `fold_chain`:
given the ordered chain of chiplets along one topological axis, the folded
ring connects every chiplet to the one **two positions away** plus the two
end pairs — i.e. the classic folded-torus interleaving expressed directly
in physical order.  Every folded link has link-range exactly one
(Principle 2), and each axis contributes ring (not path) distances, which
halves the per-axis diameter (Principle 1):

    chain  a-b-c-d-e-f      (path, diameter 5)
    folded a-c-e ... f-d-b  (ring a,c,e,f,d,b: diameter 3)

* FoldedTorus       = fold rows + fold columns of a Mesh          (radix 4)
* FoldedHexaTorus   = fold all three axes of a HexaMesh           (radix 6)
* FoldedOctaTorus   = fold rows, columns and both diagonal axes
                      of an OctaMesh                               (radix 8)

Baselines whose original papers target different substrates
(DoubleButterfly, ButterDonut, ClusCross, Kite, SID-Mesh) are
reconstructed from their published descriptions and Table III's
radix/diameter/link-range; the paper itself adapts them ("we adapt them to
our setting"), so bit-exactness with the originals is not expected —
structural properties are validated in tests/test_topology.py.
"""
from __future__ import annotations

import dataclasses
import hashlib
import math
from typing import Callable

import numpy as np
import scipy.sparse as sp
import scipy.sparse.csgraph as csgraph

from . import placement as pl
from .linkmodel import CHIPLET_AREA_MM2


def link_range_from_pitch(dist_pitch) -> np.ndarray:
    """The paper's §III-B link-range convention, shared by
    `Topology.link_ranges` and the synthesis design space
    (`synth.space.candidate_pairs`): round(centre distance in pitch
    units) - 1, floored at 0 — one copy, so generation and the
    feasibility filter can never disagree on the budget."""
    return np.maximum(np.rint(np.asarray(dist_pitch)).astype(int) - 1, 0)


@dataclasses.dataclass
class Topology:
    name: str
    n: int
    pos: np.ndarray            # [N, 2] centres, pitch units
    edges: np.ndarray          # [E, 2] undirected, int32
    substrate: str
    chiplet_area_mm2: float
    roles: np.ndarray | None = None   # 'C'/'M'/'I' per chiplet

    # ---- geometry ----------------------------------------------------
    @property
    def pitch_mm(self) -> float:
        return pl.pitch_mm(self.chiplet_area_mm2, self.substrate)

    @property
    def side_mm(self) -> float:
        return pl.chiplet_side_mm(self.chiplet_area_mm2)

    def pos_mm(self) -> np.ndarray:
        return self.pos * self.pitch_mm

    def link_lengths_mm(self) -> np.ndarray:
        """Centre-to-centre link lengths in mm (Fig. 2 gray band uses the
        same convention: a range-1 straight link spans ~2 pitches)."""
        p = self.pos_mm()
        d = p[self.edges[:, 0]] - p[self.edges[:, 1]]
        return np.sqrt((d ** 2).sum(-1))

    def max_link_length_mm(self) -> float:
        return float(self.link_lengths_mm().max()) if len(self.edges) else 0.0

    def link_ranges(self) -> np.ndarray:
        """Number of intermediate chiplets a link stretches across
        (paper §III-B definition; adjacency -> 0)."""
        return link_range_from_pitch(self.link_lengths_mm()
                                     / self.pitch_mm)

    # ---- graph properties ---------------------------------------------
    def adjacency(self) -> sp.csr_matrix:
        e = self.edges
        data = np.ones(len(e) * 2)
        ij = np.concatenate([e, e[:, ::-1]])
        return sp.csr_matrix((data, (ij[:, 0], ij[:, 1])),
                             shape=(self.n, self.n))

    def degrees(self) -> np.ndarray:
        return np.asarray(self.adjacency().sum(axis=1)).ravel().astype(int)

    @property
    def radix(self) -> int:
        return int(self.degrees().max())

    def hop_matrix(self) -> np.ndarray:
        return csgraph.shortest_path(self.adjacency(), method="D",
                                     unweighted=True)

    @property
    def diameter(self) -> int:
        h = self.hop_matrix()
        if np.isinf(h).any():
            raise ValueError(f"{self.name}: graph is disconnected")
        return int(h.max())

    @property
    def avg_hops(self) -> float:
        h = self.hop_matrix()
        return float(h.sum() / (self.n * (self.n - 1)))

    def is_connected(self) -> bool:
        ncomp, _ = csgraph.connected_components(self.adjacency())
        return ncomp == 1

    def structural_hash(self) -> str:
        """Stable hash of the topology *structure and geometry* — node
        count, canonical undirected edge set, and centre positions
        (quantized to 1e-6 pitch).  Two topologies with equal hashes
        route identically for a given (substrate, area), so this is the
        cache identity for `routing.routing_for` — names are labels,
        not identities (synthesized topologies may share or reuse
        names)."""
        e = np.sort(np.asarray(self.edges, np.int64), axis=1)
        e = e[np.lexsort((e[:, 1], e[:, 0]))]
        q = np.rint(np.asarray(self.pos, np.float64) * 1e6).astype(np.int64)
        h = hashlib.sha256()
        h.update(np.int64(self.n).tobytes())
        h.update(e.tobytes())
        h.update(q.tobytes())
        return h.hexdigest()


# =====================================================================
# helpers
# =====================================================================

def _dedupe(edges: list[tuple[int, int]]) -> np.ndarray:
    es = {(min(a, b), max(a, b)) for a, b in edges if a != b}
    return np.array(sorted(es), dtype=np.int32)


def validate_edges(n: int, edges: np.ndarray, name: str = "topology",
                   require_connected: bool = True) -> np.ndarray:
    """Validate a raw undirected edge list against graph invariants.

    The synthesis engine (repro.synth) feeds `build`/`make_topology`
    arbitrary generated edge lists, so the invariants the hand-written
    generators maintain by construction are enforced here with clear
    errors: indices in range, no self-loops, no duplicate undirected
    edges, and (by default) a single connected component.  Returns the
    edges as a canonical int32 [E, 2] array.
    """
    e = np.asarray(edges, dtype=np.int64)
    if e.size == 0:
        e = e.reshape(0, 2)
    if e.ndim != 2 or e.shape[1] != 2:
        raise ValueError(f"{name}: edges must be [E, 2], got {e.shape}")
    if e.size and (e.min() < 0 or e.max() >= n):
        bad = e[(e[:, 0] < 0) | (e[:, 0] >= n)
                | (e[:, 1] < 0) | (e[:, 1] >= n)][0]
        raise ValueError(f"{name}: edge {tuple(int(x) for x in bad)} "
                         f"out of range for N={n}")
    loops = e[e[:, 0] == e[:, 1]]
    if len(loops):
        raise ValueError(f"{name}: self-loop at node {int(loops[0, 0])}")
    und = np.sort(e, axis=1)
    uniq, counts = np.unique(und, axis=0, return_counts=True)
    if (counts > 1).any():
        dup = uniq[counts > 1][0]
        raise ValueError(f"{name}: duplicate edge {tuple(int(x) for x in dup)}")
    if require_connected:
        if len(e) < n - 1:
            raise ValueError(f"{name}: disconnected graph "
                             f"({len(e)} edges < N-1={n - 1})")
        data = np.ones(len(e) * 2)
        ij = np.concatenate([e, e[:, ::-1]])
        adj = sp.csr_matrix((data, (ij[:, 0], ij[:, 1])), shape=(n, n))
        ncomp, _ = csgraph.connected_components(adj)
        if ncomp != 1:
            raise ValueError(f"{name}: disconnected graph "
                             f"({ncomp} components)")
    return np.asarray(und[np.lexsort((und[:, 1], und[:, 0]))],
                      dtype=np.int32)


def make_topology(name: str, pos: np.ndarray, edges: np.ndarray,
                  substrate: str = "organic",
                  chiplet_area_mm2: float = CHIPLET_AREA_MM2,
                  roles_scheme: str = "homogeneous") -> Topology:
    """Build a validated `Topology` from raw position/edge arrays.

    This is the front door for *custom* topologies (the synthesis
    engine, notebooks, registered generators): the same validation as
    `build`, with positions given directly instead of via a generator.
    """
    pos = np.asarray(pos, dtype=np.float64)
    n = len(pos)
    if pos.ndim != 2 or pos.shape[1] != 2:
        raise ValueError(f"{name}: pos must be [N, 2], got {pos.shape}")
    edges = validate_edges(n, edges, name=name)
    topo = Topology(name=name, n=n, pos=pos, edges=edges,
                    substrate=substrate,
                    chiplet_area_mm2=chiplet_area_mm2)
    topo.roles = pl.assign_roles(pos, roles_scheme)
    return topo


def fold_chain(chain: list[int]) -> list[tuple[int, int]]:
    """Folded-ring links for one physical chain (see module docstring)."""
    k = len(chain)
    if k < 2:
        return []
    if k == 2:
        return [(chain[0], chain[1])]
    edges = [(chain[j], chain[j + 2]) for j in range(k - 2)]
    edges.append((chain[0], chain[1]))
    edges.append((chain[k - 2], chain[k - 1]))
    return edges


def _grid_chains_rows(rows, cols):
    return [[i * cols + j for j in range(cols)] for i in range(rows)]


def _grid_chains_cols(rows, cols):
    return [[i * cols + j for i in range(rows)] for j in range(cols)]


def _diag_chains(rows, cols, slope):
    """Diagonal chains on a rectangular grid; slope=+1 is down-right."""
    chains = []
    starts = [(0, j) for j in range(cols)]
    starts += [(i, 0 if slope > 0 else cols - 1) for i in range(1, rows)]
    for (i0, j0) in starts:
        chain, i, j = [], i0, j0
        while 0 <= i < rows and 0 <= j < cols:
            chain.append(i * cols + j)
            i, j = i + 1, j + slope
        if len(chain) >= 2:
            chains.append(chain)
    return chains


def _brick_next(i, j, direction):
    """Successor in a brick-wall diagonal walk.  direction: 'dr'/'dl'."""
    if direction == "dr":
        return (i + 1, j) if i % 2 == 0 else (i + 1, j + 1)
    return (i + 1, j - 1) if i % 2 == 0 else (i + 1, j)


def _brick_chains(rows, cols, direction):
    """Maximal diagonal chains of a brick-wall lattice."""
    def prev(i, j):
        # invert _brick_next
        if direction == "dr":
            return (i - 1, j) if (i - 1) % 2 == 0 else (i - 1, j - 1)
        return (i - 1, j + 1) if (i - 1) % 2 == 0 else (i - 1, j)

    chains = []
    for i0 in range(rows):
        for j0 in range(cols):
            pi, pj = prev(i0, j0)
            if 0 <= pi < rows and 0 <= pj < cols:
                continue  # not a chain head
            chain, i, j = [], i0, j0
            while 0 <= i < rows and 0 <= j < cols:
                chain.append(i * cols + j)
                i, j = _brick_next(i, j, direction)
            if len(chain) >= 2:
                chains.append(chain)
    return chains


# =====================================================================
# generators (rectangular-grid placement)
# =====================================================================

def _grid_topo(name, n, edges_fn, brick=False, **kw):
    rows, cols = pl.grid_dims(n)
    pos = pl.grid_positions(rows, cols, brick=brick)
    edges = edges_fn(rows, cols)
    return name, pos, _dedupe(edges)


def _mesh_edges(rows, cols):
    e = []
    for ch in _grid_chains_rows(rows, cols) + _grid_chains_cols(rows, cols):
        e += list(zip(ch[:-1], ch[1:]))
    return e


def gen_mesh(n, **kw):
    return _grid_topo("mesh", n, _mesh_edges)


def gen_torus(n, **kw):
    def edges(rows, cols):
        e = _mesh_edges(rows, cols)
        for ch in _grid_chains_rows(rows, cols) + _grid_chains_cols(rows, cols):
            if len(ch) > 2:
                e.append((ch[0], ch[-1]))
        return e
    return _grid_topo("torus", n, edges)


def gen_folded_torus(n, **kw):
    def edges(rows, cols):
        e = []
        for ch in _grid_chains_rows(rows, cols) + _grid_chains_cols(rows, cols):
            e += fold_chain(ch)
        return e
    return _grid_topo("folded_torus", n, edges)


def gen_octamesh(n, **kw):
    def edges(rows, cols):
        e = _mesh_edges(rows, cols)
        for slope in (+1, -1):
            for ch in _diag_chains(rows, cols, slope):
                e += list(zip(ch[:-1], ch[1:]))
        return e
    return _grid_topo("octamesh", n, edges)


def gen_folded_octa_torus(n, **kw):
    def edges(rows, cols):
        e = []
        for ch in _grid_chains_rows(rows, cols) + _grid_chains_cols(rows, cols):
            e += fold_chain(ch)
        for slope in (+1, -1):
            for ch in _diag_chains(rows, cols, slope):
                e += fold_chain(ch)
        return e
    return _grid_topo("folded_octa_torus", n, edges)


# ---- hex family (brick-wall placement) -------------------------------

def _hexa_edges(rows, cols):
    e = []
    for ch in _grid_chains_rows(rows, cols):
        e += list(zip(ch[:-1], ch[1:]))
    for d in ("dr", "dl"):
        for ch in _brick_chains(rows, cols, d):
            e += list(zip(ch[:-1], ch[1:]))
    return e


def gen_hexamesh(n, hex_region=False, **kw):
    if hex_region:
        return _hex_region_topo("hexamesh", n, folded=False)
    return _grid_topo("hexamesh", n, _hexa_edges, brick=True)


def gen_folded_hexa_torus(n, hex_region=False, **kw):
    if hex_region:
        return _hex_region_topo("folded_hexa_torus", n, folded=True)

    def edges(rows, cols):
        e = []
        for ch in _grid_chains_rows(rows, cols):
            e += fold_chain(ch)
        for d in ("dr", "dl"):
            for ch in _brick_chains(rows, cols, d):
                e += fold_chain(ch)
        return e
    return _grid_topo("folded_hexa_torus", n, edges, brick=True)


def _hex_region_topo(name, n, folded):
    """Hex-spiral region variant (validates Table III formulas at perfect
    hex counts N = 3R^2+3R+1)."""
    pos = pl.hex_spiral_positions(n)
    # identify the three axes by direction between unit-distance neighbours
    key = {tuple(np.round(p * 2).astype(int)): i for i, p in enumerate(pos)}

    def axis_chains(step):
        chains, seen = [], set()
        for idx in range(n):
            p = pos[idx]
            prev = tuple(np.round((p - step) * 2).astype(int))
            if prev in key:
                continue
            chain, cur = [], tuple(np.round(p * 2).astype(int))
            while cur in key:
                chain.append(key[cur])
                cur = (cur[0] + int(round(step[0] * 2)),
                       cur[1] + int(round(step[1] * 2)))
            if len(chain) >= 2:
                chains.append(chain)
        return chains

    steps = [np.array([1.0, 0.0]), np.array([0.5, 1.0]), np.array([-0.5, 1.0])]
    e = []
    for s in steps:
        for ch in axis_chains(s):
            e += fold_chain(ch) if folded else list(zip(ch[:-1], ch[1:]))
    return name, pos, _dedupe(e)


# ---- interposer-baseline reconstructions ------------------------------

def gen_double_butterfly(n, **kw):
    def edges(rows, cols):
        e = []
        for ch in _grid_chains_cols(rows, cols):
            e += list(zip(ch[:-1], ch[1:]))
        for i in range(rows):
            stride = max(cols // 2, 1) if i % 2 == 0 else max(cols // 4, 1)
            for j in range(cols - stride):
                e.append((i * cols + j, i * cols + j + stride))
            # short pair links, staggered per row so stride classes mix
            off = i % 2
            for j in range(off, cols - 1, 2):
                e.append((i * cols + j, i * cols + j + 1))
        return e
    return _grid_topo("double_butterfly", n, edges)


def gen_butterdonut(n, **kw):
    def edges(rows, cols):
        name_, pos_, e = gen_double_butterfly(rows * cols)
        e = [tuple(x) for x in e]
        half = max(cols // 2, 1)
        for i in range(1, rows, 2):    # donut links: half-row spans on the
            if cols > 2:               # rows that only have c/4 strides
                e.append((i * cols, i * cols + half))
                e.append((i * cols + cols - 1 - half, i * cols + cols - 1))
        return e
    return _grid_topo("butterdonut", n, edges)


def _cluscross_edges(rows, cols, version):
    """ClusCross reconstruction: 2x2 clusters wired as rings; one inter-
    cluster link per node forming a cluster-level mesh, except that each
    cluster's eastbound link is replaced by a long *cross* link — to the
    row-mirrored cluster (V1) or to the cluster half a row away (V2)."""
    e = []
    cr, cc = rows // 2, cols // 2     # cluster grid
    def corners(I, J):
        # [TL, TR, BL, BR]
        return [(2 * I) * cols + 2 * J, (2 * I) * cols + 2 * J + 1,
                (2 * I + 1) * cols + 2 * J, (2 * I + 1) * cols + 2 * J + 1]
    for I in range(cr):
        for J in range(cc):
            tl, tr, bl, br = corners(I, J)
            e += [(tl, tr), (tr, br), (br, bl), (bl, tl)]   # intra ring
            if I > 0:                      # north: TL -> BL of cluster above
                e.append((tl, corners(I - 1, J)[2]))
            if J > 0:                      # west:  BL -> BR of left cluster
                e.append((bl, corners(I, J - 1)[3]))
            # east cross link from TR
            J2 = (cc - 1 - J) if version == 1 else (J + cc // 2) % cc
            if J2 != J:
                e.append((tr, corners(I, J2)[0]))
    return e


def gen_cluscross_v1(n, **kw):
    return _grid_topo("cluscross_v1", n,
                      lambda r, c: _cluscross_edges(r, c, 1))


def gen_cluscross_v2(n, **kw):
    return _grid_topo("cluscross_v2", n,
                      lambda r, c: _cluscross_edges(r, c, 2))


def _kite_diag_edges(rows, cols):
    e = []
    for i in range(rows - 1):
        for j in range(cols):
            jj = j + 1 if j % 2 == 0 else j - 1
            if 0 <= jj < cols:
                e.append((i * cols + j, (i + 1) * cols + jj))
    return e


def gen_kite_small(n, **kw):
    def edges(rows, cols):
        e = []
        for ch in _grid_chains_rows(rows, cols):
            e += list(zip(ch[:-1], ch[1:]))
        e += _kite_diag_edges(rows, cols)
        return e
    return _grid_topo("kite_small", n, edges)


def gen_kite_medium(n, **kw):
    def edges(rows, cols):
        e = []
        for i, ch in enumerate(_grid_chains_rows(rows, cols)):
            e += (fold_chain(ch) if i % 2 == 1 else
                  list(zip(ch[:-1], ch[1:])))
        e += _kite_diag_edges(rows, cols)
        return e
    return _grid_topo("kite_medium", n, edges)


def gen_kite_large(n, **kw):
    def edges(rows, cols):
        e = []
        for ch in _grid_chains_rows(rows, cols):
            e += fold_chain(ch)
        e += _kite_diag_edges(rows, cols)
        return e
    return _grid_topo("kite_large", n, edges)


def gen_sid_mesh(n, **kw):
    def edges(rows, cols):
        e = []
        for slope in (+1, -1):
            for ch in _diag_chains(rows, cols, slope):
                e += list(zip(ch[:-1], ch[1:]))
        # orthogonal boundary links join the two diagonal sublattices
        for j in range(cols - 1):
            e.append((j, j + 1))
            e.append(((rows - 1) * cols + j, (rows - 1) * cols + j + 1))
        for i in range(rows - 1):
            e.append((i * cols, (i + 1) * cols))
            e.append((i * cols + cols - 1, (i + 1) * cols + cols - 1))
        return e
    return _grid_topo("sid_mesh", n, edges)


def gen_hypercube(n, **kw):
    k = int(round(math.log2(n)))
    if 2 ** k != n:
        raise ValueError(f"hypercube needs a power-of-two N, got {n}")
    rows, cols = pl.grid_dims(n)
    kr, kc = int(round(math.log2(rows))), int(round(math.log2(cols)))
    gray = lambda x: x ^ (x >> 1)
    # gray-code placement minimizes physical length of dimension links
    coord = np.zeros((n, 2))
    inv_gray_r = {gray(i): i for i in range(rows)}
    inv_gray_c = {gray(i): i for i in range(cols)}
    for v in range(n):
        hi, lo = v >> kc, v & (cols - 1)
        coord[v] = (inv_gray_c[lo] if lo in inv_gray_c else lo,
                    inv_gray_r[hi] if hi in inv_gray_r else hi)
    e = [(v, v ^ (1 << b)) for v in range(n) for b in range(k) if v < v ^ (1 << b)]
    return "hypercube", coord, _dedupe(e)


def gen_flattened_butterfly(n, **kw):
    def edges(rows, cols):
        e = []
        for ch in _grid_chains_rows(rows, cols) + _grid_chains_cols(rows, cols):
            for a in range(len(ch)):
                for b in range(a + 1, len(ch)):
                    e.append((ch[a], ch[b]))
        return e
    return _grid_topo("flattened_butterfly", n, edges)


def gen_honeycomb_mesh(n, **kw):
    def edges(rows, cols):
        e = []
        for ch in _grid_chains_rows(rows, cols):
            e += list(zip(ch[:-1], ch[1:]))
        for i in range(rows - 1):
            for j in range(cols):
                if (i + j) % 2 == 0:
                    e.append((i * cols + j, (i + 1) * cols + j))
        return e
    return _grid_topo("honeycomb_mesh", n, edges)


def gen_honeycomb_torus(n, **kw):
    def edges(rows, cols):
        e = []
        for ch in _grid_chains_rows(rows, cols):
            e += list(zip(ch[:-1], ch[1:]))
            if cols > 2:
                e.append((ch[0], ch[-1]))
        for i in range(rows - 1):
            for j in range(cols):
                if (i + j) % 2 == 0:
                    e.append((i * cols + j, (i + 1) * cols + j))
        for j in range(cols):            # vertical wraps keep degree 3
            if (rows - 1 + j) % 2 == 0 and rows > 2:
                e.append(((rows - 1) * cols + j, j))
        return e
    return _grid_topo("honeycomb_torus", n, edges)


# =====================================================================
# registry
# =====================================================================

GENERATORS: dict[str, Callable] = {
    "mesh": gen_mesh,
    "torus": gen_torus,
    "folded_torus": gen_folded_torus,
    "hexamesh": gen_hexamesh,
    "folded_hexa_torus": gen_folded_hexa_torus,
    "octamesh": gen_octamesh,
    "folded_octa_torus": gen_folded_octa_torus,
    "double_butterfly": gen_double_butterfly,
    "butterdonut": gen_butterdonut,
    "cluscross_v1": gen_cluscross_v1,
    "cluscross_v2": gen_cluscross_v2,
    "kite_small": gen_kite_small,
    "kite_medium": gen_kite_medium,
    "kite_large": gen_kite_large,
    "sid_mesh": gen_sid_mesh,
    "hypercube": gen_hypercube,
    "flattened_butterfly": gen_flattened_butterfly,
    "honeycomb_mesh": gen_honeycomb_mesh,
    "honeycomb_torus": gen_honeycomb_torus,
}

# topologies whose generators require power-of-two / even-grid N
N_CONSTRAINTS = {
    "hypercube": lambda n: (n & (n - 1)) == 0,
    "cluscross_v1": lambda n: all(d % 2 == 0 for d in pl.grid_dims(n)),
    "cluscross_v2": lambda n: all(d % 2 == 0 for d in pl.grid_dims(n)),
}

def valid_n(name: str, n: int) -> bool:
    """Does `name`'s generator accept this chiplet count?  (True for
    names without an entry in `N_CONSTRAINTS` — including custom
    generators, which validate at build time.)"""
    rule = N_CONSTRAINTS.get(name)
    return rule is None or bool(rule(n))


def nearest_valid_n(name: str, n: int) -> int:
    """Largest supported N' <= n for a constrained generator (falls
    back to the smallest supported N' > n when nothing below fits).
    Used by sweep CLIs so `--all-builtin -n 36` can still exercise
    e.g. the hypercube at 32 instead of skipping it."""
    if valid_n(name, n):
        return n
    for cand in range(n - 1, 1, -1):
        if valid_n(name, cand):
            return cand
    for cand in range(n + 1, 4 * n + 2):
        if valid_n(name, cand):
            return cand
    raise ValueError(f"{name}: no supported N near {n}")


#: user/synth-registered generators, consulted by `build` after the
#: built-in table.  A custom generator is `gen(n, **kw)` returning either
#: a `(name, pos, edges)` triple (the built-in convention) or a full
#: `Topology` (re-stamped with the requested substrate/area/roles).
CUSTOM_GENERATORS: dict[str, Callable] = {}


def register_topology(name: str, generator: Callable,
                      overwrite: bool = False) -> None:
    """Register a custom topology generator under `name` for `build`.

    Registered names live alongside the paper's Table-III registry: the
    experiment planner, `cached_routing` and benchmarks resolve them
    transparently.  Routing caching keys on the *structural hash* of
    what the generator emits, so re-registering a name with a different
    structure cannot serve stale routing (see routing.routing_for).
    """
    if name in GENERATORS:
        raise ValueError(f"{name!r} is a built-in Table-III topology; "
                         "pick a different name")
    if name in CUSTOM_GENERATORS and not overwrite:
        raise ValueError(f"{name!r} already registered; pass "
                         "overwrite=True to replace it")
    if not callable(generator):
        raise TypeError(f"generator for {name!r} must be callable")
    CUSTOM_GENERATORS[name] = generator


def unregister_topology(name: str) -> None:
    CUSTOM_GENERATORS.pop(name, None)


def build(name: str, n: int, substrate: str = "organic",
          chiplet_area_mm2: float = CHIPLET_AREA_MM2,
          roles_scheme: str = "homogeneous", hex_region: bool = False,
          ) -> Topology:
    if name in GENERATORS:
        if not valid_n(name, n):
            raise ValueError(f"{name} does not support N={n}")
        kw = {"hex_region": hex_region} if name in (
            "hexamesh", "folded_hexa_torus") else {}
        name_, pos, edges = GENERATORS[name](n, **kw)
    elif name in CUSTOM_GENERATORS:
        out = CUSTOM_GENERATORS[name](n)
        if isinstance(out, Topology):
            if out.n != n:
                raise ValueError(f"{name}: generator returned N={out.n}, "
                                 f"requested N={n}")
            name_, pos, edges = out.name, out.pos, out.edges
        else:
            name_, pos, edges = out
    else:
        raise KeyError(f"unknown topology {name!r}; choose from "
                       f"{sorted(GENERATORS)} or register_topology() it")
    if len(pos) != n:
        raise ValueError(f"{name_}: generator emitted {len(pos)} "
                         f"positions, requested N={n}")
    edges = validate_edges(len(pos), edges, name=name_)
    topo = Topology(name=name_, n=n, pos=np.asarray(pos, np.float64),
                    edges=edges,
                    substrate=substrate, chiplet_area_mm2=chiplet_area_mm2)
    topo.roles = pl.assign_roles(topo.pos, roles_scheme)
    return topo
