"""Cycle-based ICI network simulator, vectorized in JAX (paper §V-B).

BookSim semantics re-expressed as dense array updates so the whole
simulation `lax.scan`s over cycles, `vmap`s over injection rates, and —
since the sweep-engine rework — `vmap`s over *topologies* as well:

  * input-queued routers, V virtual channels x B-flit buffers per input
    port (paper: 4 x 4),
  * credit-based flow control with wire-delayed credit return,
  * two-phase separable switch allocation (rotating priority; an input
    port forwards at most one flit per cycle, an output port accepts at
    most one),
  * per-channel link pipelines whose depth is the Table-IV hop latency
    (router 3 ns + 2 PHY x 2 ns + wire ceil(L*sqrt(eps_r)/c)), cycle=1 ns,
  * one injection queue and one ejection port per chiplet (1 flit/cycle).

Packets are single-flit; multi-flit data packets are injected as bursts
(§V-E traces), which approximates wormhole serialization without ownership
state.  Saturation throughput is measured as the plateau of delivered
throughput over an offered-rate sweep (vmapped), the same quantity BookSim
reports as relative throughput T_r.

Batched execution (DESIGN.md §6)
--------------------------------
`run_batch` executes many heterogeneous `SimSpec`s — different node
counts, port counts, channel counts — in ONE jitted program.  Specs are
padded to a common shape by `repro.sweep.padding` and the step function is
written to be *padding-invariant*: a spec simulated inside a padded batch
produces counters bitwise-equal to the same spec simulated alone.  The
three ingredients:

  * injection randomness is a counter-based hash of (seed, cycle, node,
    stream) rather than `jax.random` array draws, whose values depend on
    the array length and therefore on padding;
  * every scatter either has provably unique indices, is a pure add of
    zeros for padded lanes, or routes padded lanes to a *sacrificial*
    row/slot (extra buffer slot B, extra channel row C) that is never
    read back — this also fixes a latent seed-code hazard where
    non-traversing ports default-wrote channel 0's link slot and could
    clobber a real flit under last-update-wins scatter semantics;
  * the rotating-priority counter advances modulo the spec's own
    V*(P_spec+1) and allocation receives it split into (rr % V,
    rr % PI_spec), which preserves the spec's priority *ordering* under a
    larger padded port axis.

Latency is accumulated per node in int32 (exact, order-independent) and
reduced to float in numpy, so no float reduction depends on padding.

The pure-jnp allocation (`router_phase` / `_alloc_jnp`) also serves as
the reference oracle for the Pallas `netstep` kernel (see repro/kernels);
`SimConfig.alloc` selects the implementation ("auto" uses the kernel on
TPU and the jnp path elsewhere).
"""
from __future__ import annotations

import dataclasses
import os
from collections import OrderedDict
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.obs.profile import profiling_enabled
from repro.obs.trace import trace as _span
from repro.obs.trace import tracing_enabled as _tracing

from . import linkmodel as lm
from .routing import Routing

INF = jnp.int32(2 ** 30)

_GOLD = np.uint32(0x9E3779B9)
_MIX_T = np.uint32(0x85EBCA6B)
_MIX_N = np.uint32(0xC2B2AE3D)

#: flight-recorder latency-histogram bins: bin h counts ejections with
#: latency in [2^(h-1), 2^h) cycles (bin 0: latency < 1 is impossible,
#: so it stays 0; the last bin is open-ended).  Coarse by design — the
#: histogram shape distinguishes "near zero-load" from "saturating"
#: without carrying a per-packet tensor through the scan.
LAT_HIST_BINS = 16

#: per-spec result keys added by `SimConfig(telemetry=True)`; every one
#: has a leading rate axis R (DESIGN.md §13).  `link_occ_escape` /
#: `link_occ_adaptive` split the per-VC occupancy sums into the escape
#: class (VC 0) and the adaptive class (VCs 1..V-1) of the DESIGN.md §15
#: VC partition — derived host-side from `link_occ_sum`, so they are
#: padding-invariant like every other counter.
TELEMETRY_KEYS = ("link_busy", "link_stall", "link_occ_sum", "link_util",
                  "link_occ_escape", "link_occ_adaptive",
                  "inj_node", "eject_node", "lat_hist")

#: additional per-spec result keys when `SimConfig(telemetry_windows=W)`
#: bins the flight recorder over time (DESIGN.md §16).  Every counter
#: key gains a window axis W right after the rate axis; the per-window
#: tensors sum over W to the aggregate counters EXACTLY (same masks,
#: same int adds, each measured cycle lands in exactly one window) and
#: are padding-invariant by the same sacrificial-slot discipline.
#: `window_cycles` [W] is the host-side normalizer (cycles per window).
TELEMETRY_WINDOW_KEYS = ("link_busy_w", "link_stall_w", "link_occ_w",
                         "link_util_w", "inj_node_w", "eject_node_w",
                         "window_cycles")

#: rate-grid headroom above the static analytic bound (DESIGN.md §15):
#: static sweeps plateau below the analytic estimate, adaptive sweeps
#: can exceed it (routing around congestion), so their grid must extend
#: further or it clips the most interesting region.
STATIC_HEADROOM = 2.0
ADAPTIVE_HEADROOM = 3.0


class SimConfig(NamedTuple):
    n_vcs: int = 4
    buf_depth: int = 4
    cycles: int = 3000
    warmup: int = 1000
    seed: int = 0
    alloc: str = "auto"     # "auto" | "jnp" | "pallas"
    telemetry: bool = False  # flight recorder (DESIGN.md §13); off path
    #                          is bitwise identical to pre-telemetry code
    routing: str = "static"  # "static" | "adaptive" (DESIGN.md §15);
    #                          "static" is bitwise identical to the
    #                          pre-adaptive simulator
    telemetry_windows: int = 0  # W > 0 bins the flight recorder into W
    #                          time windows over the measured cycles
    #                          (DESIGN.md §16); requires telemetry=True;
    #                          0 leaves the compiled program unchanged


class SimState(NamedTuple):
    buf_dst: jnp.ndarray     # [N, PI, V, B+1] destination (-1 empty; slot B
    buf_t: jnp.ndarray      # [N, PI, V, B+1]  is a sacrificial write sink)
    head: jnp.ndarray        # [N, PI, V]
    cnt: jnp.ndarray         # [N, PI, V]
    credits: jnp.ndarray     # [N, P, V]
    link_dst: jnp.ndarray    # [C+1, D] (row C is a sacrificial write sink)
    link_t: jnp.ndarray      # [C+1, D]
    link_vc: jnp.ndarray     # [C+1, D]
    credit_pipe: jnp.ndarray  # [C+1, D, V]
    rr: jnp.ndarray          # [] rotating priority
    delivered: jnp.ndarray   # []
    lat_node: jnp.ndarray    # [N] int32 summed ejection latency per node
    offered: jnp.ndarray     # []
    accepted: jnp.ndarray    # []
    # per-phase counters (workload mode only; None in static mode)
    delivered_ph: jnp.ndarray | None = None   # [K]
    offered_ph: jnp.ndarray | None = None     # [K]
    accepted_ph: jnp.ndarray | None = None    # [K]
    lat_ph: jnp.ndarray | None = None         # [K, N] int32
    # flight-recorder counters (telemetry mode only; DESIGN.md §13).
    # Row C / padded tails are sacrificial, sliced away host-side.
    tel_busy: jnp.ndarray | None = None       # [C+1] measured traversals
    tel_stall: jnp.ndarray | None = None      # [C+1] credit-starved cycles
    tel_occ: jnp.ndarray | None = None        # [C+1, V] occupancy sums
    tel_inj: jnp.ndarray | None = None        # [N] accepted injections
    tel_eject: jnp.ndarray | None = None      # [N] ejections
    tel_hist: jnp.ndarray | None = None       # [LAT_HIST_BINS] latency
    # windowed flight-recorder counters (telemetry_windows=W > 0 only;
    # DESIGN.md §16).  Same sacrificial-row discipline, one extra
    # leading window axis; each sums over W to its aggregate above.
    tel_busy_w: jnp.ndarray | None = None     # [W, C+1]
    tel_stall_w: jnp.ndarray | None = None    # [W, C+1]
    tel_occ_w: jnp.ndarray | None = None      # [W, C+1, V]
    tel_inj_w: jnp.ndarray | None = None      # [W, N]
    tel_eject_w: jnp.ndarray | None = None    # [W, N]


@dataclasses.dataclass
class SimSpec:
    """Static simulator inputs derived from a Routing + traffic matrix."""
    n: int
    p: int                  # max real ports
    c: int                  # directed channels
    d: int                  # link pipeline ring depth
    table: np.ndarray       # [N_dst, N, P+1] -> out port, EJECT=-2
    out_ch: np.ndarray      # [N, P]
    in_ch: np.ndarray       # [N, P]
    ch_dst: np.ndarray      # [C]
    ch_in_port: np.ndarray  # [C]
    ch_src: np.ndarray
    ch_out_port: np.ndarray
    ch_depth: np.ndarray    # [C] pipeline depth (cycles per hop)
    traffic_cum: np.ndarray  # [N, N] cumulative traffic rows
    inj_weight: np.ndarray   # [N] relative injection rate per node
    # productive-ports mask [N_dst, N, P] (DESIGN.md §15); consumed only
    # by the adaptive runner — the static runner never reads it, so the
    # leaf is dead-code-eliminated from the compiled static program
    prod: np.ndarray = None


def _traffic_arrays(traffic: np.ndarray) -> tuple[np.ndarray, np.ndarray]:
    """(cumulative rows, injection weights) for one traffic matrix.

    Shared by the static `make_spec` path and the phase-schedule compiler
    (`make_sched_spec`) so a single-phase schedule reproduces the static
    arrays bitwise — the workload path is a strict generalization.
    """
    rows = traffic.sum(axis=1)
    inj_weight = rows / max(rows.max(), 1e-12)
    cum = np.cumsum(traffic, axis=1)
    cum = cum / np.maximum(cum[:, -1:], 1e-12)
    cum[rows <= 0] = 1.0   # inert sources: any draw maps to dst 0, gated off
    return cum, inj_weight


def make_spec(routing: Routing, traffic: np.ndarray) -> SimSpec:
    from .routing import productive_ports
    depth = lm.hop_latency_cycles(routing.ch_len_mm, routing.topo.substrate)
    depth = np.maximum(np.asarray(depth, np.int32), 1)
    d = int(depth.max()) + 1
    cum, inj_weight = _traffic_arrays(traffic)
    return SimSpec(
        n=routing.topo.n, p=routing.max_ports, c=routing.n_channels, d=d,
        table=routing.table, out_ch=routing.out_ch, in_ch=routing.in_ch,
        ch_dst=routing.ch_dst, ch_in_port=routing.ch_in_port,
        ch_src=routing.ch_src, ch_out_port=routing.ch_out_port,
        ch_depth=depth, traffic_cum=cum, inj_weight=inj_weight,
        prod=productive_ports(routing))


# =====================================================================
# phase schedules (time-varying workloads, DESIGN.md §9)
# =====================================================================

@dataclasses.dataclass
class SchedSpec:
    """Compiled phase schedule for one spec (numpy, [K, ...] leaves).

    A workload is a sequence of K phases; phase k is active for cycles
    [start[k], end[k]) of the schedule, which replays cyclically
    (`t_eff = t % total`).  During a phase, injection draws destinations
    from that phase's cumulative traffic rows and offers
    `rate * gain * inj_w[node]` flits/cycle, where the gain is
    `gain_on[k]` inside the ON window of the phase's ON/OFF burst
    modulation and 0 inside the OFF window (no modulation: always ON,
    `gain_on == intensity`).
    """
    k: int
    n: int
    cum: np.ndarray       # [K, N, N] cumulative traffic rows per phase
    inj_w: np.ndarray     # [K, N] relative injection weight per phase
    gain_on: np.ndarray   # [K] float32 rate gain inside the ON window
    start: np.ndarray     # [K] int32 cumulative phase start (cycles)
    end: np.ndarray       # [K] int32 cumulative phase end (cycles)
    on: np.ndarray        # [K] int32 ON window length
    period: np.ndarray    # [K] int32 ON+OFF period (>= 1)
    total: int            # schedule length in cycles


def make_sched_spec(phases) -> SchedSpec:
    """Compile (traffic, intensity, duration[, burst_on, burst_off])
    tuples into a `SchedSpec`.

    intensity scales the offered rate for the whole phase; burst_on/off
    add ON/OFF modulation *within* the phase: during ON the gain is
    intensity * period/on, during OFF it is 0, which preserves the
    phase's mean offered load exactly when the phase duration is a
    multiple of the period (and to within one partial period's ON
    surplus otherwise).  burst_on or burst_off <= 0 disables modulation
    (gain_on == intensity exactly, so an unmodulated unit-intensity
    phase multiplies the rate by exactly 1.0f).
    """
    if not phases:
        raise ValueError("schedule needs at least one phase")
    cums, injs, gains, ons, periods, durs = [], [], [], [], [], []
    n = np.asarray(phases[0][0]).shape[0]
    for ph in phases:
        traffic, intensity, duration = ph[0], float(ph[1]), int(ph[2])
        burst_on = int(ph[3]) if len(ph) > 3 else 0
        burst_off = int(ph[4]) if len(ph) > 4 else 0
        traffic = np.asarray(traffic, np.float64)
        if traffic.shape != (n, n):
            raise ValueError(f"phase traffic shape {traffic.shape} != "
                             f"({n}, {n})")
        if duration < 1:
            raise ValueError("phase duration must be >= 1 cycle")
        cum, inj = _traffic_arrays(traffic)
        cums.append(cum), injs.append(inj), durs.append(duration)
        if burst_on > 0 and burst_off > 0:
            ons.append(burst_on)
            periods.append(burst_on + burst_off)
            gains.append(intensity * (burst_on + burst_off) / burst_on)
        else:
            ons.append(1), periods.append(1)
            gains.append(intensity)
    end = np.cumsum(np.asarray(durs, np.int64)).astype(np.int32)
    start = np.concatenate([[0], end[:-1]]).astype(np.int32)
    return SchedSpec(
        k=len(phases), n=n, cum=np.stack(cums), inj_w=np.stack(injs),
        gain_on=np.asarray(gains, np.float32), start=start, end=end,
        on=np.asarray(ons, np.int32), period=np.asarray(periods, np.int32),
        total=int(end[-1]))


def telemetry_window_cycles(cfg: SimConfig) -> np.ndarray:
    """[W] measured cycles falling in each telemetry window — the
    normalizer for per-window utilization.  Mirrors the in-scan window
    pointer exactly: cycle t (warmup <= t < cycles) lands in window
    ((t - warmup) * W) // meas, so windows partition the measured
    cycles (sum == cycles - warmup) and differ by at most one cycle."""
    w = cfg.telemetry_windows
    if w <= 0:
        raise ValueError("telemetry_windows must be > 0 for a window "
                         "grid")
    meas = cfg.cycles - cfg.warmup
    return np.bincount((np.arange(meas, dtype=np.int64) * w) // meas,
                       minlength=w).astype(np.int64)


def phase_measured_cycles(sched: SchedSpec, cfg: SimConfig) -> np.ndarray:
    """[K] measured (post-warmup) cycles spent in each phase — the
    normalizer for per-phase throughput.  Mirrors the in-scan phase
    pointer exactly: t_eff = t % total, phase = #{ends <= t_eff}."""
    t_eff = np.arange(cfg.warmup, cfg.cycles) % sched.total
    ph = (sched.end[None, :] <= t_eff[:, None]).sum(axis=1)
    return np.bincount(ph, minlength=sched.k).astype(np.int64)


# =====================================================================
# padding-invariant injection randomness
# =====================================================================

def _mix32(h):
    """splitmix-style avalanche on uint32 (wrapping jnp arithmetic)."""
    h = jnp.asarray(h, jnp.uint32)
    h = (h ^ (h >> 16)) * jnp.uint32(0x7FEB352D)
    h = (h ^ (h >> 15)) * jnp.uint32(0x846CA68B)
    return h ^ (h >> 16)


def _node_bits(seed: int, t, node_idx, stream: int):
    """Per-node uint32 depending only on (seed, cycle, node, stream) —
    bitwise invariant to the node-axis padding, unlike jax.random draws
    whose threefry counter pairing depends on the array length."""
    h = _mix32(jnp.uint32(np.uint32(seed)) ^ (jnp.uint32(stream) * _GOLD))
    h = _mix32(h ^ (jnp.asarray(t, jnp.uint32) * _MIX_T))
    return _mix32(h ^ (node_idx.astype(jnp.uint32) * _MIX_N))


def _bits_to_unit(bits):
    """uint32 -> float32 in [0, 1) using the top 24 bits (exact)."""
    return (bits >> 8).astype(jnp.float32) * jnp.float32(1.0 / (1 << 24))


# =====================================================================
# route lookup + two-phase separable allocation
# =====================================================================

def _route_lookup(table, cred_pad, head_dst, cnt, n: int, p: int, v: int):
    """Table lookup + credit check for every (node, in-port, VC) head flit.

    Returns op_slot [N, PI, V] int32 (requested output slot, ejection = P,
    negative = no request), eligible [N, PI, V] bool, and starved
    [N, PI, V] bool — a valid head flit whose route names a real output
    port but whose downstream VC has no credit (the flight recorder's
    credit-starvation counter; unused outputs are DCE'd under jit, so
    the telemetry-off path is unchanged).
    """
    PI = p + 1
    node_idx = jnp.arange(n)[:, None, None]
    port_idx = jnp.arange(PI)[None, :, None]
    vcs = jnp.arange(v)[None, None, :]

    valid = cnt > 0
    dst = jnp.where(valid, head_dst, 0)
    op = table[dst, node_idx, port_idx].astype(jnp.int32)  # [N, PI, V]
    op = jnp.where(valid, op, -3)
    is_eject = op == Routing.EJECT
    op_slot = jnp.where(is_eject, p, op)           # [N, PI, V]
    have_credit = cred_pad[node_idx, jnp.clip(op_slot, 0, p), vcs] > 0
    eligible = valid & (op_slot >= 0) & (have_credit | is_eject)
    starved = valid & (op_slot >= 0) & ~is_eject & ~have_credit
    return op_slot, eligible, starved


def _route_lookup_adaptive(table, prod, cred_pad, head_dst, cnt,
                           n: int, p: int, v: int):
    """Minimal-adaptive route selection with escape fallback (§15).

    The Duato-style VC partition: VC 0 is the escape class, following
    the static up*/down* table (indexed by the arrival in-port, whose
    channel-dependency graph is certified acyclic); VCs 1..V-1 are the
    adaptive class, free to take any *productive* port — a minimal,
    escape-safe next hop from `routing.productive_ports` — chosen by
    downstream adaptive-credit count (deterministic first-max
    tie-break).  A head flit prefers an adaptive hop whenever some
    productive port has adaptive credit; otherwise it falls back to the
    escape route, gated on VC-0 credit.  Ejection is always eligible.

    Returns (op_slot, eligible, starved) shaped like `_route_lookup`
    plus dvc [N, PI, V] — the downstream VC class of each choice (>= 1
    adaptive, 0 escape).
    """
    PI = p + 1
    node_idx = jnp.arange(n)[:, None, None]
    port_idx = jnp.arange(PI)[None, :, None]

    valid = cnt > 0
    dst = jnp.where(valid, head_dst, 0)
    # escape route: the static table, arrival-in-port indexed
    op = table[dst, node_idx, port_idx].astype(jnp.int32)  # [N, PI, V]
    op = jnp.where(valid, op, -3)
    is_eject = op == Routing.EJECT
    esc_slot = jnp.where(is_eject, p, op)
    esc_credit = cred_pad[node_idx, jnp.clip(esc_slot, 0, p), 0] > 0

    # adaptive candidates: productive ports weighted by the summed
    # downstream adaptive-class credit (argmax = first-max tie-break)
    cand = prod[dst, node_idx]                     # [N, PI, V, P]
    cred_ad = jnp.sum(cred_pad[:, :p, 1:], axis=2)  # [N, P]
    score = jnp.where(cand & (cred_ad[:, None, None, :] > 0),
                      cred_ad[:, None, None, :], -1)
    ad_port = jnp.argmax(score, axis=3).astype(jnp.int32)  # [N, PI, V]
    ad_ok = jnp.max(score, axis=3) > 0
    # downstream adaptive VC with the most credit at the chosen port
    pcred = cred_pad[node_idx, jnp.clip(ad_port, 0, p - 1), 1:]
    dvc_ad = 1 + jnp.argmax(pcred, axis=3).astype(jnp.int32)

    use_ad = valid & ~is_eject & ad_ok
    op_slot = jnp.where(use_ad, ad_port, esc_slot)
    eligible = valid & (op_slot >= 0) & \
        (use_ad | is_eject | ((esc_slot >= 0) & esc_credit))
    starved = valid & ~is_eject & (esc_slot >= 0) & ~eligible
    dvc = jnp.where(use_ad, dvc_ad, 0)
    return op_slot, eligible, starved, dvc


def _alloc_jnp(op_slot, eligible, rr_vc, rr_port):
    """Two-phase separable allocation (pure jnp; Pallas netstep oracle).

    rr_vc rotates the VC priority (phase a), rr_port the input-port
    priority (phase b).  Returns (win_mask [N,PI,V], vc_choice [N,PI],
    out_req [N,PI] in [0..P] or -1).
    """
    N, PI, V = op_slot.shape
    vcs = jnp.arange(V)[None, None, :]

    # phase a: each input port picks one eligible VC (rotating priority)
    vc_score = jnp.where(eligible, (vcs - rr_vc) % V, INF)
    vc_choice = jnp.argmin(vc_score, axis=2).astype(jnp.int32)
    port_ok = jnp.min(vc_score, axis=2) < INF
    out_req = jnp.where(
        port_ok,
        jnp.take_along_axis(op_slot, vc_choice[..., None], axis=2)[..., 0],
        -1)                                        # [N, PI]

    # phase b: each output slot picks one requesting input port
    p_score = (jnp.arange(PI)[None, :] - rr_port) % PI   # [1, PI]
    req_1h = jax.nn.one_hot(jnp.where(out_req >= 0, out_req, PI),
                            PI + 1, dtype=jnp.bool_)[:, :, :PI]  # [N,PI,PI]
    scores = jnp.where(req_1h, p_score[:, :, None], INF)  # [N, in, out]
    win_p = jnp.argmin(scores, axis=1)             # [N, PI(out)]
    win_ok = jnp.min(scores, axis=1) < INF

    # scatter wins back onto input ports; invalid wins go to a dump column
    win_p_safe = jnp.where(win_ok, win_p, PI)
    won = jnp.zeros((N, PI + 1), jnp.bool_)
    won = won.at[jnp.arange(N)[:, None], win_p_safe].set(win_ok)
    port_wins = won[:, :PI] & port_ok              # [N, PI]
    win_mask = (jax.nn.one_hot(vc_choice, V, dtype=jnp.bool_)
                & eligible & port_wins[:, :, None])
    return win_mask, vc_choice, out_req


def _alloc_pallas(op_slot, eligible, rr_vc, rr_port):
    from repro.kernels.netstep.ops import netstep
    return netstep(op_slot, eligible, (rr_vc, rr_port))


def resolve_alloc(alloc: str) -> str:
    """Map SimConfig.alloc to a concrete implementation for this backend."""
    if alloc == "auto":
        return "pallas" if jax.default_backend() == "tpu" else "jnp"
    if alloc not in ("jnp", "pallas"):
        raise ValueError(f"unknown alloc impl {alloc!r}")
    return alloc


def router_phase(table, out_ch_pad_credits, head_dst, cnt, rr,
                 n: int, p: int, v: int):
    """Route + allocate with a single rotating counter (legacy signature).

    Kept as the documented oracle entry point; the batched runner calls
    `_route_lookup` + the selected allocator directly with the counter
    split per DESIGN.md §6.  Returns (win_mask, out_req, vc_choice,
    port_wins) like the seed implementation.
    """
    op_slot, eligible, _ = _route_lookup(table, out_ch_pad_credits,
                                         head_dst, cnt, n, p, v)
    win_mask, vc_choice, out_req = _alloc_jnp(op_slot, eligible, rr, rr)
    return win_mask, out_req, vc_choice, jnp.any(win_mask, axis=2)


# =====================================================================
# batched runner
# =====================================================================

def _init_state(nm: int, pm: int, cm: int, dm: int, cfg: SimConfig,
                kmax: int = 0) -> SimState:
    V, B = cfg.n_vcs, cfg.buf_depth
    PI = pm + 1
    z = jnp.zeros
    ph = dict(delivered_ph=z((kmax,), jnp.int32),
              offered_ph=z((kmax,), jnp.int32),
              accepted_ph=z((kmax,), jnp.int32),
              lat_ph=z((kmax, nm), jnp.int32)) if kmax else {}
    tel = dict(tel_busy=z((cm + 1,), jnp.int32),
               tel_stall=z((cm + 1,), jnp.int32),
               tel_occ=z((cm + 1, V), jnp.int32),
               tel_inj=z((nm,), jnp.int32),
               tel_eject=z((nm,), jnp.int32),
               tel_hist=z((LAT_HIST_BINS,), jnp.int32)) \
        if cfg.telemetry else {}
    W = cfg.telemetry_windows
    if cfg.telemetry and W > 0:
        tel.update(tel_busy_w=z((W, cm + 1), jnp.int32),
                   tel_stall_w=z((W, cm + 1), jnp.int32),
                   tel_occ_w=z((W, cm + 1, V), jnp.int32),
                   tel_inj_w=z((W, nm), jnp.int32),
                   tel_eject_w=z((W, nm), jnp.int32))
    return SimState(
        **ph, **tel,
        buf_dst=jnp.full((nm, PI, V, B + 1), -1, jnp.int32),
        buf_t=z((nm, PI, V, B + 1), jnp.int32),
        head=z((nm, PI, V), jnp.int32),
        cnt=z((nm, PI, V), jnp.int32),
        credits=jnp.full((nm, pm, V), B, jnp.int32),
        link_dst=jnp.full((cm + 1, dm), -1, jnp.int32),
        link_t=z((cm + 1, dm), jnp.int32),
        link_vc=z((cm + 1, dm), jnp.int32),
        credit_pipe=z((cm + 1, dm, V), jnp.int32),
        rr=jnp.int32(0),
        delivered=z((), jnp.int32), lat_node=z((nm,), jnp.int32),
        offered=z((), jnp.int32), accepted=z((), jnp.int32),
    )


def _make_batch_runner(nm: int, pm: int, cm: int, dm: int,
                       cfg: SimConfig, alloc_impl: str, kmax: int = 0):
    """Jitted (batch_arrays, rates[S, R]) -> raw int counters [S, R, ...].

    batch_arrays is a `repro.sweep.padding.BatchSpec` pytree whose array
    leaves carry a leading spec axis S; rates carries one row of R
    injection rates per spec.  All shape parameters are static, so the
    executable is reused for any batch padded to the same shape.

    kmax > 0 builds the *workload* runner: the jitted function takes a
    third argument, a `repro.sweep.padding.SchedBatch` pytree of phase
    schedules padded to kmax phases, and injection becomes time-varying
    (phase pointer advanced inside the scan).  The phase pointer is
    padding-invariant: it counts phase *ends* <= t_eff, and padded phase
    rows carry end == 2^30, so they never register for any real cycle.
    kmax == 0 is the static path, byte-identical to the pre-workload
    runner.
    """
    N, P, V, B, C, D = nm, pm, cfg.n_vcs, cfg.buf_depth, cm, dm
    PI = P + 1
    if cfg.routing not in ("static", "adaptive"):
        raise ValueError(f"unknown routing mode {cfg.routing!r}; "
                         f"choose 'static' or 'adaptive'")
    adaptive = cfg.routing == "adaptive"
    if adaptive and V < 2:
        raise ValueError(
            f"adaptive routing needs n_vcs >= 2 (VC 0 escape + at least "
            f"one adaptive VC), got n_vcs={V}")
    W = cfg.telemetry_windows
    if W < 0:
        raise ValueError(f"telemetry_windows must be >= 0, got {W}")
    if W and not cfg.telemetry:
        raise ValueError(
            "telemetry_windows requires telemetry=True — the windowed "
            "counters bin the flight recorder, they cannot replace it")
    meas = cfg.cycles - cfg.warmup
    if W > meas:
        raise ValueError(
            f"telemetry_windows={W} exceeds the measured window "
            f"({meas} cycles) — some windows would be empty")
    alloc_fn = _alloc_pallas if alloc_impl == "pallas" else _alloc_jnp
    nn = jnp.arange(N)[:, None]
    pp = jnp.arange(PI)[None, :]
    node_r = jnp.arange(N)

    def step(a, sch, state: SimState, t_rate):
        t, rate = t_rate
        slot = t % D
        measuring = t >= cfg.warmup
        ch_depth_pad = jnp.concatenate(
            [a.ch_depth, jnp.ones((1,), jnp.int32)])        # [C+1]

        # ---- 1. link deliveries -> input buffers ----------------------
        arr_dst = state.link_dst[:C, slot]           # [C]
        arr_ok = arr_dst >= 0
        arr_vc = state.link_vc[:C, slot]
        pos = (state.head[a.ch_dst, a.ch_in_port, arr_vc] +
               state.cnt[a.ch_dst, a.ch_in_port, arr_vc]) % B
        pos_w = jnp.where(arr_ok, pos, B)            # B = sacrificial slot
        buf_dst = state.buf_dst.at[a.ch_dst, a.ch_in_port, arr_vc,
                                   pos_w].set(arr_dst)
        buf_t = state.buf_t.at[a.ch_dst, a.ch_in_port, arr_vc,
                               pos_w].set(state.link_t[:C, slot])
        cnt = state.cnt.at[a.ch_dst, a.ch_in_port, arr_vc].add(
            arr_ok.astype(jnp.int32))
        link_dst = state.link_dst.at[:, slot].set(-1)

        # ---- 2. credit returns ----------------------------------------
        credits = state.credits.at[a.ch_src, a.ch_out_port].add(
            state.credit_pipe[:C, slot])
        credit_pipe = state.credit_pipe.at[:, slot].set(0)

        # ---- 3. injection ----------------------------------------------
        if kmax:
            # phase pointer: replay the schedule cyclically and count the
            # phase ends already passed (padded rows end at 2^30 — inert)
            t_eff = t % sch.total
            ph = jnp.sum((sch.end <= t_eff).astype(jnp.int32))
            in_on = ((t_eff - sch.start[ph]) % sch.period[ph]) < sch.on[ph]
            rate_eff = rate * jnp.where(in_on, sch.gain_on[ph],
                                        jnp.float32(0.0))
            inj_w, cum = sch.inj_w[ph], sch.cum[ph]
        else:
            rate_eff, inj_w, cum = rate, a.inj_weight, a.traffic_cum
        u_inj = _bits_to_unit(_node_bits(cfg.seed, t, node_r, 0))
        want = u_inj < rate_eff * inj_w
        u_dst = _bits_to_unit(_node_bits(cfg.seed, t, node_r, 1))
        dsts = jnp.sum(cum < u_dst[:, None], axis=1)
        dsts = jnp.clip(dsts, 0, N - 1).astype(jnp.int32)
        vcs_inj = (_node_bits(cfg.seed, t, node_r, 2) % V).astype(jnp.int32)
        want &= dsts != node_r
        space = cnt[node_r, P, vcs_inj] < B
        do_inj = want & space
        posi = (state.head[node_r, P, vcs_inj] + cnt[node_r, P, vcs_inj]) % B
        posi_w = jnp.where(do_inj, posi, B)
        buf_dst = buf_dst.at[node_r, P, vcs_inj, posi_w].set(dsts)
        buf_t = buf_t.at[node_r, P, vcs_inj, posi_w].set(t)
        cnt = cnt.at[node_r, P, vcs_inj].add(do_inj.astype(jnp.int32))
        m32 = measuring.astype(jnp.int32)
        offered = state.offered + m32 * jnp.sum(want.astype(jnp.int32))
        accepted = state.accepted + m32 * jnp.sum(do_inj.astype(jnp.int32))

        # ---- 4. route + allocate ---------------------------------------
        cnt_obs = cnt            # occupancy snapshot (flight recorder):
        #                          post-arrival, post-injection, pre-pop
        head_dst = jnp.take_along_axis(
            buf_dst, state.head[..., None], axis=3)[..., 0]
        head_t = jnp.take_along_axis(
            buf_t, state.head[..., None], axis=3)[..., 0]
        cred_pad = jnp.concatenate(
            [credits, jnp.full((N, 1, V), INF, jnp.int32)], axis=1)
        if adaptive:
            op_slot, eligible, starved, dvc = _route_lookup_adaptive(
                a.table, a.prod, cred_pad, head_dst, cnt, N, P, V)
        else:
            op_slot, eligible, starved = _route_lookup(
                a.table, cred_pad, head_dst, cnt, N, P, V)
        rr_vc = state.rr % V
        rr_port = state.rr % a.pi
        win_mask, vc_choice, out_req = alloc_fn(op_slot, eligible,
                                                rr_vc, rr_port)
        port_wins = jnp.any(win_mask, axis=2)      # [N, PI]

        # ---- 5. winners: pop, move, credit ------------------------------
        # wvc is the *source* VC lane popped at (node, in-port); w_dvc is
        # the *downstream* VC lane the flit occupies after the hop.  The
        # static path keeps them equal (bitwise-identical jaxpr); the
        # adaptive path redirects to the class chosen by the route
        # lookup, so the upstream credit return (freeing the popped
        # lane) stays on wvc while the link VC tag and the downstream
        # credit decrement move to w_dvc.
        wvc = vc_choice
        w_dvc = dvc[nn, pp, wvc] if adaptive else wvc
        w_dst = head_dst[nn, pp, wvc]
        w_t = head_t[nn, pp, wvc]
        head = (state.head.at[nn, pp, wvc]
                .add(port_wins.astype(jnp.int32))) % B
        cnt = cnt.at[nn, pp, wvc].add(-port_wins.astype(jnp.int32))

        # upstream credit return for real input ports
        up_ch = a.in_ch[nn, jnp.clip(pp, 0, P - 1)]  # [N, PI]
        has_up = (pp < P) & (up_ch >= 0) & port_wins
        up_ch_s = jnp.maximum(up_ch, 0)
        ret_slot = (t + ch_depth_pad[up_ch_s]) % D
        credit_pipe = credit_pipe.at[up_ch_s, ret_slot, wvc].add(
            has_up.astype(jnp.int32))

        # ejection vs traversal
        eject = port_wins & (out_req == P)
        traverse = port_wins & (out_req >= 0) & (out_req < P)
        ej32 = jnp.sum(eject.astype(jnp.int32))
        lat_row = jnp.sum(jnp.where(eject, t - w_t, 0), axis=1)
        delivered = state.delivered + m32 * ej32
        lat_node = state.lat_node + m32 * lat_row
        ph_upd = {}
        if kmax:
            ph_upd = dict(
                delivered_ph=state.delivered_ph.at[ph].add(m32 * ej32),
                offered_ph=state.offered_ph.at[ph].add(
                    m32 * jnp.sum(want.astype(jnp.int32))),
                accepted_ph=state.accepted_ph.at[ph].add(
                    m32 * jnp.sum(do_inj.astype(jnp.int32))),
                lat_ph=state.lat_ph.at[ph].add(m32 * lat_row))

        out_c = a.out_ch[nn, jnp.clip(out_req, 0, P - 1)]
        oc_w = jnp.where(traverse, out_c, C)       # C = sacrificial row
        wslot = (t + ch_depth_pad[oc_w]) % D
        link_dst = link_dst.at[oc_w, wslot].set(w_dst)
        link_t = state.link_t.at[oc_w, wslot].set(w_t)
        link_vc = state.link_vc.at[oc_w, wslot].set(w_dvc)
        credits = credits.at[nn, jnp.clip(out_req, 0, P - 1), w_dvc].add(
            -traverse.astype(jnp.int32))

        # ---- 6. flight recorder (telemetry mode only; DESIGN.md §13) ---
        # Pure observers: every update is an int scatter-add onto a
        # dedicated counter tensor, weighted by masks the step already
        # computed, with non-contributing lanes routed to the sacrificial
        # row C (or weighted 0) — so real counters are untouched and the
        # per-spec slices stay padding-invariant.
        tel_upd = {}
        if cfg.telemetry:
            # channel utilization: one traversal per (channel, cycle)
            tel_busy = state.tel_busy.at[oc_w].add(
                m32 * traverse.astype(jnp.int32))
            # credit starvation, attributed to the requested out channel
            st_ch = a.out_ch[jnp.arange(N)[:, None, None],
                             jnp.clip(op_slot, 0, P - 1)]  # [N, PI, V]
            st_ch_w = jnp.where(starved, st_ch, C)
            tel_stall = state.tel_stall.at[st_ch_w].add(
                m32 * starved.astype(jnp.int32))
            # per-VC occupancy of each channel's downstream input buffer
            occ = cnt_obs[a.ch_dst, a.ch_in_port]          # [C, V]
            tel_occ = state.tel_occ.at[jnp.arange(C)].add(m32 * occ)
            # injection/ejection conservation counters (sum == accepted /
            # delivered exactly — the reconciliation tests rely on this)
            tel_inj = state.tel_inj + m32 * do_inj.astype(jnp.int32)
            tel_eject = state.tel_eject + m32 * jnp.sum(
                eject.astype(jnp.int32), axis=1)
            # coarse latency histogram: bin h counts lat in [2^(h-1), 2^h)
            edges = jnp.int32(2) ** jnp.arange(LAT_HIST_BINS - 1)
            lat = t - w_t                                  # [N, PI]
            hbin = jnp.sum((lat[..., None] >= edges).astype(jnp.int32),
                           axis=-1)
            tel_hist = state.tel_hist.at[hbin].add(
                m32 * eject.astype(jnp.int32))
            tel_upd = dict(tel_busy=tel_busy, tel_stall=tel_stall,
                           tel_occ=tel_occ, tel_inj=tel_inj,
                           tel_eject=tel_eject, tel_hist=tel_hist)
            if W:
                # time-windowed bins (DESIGN.md §16): the SAME masks and
                # weights as the aggregates above, scattered once more
                # with a leading window index — so summing the window
                # axis reconciles to the aggregates bitwise (int adds,
                # every measured cycle lands in exactly one window;
                # pre-warmup cycles clip to window 0 with weight 0).
                w = jnp.clip(((t - cfg.warmup) * W) // meas, 0, W - 1)
                tel_upd.update(
                    tel_busy_w=state.tel_busy_w.at[w, oc_w].add(
                        m32 * traverse.astype(jnp.int32)),
                    tel_stall_w=state.tel_stall_w.at[w, st_ch_w].add(
                        m32 * starved.astype(jnp.int32)),
                    tel_occ_w=state.tel_occ_w.at[w, jnp.arange(C)].add(
                        m32 * occ),
                    tel_inj_w=state.tel_inj_w.at[w].add(
                        m32 * do_inj.astype(jnp.int32)),
                    tel_eject_w=state.tel_eject_w.at[w].add(
                        m32 * jnp.sum(eject.astype(jnp.int32), axis=1)))

        return SimState(
            buf_dst=buf_dst, buf_t=buf_t, head=head, cnt=cnt,
            credits=credits, link_dst=link_dst, link_t=link_t,
            link_vc=link_vc, credit_pipe=credit_pipe,
            rr=(state.rr + 1) % (V * a.pi),
            delivered=delivered, lat_node=lat_node, offered=offered,
            accepted=accepted, **ph_upd, **tel_upd)

    def run_one(a, sch, rate):
        state = _init_state(N, P, C, D, cfg, kmax)
        ts = jnp.arange(cfg.cycles)
        rates = jnp.full((cfg.cycles,), rate)
        state, _ = jax.lax.scan(lambda s, tr: (step(a, sch, s, tr), None),
                                state, (ts, rates))
        out = (state.delivered, state.offered, state.accepted,
               state.lat_node)
        if kmax:
            out += (state.delivered_ph, state.offered_ph,
                    state.accepted_ph, state.lat_ph)
        if cfg.telemetry:
            out += (state.tel_busy, state.tel_stall, state.tel_occ,
                    state.tel_inj, state.tel_eject, state.tel_hist)
            if W:
                out += (state.tel_busy_w, state.tel_stall_w,
                        state.tel_occ_w, state.tel_inj_w,
                        state.tel_eject_w)
        return out

    if kmax:
        def runner(batch, rates, sched):
            per_spec = lambda a, sch, rr_: jax.vmap(
                lambda r: run_one(a, sch, r))(rr_)
            return jax.vmap(per_spec)(batch, sched, rates)
    else:
        def runner(batch, rates):
            per_spec = lambda a, rr_: jax.vmap(
                lambda r: run_one(a, None, r))(rr_)
            return jax.vmap(per_spec)(batch, rates)

    return jax.jit(runner)


_RUNNER_CACHE: OrderedDict = OrderedDict()
_RUNNER_CACHE_MAX = max(
    int(os.environ.get("REPRO_RUNNER_CACHE_MAX", "64")), 1)
_RUNNER_CACHE_STATS = dict(hits=0, misses=0, evictions=0)


def set_runner_cache_limit(max_entries: int) -> None:
    """Bound the compiled-runner LRU (env: REPRO_RUNNER_CACHE_MAX).

    Long-lived sweep services accumulate one jitted runner per padded
    shape x SimConfig; each pins its compiled executables.  The LRU
    evicts the least-recently-used runner beyond `max_entries` —
    eviction only costs recompilation, never changes results
    (tests/test_sweep.py::test_runner_cache_lru_eviction)."""
    global _RUNNER_CACHE_MAX
    if max_entries < 1:
        raise ValueError("runner cache needs at least 1 entry")
    _RUNNER_CACHE_MAX = max_entries
    while len(_RUNNER_CACHE) > _RUNNER_CACHE_MAX:
        _RUNNER_CACHE.popitem(last=False)
        _RUNNER_CACHE_STATS["evictions"] += 1


def get_batch_runner(nm: int, pm: int, cm: int, dm: int, cfg: SimConfig,
                     alloc_impl: str, kmax: int = 0):
    """Compiled-runner LRU keyed on the padded shape + SimConfig; a new
    topology padded to a known shape reuses the existing executable.
    kmax > 0 selects the workload (phase-schedule) runner variant."""
    key = (nm, pm, cm, dm, cfg, alloc_impl, kmax, jax.default_backend())
    fn = _RUNNER_CACHE.get(key)
    if fn is None:
        _RUNNER_CACHE_STATS["misses"] += 1
        fn = _RUNNER_CACHE[key] = _make_batch_runner(
            nm, pm, cm, dm, cfg, alloc_impl, kmax)
        while len(_RUNNER_CACHE) > _RUNNER_CACHE_MAX:
            _RUNNER_CACHE.popitem(last=False)
            _RUNNER_CACHE_STATS["evictions"] += 1
    else:
        _RUNNER_CACHE_STATS["hits"] += 1
        _RUNNER_CACHE.move_to_end(key)
    return fn


def runner_cache_info() -> dict:
    """Executable-cache introspection (sweep-engine stats + ops):
    `entries` maps each full cache key (shape + config + impl) to its
    compiled-variant count; `hits`/`misses`/`evictions` count LRU
    traffic since process start (monotonic, survive cache clears)."""
    return dict(
        entries={key: fn._cache_size()
                 for key, fn in _RUNNER_CACHE.items()},
        size=len(_RUNNER_CACHE), max_size=_RUNNER_CACHE_MAX,
        **_RUNNER_CACHE_STATS)


def _pad_fill(specs, shape, schedules, kmax) -> list[dict]:
    """Live-work fraction of a padded batch, one dict per spec.

    `state` is the live fraction of the router-state grid the compiled
    program iterates (n*(p+1) of N*(P+1) cells — +1 for the ejection
    lane); `chan`/`depth` are the live channel-row and ring-depth
    fractions; `phase` is live schedule phases over k_pad (1.0 on the
    static path).  1 - fill is pad waste: device work spent keeping
    heterogeneous specs in one executable (DESIGN.md §16).
    """
    fills = []
    for i, spec in enumerate(specs):
        fills.append(dict(
            state=(spec.n * (spec.p + 1)) / (shape.n * (shape.p + 1)),
            chan=spec.c / shape.c,
            depth=spec.d / shape.d,
            phase=(schedules[i].k / kmax) if schedules is not None else 1.0))
    return fills


def run_batch(specs, rates, cfg: SimConfig = SimConfig(), *,
              pad_shape=None, schedules=None, k_pad=None) -> list[dict]:
    """Run many SimSpecs x injection rates in one batched jitted program.

    rates: [R] shared across specs, or [S, R] one row per spec.  Returns
    one dict per spec with raw integer counters (`delivered`, `offered`,
    `accepted`, `lat_sum`) plus derived float metrics (`throughput`,
    `latency`, ...) computed in numpy — so derived values are bitwise
    reproducible for any padding of the same spec.

    schedules: optional list of `SchedSpec` (one per spec) switching the
    batch to time-varying workload injection (DESIGN.md §9).  Each spec's
    `traffic_cum`/`inj_weight` are then ignored in favour of its
    schedule's per-phase arrays, and result dicts gain per-phase counters
    (`delivered_ph` [R, K], `lat_sum_ph`, `throughput_ph`, `latency_ph`,
    `phase_cycles` [K]).  k_pad pads the phase axis (executable reuse
    across workloads with different phase counts).

    cfg.telemetry=True switches on the flight recorder (DESIGN.md §13):
    result dicts gain `TELEMETRY_KEYS` — per-directed-channel busy /
    stall / occupancy-sum counters (`link_busy`/`link_stall` [R, c],
    `link_occ_sum` [R, c, V]), derived `link_util` (busy / measured
    cycles), per-node `inj_node`/`eject_node` [R, n] (summing exactly
    to `accepted_n`/`delivered`), and a coarse `lat_hist` [R,
    LAT_HIST_BINS].  Sacrificial and padded lanes are sliced away, so
    telemetry is padding-invariant like every other counter; with
    telemetry off the compiled program is unchanged.

    cfg.telemetry_windows=W (> 0, with telemetry on) additionally bins
    the busy/stall/occupancy/inject/eject counters into W time windows
    over the measured cycles (`TELEMETRY_WINDOW_KEYS`, DESIGN.md §16):
    `link_busy_w`/`link_stall_w` [R, W, c], `link_occ_w` [R, W, c, V],
    `inj_node_w`/`eject_node_w` [R, W, n], derived `link_util_w`
    (busy_w / that window's cycle count) and the `window_cycles` [W]
    normalizer.  Each windowed tensor sums over W to its aggregate
    counter EXACTLY, and the same sacrificial-slot discipline keeps the
    windows padding-invariant.

    Every result dict also carries `pad_fill` — the live-work fraction
    of this padded batch (DESIGN.md §16): `state` = live router-state
    cells / padded cells (n*(p+1) / N*(P+1)), `chan` = c/C, `depth` =
    d/D, `phase` = k/k_pad (1.0 static) — the pad-waste numbers the
    warm-path investigation reads off `ResultFrame` rows.
    """
    from repro.sweep.padding import stack_schedules, stack_specs
    with _span("sim.stack", cat="sim", specs=len(specs)):
        batch, shape = stack_specs(specs, pad_shape)
    s = len(specs)
    rates = np.asarray(rates, np.float32)
    if rates.ndim == 1:
        rates = np.broadcast_to(rates, (s, rates.shape[0]))
    if rates.shape[0] != s:
        raise ValueError(f"rates rows {rates.shape[0]} != specs {s}")
    if schedules is None:
        kmax = 0
        runner = get_batch_runner(shape.n, shape.p, shape.c, shape.d, cfg,
                                  resolve_alloc(cfg.alloc))
        args = (batch, jnp.asarray(rates))
    else:
        if len(schedules) != s:
            raise ValueError(f"schedules {len(schedules)} != specs {s}")
        for spec, sched in zip(specs, schedules):
            if sched.n != spec.n:
                raise ValueError(f"schedule for {sched.n} nodes paired "
                                 f"with a {spec.n}-node spec")
        sbatch, kmax = stack_schedules(schedules, shape.n, k_pad)
        runner = get_batch_runner(shape.n, shape.p, shape.c, shape.d, cfg,
                                  resolve_alloc(cfg.alloc), kmax)
        args = (batch, jnp.asarray(rates), sbatch)
    fills = _pad_fill(specs, shape, schedules, kmax)
    if profiling_enabled():
        from repro.obs.profile import record_runner_profile
        record_runner_profile(shape, cfg, resolve_alloc(cfg.alloc), kmax,
                              runner, args)
    # dispatch vs wait split (DESIGN.md §13): the dispatch span covers
    # trace+compile on a cold executable (jit compiles synchronously at
    # dispatch) plus argument transfer; the wait span is the device
    # execution tail (`block_until_ready`).  A span with cold=True is a
    # compile; warm dispatches are microseconds.
    variants = runner._cache_size() if _tracing() else 0
    with _span("sim.dispatch", cat="sim", specs=s, shape=str(shape),
               kind="static" if schedules is None else "workload") as sp:
        raw = runner(*args)
        if _tracing():
            d = runner._cache_size() - variants
            sp.set(cold=d > 0, compiled_variants=d,
                   **{f"fill_{k}": round(float(np.mean(
                       [f[k] for f in fills])), 4) for k in fills[0]})
    with _span("sim.wait", cat="sim", specs=s):
        raw = jax.block_until_ready(raw)
    delivered = np.asarray(raw[0])             # [S, R]
    offered = np.asarray(raw[1])
    accepted = np.asarray(raw[2])
    lat_sum = np.asarray(raw[3]).astype(np.int64).sum(axis=2)  # [S, R]
    meas = cfg.cycles - cfg.warmup
    tel = None
    telw = None
    win_cycles = None
    if cfg.telemetry:
        off = 8 if schedules is not None else 4
        tel = tuple(np.asarray(raw[off + j]) for j in range(6))
        if cfg.telemetry_windows:
            telw = tuple(np.asarray(raw[off + 6 + j]) for j in range(5))
            win_cycles = telemetry_window_cycles(cfg)
    out = []
    for i, spec in enumerate(specs):
        norm = spec.n * meas
        res = dict(
            rate=rates[i].astype(np.float64),
            delivered=delivered[i], offered_n=offered[i],
            accepted_n=accepted[i], lat_sum=lat_sum[i],
            throughput=delivered[i] / norm,
            latency=lat_sum[i] / np.maximum(delivered[i], 1),
            offered=offered[i] / norm,
            accepted=accepted[i] / norm,
            pad_fill=fills[i])
        if schedules is not None:
            sched = schedules[i]
            k = sched.k
            dp = np.asarray(raw[4])[i, :, :k]              # [R, K]
            op = np.asarray(raw[5])[i, :, :k]
            ap = np.asarray(raw[6])[i, :, :k]
            lp = np.asarray(raw[7])[i, :, :k].astype(np.int64).sum(axis=2)
            ph_cy = phase_measured_cycles(sched, cfg)      # [K]
            ph_norm = np.maximum(spec.n * ph_cy, 1)[None, :]
            res.update(
                delivered_ph=dp, offered_ph=op, accepted_ph=ap,
                lat_sum_ph=lp, phase_cycles=ph_cy,
                throughput_ph=dp / ph_norm,
                latency_ph=lp / np.maximum(dp, 1),
                offered_rate_ph=op / ph_norm)
        if tel is not None:
            # flight-recorder slices: drop the sacrificial channel row
            # and every padded channel/node lane (rows beyond the spec's
            # own c/n) so telemetry never reports pad slots
            t_busy, t_stall, t_occ, t_inj, t_ej, t_hist = tel
            c, n = spec.c, spec.n
            busy = t_busy[i, :, :c]                        # [R, c]
            occ = t_occ[i, :, :c, :]                       # [R, c, V]
            res.update(
                link_busy=busy, link_stall=t_stall[i, :, :c],
                link_occ_sum=occ,
                link_occ_escape=occ[:, :, 0],
                link_occ_adaptive=occ[:, :, 1:].sum(axis=-1),
                link_util=busy / float(meas),
                inj_node=t_inj[i, :, :n], eject_node=t_ej[i, :, :n],
                lat_hist=t_hist[i])
            if telw is not None:
                # windowed flight recorder (DESIGN.md §16): same
                # sacrificial/pad-lane slicing as the aggregates, plus
                # the per-window cycle-count normalizer for utilisation
                w_busy, w_stall, w_occ, w_inj, w_ej = telw
                busy_w = w_busy[i, :, :, :c]               # [R, W, c]
                occ_w = w_occ[i, :, :, :c, :]              # [R, W, c, V]
                res.update(
                    link_busy_w=busy_w,
                    link_stall_w=w_stall[i, :, :, :c],
                    link_occ_w=occ_w,
                    link_util_w=busy_w / np.maximum(
                        win_cycles, 1).astype(np.float64)[None, :, None],
                    inj_node_w=w_inj[i, :, :, :n],
                    eject_node_w=w_ej[i, :, :, :n],
                    window_cycles=win_cycles)
        out.append(res)
    return out


def trace_batch(specs, rates, cfg: SimConfig = SimConfig(), *,
                pad_shape=None, schedules=None, k_pad=None):
    """Abstractly trace the batched runner without compiling or running.

    Builds exactly the arguments `run_batch` would dispatch (same
    padding, same runner construction) but hands them to
    `jax.make_jaxpr` instead of the jitted callable — tracing evaluates
    the step symbolically on avals, so it is cheap even for cycle
    counts that would take minutes to simulate.  Returns
    `(closed_jaxpr, pad_shape, batch)`; the static analyzer
    (`repro.analysis.jaxpr_hazards`) walks the jaxpr for host
    callbacks and dtype promotions and inspects `batch` against the
    sacrificial-slot padding contract.
    """
    from repro.sweep.padding import stack_schedules, stack_specs
    batch, shape = stack_specs(specs, pad_shape)
    s = len(specs)
    rates = np.asarray(rates, np.float32)
    if rates.ndim == 1:
        rates = np.broadcast_to(rates, (s, rates.shape[0]))
    if schedules is None:
        fn = _make_batch_runner(shape.n, shape.p, shape.c, shape.d, cfg,
                                resolve_alloc(cfg.alloc))
        args = (batch, jnp.asarray(rates))
    else:
        sbatch, kmax = stack_schedules(schedules, shape.n, k_pad)
        fn = _make_batch_runner(shape.n, shape.p, shape.c, shape.d, cfg,
                                resolve_alloc(cfg.alloc), kmax)
        args = (batch, jnp.asarray(rates), sbatch)
    return jax.make_jaxpr(fn)(*args), shape, batch


# =====================================================================
# single-spec conveniences (thin wrappers over the batched path)
# =====================================================================

def simulate(routing: Routing, traffic: np.ndarray, rates,
             cfg: SimConfig = SimConfig()):
    """Run the simulator for a sweep of injection rates (vmapped).

    Returns dict of numpy arrays: delivered throughput (flits/node/cycle),
    avg packet latency (cycles), offered and accepted rates.  This is a
    batch of one through `run_batch` at the spec's exact shape.
    """
    spec = make_spec(routing, traffic)
    res = run_batch([spec], np.asarray(rates, np.float32)[None, :], cfg)[0]
    return dict(rate=np.asarray(rates), throughput=res["throughput"],
                latency=res["latency"], offered=res["offered"],
                accepted=res["accepted"])


def saturation_throughput(routing: Routing, traffic: np.ndarray,
                          cfg: SimConfig = SimConfig(),
                          n_rates: int = 8) -> dict:
    """Saturation = plateau of delivered throughput over an offered sweep.

    The sweep is seeded by the analytic channel-load bound and refined
    around it.
    """
    analytic = routing.saturation_rate(traffic)
    rates = saturation_rate_grid(analytic, n_rates,
                                 headroom=routing_headroom(cfg.routing))
    res = simulate(routing, traffic, rates, cfg)
    i = int(np.argmax(res["throughput"]))
    return dict(sim_saturation=float(res["throughput"][i]),
                analytic_saturation=float(analytic),
                latency_at_sat=float(res["latency"][i]), sweep=res)


def routing_headroom(routing: str) -> float:
    """Default rate-grid ceiling multiplier for a routing mode: adaptive
    sweeps must extend past the *static* analytic bound (they can beat
    it), static sweeps keep the historical 2x bracket."""
    return ADAPTIVE_HEADROOM if routing == "adaptive" else STATIC_HEADROOM


def saturation_rate_grid(analytic: float, n_rates: int = 8,
                         headroom: float = STATIC_HEADROOM) -> np.ndarray:
    """Offered-rate grid bracketing the analytic saturation estimate.

    `headroom` parameterizes the ceiling above the (static) analytic
    bound; the default reproduces the historical static grid exactly.
    """
    hi = min(1.0, headroom * analytic)
    return np.linspace(max(analytic * 0.25, 1e-3), hi, n_rates)


def zero_load_latency(routing: Routing, traffic: np.ndarray) -> float:
    """Analytic average packet latency at zero load (cycles)."""
    _, hops, lat = routing.paths_channel_loads(traffic)
    w = traffic / max(traffic.sum(), 1e-12)
    return float((lat * w).sum())
