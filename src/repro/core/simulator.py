"""Cycle-based ICI network simulator, vectorized in JAX (paper §V-B).

BookSim semantics re-expressed as dense array updates so the whole
simulation `lax.scan`s over cycles and `vmap`s over injection rates:

  * input-queued routers, V virtual channels x B-flit buffers per input
    port (paper: 4 x 4),
  * credit-based flow control with wire-delayed credit return,
  * two-phase separable switch allocation (rotating priority; an input
    port forwards at most one flit per cycle, an output port accepts at
    most one),
  * per-channel link pipelines whose depth is the Table-IV hop latency
    (router 3 ns + 2 PHY x 2 ns + wire ceil(L*sqrt(eps_r)/c)), cycle=1 ns,
  * one injection queue and one ejection port per chiplet (1 flit/cycle).

Packets are single-flit; multi-flit data packets are injected as bursts
(§V-E traces), which approximates wormhole serialization without ownership
state.  Saturation throughput is measured as the plateau of delivered
throughput over an offered-rate sweep (vmapped), the same quantity BookSim
reports as relative throughput T_r.

The pure-jnp router allocation (`router_phase`) also serves as the
reference oracle for the Pallas `netstep` kernel (see repro/kernels).
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from . import linkmodel as lm
from .routing import Routing

INF = jnp.int32(2 ** 30)


class SimConfig(NamedTuple):
    n_vcs: int = 4
    buf_depth: int = 4
    cycles: int = 3000
    warmup: int = 1000
    seed: int = 0


class SimState(NamedTuple):
    buf_dst: jnp.ndarray     # [N, PI, V, B] destination (or -1)
    buf_t: jnp.ndarray       # [N, PI, V, B] injection cycle
    head: jnp.ndarray        # [N, PI, V]
    cnt: jnp.ndarray         # [N, PI, V]
    credits: jnp.ndarray     # [N, P, V]
    link_dst: jnp.ndarray    # [C, D]
    link_t: jnp.ndarray      # [C, D]
    link_vc: jnp.ndarray     # [C, D]
    credit_pipe: jnp.ndarray  # [C, D, V]
    rr: jnp.ndarray          # [] rotating priority
    delivered: jnp.ndarray   # []
    lat_sum: jnp.ndarray     # [] float32
    offered: jnp.ndarray     # []
    accepted: jnp.ndarray    # []


@dataclasses.dataclass
class SimSpec:
    """Static simulator inputs derived from a Routing + traffic matrix."""
    n: int
    p: int                  # max real ports
    c: int                  # directed channels
    d: int                  # link pipeline ring depth
    table: np.ndarray       # [N_dst, N, P+1] -> out port, EJECT=-2
    out_ch: np.ndarray      # [N, P]
    in_ch: np.ndarray       # [N, P]
    ch_dst: np.ndarray      # [C]
    ch_in_port: np.ndarray  # [C]
    ch_src: np.ndarray
    ch_out_port: np.ndarray
    ch_depth: np.ndarray    # [C] pipeline depth (cycles per hop)
    traffic_cum: np.ndarray  # [N, N] cumulative traffic rows
    inj_weight: np.ndarray   # [N] relative injection rate per node


def make_spec(routing: Routing, traffic: np.ndarray) -> SimSpec:
    depth = lm.hop_latency_cycles(routing.ch_len_mm, routing.topo.substrate)
    depth = np.maximum(np.asarray(depth, np.int32), 1)
    d = int(depth.max()) + 1
    rows = traffic.sum(axis=1)
    inj_weight = rows / max(rows.max(), 1e-12)
    cum = np.cumsum(traffic, axis=1)
    cum = cum / np.maximum(cum[:, -1:], 1e-12)
    cum[rows <= 0] = 1.0   # inert sources: any draw maps to dst 0, gated off
    return SimSpec(
        n=routing.topo.n, p=routing.max_ports, c=routing.n_channels, d=d,
        table=routing.table, out_ch=routing.out_ch, in_ch=routing.in_ch,
        ch_dst=routing.ch_dst, ch_in_port=routing.ch_in_port,
        ch_src=routing.ch_src, ch_out_port=routing.ch_out_port,
        ch_depth=depth, traffic_cum=cum, inj_weight=inj_weight)


def init_state(spec: SimSpec, cfg: SimConfig) -> SimState:
    N, P, V, B, C, D = (spec.n, spec.p, cfg.n_vcs, cfg.buf_depth,
                        spec.c, spec.d)
    PI = P + 1
    z = jnp.zeros
    return SimState(
        buf_dst=jnp.full((N, PI, V, B), -1, jnp.int32),
        buf_t=z((N, PI, V, B), jnp.int32),
        head=z((N, PI, V), jnp.int32),
        cnt=z((N, PI, V), jnp.int32),
        credits=jnp.full((N, P, V), B, jnp.int32),
        link_dst=jnp.full((C, D), -1, jnp.int32),
        link_t=z((C, D), jnp.int32),
        link_vc=z((C, D), jnp.int32),
        credit_pipe=z((C, D, V), jnp.int32),
        rr=jnp.int32(0),
        delivered=z((), jnp.int32), lat_sum=z((), jnp.float32),
        offered=z((), jnp.int32), accepted=z((), jnp.int32),
    )


def router_phase(table, out_ch_pad_credits, head_dst, cnt, rr,
                 n: int, p: int, v: int):
    """Route + two-phase separable allocation (pure jnp; Pallas oracle).

    table: [N_dst, N, PI]; out_ch_pad_credits: [N, P+1, V] credits with an
    INF ejection column appended.  Returns (win_mask [N,PI,V],
    out_req [N,PI] in [0..P] or -1, vc_choice [N,PI], port_wins [N,PI]).
    """
    N, P, V = n, p, v
    PI = P + 1
    node_idx = jnp.arange(N)[:, None, None]
    port_idx = jnp.arange(PI)[None, :, None]
    vcs = jnp.arange(V)[None, None, :]

    valid = cnt > 0
    dst = jnp.where(valid, head_dst, 0)
    op = table[dst, node_idx, port_idx]            # [N, PI, V]
    op = jnp.where(valid, op, -3)
    is_eject = op == Routing.EJECT
    op_slot = jnp.where(is_eject, P, op)           # [N, PI, V]

    have_credit = out_ch_pad_credits[
        node_idx, jnp.clip(op_slot, 0, P), vcs] > 0
    eligible = valid & (op_slot >= 0) & (have_credit | is_eject)

    # phase a: each input port picks one eligible VC (rotating priority)
    vc_score = jnp.where(eligible, (vcs - rr) % V, INF)
    vc_choice = jnp.argmin(vc_score, axis=2)       # [N, PI]
    port_ok = jnp.min(vc_score, axis=2) < INF
    out_req = jnp.where(
        port_ok,
        jnp.take_along_axis(op_slot, vc_choice[..., None], axis=2)[..., 0],
        -1)                                        # [N, PI]

    # phase b: each output slot picks one requesting input port
    p_score = (jnp.arange(PI)[None, :] - rr) % PI  # [1, PI]
    req_1h = jax.nn.one_hot(jnp.where(out_req >= 0, out_req, PI),
                            PI + 1, dtype=jnp.bool_)[:, :, :PI]  # [N,PI,PI]
    scores = jnp.where(req_1h, p_score[:, :, None], INF)  # [N, PI(in), PI(out)]
    win_p = jnp.argmin(scores, axis=1)             # [N, PI(out)]
    win_ok = jnp.min(scores, axis=1) < INF

    # scatter wins back onto input ports; invalid wins go to a dump column
    win_p_safe = jnp.where(win_ok, win_p, PI)
    won = jnp.zeros((N, PI + 1), jnp.bool_)
    won = won.at[jnp.arange(N)[:, None], win_p_safe].set(win_ok)
    port_wins = won[:, :PI] & port_ok              # [N, PI]
    win_mask = (jax.nn.one_hot(vc_choice, V, dtype=jnp.bool_)
                & eligible & port_wins[:, :, None])
    return win_mask, out_req, vc_choice, port_wins


def _build_runner(spec: SimSpec, cfg: SimConfig):
    """Return a jitted fn rate -> (throughput, latency, offered, accepted)."""
    N, P, V, B, C, D = (spec.n, spec.p, cfg.n_vcs, cfg.buf_depth,
                        spec.c, spec.d)
    PI = P + 1
    table = jnp.asarray(spec.table)
    out_ch = jnp.asarray(spec.out_ch)
    in_ch = jnp.asarray(spec.in_ch)
    ch_dst = jnp.asarray(spec.ch_dst)
    ch_in_port = jnp.asarray(spec.ch_in_port)
    ch_src = jnp.asarray(spec.ch_src)
    ch_out_port = jnp.asarray(spec.ch_out_port)
    ch_depth = jnp.asarray(spec.ch_depth)
    traffic_cum = jnp.asarray(spec.traffic_cum)
    inj_weight = jnp.asarray(spec.inj_weight, jnp.float32)
    base_key = jax.random.PRNGKey(cfg.seed)
    nn = jnp.arange(N)[:, None]
    pp = jnp.arange(PI)[None, :]
    node_r = jnp.arange(N)

    def step(state: SimState, t_rate):
        t, rate = t_rate
        slot = t % D
        measuring = t >= cfg.warmup

        # ---- 1. link deliveries -> input buffers ----------------------
        arr_dst = state.link_dst[:, slot]            # [C]
        arr_ok = arr_dst >= 0
        arr_vc = state.link_vc[:, slot]
        pos = (state.head[ch_dst, ch_in_port, arr_vc] +
               state.cnt[ch_dst, ch_in_port, arr_vc]) % B
        buf_dst = state.buf_dst.at[ch_dst, ch_in_port, arr_vc, pos].set(
            jnp.where(arr_ok, arr_dst,
                      state.buf_dst[ch_dst, ch_in_port, arr_vc, pos]))
        buf_t = state.buf_t.at[ch_dst, ch_in_port, arr_vc, pos].set(
            jnp.where(arr_ok, state.link_t[:, slot],
                      state.buf_t[ch_dst, ch_in_port, arr_vc, pos]))
        cnt = state.cnt.at[ch_dst, ch_in_port, arr_vc].add(
            arr_ok.astype(jnp.int32))
        link_dst = state.link_dst.at[:, slot].set(-1)

        # ---- 2. credit returns ----------------------------------------
        credits = state.credits.at[ch_src, ch_out_port].add(
            state.credit_pipe[:, slot])
        credit_pipe = state.credit_pipe.at[:, slot].set(0)

        # ---- 3. injection ----------------------------------------------
        key = jax.random.fold_in(base_key, t)
        k1, k2, k3 = jax.random.split(key, 3)
        want = jax.random.uniform(k1, (N,)) < rate * inj_weight
        u = jax.random.uniform(k2, (N,))
        dsts = jnp.sum(traffic_cum < u[:, None], axis=1).astype(jnp.int32)
        dsts = jnp.clip(dsts, 0, N - 1)
        vcs_inj = jax.random.randint(k3, (N,), 0, V)
        want &= dsts != node_r
        space = cnt[node_r, P, vcs_inj] < B
        do_inj = want & space
        posi = (state.head[node_r, P, vcs_inj] + cnt[node_r, P, vcs_inj]) % B
        buf_dst = buf_dst.at[node_r, P, vcs_inj, posi].set(
            jnp.where(do_inj, dsts, buf_dst[node_r, P, vcs_inj, posi]))
        buf_t = buf_t.at[node_r, P, vcs_inj, posi].set(
            jnp.where(do_inj, t, buf_t[node_r, P, vcs_inj, posi]))
        cnt = cnt.at[node_r, P, vcs_inj].add(do_inj.astype(jnp.int32))
        m32 = measuring.astype(jnp.int32)
        offered = state.offered + m32 * jnp.sum(want.astype(jnp.int32))
        accepted = state.accepted + m32 * jnp.sum(do_inj.astype(jnp.int32))

        # ---- 4. route + allocate ---------------------------------------
        head_dst = jnp.take_along_axis(
            buf_dst, state.head[..., None], axis=3)[..., 0]
        head_t = jnp.take_along_axis(
            buf_t, state.head[..., None], axis=3)[..., 0]
        cred_pad = jnp.concatenate(
            [credits, jnp.full((N, 1, V), INF, jnp.int32)], axis=1)
        win_mask, out_req, vc_choice, port_wins = router_phase(
            table, cred_pad, head_dst, cnt, state.rr, N, P, V)

        # ---- 5. winners: pop, move, credit ------------------------------
        win_any = port_wins                        # [N, PI]
        wvc = vc_choice
        w_dst = head_dst[nn, pp, wvc]
        w_t = head_t[nn, pp, wvc]
        head = (state.head.at[nn, pp, wvc]
                .add(win_any.astype(jnp.int32))) % B
        cnt = cnt.at[nn, pp, wvc].add(-win_any.astype(jnp.int32))

        # upstream credit return for real input ports
        up_ch = in_ch[nn, jnp.clip(pp, 0, P - 1)]  # [N, PI]
        has_up = (pp < P) & (up_ch >= 0) & win_any
        up_ch_s = jnp.maximum(up_ch, 0)
        ret_slot = (t + ch_depth[up_ch_s]) % D
        credit_pipe = credit_pipe.at[up_ch_s, ret_slot, wvc].add(
            has_up.astype(jnp.int32))

        # ejection vs traversal
        eject = win_any & (out_req == P)
        traverse = win_any & (out_req >= 0) & (out_req < P)
        delivered = state.delivered + m32 * jnp.sum(eject.astype(jnp.int32))
        lat_sum = state.lat_sum + measuring.astype(jnp.float32) * jnp.sum(
            jnp.where(eject, (t - w_t).astype(jnp.float32), 0.0))

        out_c = out_ch[nn, jnp.clip(out_req, 0, P - 1)]
        oc = jnp.where(traverse, out_c, -1).ravel()
        ok = traverse.ravel()
        oc_s = jnp.maximum(oc, 0)
        wslot = (t + ch_depth[oc_s]) % D
        link_dst = link_dst.at[oc_s, wslot].set(
            jnp.where(ok, w_dst.ravel(), link_dst[oc_s, wslot]))
        link_t = state.link_t.at[oc_s, wslot].set(
            jnp.where(ok, w_t.ravel(), state.link_t[oc_s, wslot]))
        link_vc = state.link_vc.at[oc_s, wslot].set(
            jnp.where(ok, wvc.ravel(), state.link_vc[oc_s, wslot]))
        credits = credits.at[nn, jnp.clip(out_req, 0, P - 1), wvc].add(
            -traverse.astype(jnp.int32))

        new_state = SimState(
            buf_dst=buf_dst, buf_t=buf_t, head=head, cnt=cnt,
            credits=credits, link_dst=link_dst, link_t=link_t,
            link_vc=link_vc, credit_pipe=credit_pipe,
            rr=(state.rr + 1) % (V * PI),
            delivered=delivered, lat_sum=lat_sum, offered=offered,
            accepted=accepted)
        return new_state, None

    def run_one(rate):
        state = init_state(spec, cfg)
        ts = jnp.arange(cfg.cycles)
        rates = jnp.full((cfg.cycles,), rate)
        state, _ = jax.lax.scan(step, state, (ts, rates))
        meas = cfg.cycles - cfg.warmup
        thr = state.delivered / (N * meas)
        lat = state.lat_sum / jnp.maximum(state.delivered, 1)
        off = state.offered / (N * meas)
        acc = state.accepted / (N * meas)
        return thr, lat, off, acc

    return jax.jit(jax.vmap(run_one))


def simulate(routing: Routing, traffic: np.ndarray, rates,
             cfg: SimConfig = SimConfig()):
    """Run the simulator for a sweep of injection rates (vmapped).

    Returns dict of numpy arrays: delivered throughput (flits/node/cycle),
    avg packet latency (cycles), offered and accepted rates.
    """
    spec = make_spec(routing, traffic)
    runner = _build_runner(spec, cfg)
    thr, lat, off, acc = runner(jnp.asarray(rates, jnp.float32))
    return dict(rate=np.asarray(rates), throughput=np.asarray(thr),
                latency=np.asarray(lat), offered=np.asarray(off),
                accepted=np.asarray(acc))


def saturation_throughput(routing: Routing, traffic: np.ndarray,
                          cfg: SimConfig = SimConfig(),
                          n_rates: int = 8) -> dict:
    """Saturation = plateau of delivered throughput over an offered sweep.

    The sweep is seeded by the analytic channel-load bound and refined
    around it.
    """
    analytic = routing.saturation_rate(traffic)
    hi = min(1.0, 2.0 * analytic)
    rates = np.linspace(max(analytic * 0.25, 1e-3), hi, n_rates)
    res = simulate(routing, traffic, rates, cfg)
    i = int(np.argmax(res["throughput"]))
    return dict(sim_saturation=float(res["throughput"][i]),
                analytic_saturation=float(analytic),
                latency_at_sat=float(res["latency"][i]), sweep=res)


def zero_load_latency(routing: Routing, traffic: np.ndarray) -> float:
    """Analytic average packet latency at zero load (cycles)."""
    _, hops, lat = routing.paths_channel_loads(traffic)
    w = traffic / max(traffic.sum(), 1e-12)
    return float((lat * w).sum())
