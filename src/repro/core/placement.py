"""Chiplet placements (paper §IV, §VI, Figs. 6 & 9).

Two placement families:
  * rectangular grid  — rows x cols of square chiplets (Fig. 6a),
  * brick-wall / hexagonal — odd rows offset by half a pitch so every
    chiplet touches six neighbours (HexaMesh arrangement, Fig. 6b),
  * hex spiral — hexagon-shaped region filled ring by ring (used to check
    the Table-III diameter formulas at perfect-hex N = 3R^2+3R+1).

Positions are chiplet centres in *pitch units*; `pitch_mm()` converts to mm
(pitch = chiplet side + chiplet spacing, per substrate).

Heterogeneous roles (paper §V-C Fig. 6 and §V-E Fig. 9):
  'C' compute, 'M' memory (leftmost/rightmost columns), 'I' IO (top/bottom
  rows; traces experiment only).
"""
from __future__ import annotations

import numpy as np

from .linkmodel import SUBSTRATE_PARAMS


def chiplet_side_mm(chiplet_area_mm2: float) -> float:
    return float(np.sqrt(chiplet_area_mm2))


def pitch_mm(chiplet_area_mm2: float, substrate: str) -> float:
    return chiplet_side_mm(chiplet_area_mm2) + \
        SUBSTRATE_PARAMS[substrate]["chiplet_spacing_mm"]


def grid_dims(n: int) -> tuple[int, int]:
    """Most-square factorization r*c == n (r <= c)."""
    best = (1, n)
    for r in range(1, int(np.sqrt(n)) + 1):
        if n % r == 0:
            best = (r, n // r)
    return best


def grid_positions(rows: int, cols: int, brick: bool = False) -> np.ndarray:
    """[N,2] centre positions in pitch units; brick=True offsets odd rows."""
    pos = np.zeros((rows * cols, 2))
    for i in range(rows):
        for j in range(cols):
            x = j + (0.5 if (brick and i % 2 == 1) else 0.0)
            pos[i * cols + j] = (x, i)
    return pos


def hex_spiral_positions(n: int) -> np.ndarray:
    """Hexagon-shaped region filled ring by ring from the centre.

    Axial coordinates (q, r); position x = q + r/2, y = r (brick-wall
    geometry with square chiplets).  Supports arbitrary n; perfect-hex
    counts are n = 3R^2+3R+1.
    """
    axial = [(0, 0)]
    ring = 1
    # axial direction vectors in ring-walk order for a start at (ring,-ring)
    dirs = [(0, 1), (-1, 1), (-1, 0), (0, -1), (1, -1), (1, 0)]
    while len(axial) < n:
        q, r = ring, -ring  # start corner of this ring (north-east)
        for d in range(6):
            for _ in range(ring):
                if len(axial) < n:
                    axial.append((q, r))
                q, r = q + dirs[d][0], r + dirs[d][1]
        ring += 1
    axial = np.array(axial[:n], dtype=np.float64)
    pos = np.stack([axial[:, 0] + axial[:, 1] / 2.0, axial[:, 1]], axis=-1)
    return pos


def assign_roles(pos: np.ndarray, scheme: str = "homogeneous",
                 mem_cols: int = 1, io_rows: int = 1) -> np.ndarray:
    """Return an array of roles 'C'/'M'/'I' per chiplet.

    'hetero_cm'  — memory chiplets in the leftmost and rightmost columns
                   (Fig. 6); 'hetero_cmi' — additionally IO chiplets in the
                   top and bottom rows (Fig. 9).
    """
    n = pos.shape[0]
    roles = np.full(n, "C", dtype="<U1")
    if scheme == "homogeneous":
        return roles
    xs, ys = pos[:, 0], pos[:, 1]
    # Fractional bands at the extremes; the 0.25 slack keeps brick-wall
    # half-pitch offsets inside the same logical column.
    x_min, x_max = xs.min(), xs.max()
    roles[xs <= x_min + mem_cols - 0.25] = "M"
    roles[xs >= x_max - mem_cols + 0.25] = "M"
    if scheme == "hetero_cmi":
        y_min, y_max = ys.min(), ys.max()
        roles[ys <= y_min + io_rows - 0.75] = "I"
        roles[ys >= y_max - io_rows + 0.75] = "I"
    return roles
