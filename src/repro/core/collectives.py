"""Topology-aware collective cost model — the paper -> framework bridge.

On a chiplet-based accelerator, the ICI topology determines the effective
bandwidth available to the collectives a sharded training step issues.
This module converts the paper's saturation-throughput results into
per-collective time estimates, so the roofline analyzer can report the
collective term *under each ICI topology* (`--ici-topology ...`).

Model: the effective all-to-all bandwidth per chiplet is the topology's
absolute saturation throughput T_a under uniform traffic (this bakes in
diameter, radix->wire-budget, link length->data rate, and congestion).
Ring-schedule lower bounds (Chan et al.) then give:

    all_reduce(S)       = 2 * S * (N-1)/N / B_eff
    all_gather(S)       =     S * (N-1)/N / B_eff
    reduce_scatter(S)   =     S * (N-1)/N / B_eff
    all_to_all(S)       =     S * (N-1)/N / B_eff   (uniform-traffic B_eff
                                                     already includes the
                                                     bisection penalty)

plus a latency term  diameter * hop_latency * log2(N) for software
pipelining depth.  S is the full buffer size in bytes per chiplet.
"""
from __future__ import annotations

import dataclasses
import functools

import numpy as np

from . import linkmodel as lm
from . import costmodel, traffic
from .routing import build_routing
from .topology import Topology, build


@dataclasses.dataclass
class IciModel:
    topology: str
    n: int
    substrate: str
    b_eff_gbps: float          # per-chiplet effective bandwidth
    diameter: int
    hop_latency_ns: float

    def collective_time_s(self, kind: str, bytes_per_chip: float) -> float:
        n = self.n
        factor = {"all_reduce": 2.0, "all_gather": 1.0,
                  "reduce_scatter": 1.0, "all_to_all": 1.0,
                  "collective_permute": 1.0 / max(n - 1, 1)}[kind]
        bw_bytes = self.b_eff_gbps * 1e9 / 8.0
        bw_term = factor * bytes_per_chip * (n - 1) / max(n, 1) / bw_bytes
        lat_term = (self.diameter * self.hop_latency_ns * 1e-9 *
                    np.log2(max(n, 2)))
        return float(bw_term + lat_term)


@functools.lru_cache(maxsize=64)
def build_ici_model(topology: str = "folded_hexa_torus", n: int = 64,
                    substrate: str = "organic",
                    use_sim: bool = False) -> IciModel:
    """use_sim=True derives B_eff from the cycle-accurate simulator via
    the batched sweep engine instead of the analytic channel-load bound
    (slower but congestion-aware; see DESIGN.md §6)."""
    topo = build(topology, n, substrate=substrate)
    r = build_routing(topo)
    u = traffic.uniform(topo)
    t_r = r.saturation_rate(u)           # analytic channel-load bound
    if use_sim:
        from repro import experiments as X
        frame = X.run(X.Experiment(
            [X.Scenario(topology, n, substrate)], name="ici_model"))
        t_r = frame.case_result(0)["sim_saturation"]
    t_a = costmodel.absolute_throughput_gbps(topo, t_r)
    hop_ns = float(lm.ROUTER_LATENCY_NS + 2 * lm.PHY_LATENCY_NS +
                   np.mean(lm.wire_latency_ns(topo.link_lengths_mm(),
                                              substrate)))
    return IciModel(topology=topology, n=n, substrate=substrate,
                    b_eff_gbps=t_a, diameter=topo.diameter,
                    hop_latency_ns=hop_ns)


# =====================================================================
# collective -> flow-matrix mapping onto chiplet placements (DESIGN.md §9)
# =====================================================================

def raster_order(topo: Topology) -> np.ndarray:
    """Chiplet ids in row-major physical order (y-major, x-fastest) —
    the canonical chiplet <-> mesh-coordinate assignment."""
    return np.lexsort((topo.pos[:, 0], topo.pos[:, 1]))


def mesh_coords(topo: Topology, mesh_shape: dict) -> dict[str, np.ndarray]:
    """Per-axis mesh coordinate of every chiplet.

    Chiplets are assigned mesh coordinates row-major over the raster
    order with the LAST mesh axis fastest — so for {"data": D, "model":
    T} the model groups are physically contiguous runs of T chiplets
    along x, the placement a real deployment would choose for its
    highest-traffic axis.
    """
    n = topo.n
    sizes = [int(s) for s in mesh_shape.values()]
    if int(np.prod(sizes)) != n:
        raise ValueError(f"mesh {mesh_shape} has {np.prod(sizes)} slots "
                         f"for {n} chiplets")
    rank = np.empty(n, dtype=np.int64)
    rank[raster_order(topo)] = np.arange(n)
    coords, rem = {}, rank
    for name, size in reversed(list(mesh_shape.items())):
        coords[name] = rem % size
        rem = rem // size
    return coords


def mesh_axis_groups(topo: Topology, mesh_shape: dict, axis: str
                     ) -> list[list[int]]:
    """Communication groups of one mesh axis: chiplets that share every
    *other* axis coordinate, ordered by their own coordinate along
    `axis` (= the ring order used for ring collectives)."""
    coords = mesh_coords(topo, mesh_shape)
    if axis not in coords:
        raise KeyError(f"axis {axis!r} not in mesh {list(mesh_shape)}")
    others = [coords[a] for a in mesh_shape if a != axis]
    key = np.zeros(topo.n, dtype=np.int64)
    for o in others:
        key = key * (int(o.max()) + 1) + o
    groups: dict[int, list[int]] = {}
    for node in np.argsort(coords[axis] + key * topo.n, kind="stable"):
        groups.setdefault(int(key[node]), []).append(int(node))
    return list(groups.values())


# flow factor: bytes each member sends to its ring successor (ring
# schedules, Chan et al.) or to each peer (all-to-all), per payload byte
_RING_FACTOR = {"all_reduce": lambda k: 2.0 * (k - 1) / k,
                "all_gather": lambda k: (k - 1) / k,
                "reduce_scatter": lambda k: (k - 1) / k,
                "collective_permute": lambda k: 1.0}


def collective_flow(n: int, kind: str, groups, bytes_per_chip: float
                    ) -> np.ndarray:
    """[N, N] byte-flow matrix of one collective over chiplet groups.

    Ring collectives put their whole payload on the group's ring edges
    (successor in group order); all-to-all spreads it over every pair.
    """
    m = np.zeros((n, n))
    for g in groups:
        k = len(g)
        if k < 2:
            continue
        if kind == "all_to_all":
            share = bytes_per_chip / k
            for i in g:
                for j in g:
                    if i != j:
                        m[i, j] += share
        elif kind in _RING_FACTOR:
            share = bytes_per_chip * _RING_FACTOR[kind](k)
            for idx, i in enumerate(g):
                m[i, g[(idx + 1) % k]] += share
        else:
            raise KeyError(f"unknown collective kind {kind!r}")
    return m


def compare_topologies(bytes_per_chip: float, kind: str = "all_reduce",
                       n: int = 64, substrate: str = "organic",
                       names=("mesh", "hexamesh", "folded_torus",
                              "folded_hexa_torus")) -> dict[str, float]:
    """Collective time (s) under each ICI topology — used by §Roofline."""
    return {name: build_ici_model(name, n, substrate)
            .collective_time_s(kind, bytes_per_chip) for name in names}
