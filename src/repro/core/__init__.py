"""repro.core — the paper's contribution: FoldedHexaTorus and the ICI
topology evaluation pipeline (topologies, routing, simulator, cost models,
and the topology-aware collective model that plugs into the training
framework's roofline analyzer)."""
from .topology import Topology, build, GENERATORS, N_CONSTRAINTS, \
    make_topology, register_topology, unregister_topology, \
    validate_edges, valid_n, nearest_valid_n  # noqa
from .routing import Routing, build_routing, dependency_graph_is_acyclic, \
    routing_for, routing_cache_info, routing_cache_clear  # noqa
from .simulator import SimConfig, simulate, saturation_throughput, \
    zero_load_latency  # noqa
from . import traffic, costmodel, linkmodel, placement, collectives  # noqa
