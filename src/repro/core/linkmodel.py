"""Link data-rate and latency models (paper §II-B, Fig. 2, Table IV).

The paper bases the rate(length) relationship on transmission-line
simulations by Kim [21] (Fig. 2).  The exact simulated curve is not
published as data; we reconstruct a piecewise-linear curve through the
anchor points the paper states explicitly:

  * organic substrates: decline begins ~10 mm; range-1 links (which span
    17.5–24.7 mm center-to-center for 74 mm^2 chiplets) run at 89–97 % of
    the max rate; range-2 links (26.3–37.2 mm) drop to 47 % worst case.
  * glass substrates: decline begins ~20 mm; range-1 links run at
    99–100 %; range-2 links drop to 66 % worst case.
  * both: no link may exceed 70 mm (rate -> 0), which is what zeroes the
    throughput of Torus / ClusCross / HoneycombTorus / FlattenedButterfly
    at large N (paper §V-C).
  * passive silicon interposers: rate drops significantly past 4 mm.

All lengths in mm, rates as a fraction of MAX_RATE_GBPS per wire.
"""
from __future__ import annotations

import numpy as np

# Maximum per-wire data rate at zero length.  UCIe on a standard (organic)
# package commonly runs 16 GT/s per wire; the absolute value only scales
# absolute throughput T_a, relative topology comparisons are invariant.
MAX_RATE_GBPS = 16.0

# C4 bumps usable for D2D signalling sit in rows along the chiplet
# perimeter (RapidChiplet's PHY placement model); the full under-die bump
# field is dominated by power/ground and core I/O.  Four signal rows
# calibrates the absolute T_a and the Table-II power-at-saturation deltas
# to the paper's magnitudes (~2 % of chiplet power, not ~20 %).
PERIMETER_SIGNAL_ROWS = 4

# Hard cutoff from the paper: "some links surpass the maximum permissible
# length of 70 mm" -> throughput drops to zero.
MAX_LINK_LENGTH_MM = 70.0

# (length_mm, fraction_of_max_rate) anchors.
_CURVES = {
    "organic": [(0.0, 1.00), (10.0, 1.00), (17.5, 0.97), (24.7, 0.89),
                (31.0, 0.68), (37.2, 0.47), (50.0, 0.25), (70.0, 0.08)],
    "glass":   [(0.0, 1.00), (20.0, 1.00), (24.7, 0.99), (31.0, 0.83),
                (37.2, 0.66), (50.0, 0.38), (70.0, 0.12)],
    "passive_interposer": [(0.0, 1.00), (4.0, 1.00), (6.0, 0.60),
                           (8.0, 0.30), (10.0, 0.12), (12.0, 0.02),
                           (15.0, 0.0)],
}

# Table IV parameters, keyed by substrate.
SUBSTRATE_PARAMS = {
    "organic": dict(chiplet_spacing_mm=0.150, bump_pitch_um=50.0,
                    dielectric_constant=3.1),
    "glass":   dict(chiplet_spacing_mm=0.100, bump_pitch_um=35.0,
                    dielectric_constant=3.3),
}

# Shared Table IV parameters.
CHIPLET_AREA_MM2 = 74.0          # A_c   [26]
PHY_AREA_MM2 = 0.88              # A_p   [27]
CHIPLET_POWER_W = 25.0           # P_c   assumption
ENERGY_PER_BIT_PJ = 0.3          # E_bit [2]
PHY_LATENCY_NS = 2.0             # L_p   [27]
ROUTER_LATENCY_NS = 3.0          # L_r   assumption
FRAC_BUMPS_POWER = 0.50          # f_pb  [5]
FRAC_BUMPS_IO = 0.20             # f_io  assumption
CORES_PER_CHIPLET = 8            # N_c   [26]
NON_DATA_WIRES = 12              # N_w   [27]
SPEED_OF_LIGHT_MM_PER_NS = 299.792458  # c


def rate_fraction(length_mm, substrate: str):
    """Fraction of MAX_RATE_GBPS achievable at a given link length (Fig. 2).

    Vectorized over `length_mm`.  Returns 0 beyond MAX_LINK_LENGTH_MM
    (70 mm) for substrates, and beyond the curve end for interposers.
    """
    curve = _CURVES[substrate]
    xs = np.array([p[0] for p in curve])
    ys = np.array([p[1] for p in curve])
    length = np.asarray(length_mm, dtype=np.float64)
    frac = np.interp(length, xs, ys, left=1.0, right=0.0)
    if substrate != "passive_interposer":
        frac = np.where(length > MAX_LINK_LENGTH_MM, 0.0, frac)
    return frac


def rate_gbps(length_mm, substrate: str):
    """Absolute per-wire data rate in Gbit/s for a link of given length."""
    return MAX_RATE_GBPS * rate_fraction(length_mm, substrate)


def wire_latency_ns(length_mm, substrate: str):
    """Transmission-line propagation latency: L * sqrt(eps_r) / c (§V-B2)."""
    eps_r = SUBSTRATE_PARAMS[substrate]["dielectric_constant"]
    return np.asarray(length_mm) * np.sqrt(eps_r) / SPEED_OF_LIGHT_MM_PER_NS


def hop_latency_cycles(length_mm, substrate: str, cycle_ns: float = 1.0):
    """Cycles consumed by one chiplet-to-chiplet hop (§V-B2).

    router (L_r) + tx PHY (L_p) + wire + rx PHY (L_p); the wire latency is
    rounded up to a full cycle as in the paper.
    """
    wire = np.ceil(wire_latency_ns(np.asarray(length_mm), substrate)
                   / cycle_ns)
    cycles = (wire + (ROUTER_LATENCY_NS + 2.0 * PHY_LATENCY_NS) / cycle_ns
              ).astype(np.int64)
    return int(cycles) if np.ndim(length_mm) == 0 else cycles


def bumps_per_chiplet(chiplet_area_mm2: float, substrate: str) -> int:
    """Full-area C4 bump array under the chiplet at the substrate pitch."""
    side_mm = np.sqrt(chiplet_area_mm2)
    pitch_mm = SUBSTRATE_PARAMS[substrate]["bump_pitch_um"] / 1000.0
    per_side = int(np.floor(side_mm / pitch_mm))
    return per_side * per_side


def data_wires_per_link(radix: int, substrate: str,
                        chiplet_area_mm2: float = CHIPLET_AREA_MM2) -> int:
    """Data wires available to one D2D link (§III-C).

    PHY bumps live in PERIMETER_SIGNAL_ROWS rows along the chiplet edge;
    50 % of the budget goes to power, 20 % to off-chip I/O; the rest is
    split across the R links, and each link pays N_w = 12 non-data wires
    (UCIe).  This is the mechanism behind Principle 3: per-link bandwidth
    shrinks as the radix grows.
    """
    side_mm = np.sqrt(chiplet_area_mm2)
    pitch_mm = SUBSTRATE_PARAMS[substrate]["bump_pitch_um"] / 1000.0
    per_row = int(np.floor(side_mm / pitch_mm))
    budget = PERIMETER_SIGNAL_ROWS * 4 * per_row \
        * (1.0 - FRAC_BUMPS_POWER - FRAC_BUMPS_IO)
    per_link = int(np.floor(budget / max(radix, 1))) - NON_DATA_WIRES
    return max(per_link, 0)
