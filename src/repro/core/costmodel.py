"""Throughput / latency / area / power cost models (paper §V-B).

Implements the paper's evaluation formulas:

  * absolute per-chiplet throughput
        T_a = T_r * n_data_wires(R) * rate(L_hat)            [bit/s]
    where T_r is the relative (BookSim) saturation throughput in
    flits/node/cycle, n_data_wires divides the post-power post-IO bump
    budget by the radix and subtracts the 12 UCIe non-data wires, and
    rate() is the Fig.-2 curve at the topology's maximum link length,
  * total chiplet area  A = A_c + R * A_p                     (§V-B3)
  * power          P = N * P_c + E_bit * total_link_bits/s    (§V-B4)
    evaluated at saturation throughput: every delivered bit crosses
    avg_hops links.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from . import linkmodel as lm
from .topology import Topology


@dataclasses.dataclass
class CostReport:
    name: str
    n: int
    radix: int
    rel_throughput: float          # T_r   [flits/node/cycle]
    abs_throughput_gbps: float     # T_a   [Gbit/s per chiplet]
    avg_latency_ns: float
    area_mm2: float                # per chiplet, incl. PHYs
    phy_area_fraction: float
    power_w: float                 # whole system at saturation
    max_link_mm: float


def data_wires(topo: Topology) -> int:
    return lm.data_wires_per_link(topo.radix, topo.substrate,
                                  topo.chiplet_area_mm2)


def absolute_throughput_gbps(topo: Topology, rel_throughput: float) -> float:
    l_hat = topo.max_link_length_mm()
    wires = data_wires(topo)
    return float(rel_throughput * wires *
                 lm.rate_gbps(l_hat, topo.substrate))


def wire_cost_mm(topo: Topology) -> float:
    """Substrate wiring-resource proxy (Principle 3): total wire length
    routed through the substrate — per-link wires (data plus the 12
    UCIe non-data wires) times centre-to-centre link length, summed
    over all links.  One of the three Pareto objectives the synthesis
    engine (repro.synth) optimizes; unit is wire-mm."""
    wires = data_wires(topo) + lm.NON_DATA_WIRES
    return float(topo.link_lengths_mm().sum() * wires)


def chiplet_area_mm2(topo: Topology) -> float:
    return topo.chiplet_area_mm2 + topo.radix * lm.PHY_AREA_MM2


def phy_area_fraction(topo: Topology) -> float:
    a = chiplet_area_mm2(topo)
    return topo.radix * lm.PHY_AREA_MM2 / a


def system_power_w(topo: Topology, abs_thr_gbps: float,
                   avg_hops: float) -> float:
    """N * P_c + E_bit * (bits/s through all links) at saturation."""
    bits_per_s = abs_thr_gbps * 1e9 * topo.n * avg_hops
    return topo.n * lm.CHIPLET_POWER_W + \
        bits_per_s * lm.ENERGY_PER_BIT_PJ * 1e-12


def report(topo: Topology, rel_throughput: float, avg_hops: float,
           avg_latency_cycles: float) -> CostReport:
    t_a = absolute_throughput_gbps(topo, rel_throughput)
    return CostReport(
        name=topo.name, n=topo.n, radix=topo.radix,
        rel_throughput=rel_throughput,
        abs_throughput_gbps=t_a,
        avg_latency_ns=avg_latency_cycles,  # cycle time = 1 ns (§V-B2)
        area_mm2=chiplet_area_mm2(topo),
        phy_area_fraction=phy_area_fraction(topo),
        power_w=system_power_w(topo, t_a, avg_hops),
        max_link_mm=topo.max_link_length_mm())
