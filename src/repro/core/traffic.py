"""Traffic patterns (paper §V-C/D/E).

All patterns return an [N, N] matrix whose row i is the probability
distribution of destinations for packets injected at node i (rows of inert
sources are all-zero).  Heterogeneous variants implement the paper's 50/50
core-to-core + core-to-memory mix (§V-C) and the C/M/I cache-coherence
placement used with traces (§V-E).
"""
from __future__ import annotations

import numpy as np

from .topology import Topology


def _normalize(m: np.ndarray) -> np.ndarray:
    np.fill_diagonal(m, 0.0)
    rows = m.sum(axis=1, keepdims=True)
    out = np.divide(m, rows, out=np.zeros_like(m), where=rows > 0)
    return out


def uniform(topo: Topology) -> np.ndarray:
    n = topo.n
    return _normalize(np.ones((n, n)))


def random_permutation(topo: Topology, seed: int = 0) -> np.ndarray:
    """Each source sends all traffic to one random distinct destination.

    The mapping is a proper *derangement*: rejection-sample a uniform
    one, falling back to a cyclic shift of a random order (always
    fixed-point-free) if none of the draws lands.  The seed code instead
    patched fixed points with pairwise swaps — a repair whose swap
    partner `j` can itself end up mapped back to `i`, reintroducing a
    fixed point that `_normalize` then silently turns into an inert
    all-zero source row (regression: tests/test_traffic_properties.py
    seed sweep).
    """
    n = topo.n
    if n < 2:
        return np.zeros((n, n))
    rng = np.random.default_rng(seed)
    for _ in range(8):
        perm = rng.permutation(n)
        if not np.any(perm == np.arange(n)):
            break
    else:
        # cyclic-shift fallback: order[i] -> order[i+1] is a single
        # n-cycle, hence a derangement for any n >= 2
        order = rng.permutation(n)
        perm = np.empty(n, dtype=np.int64)
        perm[order] = np.roll(order, -1)
    m = np.zeros((n, n))
    m[np.arange(n), perm] = 1.0
    return _normalize(m)


def tornado(topo: Topology) -> np.ndarray:
    """Half-machine offset along the x dimension (adversarial for rings)."""
    n = topo.n
    order = np.lexsort((topo.pos[:, 0], topo.pos[:, 1]))  # row-major ranks
    rank = np.empty(n, dtype=int)
    rank[order] = np.arange(n)
    m = np.zeros((n, n))
    shift = n // 2
    for i in range(n):
        target_rank = (rank[i] + shift) % n
        m[i, order[target_rank]] = 1.0
    return _normalize(m)


def neighbor(topo: Topology) -> np.ndarray:
    """Each source spreads traffic uniformly over its physical neighbours
    (chiplets within 1.75 pitch — the adjacent ring)."""
    n = topo.n
    d = np.sqrt(((topo.pos[:, None, :] - topo.pos[None, :, :]) ** 2).sum(-1))
    m = ((d > 0) & (d <= 1.75)).astype(float)
    # isolated fallbacks: nearest node
    for i in range(n):
        if m[i].sum() == 0:
            j = np.argsort(d[i])[1]
            m[i, j] = 1.0
    return _normalize(m)


def hetero_mix(topo: Topology, frac_mem: float = 0.5) -> np.ndarray:
    """50/50 core-to-core + core-to-memory (paper §V-C, Fig. 6).

    Compute chiplets send `1-frac_mem` uniformly to other compute chiplets
    and `frac_mem` uniformly to memory chiplets; memory chiplets reply
    uniformly to compute chiplets (read responses).
    """
    roles = topo.roles
    n = topo.n
    is_c = roles == "C"
    is_m = roles == "M"
    if is_m.sum() == 0:
        return uniform(topo)
    m = np.zeros((n, n))
    m[np.ix_(is_c, is_c)] = (1 - frac_mem) / max(is_c.sum() - 1, 1)
    m[np.ix_(is_c, is_m)] = frac_mem / is_m.sum()
    m[np.ix_(is_m, is_c)] = 1.0 / is_c.sum()
    return _normalize(m)


def coherence_cmi(topo: Topology) -> np.ndarray:
    """Cache-coherence-style flows for the trace experiment (§V-E):
    L1 (compute) <-> L2 (memory) <-> main memory (IO)."""
    roles = topo.roles
    n = topo.n
    is_c, is_m, is_i = roles == "C", roles == "M", roles == "I"
    if is_m.sum() == 0 or is_i.sum() == 0:
        return hetero_mix(topo)
    m = np.zeros((n, n))
    m[np.ix_(is_c, is_m)] = 0.8 / is_m.sum()     # L1 -> L2
    m[np.ix_(is_c, is_c)] = 0.2 / max(is_c.sum() - 1, 1)  # C2C coherence
    m[np.ix_(is_m, is_c)] = 0.7 / is_c.sum()     # L2 fills
    m[np.ix_(is_m, is_i)] = 0.3 / is_i.sum()     # L2 -> memory
    m[np.ix_(is_i, is_m)] = 1.0 / is_m.sum()     # memory -> L2
    return _normalize(m)


PATTERNS = {
    "uniform": uniform,
    "permutation": random_permutation,
    "tornado": tornado,
    "neighbor": neighbor,
    "hetero_mix": hetero_mix,
    "coherence_cmi": coherence_cmi,
}


# --------------------------------------------------------------------------
# Synthetic Netrace-like traces (§V-E).  Real PARSEC Netrace files are not
# available offline; we generate dependency-light traces with the same
# region structure: per-region packet intensity and flow mix between C/M/I
# chiplets, modelled after blackscholes (compute-heavy, low traffic) and
# fluidanimate (memory-heavy bursts).
# --------------------------------------------------------------------------

TRACE_PROFILES = {
    # per-region (intensity multiplier, mem_fraction) pairs; 5 regions each
    "blackscholes": [(0.15, 0.6), (0.35, 0.55), (0.25, 0.5), (0.4, 0.6),
                     (0.2, 0.5)],
    "fluidanimate": [(0.5, 0.7), (0.8, 0.75), (0.65, 0.7), (0.9, 0.8),
                     (0.55, 0.65)],
}


def region_traffic(topo: Topology, mem_frac: float) -> np.ndarray:
    """Traffic matrix of one trace region: coherence flows blended with a
    memory mix of the region's intensity (shared by the legacy
    `trace_region_traffic` and `repro.workloads.traces`)."""
    base = coherence_cmi(topo)
    mix = hetero_mix(topo, frac_mem=mem_frac)
    return _normalize(0.5 * base + 0.5 * mix)


def trace_region_traffic(topo: Topology, profile: str, region: int):
    """Return (traffic matrix, relative intensity) for one trace region."""
    intensity, mem_frac = TRACE_PROFILES[profile][region]
    return region_traffic(topo, mem_frac), intensity
