"""Deadlock-free shortest-path routing on arbitrary ICI topologies.

This is the paper's §V-B recipe: a custom routing algorithm based on
Dijkstra's algorithm, incorporating the turn model [34], a simple
cycle-breaking algorithm [35], and a dual-graph construction [36]:

  1. **Cycle breaking / turn prohibition** — nodes are BFS-labelled from a
     central root; a directed channel u->v is *up* if it decreases the
     (depth, id) label.  Turns *down->up* are prohibited (up*/down*
     ordering), which makes the channel-dependency graph acyclic and hence
     the routing deadlock-free on any connected topology.
  2. **Dual graph** — vertices are directed channels (plus one virtual
     ejection vertex per node); edges are the *allowed* turns.
  3. **Dijkstra** — run from every destination's ejection vertex over the
     reversed dual graph; the routing table then maps
     (destination, current node, input channel) -> output port by greedy
     descent on the dual-graph distance.

The module also provides the *analytic* channel-load throughput bound used
as a fast cross-check of the cycle-accurate simulator: for a traffic
matrix P (rows sum to 1), the expected per-channel load at unit injection
is  load_e = sum_{s,d} P[s,d] * [e on path(s,d)]  and the saturation
injection rate is  min(1, 1/max_e load_e)  flits/node/cycle.
"""
from __future__ import annotations

import dataclasses
import functools
import os

import numpy as np
import scipy.sparse as sp
import scipy.sparse.csgraph as csgraph

from repro.obs.trace import trace as _span

from .topology import Topology, build
from . import linkmodel as lm


@dataclasses.dataclass
class Routing:
    topo: Topology
    # directed channels
    ch_src: np.ndarray          # [C] source node of channel
    ch_dst: np.ndarray          # [C] destination node
    ch_len_mm: np.ndarray       # [C] physical length
    ch_out_port: np.ndarray     # [C] output-port index at src
    ch_in_port: np.ndarray      # [C] input-port index at dst
    out_ch: np.ndarray          # [N, P] channel id per output port (-1 pad)
    in_ch: np.ndarray           # [N, P] channel id per input port (-1 pad)
    n_ports: np.ndarray         # [N] real (non-virtual) port count
    # routing table: [dst, node, in_port(+1 for injection)] -> out port
    # value == EJECT means deliver locally; -1 means unused/unreachable.
    table: np.ndarray
    prohibited_turns: int
    total_turns: int

    EJECT: int = -2

    #: verification certificate (`analysis.routing_verify
    #: .RoutingCertificate`), attached by `routing_for(certify=True)`
    #: and cached with the routing; None until certified.
    cert: object = None

    #: productive-ports mask [N_dst, N, P] (minimal-adaptive routing,
    #: DESIGN.md §15), computed lazily by `productive_ports` and cached
    #: with the routing; None until first requested.
    prod: object = None

    @property
    def n_channels(self) -> int:
        return len(self.ch_src)

    @property
    def max_ports(self) -> int:
        return self.out_ch.shape[1]

    # -- path following ------------------------------------------------
    def paths_channel_loads(self, traffic: np.ndarray,
                            max_hops: int | None = None):
        """Follow the routing table for all (s, d) pairs simultaneously.

        traffic: [N, N] matrix, rows sum to 1 (diagonal ignored).
        Returns (loads[C], hops[N, N], lat_cycles[N, N]).
        """
        topo, n = self.topo, self.topo.n
        if max_hops is None:
            max_hops = 4 * topo.n  # safe upper bound; loops would exceed it
        hop_cy = lm.hop_latency_cycles(self.ch_len_mm, topo.substrate)

        s_idx, d_idx = np.meshgrid(np.arange(n), np.arange(n), indexing="ij")
        s_idx, d_idx = s_idx.ravel(), d_idx.ravel()
        w = traffic[s_idx, d_idx]
        # follow only pairs that carry traffic: on fault-degraded
        # topologies (repro.faults) pairs involving dead chiplets are
        # unreachable by construction, and their masked weight is 0 —
        # routing them would false-alarm the dead-end check.  Zero-
        # weight pairs contribute 0 to every weighted consumer (loads,
        # avg hops, zero-load latency) either way.
        alive = (s_idx != d_idx) & (w > 0)
        cur = s_idx.copy()
        in_port = np.full(n * n, self.max_ports, dtype=np.int32)  # injection
        loads = np.zeros(self.n_channels)
        hops = np.zeros(n * n, dtype=np.int32)
        lat = np.zeros(n * n, dtype=np.float64)

        for _ in range(max_hops):
            if not alive.any():
                break
            out_port = self.table[d_idx[alive], cur[alive], in_port[alive]]
            if (out_port < 0).any():
                bad = np.where(out_port < 0)[0]
                raise RuntimeError(
                    f"routing table dead end for "
                    f"{(s_idx[alive][bad[0]], d_idx[alive][bad[0]])}")
            ch = self.out_ch[cur[alive], out_port]
            np.add.at(loads, ch, w[alive])
            hops[alive] += 1
            lat[alive] += hop_cy[ch]
            cur_new = self.ch_dst[ch]
            in_port_new = self.ch_in_port[ch]
            cur[alive] = cur_new
            in_port[alive] = in_port_new
            arrived = cur == d_idx
            alive = alive & ~arrived
        if alive.any():
            raise RuntimeError("routing did not converge (livelock?)")
        return loads, hops.reshape(n, n), lat.reshape(n, n)

    def saturation_rate(self, traffic: np.ndarray) -> float:
        """Analytic saturation injection rate (flits/node/cycle)."""
        loads, _, _ = self.paths_channel_loads(traffic)
        max_load = loads.max()
        # ejection bottleneck: a node cannot absorb more than 1 flit/cycle
        ej_load = traffic.sum(axis=0).max()
        return float(min(1.0 / max(max_load, 1e-12),
                         1.0 / max(ej_load, 1e-12), 1.0))

    def restricted_hops(self) -> np.ndarray:
        u = np.ones((self.topo.n, self.topo.n))
        np.fill_diagonal(u, 0.0)
        rs = u.sum(1, keepdims=True)
        _, hops, _ = self.paths_channel_loads(u / np.maximum(rs, 1))
        return hops


def build_routing(topo: Topology, root: int | None = None,
                  sweep_roots: bool = False,
                  include_orderings: bool = False) -> Routing:
    """Build deadlock-free routing.

    Default (root=None): BFS up*/down* from the central chiplet — ONE
    uniform policy for every topology, mirroring the paper's §V-B setup
    (their comparison holds the routing methodology fixed).

    sweep_roots=True tries several spanning-tree roots and keeps the one
    with the highest uniform saturation; include_orderings=True also
    tries coordinate-lexicographic channel orderings.  Both lift
    individual topologies substantially (EXPERIMENTS.md §I7) but amount
    to per-topology routing tuning, so they are opt-in diagnostics, not
    the default evaluation.
    """
    if root is None and not sweep_roots:
        return _build_routing_rooted(topo, _central_node(topo))
    if root is None:
        n = topo.n
        center = _central_node(topo)
        candidates: list = sorted({0, center, n // 2, n // 4, n - 1})
        builds = [lambda c=c: _build_routing_rooted(topo, c)
                  for c in candidates]
        if include_orderings:
            xy = np.lexsort((topo.pos[:, 0], topo.pos[:, 1]))
            yx = np.lexsort((topo.pos[:, 1], topo.pos[:, 0]))
            lab_xy = np.empty(n)
            lab_xy[xy] = np.arange(n)
            lab_yx = np.empty(n)
            lab_yx[yx] = np.arange(n)
            builds += [lambda lab=lab: _build_routing_rooted(topo, 0,
                                                             labels=lab)
                       for lab in (lab_xy, lab_yx)]
        best, best_rate = None, -1.0
        u = np.ones((n, n))
        np.fill_diagonal(u, 0.0)
        u /= np.maximum(u.sum(1, keepdims=True), 1)
        for make in builds:
            try:
                r = make()
                rate = r.saturation_rate(u)     # raises on dead ends
            except RuntimeError:
                continue   # ordering invalid for this topology — skip
            if rate > best_rate:
                best, best_rate = r, rate
        assert best is not None, "no valid routing found"
        return best
    return _build_routing_rooted(topo, root)


def _central_node(topo: Topology) -> int:
    """Most-central chiplet with at least one live link.  On pristine
    topologies every node has links, so this is exactly the old
    geometric-centre rule; on fault-degraded topologies (repro.faults)
    a dead chiplet may sit isolated at the centre, and rooting the
    up*/down* BFS there would label every survivor unreachable (all
    channels 'down' -> no turn prohibited -> deadlock)."""
    d2 = ((topo.pos - topo.pos.mean(0)) ** 2).sum(-1)
    deg = topo.degrees()
    if (deg > 0).any():
        d2 = np.where(deg > 0, d2, np.inf)
    return int(np.argmin(d2))


def _build_routing_rooted(topo: Topology, root: int,
                          labels: np.ndarray | None = None) -> Routing:
    n, edges = topo.n, topo.edges
    # ---- directed channels and port maps -------------------------------
    ch_src = np.concatenate([edges[:, 0], edges[:, 1]]).astype(np.int32)
    ch_dst = np.concatenate([edges[:, 1], edges[:, 0]]).astype(np.int32)
    pmm = topo.pos_mm()
    ch_len = np.sqrt(((pmm[ch_src] - pmm[ch_dst]) ** 2).sum(-1))
    C = len(ch_src)

    order = np.lexsort((ch_dst, ch_src))
    # per-node port indices (output side)
    ch_out_port = np.zeros(C, dtype=np.int32)
    out_counts = np.zeros(n, dtype=np.int32)
    for c in order:
        ch_out_port[c] = out_counts[ch_src[c]]
        out_counts[ch_src[c]] += 1
    in_counts = np.zeros(n, dtype=np.int32)
    ch_in_port = np.zeros(C, dtype=np.int32)
    order_in = np.lexsort((ch_src, ch_dst))
    for c in order_in:
        ch_in_port[c] = in_counts[ch_dst[c]]
        in_counts[ch_dst[c]] += 1
    P = int(max(out_counts.max(), in_counts.max()))
    out_ch = np.full((n, P), -1, dtype=np.int32)
    in_ch = np.full((n, P), -1, dtype=np.int32)
    out_ch[ch_src, ch_out_port] = np.arange(C)
    in_ch[ch_dst, ch_in_port] = np.arange(C)

    # ---- up/down labels (cycle breaking) --------------------------------
    adj = topo.adjacency()
    if labels is None:
        depth = csgraph.shortest_path(adj, unweighted=True, indices=root)
        label = depth * n + np.arange(n)       # (depth, id) lexicographic
    else:
        label = np.asarray(labels, dtype=np.float64)
    ch_is_up = label[ch_dst] < label[ch_src]

    # ---- dual graph ------------------------------------------------------
    # vertices: channels [0, C), ejection vertices [C, C+n)
    rows, cols, wts = [], [], []
    n_turns = n_prohibited = 0
    for c1 in range(C):
        v = ch_dst[c1]
        for p in range(P):
            c2 = out_ch[v, p]
            if c2 < 0:
                continue
            if ch_dst[c2] == ch_src[c1]:
                continue                        # no u-turns
            n_turns += 1
            if (not ch_is_up[c1]) and ch_is_up[c2]:
                n_prohibited += 1               # down -> up prohibited
                continue
            rows.append(c1), cols.append(c2), wts.append(1.0)
    for c in range(C):                          # channel -> ejection at dst
        rows.append(c), cols.append(C + ch_dst[c]), wts.append(0.0)
    dual = sp.csr_matrix((wts, (rows, cols)), shape=(C + n, C + n))

    # distance from every channel to every destination's ejection vertex:
    # Dijkstra on the reversed dual graph, sources = ejection vertices.
    dist = csgraph.dijkstra(dual.T, indices=np.arange(C, C + n))  # [n, C+n]
    dist = dist[:, :C]                          # to-dst distance per channel

    # ---- routing table ---------------------------------------------------
    # table[d, u, in_port]: in_port == P means freshly injected at u.
    table = np.full((n, n, P + 1), -1, dtype=np.int16)
    big = np.inf
    for d in range(n):
        cand = np.where(out_ch >= 0, 1.0 + dist[d][np.maximum(out_ch, 0)],
                        big)                    # [n, P]
        # injected packets: all turns allowed
        inj_port = np.argmin(cand, axis=1)
        ok = cand[np.arange(n), inj_port] < big
        table[d, :, P] = np.where(ok, inj_port, -1)
    # arrived-via-channel entries: restrict to allowed turns
    allowed = (dual[:C, :C].toarray() > 0)      # [C, C] allowed turns
    for c1 in range(C):
        v = ch_dst[c1]
        costs = np.full((n, P), big)
        for p in range(P):
            c2 = out_ch[v, p]
            if c2 >= 0 and allowed[c1, c2]:
                costs[:, p] = 1.0 + dist[:, c2]
        p_best = np.argmin(costs, axis=1)       # [n] best port per dst
        valid = costs[np.arange(n), p_best] < big
        table[:, v, ch_in_port[c1]] = np.where(valid, p_best, -1)
    for d in range(n):
        table[d, d, :] = Routing.EJECT

    return Routing(topo=topo, ch_src=ch_src, ch_dst=ch_dst, ch_len_mm=ch_len,
                   ch_out_port=ch_out_port, ch_in_port=ch_in_port,
                   out_ch=out_ch, in_ch=in_ch, n_ports=out_counts,
                   table=table, prohibited_turns=n_prohibited,
                   total_turns=n_turns)


def productive_ports(r: Routing) -> np.ndarray:
    """[N_dst, N, P] bool: escape-safe minimal next hops (DESIGN.md §15).

    `prod[d, u, p]` is True when forwarding a flit for destination d out
    of node u's port p is both

      * **minimal** — the channel at (u, p) leads to a neighbour w with
        `hops(w, d) + 1 == hops(u, d)` (unweighted shortest-path
        distances on the live adjacency; disconnected pairs are never
        minimal), and
      * **escape-safe** — after the hop the flit can still drain through
        the escape class: either `w == d` (next stop is ejection) or the
        static up*/down* table has a route from w's arrival in-port,
        `table[d, w, ch_in_port] >= 0`.  The escape table is indexed by
        the *arrival in-port*, whose turn restrictions keep the escape
        channel-dependency graph acyclic — re-looking-up the injection
        column at intermediate hops could retake a prohibited down->up
        turn and deadlock.

    This is the adaptive routing function of the Duato-style VC split in
    `core.simulator` (VC 0 = escape, VCs 1.. = adaptive): any subset of
    these choices keeps every buffered flit one table lookup away from a
    deadlock-free drain.  Rows at the destination itself are False (the
    table ejects).  The mask is cached on `r.prod`.
    """
    if r.prod is not None:
        return r.prod
    n, P = r.topo.n, r.max_ports
    prod = np.zeros((n, n, P), dtype=bool)
    if r.n_channels:
        hops = csgraph.shortest_path(r.topo.adjacency(), unweighted=True)
        u, w = r.ch_src, r.ch_dst
        hw, hu = hops[w], hops[u]                     # [C, N] per dst
        minimal = np.isfinite(hw) & (hw + 1 == hu)
        esc = (w[:, None] == np.arange(n)[None, :]) | \
            (r.table[:, w, r.ch_in_port].T >= 0)
        prod[:, u, r.ch_out_port] = (minimal & esc).T
        prod[np.arange(n), np.arange(n), :] = False
    r.prod = prod
    return prod


# ---------------------------------------------------------------------
# routing cache — keyed on structural hash, never on names
# ---------------------------------------------------------------------
# The old lru_cache keyed on (name, n, substrate, ...) silently collided
# for custom/synthesized topologies sharing a name (re-registering a
# name, or two search candidates both called "rg_0", served each other's
# stale routing tables).  The cache identity is now what routing
# actually depends on: the structural hash (nodes + edges + positions)
# plus substrate and chiplet area, which set link lengths and hop
# latencies.  Names are labels only.

_ROUTING_CACHE: dict[tuple, Routing] = {}
_ROUTING_CACHE_MAX = int(os.environ.get("REPRO_ROUTING_CACHE_MAX", "4096"))
_ROUTING_CACHE_STATS = dict(hits=0, misses=0, evictions=0)


def routing_for(topo: Topology, certify: bool = False) -> Routing:
    """Build-and-cache the deadlock-free routing for a topology.

    Routing construction (Dijkstra over the dual graph) dominates
    analytic evaluation time; benchmarks, the experiment planner and
    the synthesis engine share this cache so a structure is only ever
    routed once per process — regardless of what it is named.

    certify=True additionally runs the exhaustive static verifier
    (`repro.analysis.routing_verify`) and attaches the resulting
    `RoutingCertificate` as `r.cert`.  The certificate lives with the
    cached routing, so a structure is certified at most once per
    process; it raises nothing — inspect `r.cert.ok` / diagnostics.
    """
    key = (topo.structural_hash(), topo.substrate,
           float(topo.chiplet_area_mm2))
    hit = _ROUTING_CACHE.pop(key, None)
    if hit is not None:
        _ROUTING_CACHE[key] = hit          # LRU: move to the back
        _ROUTING_CACHE_STATS["hits"] += 1
        if certify and hit.cert is None:
            hit.cert = _certify(hit)
        return hit
    _ROUTING_CACHE_STATS["misses"] += 1
    with _span("routing.build", cat="routing", topology=topo.name,
               n=topo.n, substrate=topo.substrate):
        r = build_routing(topo)
    if certify:
        r.cert = _certify(r)
    _ROUTING_CACHE[key] = r
    while len(_ROUTING_CACHE) > _ROUTING_CACHE_MAX:
        _ROUTING_CACHE.pop(next(iter(_ROUTING_CACHE)))
        _ROUTING_CACHE_STATS["evictions"] += 1
    return r


def _certify(r: Routing):
    from repro.analysis.routing_verify import certify_routing
    with _span("routing.certify", cat="routing", topology=r.topo.name,
               n=r.topo.n, substrate=r.topo.substrate):
        return certify_routing(r)


def routing_cache_info() -> dict:
    """Routing-cache introspection, same shape idea as the simulator's
    `runner_cache_info`: size/max plus monotonic hit/miss/eviction
    counters (they survive `routing_cache_clear`)."""
    return dict(size=len(_ROUTING_CACHE), max_size=_ROUTING_CACHE_MAX,
                **_ROUTING_CACHE_STATS)


def routing_cache_clear() -> None:
    _ROUTING_CACHE.clear()


@functools.lru_cache(maxsize=4096)
def _cached_build(name: str, n: int, substrate: str, area: float,
                  roles: str, hex_region: bool) -> Topology:
    return build(name, n, substrate=substrate, chiplet_area_mm2=area,
                 roles_scheme=roles, hex_region=hex_region)


def cached_routing(name: str, n: int, substrate: str = "organic",
                   area: float = 74.0, roles: str = "homogeneous",
                   hex_region: bool = False) -> tuple[Topology, Routing]:
    """Build-and-cache (topology, routing) for one *named* evaluation
    cell.  Topology construction is memoized per name cell (cheap,
    needed for registered generators whose output may change between
    registrations — the build is re-validated, not the cache, in that
    case); the expensive routing is cached by `routing_for` on the
    structural hash, so same-named cells with different structures can
    no longer collide."""
    if name in _CUSTOM():
        # registered generators can be re-registered: never serve a
        # memoized build for them, rebuild (cheap) and let routing_for
        # key on the structure.
        topo = build(name, n, substrate=substrate, chiplet_area_mm2=area,
                     roles_scheme=roles, hex_region=hex_region)
    else:
        topo = _cached_build(name, n, substrate, area, roles, hex_region)
    return topo, routing_for(topo)


def _CUSTOM():
    from .topology import CUSTOM_GENERATORS
    return CUSTOM_GENERATORS


def dependency_graph_is_acyclic(r: Routing) -> bool:
    """Deprecated: use `repro.analysis.routing_verify` instead.

    This predicate answers yes/no with no witness; the verifier's
    `check_acyclic` returns the actual channel-dependency cycle (as an
    RT001 diagnostic) and `certify_routing` bundles it with the
    reachability and table-well-formedness checks.  Kept as a shim over
    the same vectorized dependency-edge extraction so existing callers
    keep working."""
    import warnings

    from repro.analysis.routing_verify import (dependency_edges,
                                               find_cdg_cycle)
    warnings.warn(
        "dependency_graph_is_acyclic is deprecated; use "
        "repro.analysis.routing_verify.certify_routing (or "
        "routing_for(topo, certify=True)) for a witness-producing "
        "certificate", DeprecationWarning, stacklevel=2)
    return not find_cdg_cycle(dependency_edges(r), r.n_channels)
