"""Adversarial / synthetic phase schedules (DESIGN.md §9).

Stationary synthetic patterns (uniform, tornado, ...) miss the failure
modes of phased traffic: a topology can look fine under each pattern in
isolation yet thrash when the pattern *changes* while queues still hold
the previous phase's flits.  These generators build such schedules from
the static pattern library in `repro.core.traffic`.
"""
from __future__ import annotations

import numpy as np

from repro.core import traffic as TR
from repro.core.topology import Topology

from .schedule import Phase, Schedule


def phase_alternating(topo: Topology, patterns=("tornado", "uniform"),
                      phase_cycles: int = 300, repeats: int = 2,
                      intensities=None, burst: tuple[int, int] = (0, 0),
                      ) -> Schedule:
    """Cycle through static patterns: tornado↔uniform by default.

    The alternation is adversarial for routings tuned to either pattern
    alone — buffered tornado flits congest the uniform phase and vice
    versa.  `intensities` optionally scales each pattern's phase.
    """
    intensities = intensities or [1.0] * len(patterns)
    phases = []
    for _ in range(repeats):
        for pat, inten in zip(patterns, intensities):
            phases.append(Phase(
                traffic=TR.PATTERNS[pat](topo), intensity=float(inten),
                duration=phase_cycles, burst_on=burst[0],
                burst_off=burst[1], label=pat))
    return Schedule(phases, name="alt:" + "-".join(patterns))


def hotspot_drift(topo: Topology, n_phases: int = 6, dwell: int = 200,
                  hot_frac: float = 0.6, n_hotspots: int = 1,
                  seed: int = 0) -> Schedule:
    """A drifting hotspot: every phase, `hot_frac` of all traffic aims at
    the current hotspot chiplet(s); the rest is uniform.  Hotspots drift
    pseudo-randomly across the placement, modelling a migrating shard or
    a hot parameter server."""
    n = topo.n
    rng = np.random.default_rng(seed)
    u = TR.uniform(topo)
    phases = []
    for k in range(n_phases):
        hots = rng.choice(n, size=min(n_hotspots, n), replace=False)
        m = (1.0 - hot_frac) * u
        m[:, hots] += hot_frac / len(hots)
        np.fill_diagonal(m, 0.0)
        phases.append(Phase(traffic=m, intensity=1.0, duration=dwell,
                            label=f"hot@{','.join(map(str, hots))}"))
    return Schedule(phases, name=f"hotspot_drift:{n_hotspots}")


def bursty_uniform(topo: Topology, on: int = 20, off: int = 60,
                   cycles: int = 1000) -> Schedule:
    """Uniform traffic under ON/OFF modulation: the mean offered load
    matches plain uniform, but arrivals come in (on+off)/on-times-denser
    waves — stresses buffer depth rather than bisection."""
    return Schedule([Phase(traffic=TR.uniform(topo), intensity=1.0,
                           duration=cycles, burst_on=on, burst_off=off,
                           label=f"burst{on}/{off}")],
                    name=f"bursty_uniform:{on}/{off}")
