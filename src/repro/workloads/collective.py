"""Collective-derived workloads: LLM training traffic on chiplets.

`collective_workload` compiles a sharded model training step into a
phase schedule (DESIGN.md §9):

  1. `models.sharding.step_collective_ops` derives the step's ordered
     collectives (FSDP all-gather, per-layer TP all-reduces, MoE
     all-to-all, gradient reduce-scatter) and their bytes from the
     architecture config and a logical mesh shape;
  2. `core.collectives.mesh_axis_groups` maps the mesh onto the chiplet
     placement (model groups physically contiguous) and
     `collective_flow` turns each collective into an [N, N] byte-flow
     matrix over those groups;
  3. ops sharing a phase are summed, phase durations are split
     proportionally to phase bytes (time ~ data over fixed wires), and
     intensities carry each phase's per-source demand *rate* so heavy
     concentrated phases drive the network harder than diffuse ones.

The result connects the repo's dormant LLM stack (configs/, models/) to
the cycle-accurate network simulator: the headline question "how does
FoldedHexaTorus hold up under qwen3-style training traffic on glass vs
organic?" becomes one batched `run_workloads` call
(benchmarks/workload_bench.py).
"""
from __future__ import annotations

import numpy as np

from repro.core.collectives import collective_flow, mesh_axis_groups
from repro.core.topology import Topology
from repro.models.sharding import step_collective_ops

from .schedule import Phase, Schedule, Workload


def default_mesh_shape(n: int, model_parallel: int = 0) -> dict:
    """{"data": D, "model": T} with T*D == N; prefers TP degree 8/4/2."""
    if model_parallel:
        if n % model_parallel:
            raise ValueError(f"model_parallel {model_parallel} does not "
                             f"divide N={n}")
        return {"data": n // model_parallel, "model": model_parallel}
    for tm in (8, 4, 2):
        if n % tm == 0 and n // tm >= 2:
            return {"data": n // tm, "model": tm}
    return {"data": n, "model": 1}


def collective_workload(config, topo: Topology, *, mesh_shape: dict = None,
                        seq_len: int = 2048, global_batch: int = 0,
                        step_cycles: int = 1000, min_phase: int = 50,
                        dtype_bytes: int = 2) -> Schedule:
    """Phase schedule of one sharded training step of `config` on `topo`.

    config: a `ModelConfig` (or any object with its size fields);
    mesh_shape defaults to TP-8/4/2 x FSDP over the remaining chiplets;
    global_batch defaults to 4 sequences per data shard; step_cycles is
    the replayed step's length, split across phases by bytes moved.
    """
    mesh_shape = mesh_shape or default_mesh_shape(topo.n)
    dm = int(mesh_shape.get("data", 1))
    global_batch = global_batch or 4 * dm
    ops = step_collective_ops(config, mesh_shape, seq_len=seq_len,
                              global_batch=global_batch,
                              dtype_bytes=dtype_bytes)
    # phase -> flow matrix + payload bytes, in op order
    flows: dict[str, np.ndarray] = {}
    payload: dict[str, float] = {}
    for op in ops:
        groups = mesh_axis_groups(topo, mesh_shape, op.axis)
        f = collective_flow(topo.n, op.kind, groups, op.bytes_per_chip)
        if f.sum() <= 0:        # degenerate axis (groups of 1): skip
            continue
        flows[op.phase] = flows.get(op.phase, 0) + f
        payload[op.phase] = payload.get(op.phase, 0.0) + op.bytes_per_chip
    if not flows:
        raise ValueError("sharded step issues no collectives on this mesh")

    total = sum(payload.values())
    durations = {p: max(min_phase, int(round(step_cycles * b / total)))
                 for p, b in payload.items()}
    # per-source demand rate: heaviest row of the phase's flow matrix,
    # spread over the phase's duration; normalized so the peak phase
    # drives intensity 1.0 (the rate sweep scales everything together)
    rates = {p: flows[p].sum(axis=1).max() / durations[p] for p in flows}
    peak = max(rates.values())
    phases = [Phase(traffic=flows[p], intensity=rates[p] / peak,
                    duration=durations[p], label=p) for p in flows]
    return Schedule(phases, name=f"collective:{config.name}")


def collective_workloads(configs, **kw) -> list[Workload]:
    """Wrap architecture configs for the sweep engine."""
    return [Workload(name=f"collective:{c.name}",
                     build=lambda topo, c=c: collective_workload(
                         c, topo, **kw))
            for c in configs]
