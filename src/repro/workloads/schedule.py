"""Workload phase schedules (DESIGN.md §9).

A *workload* is a schedule of phases.  Each phase carries a traffic
matrix (who talks to whom), a relative intensity (how hard), a duration
in cycles, and optional ON/OFF burst modulation (how spiky).  Schedules
replay cyclically through the simulator — `repro.core.simulator` owns
the compiled representation (`SchedSpec`) and the time-varying
injection; this module owns the user-facing objects and the generators
live in the sibling modules:

  * `repro.workloads.collective` — phases derived from the collectives
    of a sharded LLM training step, mapped onto chiplet positions;
  * `repro.workloads.traces` — loadable region traces (generalizing the
    old hard-coded `traffic.TRACE_PROFILES`);
  * `repro.workloads.synthetic` — adversarial phase-alternating and
    hotspot-drift schedules.

A single uniform phase at intensity 1 with no burst modulation
reproduces the static-traffic simulator counters bitwise (verified in
tests/test_workloads.py) — the workload path strictly generalizes the
static path.
"""
from __future__ import annotations

import dataclasses
from typing import Callable

import numpy as np

from repro.core.simulator import SchedSpec, make_sched_spec
from repro.core.topology import Topology


@dataclasses.dataclass
class Phase:
    """One workload phase: (traffic, intensity, duration, burstiness).

    traffic may be any non-negative [N, N] matrix (raw bytes, flow
    counts, probabilities) — rows are normalized into destination
    distributions and relative injection weights at compile time.
    intensity multiplies the offered rate for the whole phase; burst_on/
    burst_off > 0 add ON/OFF modulation within it (mean-preserving when
    the duration is a multiple of the burst period).
    """
    traffic: np.ndarray
    intensity: float = 1.0
    duration: int = 500
    burst_on: int = 0
    burst_off: int = 0
    label: str = ""


@dataclasses.dataclass
class Schedule:
    """An ordered list of phases, replayed cyclically by the simulator."""
    phases: list[Phase]
    name: str = "workload"

    @property
    def n(self) -> int:
        return int(np.asarray(self.phases[0].traffic).shape[0])

    @property
    def total_cycles(self) -> int:
        return sum(p.duration for p in self.phases)

    def compile(self) -> SchedSpec:
        """Compile to the simulator's dense [K, ...] representation."""
        return make_sched_spec(
            [(p.traffic, p.intensity, p.duration, p.burst_on, p.burst_off)
             for p in self.phases])

    def mean_traffic(self) -> np.ndarray:
        """Time-averaged offered-demand matrix (for analytic seeding).

        Each phase contributes its row-normalized matrix scaled by its
        injection weights and intensity, weighted by duration (burst
        modulation is mean-preserving, so it drops out).
        """
        n = self.n
        acc, wsum = np.zeros((n, n)), 0.0
        for p in self.phases:
            m = np.asarray(p.traffic, np.float64)
            rows = m.sum(axis=1, keepdims=True)
            dist = np.divide(m, rows, out=np.zeros_like(m), where=rows > 0)
            inj = rows.ravel() / max(rows.max(), 1e-12)
            w = float(p.intensity) * p.duration
            acc += w * inj[:, None] * dist
            wsum += p.duration
        return acc / max(wsum, 1e-12)

    def scaled(self, factor: float) -> "Schedule":
        """Copy with durations scaled by `factor` (floor 1 cycle)."""
        return Schedule(
            phases=[dataclasses.replace(
                p, duration=max(int(round(p.duration * factor)), 1))
                for p in self.phases],
            name=self.name)

    def fit(self, total_cycles: int) -> "Schedule":
        """Rescale so the schedule totals exactly `total_cycles`.

        Keeps phase-duration ratios (rounding absorbed by the longest
        phase).  The sweep engine fits schedules to the simulator's
        measurement window so one replay covers every phase exactly
        once — otherwise a schedule longer than the simulated cycle
        count would never reach its tail phases.
        """
        if total_cycles < len(self.phases):
            raise ValueError(f"cannot fit {len(self.phases)} phases into "
                             f"{total_cycles} cycles")
        out = self.scaled(total_cycles / self.total_cycles)
        # absorb the rounding residual longest-phase-first; a negative
        # residual may exceed one phase's slack (many 1-cycle phases), so
        # keep distributing until it is gone — the guard above ensures
        # the all-phases-at-1 floor can always be reached
        diff = total_cycles - out.total_cycles
        while diff:
            longest = max(range(len(out.phases)),
                          key=lambda i: out.phases[i].duration)
            p = out.phases[longest]
            take = diff if diff > 0 else max(diff, 1 - p.duration)
            out.phases[longest] = dataclasses.replace(
                p, duration=p.duration + take)
            diff -= take
        assert out.total_cycles == total_cycles
        return out


def static_schedule(traffic: np.ndarray, cycles: int,
                    name: str = "static") -> Schedule:
    """Single-phase schedule equivalent to static traffic (bitwise)."""
    return Schedule([Phase(traffic=traffic, intensity=1.0,
                           duration=cycles, label="static")], name=name)


@dataclasses.dataclass
class Workload:
    """A named, topology-independent schedule builder.

    The sweep engine crosses workloads with topology cases; `build` is
    called once per topology to materialize the [N, N] phase matrices at
    that topology's size and placement.
    """
    name: str
    build: Callable[[Topology], Schedule]

    def __call__(self, topo: Topology) -> Schedule:
        return self.build(topo)
