"""Workload engine: time-varying traffic through the batched sweep.

A workload is a `Schedule` of `Phase`s — (traffic matrix, intensity,
duration, burstiness) tuples — replayed cyclically by the cycle
simulator (DESIGN.md §9).  Three generator families:

  * `collective_workload` — the collectives of a sharded LLM training
    step mapped onto chiplet positions (configs/ + models/sharding);
  * `trace_workload` — loadable region traces (generalizes the old
    hard-coded `traffic.TRACE_PROFILES`), with ON/OFF bursts;
  * `synthetic` — adversarial phase-alternating / hotspot-drift /
    bursty-uniform schedules.

Run them with `SweepEngine.run_workloads` (topologies x workloads in
few batched compiled programs) or directly via
`simulator.run_batch(specs, rates, schedules=...)`.
"""
from .collective import (collective_workload, collective_workloads,
                         default_mesh_shape)
from .mixed import mixed_tenant, mixed_tenant_workload, superimpose
from .schedule import Phase, Schedule, Workload, static_schedule
from .synthetic import bursty_uniform, hotspot_drift, phase_alternating
from .traces import (Trace, TraceRegion, builtin_traces, load_trace,
                     trace_workload, trace_workloads)

__all__ = [
    "Phase", "Schedule", "Workload", "static_schedule",
    "collective_workload", "collective_workloads", "default_mesh_shape",
    "mixed_tenant", "mixed_tenant_workload", "superimpose",
    "trace_workload", "trace_workloads", "Trace", "TraceRegion",
    "builtin_traces", "load_trace",
    "phase_alternating", "hotspot_drift", "bursty_uniform",
]
