"""Mixed-tenant workloads: serving traffic under a training step.

The production scenario the paper never measures (ROADMAP "Resilience
and multi-tenant serving"): one package simultaneously runs a training
job — whose collectives arrive in phases (`collective_workload`) — and
a serving tenant whose request/KV-cache traffic is a steady background
pattern.  `superimpose` blends a background matrix into every phase of
a schedule; `mixed_tenant_workload` packages the common case (training
collectives + a named serving pattern) for the sweep engine and the
fault-degradation benchmark (DESIGN.md §12).

Blending happens in *offered-demand* space: each phase's raw flow
matrix is converted to its demand matrix (row-normalized destinations
scaled by the phase's relative injection weights — exactly the terms of
`Schedule.mean_traffic`), then mixed as

    demand' = (1 - serve_frac) * demand_phase + serve_frac * serving

so `serve_frac` is the serving tenant's share of every phase's offered
load, independent of how bytes were scaled in the raw collectives.
`serve_frac=0` returns phases whose demand equals the original
schedule's demand; `serve_frac=1` is pure serving traffic paced by the
training phases' durations.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core import traffic as TR
from repro.core.topology import Topology

from .collective import collective_workload
from .schedule import Phase, Schedule, Workload


def _phase_demand(p: Phase) -> np.ndarray:
    """One phase's offered-demand matrix (rows sum to the phase's
    relative per-source injection rate, peak row = intensity)."""
    m = np.asarray(p.traffic, np.float64)
    rows = m.sum(axis=1, keepdims=True)
    dist = np.divide(m, rows, out=np.zeros_like(m), where=rows > 0)
    inj = rows.ravel() / max(rows.max(), 1e-12)
    return float(p.intensity) * inj[:, None] * dist


def superimpose(schedule: Schedule, background: np.ndarray,
                frac: float, name: str | None = None) -> Schedule:
    """Blend a steady `background` demand matrix into every phase.

    background: [N, N] non-negative matrix (rows are destination
    distributions — any `traffic.PATTERNS` output qualifies); frac in
    [0, 1] is the background tenant's share of each phase's offered
    load.  Phase durations, labels and burst modulation are preserved;
    intensities are folded into the blended matrices (the demand
    construction already carries them)."""
    if not 0.0 <= frac <= 1.0:
        raise ValueError(f"frac must be in [0, 1], got {frac}")
    bg = np.asarray(background, np.float64)
    n = schedule.n
    if bg.shape != (n, n):
        raise ValueError(f"background shape {bg.shape} != ({n}, {n})")
    phases = []
    for p in schedule.phases:
        blended = (1.0 - frac) * _phase_demand(p) + frac * bg
        # the schedule compiler renormalizes injection weights by each
        # phase's peak row, so the blended matrix's absolute demand is
        # carried in the intensity (inj_w * intensity == row sums)
        phases.append(dataclasses.replace(
            p, traffic=blended,
            intensity=float(blended.sum(axis=1).max())))
    return Schedule(phases, name=name or f"{schedule.name}+bg{frac:g}")


def mixed_tenant_workload(config, topo: Topology, *,
                          serve_pattern: str = "uniform",
                          serve_frac: float = 0.3,
                          **collective_kw) -> Schedule:
    """Training collectives of `config` + a serving tenant on `topo`.

    The serving tenant offers `serve_frac` of every phase's load as the
    named static pattern (requests and KV-cache reads spread over the
    package); the remaining (1 - serve_frac) is the training step's
    phase-varying collective traffic."""
    train = collective_workload(config, topo, **collective_kw)
    bg = TR.PATTERNS[serve_pattern](topo)
    return superimpose(
        train, bg, serve_frac,
        name=f"mixed:{config.name}+{serve_pattern}{serve_frac:g}")


def mixed_tenant(config, serve_pattern: str = "uniform",
                 serve_frac: float = 0.3, **kw) -> Workload:
    """`Workload` wrapper for the sweep engine / experiment scenarios."""
    return Workload(
        name=f"mixed:{config.name}+{serve_pattern}{serve_frac:g}",
        build=lambda topo: mixed_tenant_workload(
            config, topo, serve_pattern=serve_pattern,
            serve_frac=serve_frac, **kw))
