"""Loadable, replayable region traces (DESIGN.md §9, paper §V-E).

The seed code hard-coded two synthetic Netrace-like profiles as
5-element `(intensity, mem_frac)` lists (`traffic.TRACE_PROFILES`).
This module generalizes them into a first-class trace format:

  * a `Trace` is a named list of `TraceRegion`s, each with an intensity
    multiplier, a C/M/I memory fraction, a duration in cycles, and
    optional ON/OFF burst parameters;
  * traces round-trip through JSON (`Trace.save` / `load_trace`) so
    externally-profiled workloads can be replayed without code changes;
  * `Trace.to_schedule(topo)` materializes the regions as workload
    phases at a concrete topology/placement — the simulator then walks
    the regions inside its `lax.scan` instead of evaluating each region
    as an independent stationary experiment (the fig10 approximation).

The built-in profiles reproduce the seed's blackscholes (compute-heavy,
low traffic) and fluidanimate (memory-heavy bursts) shapes; the
fluidanimate regions carry ON/OFF bursts to model its phase-coupled
memory waves.
"""
from __future__ import annotations

import dataclasses
import json

from repro.core import traffic as TR
from repro.core.topology import Topology

from .schedule import Phase, Schedule, Workload


@dataclasses.dataclass
class TraceRegion:
    """One trace region -> one workload phase."""
    intensity: float            # injection-rate multiplier
    mem_frac: float             # C->M share of the region's flows
    duration: int = 500         # cycles
    burst_on: int = 0           # ON/OFF arrival modulation (0 = off)
    burst_off: int = 0


@dataclasses.dataclass
class Trace:
    name: str
    regions: list[TraceRegion]

    def to_schedule(self, topo: Topology) -> Schedule:
        """Regions -> phases at this topology's size and C/M/I placement."""
        phases = [Phase(traffic=TR.region_traffic(topo, r.mem_frac),
                        intensity=r.intensity, duration=r.duration,
                        burst_on=r.burst_on, burst_off=r.burst_off,
                        label=f"region{i}")
                  for i, r in enumerate(self.regions)]
        return Schedule(phases, name=f"trace:{self.name}")

    def save(self, path: str):
        with open(path, "w") as f:
            json.dump(dict(name=self.name,
                           regions=[dataclasses.asdict(r)
                                    for r in self.regions]), f, indent=2)


def load_trace(path: str) -> Trace:
    with open(path) as f:
        rec = json.load(f)
    return Trace(name=rec["name"],
                 regions=[TraceRegion(**r) for r in rec["regions"]])


def from_profile(profile: str, region_cycles: int = 500,
                 burst: tuple[int, int] = (0, 0)) -> Trace:
    """Lift a legacy `traffic.TRACE_PROFILES` entry into a Trace."""
    regions = [TraceRegion(intensity=i, mem_frac=m, duration=region_cycles,
                           burst_on=burst[0], burst_off=burst[1])
               for i, m in TR.TRACE_PROFILES[profile]]
    return Trace(name=profile, regions=regions)


# built-in traces: the seed profiles, with fluidanimate's memory waves
# modelled as ON/OFF bursts (§V-E "memory-heavy bursts")
def builtin_traces(region_cycles: int = 500) -> dict[str, Trace]:
    t = {name: from_profile(name, region_cycles)
         for name in TR.TRACE_PROFILES}
    for r in t["fluidanimate"].regions:
        r.burst_on, r.burst_off = 25, 75
    return t


def trace_workload(topo: Topology, trace: str | Trace = "fluidanimate",
                   region_cycles: int = 500) -> Schedule:
    """Replayable schedule for a built-in profile name, a `Trace`, or a
    path to a saved trace JSON."""
    if isinstance(trace, str):
        if trace in TR.TRACE_PROFILES:
            trace = builtin_traces(region_cycles)[trace]
        else:
            trace = load_trace(trace)
    return trace.to_schedule(topo)


def trace_workloads(region_cycles: int = 500) -> list[Workload]:
    """Built-in traces wrapped for the sweep engine."""
    return [Workload(name=f"trace:{name}",
                     build=lambda topo, t=t: t.to_schedule(topo))
            for name, t in builtin_traces(region_cycles).items()]
