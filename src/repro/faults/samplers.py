"""Seeded fault-draw samplers (DESIGN.md §12).

Three link-fault distributions cover the failure modes that matter for
a degradation curve, plus a chiplet-fault draw:

  * `random_link_faults` — independent uniform link failures (the
    baseline reliability model: solder/bump opens scattered over the
    package);
  * `correlated_link_faults` — a spatial *blast*: one epicenter link
    plus its nearest neighbours by physical midpoint distance (a warped
    substrate region, a delaminated corner — glass's failure mode is
    spatially correlated, not i.i.d.);
  * `adversarial_link_faults` — greedy worst-link: repeatedly kill the
    most-loaded surviving link under the routed traffic (the lower
    envelope of the degradation curve; what an adversary — or Murphy —
    takes first);
  * `random_chiplet_faults` — whole-chiplet fail-stop draws.

All samplers are deterministic in (topology, k, seed) and, by default,
survivable: candidates whose removal would partition the surviving
chiplets are skipped (greedy over a seeded permutation), so the
returned `FaultSet.apply` always succeeds.  If fewer than k survivable
faults exist the sampler raises rather than silently degrading less
than asked.
"""
from __future__ import annotations

import numpy as np

from repro.core.topology import Topology

from .faultset import FaultError, FaultSet, surviving_connected

# stable per-kind seed-stream tags (process-independent, unlike hash())
_KIND_RAND, _KIND_BLAST, _KIND_CHIP = 0xFA01, 0xFA02, 0xFA03


def _sorted_edges(topo: Topology) -> np.ndarray:
    return np.sort(np.asarray(topo.edges, np.int64), axis=1)


def _greedy_links(topo: Topology, k: int, order: np.ndarray,
                  require_connected: bool, label: str) -> FaultSet:
    """First k links of `order` whose cumulative removal keeps the
    survivors connected (or simply the first k)."""
    e = _sorted_edges(topo)
    chosen: list = []
    for idx in order:
        if len(chosen) == k:
            break
        cand = chosen + [tuple(int(x) for x in e[idx])]
        if require_connected and not surviving_connected(
                topo, FaultSet(links=tuple(cand))):
            continue
        chosen = cand
    if len(chosen) < k:
        raise FaultError(
            f"{topo.name}: only {len(chosen)} of {k} requested link "
            f"faults are survivable (E={len(e)}); the topology cannot "
            f"lose that many links and stay connected")
    return FaultSet(links=tuple(chosen), name=label)


def random_link_faults(topo: Topology, k: int, seed: int = 0,
                       require_connected: bool = True) -> FaultSet:
    """k links drawn uniformly (seeded permutation; greedy-survivable)."""
    if k == 0:
        return FaultSet(name=f"rand:k0:s{seed}")
    rng = np.random.default_rng([_KIND_RAND, topo.n, k, seed])
    order = rng.permutation(len(topo.edges))
    return _greedy_links(topo, k, order, require_connected,
                         f"rand:k{k}:s{seed}")


def correlated_link_faults(topo: Topology, k: int, seed: int = 0,
                           require_connected: bool = True) -> FaultSet:
    """A spatially-correlated blast of k links.

    The seeded draw picks an epicenter link; candidates are then
    ordered by physical midpoint distance to it, so the fault set is a
    contiguous damaged region of the substrate."""
    if k == 0:
        return FaultSet(name=f"blast:k0:s{seed}")
    rng = np.random.default_rng([_KIND_BLAST, topo.n, k, seed])
    e = _sorted_edges(topo)
    pmm = topo.pos_mm()
    mid = 0.5 * (pmm[e[:, 0]] + pmm[e[:, 1]])
    epi = int(rng.integers(0, len(e)))
    d = np.sqrt(((mid - mid[epi]) ** 2).sum(-1))
    order = np.lexsort((np.arange(len(e)), d))      # stable: distance, id
    return _greedy_links(topo, k, order, require_connected,
                         f"blast:k{k}:s{seed}")


def adversarial_link_faults(topo: Topology, k: int,
                            traffic: np.ndarray | None = None,
                            require_connected: bool = True) -> FaultSet:
    """Greedy worst-link faults: at each step kill the surviving link
    carrying the highest routed channel load (ties broken by edge id),
    re-routing the degraded topology between steps.  Deterministic —
    no seed — and the pessimistic envelope of the degradation curve."""
    from repro.core.routing import routing_for
    from repro.core import traffic as TR

    if traffic is None:
        traffic = TR.uniform(topo)
    chosen: list = []
    for _ in range(k):
        fs = FaultSet(links=tuple(chosen))
        degraded = fs.apply(topo)
        r = routing_for(degraded)
        loads, _, _ = r.paths_channel_loads(np.asarray(traffic, np.float64))
        # fold directed-channel loads onto undirected links
        e = _sorted_edges(degraded)
        key = {(int(a), int(b)): i for i, (a, b) in enumerate(e)}
        link_load = np.zeros(len(e))
        for c in range(len(loads)):
            a, b = int(r.ch_src[c]), int(r.ch_dst[c])
            link_load[key[(min(a, b), max(a, b))]] += loads[c]
        order = np.lexsort((np.arange(len(e)), -link_load))
        placed = False
        for idx in order:
            cand = chosen + [tuple(int(x) for x in e[idx])]
            if require_connected and not surviving_connected(
                    topo, FaultSet(links=tuple(cand))):
                continue
            chosen, placed = cand, True
            break
        if not placed:
            raise FaultError(
                f"{topo.name}: only {len(chosen)} of {k} adversarial "
                f"link faults are survivable")
    return FaultSet(links=tuple(chosen), name=f"worst:k{k}")


def random_chiplet_faults(topo: Topology, k: int, seed: int = 0,
                          require_connected: bool = True) -> FaultSet:
    """k whole-chiplet fail-stop faults (seeded; greedy-survivable among
    the *remaining* chiplets)."""
    if k == 0:
        return FaultSet(name=f"chip:k0:s{seed}")
    rng = np.random.default_rng([_KIND_CHIP, topo.n, k, seed])
    order = rng.permutation(topo.n)
    chosen: list = []
    for node in order:
        if len(chosen) == k:
            break
        cand = chosen + [int(node)]
        if require_connected and not surviving_connected(
                topo, FaultSet(chiplets=tuple(cand))):
            continue
        chosen = cand
    if len(chosen) < k:
        raise FaultError(
            f"{topo.name}: only {len(chosen)} of {k} requested chiplet "
            f"faults are survivable")
    return FaultSet(chiplets=tuple(chosen), name=f"chip:k{k}:s{seed}")


#: named fault-draw registry, mirroring `traffic.PATTERNS`
SAMPLERS = {
    "random": random_link_faults,
    "correlated": correlated_link_faults,
    "adversarial": adversarial_link_faults,
    "chiplets": random_chiplet_faults,
}


def sample_faults(topo: Topology, k: int, kind: str = "random",
                  seed: int = 0, require_connected: bool = True,
                  **kw) -> FaultSet:
    """Front door: draw a k-fault `FaultSet` of the named kind."""
    if kind not in SAMPLERS:
        raise KeyError(f"unknown fault kind {kind!r}; choose from "
                       f"{sorted(SAMPLERS)}")
    fn = SAMPLERS[kind]
    if kind == "adversarial":
        return fn(topo, k, require_connected=require_connected, **kw)
    return fn(topo, k, seed=seed, require_connected=require_connected,
              **kw)
