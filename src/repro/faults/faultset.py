"""`FaultSet`: failed links / failed chiplets lowered onto `Topology`.

The paper evaluates pristine topologies only; at the chiplet counts its
design principles target (hundreds per package, HexaMesh arXiv
2211.13989) link and chiplet failures are a certainty.  The fault model
here is *fail-stop*: a dead link carries no flits in either direction, a
dead chiplet loses all of its links and neither injects nor receives
traffic.  A degraded topology is just the same `Topology` with a masked
edge list — routing is rebuilt automatically because
`routing.routing_for` keys on the structural hash, and the degraded
structure hashes differently (DESIGN.md §12).

Failure semantics:

  * `apply(topo)` returns the degraded `Topology` (the empty fault set
    returns `topo` itself, so the zero-fault path is bitwise identical
    to never having constructed a `FaultSet` at all);
  * survivors must stay connected: a fault set that splits the
    *surviving* chiplets into islands raises `DisconnectedFaultError`
    with the component sizes — serving traffic through a partitioned
    package is not graceful degradation, it is an outage, and silently
    simulating one island would misreport the curve;
  * dead chiplets may legitimately end up isolated (that is what dying
    means); they are excluded from the connectivity requirement and
    from traffic (`mask_traffic` / `mask_schedule` zero their rows and
    columns and renormalize the survivors' destination rows).
"""
from __future__ import annotations

import dataclasses

import numpy as np
import scipy.sparse as sp
import scipy.sparse.csgraph as csgraph

from repro.core.topology import Topology


class FaultError(ValueError):
    """A fault set that cannot be applied to the given topology."""


class DisconnectedFaultError(FaultError):
    """The fault set splits the surviving chiplets into islands."""


def _canon_links(links) -> tuple:
    out = set()
    for link in links:
        a, b = int(link[0]), int(link[1])
        if a == b:
            raise FaultError(f"fault link ({a}, {b}) is a self-loop")
        out.add((min(a, b), max(a, b)))
    return tuple(sorted(out))


@dataclasses.dataclass(frozen=True)
class FaultSet:
    """An immutable set of failed links and failed chiplets.

    `links` are undirected (u, v) pairs (canonicalized and deduped);
    `chiplets` are node ids.  The set is topology-independent until
    `apply(topo)` checks it against a concrete edge list.
    """
    links: tuple = ()
    chiplets: tuple = ()
    name: str = ""

    def __post_init__(self):
        object.__setattr__(self, "links", _canon_links(self.links))
        object.__setattr__(
            self, "chiplets",
            tuple(sorted({int(c) for c in self.chiplets})))
        if not self.name:
            object.__setattr__(self, "name", self.describe())

    # ---- introspection -------------------------------------------------
    @property
    def empty(self) -> bool:
        return not self.links and not self.chiplets

    @property
    def n_links(self) -> int:
        return len(self.links)

    @property
    def n_chiplets(self) -> int:
        return len(self.chiplets)

    def describe(self) -> str:
        if not self.links and not self.chiplets:
            return "none"
        parts = []
        if self.links:
            parts.append("L" + ",".join(f"{a}-{b}" for a, b in self.links))
        if self.chiplets:
            parts.append("C" + ",".join(str(c) for c in self.chiplets))
        return "+".join(parts)

    # ---- lowering onto a Topology --------------------------------------
    def dead_link_mask(self, topo: Topology) -> np.ndarray:
        """[E] bool — True where `topo.edges` dies under this fault set.

        Every failed link must name an existing edge; a typo'd pair is
        an error, not a no-op (the caller believes they degraded the
        topology)."""
        e = np.sort(np.asarray(topo.edges, np.int64), axis=1)
        have = {(int(a), int(b)) for a, b in e}
        missing = [lk for lk in self.links if lk not in have]
        if missing:
            raise FaultError(
                f"{topo.name}: fault links {missing} are not links of "
                f"this topology (N={topo.n}, {len(e)} edges)")
        bad = [c for c in self.chiplets if not 0 <= c < topo.n]
        if bad:
            raise FaultError(f"{topo.name}: fault chiplets {bad} out of "
                             f"range for N={topo.n}")
        mask = np.zeros(len(e), dtype=bool)
        if self.links:
            dead = set(self.links)
            mask |= np.fromiter(((int(a), int(b)) in dead for a, b in e),
                                dtype=bool, count=len(e))
        if self.chiplets:
            dc = np.asarray(self.chiplets)
            mask |= np.isin(e[:, 0], dc) | np.isin(e[:, 1], dc)
        return mask

    def alive(self, n: int) -> np.ndarray:
        """[N] bool — surviving chiplets."""
        up = np.ones(n, dtype=bool)
        if self.chiplets:
            up[np.asarray(self.chiplets)] = False
        return up

    def apply(self, topo: Topology) -> Topology:
        """The degraded `Topology`: dead links and dead chiplets' links
        removed, same nodes/positions/name.  Empty fault set returns
        `topo` unchanged (same object — the zero-fault path shares the
        pristine routing cache entry bitwise).  Raises
        `DisconnectedFaultError` if the survivors are partitioned."""
        if self.empty:
            return topo
        mask = self.dead_link_mask(topo)
        edges = np.asarray(topo.edges)[~mask]
        check_survivors_connected(topo.n, edges, self.alive(topo.n),
                                  name=f"{topo.name}[{self.name}]")
        return dataclasses.replace(topo, edges=edges)

    # ---- traffic masking -----------------------------------------------
    def mask_traffic(self, traffic: np.ndarray) -> np.ndarray:
        """Zero rows/columns of dead chiplets, renormalize survivor rows.

        No dead chiplets -> the input array is returned unchanged (the
        zero-fault path stays bitwise identical).  A survivor whose
        whole row pointed at dead chiplets simply stops injecting (row
        stays zero), matching the simulator's inert-source handling.
        """
        if not self.chiplets:
            return traffic
        tm = np.asarray(traffic, np.float64).copy()
        up = self.alive(tm.shape[0])
        tm[~up, :] = 0.0
        tm[:, ~up] = 0.0
        rows = tm.sum(axis=1, keepdims=True)
        np.divide(tm, rows, out=tm, where=rows > 0)
        return tm

    def mask_schedule(self, schedule):
        """A copy of a `workloads.Schedule` with every phase's traffic
        masked (no dead chiplets -> the schedule is returned as is)."""
        if not self.chiplets:
            return schedule
        phases = [dataclasses.replace(p, traffic=self.mask_traffic(
            np.asarray(p.traffic, np.float64))) for p in schedule.phases]
        return dataclasses.replace(schedule, phases=phases)


def check_survivors_connected(n: int, edges: np.ndarray,
                              alive: np.ndarray, name: str = "topology"):
    """Raise `DisconnectedFaultError` unless the surviving chiplets form
    one connected component of the degraded edge list."""
    n_alive = int(alive.sum())
    if n_alive == 0:
        raise DisconnectedFaultError(f"{name}: every chiplet is dead")
    e = np.asarray(edges, np.int64).reshape(-1, 2)
    adj = sp.csr_matrix(
        (np.ones(2 * len(e)),
         (np.concatenate([e[:, 0], e[:, 1]]),
          np.concatenate([e[:, 1], e[:, 0]]))), shape=(n, n))
    ncomp, labels = csgraph.connected_components(adj)
    comp = labels[alive]
    sizes = np.bincount(comp)
    sizes = sorted((int(s) for s in sizes if s > 0), reverse=True)
    if len(sizes) > 1:
        raise DisconnectedFaultError(
            f"{name}: fault set disconnects the surviving chiplets "
            f"into {len(sizes)} islands of sizes {sizes}; a partitioned "
            f"package cannot serve traffic — choose a survivable fault "
            f"set (see faults.sample_faults(..., require_connected=True))")


def surviving_connected(topo: Topology, fs: FaultSet) -> bool:
    """True iff `fs.apply(topo)` would succeed (no exception control
    flow — the samplers probe many candidate sets)."""
    try:
        mask = fs.dead_link_mask(topo)
    except FaultError:
        return False
    edges = np.asarray(topo.edges)[~mask]
    try:
        check_survivors_connected(topo.n, edges, fs.alive(topo.n))
    except DisconnectedFaultError:
        return False
    return True
