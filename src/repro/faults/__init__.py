"""Fault injection & graceful degradation (DESIGN.md §12).

    import repro.faults as F

    fs = F.sample_faults(topo, k=2, kind="random", seed=0)
    degraded = fs.apply(topo)              # masked edges, same nodes
    routing = routing_for(degraded)        # rebuilt via structural hash

    # or through the experiment pipeline (the usual way):
    X.Scenario("folded_hexa_torus", 36, faults=fs)

A `FaultSet` is failed links + failed chiplets; `apply` lowers it onto
a `Topology` as a degraded-edge mask, and the experiments planner
rebuilds deadlock-free routing for the degraded structure through the
shared structural-hash cache.  Fault sets that partition the surviving
chiplets raise `DisconnectedFaultError` — degraded topologies are just
more custom topologies, but a partitioned package is an outage, not a
scenario.
"""
from .enumerate import apply_variant, iter_fault_variants
from .faultset import (DisconnectedFaultError, FaultError, FaultSet,
                       check_survivors_connected, surviving_connected)
from .samplers import (SAMPLERS, adversarial_link_faults,
                       correlated_link_faults, random_chiplet_faults,
                       random_link_faults, sample_faults)

__all__ = [
    "FaultSet", "FaultError", "DisconnectedFaultError",
    "check_survivors_connected", "surviving_connected",
    "sample_faults", "SAMPLERS", "random_link_faults",
    "correlated_link_faults", "adversarial_link_faults",
    "random_chiplet_faults", "iter_fault_variants", "apply_variant",
]
