"""Fault-variant enumeration for the static verifier (DESIGN.md §14).

The analysis CLI certifies not just each pristine topology but its
fault-degraded variants: `iter_fault_variants` yields labelled
`(label, FaultSet)` pairs for every k up to `kmax`, per sampler kind
and seed — the grid the certification tests sweep (Table III x
substrate x fault masks k<=2).  Unsurvivable draws (a FaultError from
the sampler: the topology cannot lose k links/chiplets and stay
connected) are skipped, not raised — certification cares about the
variants that can actually be served.
"""
from __future__ import annotations

from typing import Iterator

from repro.core.topology import Topology

from .faultset import FaultError, FaultSet
from .samplers import sample_faults


def iter_fault_variants(topo: Topology, kmax: int,
                        kinds: tuple = ("random",),
                        seeds: tuple = (0,),
                        include_pristine: bool = True,
                        ) -> Iterator[tuple[str, FaultSet | None]]:
    """Yield (label, fault_set) for the degradation grid of one topology.

    label is "pristine" or "<kind>:k<k>:s<seed>"; fault_set is None for
    the pristine entry (no mask to apply).  Draws that the sampler
    rejects as unsurvivable are silently skipped — fewer variants, not
    an error.
    """
    if kmax < 0:
        raise ValueError(f"kmax must be >= 0, got {kmax}")
    if include_pristine:
        yield "pristine", None
    for kind in kinds:
        for k in range(1, kmax + 1):
            for seed in seeds:
                try:
                    fs = sample_faults(topo, k, kind=kind, seed=seed)
                except FaultError:
                    continue
                yield f"{kind}:k{k}:s{seed}", fs


def apply_variant(topo: Topology, fault_set: FaultSet | None) -> Topology:
    """The degraded topology for one variant (identity for pristine)."""
    return topo if fault_set is None else fault_set.apply(topo)
