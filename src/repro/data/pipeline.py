"""Deterministic synthetic LM data pipeline.

Produces a reproducible, loosely Zipf-distributed token stream with
enough sequential structure (a noisy mod-vocab random walk) that a model
can actually reduce loss on it — which the end-to-end example and the
loss-descent test rely on.  Sharding: each host materializes only its own
per-host slice (`host_batch_slice`), the standard per-host input pipeline
pattern for multi-pod SPMD.
"""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass
class SyntheticLMData:
    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 0
    n_hosts: int = 1
    host_index: int = 0

    def __post_init__(self):
        assert self.global_batch % self.n_hosts == 0
        self.host_batch = self.global_batch // self.n_hosts

    def _walk(self, rng, n):
        """Noisy multiplicative random walk over the vocab."""
        steps = rng.integers(1, 17, size=n)
        noise = rng.integers(0, self.vocab, size=n)
        use_noise = rng.uniform(size=n) < 0.15
        toks = np.empty(n, dtype=np.int64)
        t = int(rng.integers(0, self.vocab))
        for i in range(n):
            t = int(noise[i]) if use_noise[i] else \
                (t * 31 + int(steps[i])) % self.vocab
            toks[i] = t
        return toks

    def batch(self, step: int) -> dict:
        """Batch for a given step (deterministic in (seed, step, host))."""
        rng = np.random.default_rng(
            (self.seed * 1_000_003 + step) * 97 + self.host_index)
        n = self.host_batch * (self.seq_len + 1)
        toks = self._walk(rng, n).reshape(self.host_batch,
                                          self.seq_len + 1)
        return {"tokens": toks[:, :-1].astype(np.int32),
                "labels": toks[:, 1:].astype(np.int32)}
