from .pipeline import SyntheticLMData  # noqa
