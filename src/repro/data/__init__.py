"""Synthetic data pipelines for the framework-side training examples."""
from .pipeline import SyntheticLMData  # noqa
