"""Fault-tolerance primitives for the training loop.

At 1000+-node scale the failure model is: slow chips (stragglers), dead
hosts (checkpoint/restart), and transient IO/compile errors (retry).
JAX SPMD is bulk-synchronous, so straggler *mitigation* is detection +
replacement (the watchdog flags the condition for the cluster layer;
within-step it cannot be hidden), while *recovery* is checkpoint/restart
with elastic resharding (repro.checkpoint).
"""
from __future__ import annotations

import json
import os
import threading
import time
from collections import deque


def retry(fn, *, retries: int = 3, backoff_s: float = 0.5,
          on=(RuntimeError, OSError)):
    """Retry transient failures with exponential backoff."""
    def wrapped(*a, **kw):
        delay = backoff_s
        for attempt in range(retries + 1):
            try:
                return fn(*a, **kw)
            except on:
                if attempt == retries:
                    raise
                time.sleep(delay)
                delay *= 2
        raise AssertionError("unreachable")
    return wrapped


class StepWatchdog:
    """Track step times; flag stragglers (step > factor x rolling median).

    On a real cluster the flag feeds the controller that cordons the slow
    host and triggers an elastic restart; here it is surfaced in metrics
    and the log.
    """

    def __init__(self, window: int = 32, factor: float = 2.5):
        self.times = deque(maxlen=window)
        self.factor = factor
        self.flagged = 0

    def observe(self, seconds: float) -> bool:
        slow = False
        if len(self.times) >= 8:
            med = sorted(self.times)[len(self.times) // 2]
            slow = seconds > self.factor * med
            self.flagged += int(slow)
        self.times.append(seconds)
        return slow


class Heartbeat:
    """Periodic liveness file for an external supervisor to watch."""

    def __init__(self, path: str, interval_s: float = 10.0):
        self.path = path
        self.interval_s = interval_s
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._run, daemon=True)

    def _run(self):
        while not self._stop.wait(self.interval_s):
            self.beat()

    def beat(self, extra: dict | None = None):
        payload = {"time": time.time(), "pid": os.getpid()}
        if extra:
            payload.update(extra)
        os.makedirs(os.path.dirname(self.path) or ".", exist_ok=True)
        tmp = self.path + ".tmp"
        with open(tmp, "w") as f:
            json.dump(payload, f)
        os.replace(tmp, self.path)

    def start(self):
        self.beat()
        self._thread.start()
        return self

    def stop(self):
        self._stop.set()


def elastic_batch(global_batch: int, world: int, prev_world: int | None
                  = None) -> tuple[int, float]:
    """Per-host batch + LR rescale after an elastic world-size change.

    Keeps the global batch constant when divisible; otherwise rounds the
    per-host batch up and returns the LR scale that compensates for the
    effective-batch change (linear scaling rule).
    """
    per = -(-global_batch // world)          # ceil
    eff = per * world
    lr_scale = eff / global_batch
    return per, lr_scale
