"""Fault-tolerance runtime helpers: retries, watchdogs, elastic batching."""
from .fault import retry, StepWatchdog, Heartbeat, elastic_batch  # noqa
