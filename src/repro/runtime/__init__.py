from .fault import retry, StepWatchdog, Heartbeat, elastic_batch  # noqa
