"""Model substrate: unified LM/enc-dec/SSM family for the 10 assigned
architectures, with logical-axis sharding and scan-over-pattern stacks."""
from .model import Model, ModelConfig, DecodeDims  # noqa
from .layers import unbox, Boxed  # noqa
from .sharding import ParallelCtx, tree_pspecs, tree_shardings, batch_spec  # noqa
