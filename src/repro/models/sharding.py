"""Logical-axis -> mesh PartitionSpec rules (MaxText-style).

Parameters and activations carry *logical* axis names ("embed", "mlp",
"heads", "vocab", "experts", ...).  A rule set maps each logical axis to
zero or more mesh axes; `to_pspec` applies the rules to a whole tree of
axis tuples, skipping mesh axes that do not divide the dimension (so the
same rules work for every architecture).

Default layout (single pod 16x16, multi-pod 2x16x16):
    batch   -> ("pod", "data")     tensor axes -> "model"
    fsdp: the "embed" axis of *weights* is sharded over "data", giving 2D
    weight sharding (ZeRO-3-style) so grok-1/qwen3-moe optimizer state
    fits per-chip HBM.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


@dataclasses.dataclass(frozen=True)
class ParallelCtx:
    mesh: Mesh
    batch_axes: tuple = ("data",)     # ("pod", "data") multi-pod
    model_axis: str = "model"
    fsdp_axes: tuple = ("data",)      # weight "embed" dim sharding
    # rules: logical axis -> tuple of mesh axes (applied if divisible)
    extra_rules: Any = None

    def rules(self, *, for_weights: bool) -> dict:
        r = {
            "batch": tuple(self.batch_axes),
            "vocab": (self.model_axis,),
            "heads": (self.model_axis,),
            "kv": (self.model_axis,),
            "mlp": (self.model_axis,),
            "experts": (self.model_axis,),
            "qkv": (),
            "layers": (),
            "seq": (),
            "embed": tuple(self.fsdp_axes) if for_weights else (),
        }
        if self.extra_rules:
            r.update(self.extra_rules)
        return r

    def axis_size(self, names) -> int:
        s = 1
        for nm in names:
            s *= self.mesh.shape[nm]
        return s


def _spec_for(axes: tuple, shape: tuple, rules: dict,
              used_check: bool = True) -> P:
    """Build a PartitionSpec for one array, dropping non-dividing axes."""
    parts = []
    used = set()
    for dim, ax in enumerate(axes):
        if ax is None or ax not in rules:
            parts.append(None)
            continue
        mesh_axes = tuple(a for a in rules[ax] if a not in used)
        size = int(np.prod([_MESH_SIZES[a] for a in mesh_axes])) \
            if mesh_axes else 1
        if mesh_axes and shape[dim] % size == 0:
            parts.append(mesh_axes if len(mesh_axes) > 1 else mesh_axes[0])
            used.update(mesh_axes)
        else:
            # try a prefix of the mesh axes that divides
            ok = None
            for k in range(len(mesh_axes) - 1, 0, -1):
                sub = mesh_axes[:k]
                size = int(np.prod([_MESH_SIZES[a] for a in sub]))
                if shape[dim] % size == 0:
                    ok = sub
                    break
            if ok:
                parts.append(ok if len(ok) > 1 else ok[0])
                used.update(ok)
            else:
                parts.append(None)
    return P(*parts)


_MESH_SIZES: dict = {}


def tree_pspecs(axes_tree, shape_tree, ctx: ParallelCtx,
                for_weights: bool = True):
    """Map a tree of logical-axis tuples + shapes to PartitionSpecs."""
    global _MESH_SIZES
    _MESH_SIZES = dict(ctx.mesh.shape)
    rules = ctx.rules(for_weights=for_weights)

    def one(axes, shaped):
        return _spec_for(tuple(axes), tuple(shaped.shape), rules)

    def is_axes_leaf(x):
        return isinstance(x, tuple) and len(x) > 0 and all(
            isinstance(e, (str, type(None))) for e in x)

    return jax.tree.map(one, axes_tree, shape_tree, is_leaf=is_axes_leaf)


def tree_shardings(axes_tree, shape_tree, ctx: ParallelCtx,
                   for_weights: bool = True):
    specs = tree_pspecs(axes_tree, shape_tree, ctx, for_weights)
    return jax.tree.map(lambda s: NamedSharding(ctx.mesh, s), specs,
                        is_leaf=lambda x: isinstance(x, P))


# =====================================================================
# collective plan of a sharded training step (workload bridge, §9)
# =====================================================================

@dataclasses.dataclass(frozen=True)
class CollectiveOp:
    """One collective a sharded train step issues, as traffic demand.

    phase groups ops that overlap in time (the workload engine turns
    each phase into one flow matrix); axis names the mesh axis whose
    groups communicate; bytes_per_chip is the payload each participant
    contributes.
    """
    phase: str                  # fsdp_gather | fwd_tp | moe_a2a | ...
    kind: str                   # all_reduce | all_gather | ...
    axis: str                   # mesh axis ("data" | "model")
    bytes_per_chip: float


def step_collective_ops(config, mesh_shape: dict, seq_len: int = 2048,
                        global_batch: int = 32, dtype_bytes: int = 2,
                        ) -> list[CollectiveOp]:
    """The ordered collectives of one training step under this module's
    sharding rules (tensor axes -> "model", ZeRO-3 weight "embed" ->
    "data"), sized from the architecture config alone.

    This is the same decomposition `launch/dryrun.py` measures from
    compiled HLO, derived analytically so the workload engine can build
    phase schedules without a compiler round-trip: per step
      1. all-gather the data-sharded weights        (fsdp_gather, data)
      2. 2 activation all-reduces per layer forward (fwd_tp, model)
      3. MoE token all-to-all, if experts exist     (moe_a2a, model)
      4. 2 activation all-reduces per layer backward (bwd_tp, model)
      5. reduce-scatter the gradients               (grad_reduce, data)
    `config` is duck-typed (any object with ModelConfig's size fields).
    """
    tm = int(mesh_shape.get("model", 1))
    dm = int(mesh_shape.get("data", 1))
    b_local = max(global_batch // max(dm, 1), 1)
    d = config.d_model
    hd = config.head_dim or d // config.n_heads
    attn = d * config.n_heads * hd + 2 * d * config.n_kv_heads * hd \
        + config.n_heads * hd * d
    dense_mlp = 3 * d * config.d_ff
    n_moe = config.n_layers // max(config.moe_every, 1) \
        if config.n_experts else 0
    mlp = (config.n_layers - n_moe) * dense_mlp \
        + n_moe * config.n_experts * dense_mlp
    params_tp = (config.n_layers * attn + mlp + 2 * config.vocab * d) / tm
    act = float(b_local) * seq_len * d * dtype_bytes

    # bytes_per_chip is always the FULL buffer size per participant;
    # ring-schedule (k-1)/k factors are applied downstream by
    # `collectives.collective_flow`, matching IciModel.collective_time_s
    ops: list[CollectiveOp] = []
    params_bytes = params_tp * dtype_bytes
    if dm > 1:
        ops.append(CollectiveOp("fsdp_gather", "all_gather", "data",
                                params_bytes))
    if tm > 1:
        ops.append(CollectiveOp("fwd_tp", "all_reduce", "model",
                                2 * config.n_layers * act))
        if n_moe:
            ops.append(CollectiveOp("moe_a2a", "all_to_all", "model",
                                    n_moe * act * max(config.top_k, 1)))
        ops.append(CollectiveOp("bwd_tp", "all_reduce", "model",
                                2 * config.n_layers * act))
    if dm > 1:
        ops.append(CollectiveOp("grad_reduce", "reduce_scatter", "data",
                                params_bytes))
    if not ops:   # unsharded mesh: the step still syncs grads pairwise
        ops.append(CollectiveOp("grad_reduce", "all_reduce", "data",
                                params_bytes))
    return ops


def batch_spec(ctx: ParallelCtx, batch_size: int, ndim: int) -> P:
    """Spec for a [B, ...] array: shard batch if divisible, else replicate."""
    bsz_axes = tuple(ctx.batch_axes)
    size = ctx.axis_size(bsz_axes)
    if batch_size % size == 0:
        return P(bsz_axes if len(bsz_axes) > 1 else bsz_axes[0],
                 *([None] * (ndim - 1)))
    # try prefix
    for k in range(len(bsz_axes) - 1, 0, -1):
        if batch_size % ctx.axis_size(bsz_axes[:k]) == 0:
            sub = bsz_axes[:k]
            return P(sub if len(sub) > 1 else sub[0],
                     *([None] * (ndim - 1)))
    return P(*([None] * ndim))
