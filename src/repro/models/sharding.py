"""Logical-axis -> mesh PartitionSpec rules (MaxText-style).

Parameters and activations carry *logical* axis names ("embed", "mlp",
"heads", "vocab", "experts", ...).  A rule set maps each logical axis to
zero or more mesh axes; `to_pspec` applies the rules to a whole tree of
axis tuples, skipping mesh axes that do not divide the dimension (so the
same rules work for every architecture).

Default layout (single pod 16x16, multi-pod 2x16x16):
    batch   -> ("pod", "data")     tensor axes -> "model"
    fsdp: the "embed" axis of *weights* is sharded over "data", giving 2D
    weight sharding (ZeRO-3-style) so grok-1/qwen3-moe optimizer state
    fits per-chip HBM.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


@dataclasses.dataclass(frozen=True)
class ParallelCtx:
    mesh: Mesh
    batch_axes: tuple = ("data",)     # ("pod", "data") multi-pod
    model_axis: str = "model"
    fsdp_axes: tuple = ("data",)      # weight "embed" dim sharding
    # rules: logical axis -> tuple of mesh axes (applied if divisible)
    extra_rules: Any = None

    def rules(self, *, for_weights: bool) -> dict:
        r = {
            "batch": tuple(self.batch_axes),
            "vocab": (self.model_axis,),
            "heads": (self.model_axis,),
            "kv": (self.model_axis,),
            "mlp": (self.model_axis,),
            "experts": (self.model_axis,),
            "qkv": (),
            "layers": (),
            "seq": (),
            "embed": tuple(self.fsdp_axes) if for_weights else (),
        }
        if self.extra_rules:
            r.update(self.extra_rules)
        return r

    def axis_size(self, names) -> int:
        s = 1
        for nm in names:
            s *= self.mesh.shape[nm]
        return s


def _spec_for(axes: tuple, shape: tuple, rules: dict,
              used_check: bool = True) -> P:
    """Build a PartitionSpec for one array, dropping non-dividing axes."""
    parts = []
    used = set()
    for dim, ax in enumerate(axes):
        if ax is None or ax not in rules:
            parts.append(None)
            continue
        mesh_axes = tuple(a for a in rules[ax] if a not in used)
        size = int(np.prod([_MESH_SIZES[a] for a in mesh_axes])) \
            if mesh_axes else 1
        if mesh_axes and shape[dim] % size == 0:
            parts.append(mesh_axes if len(mesh_axes) > 1 else mesh_axes[0])
            used.update(mesh_axes)
        else:
            # try a prefix of the mesh axes that divides
            ok = None
            for k in range(len(mesh_axes) - 1, 0, -1):
                sub = mesh_axes[:k]
                size = int(np.prod([_MESH_SIZES[a] for a in sub]))
                if shape[dim] % size == 0:
                    ok = sub
                    break
            if ok:
                parts.append(ok if len(ok) > 1 else ok[0])
                used.update(ok)
            else:
                parts.append(None)
    return P(*parts)


_MESH_SIZES: dict = {}


def tree_pspecs(axes_tree, shape_tree, ctx: ParallelCtx,
                for_weights: bool = True):
    """Map a tree of logical-axis tuples + shapes to PartitionSpecs."""
    global _MESH_SIZES
    _MESH_SIZES = dict(ctx.mesh.shape)
    rules = ctx.rules(for_weights=for_weights)

    def one(axes, shaped):
        return _spec_for(tuple(axes), tuple(shaped.shape), rules)

    def is_axes_leaf(x):
        return isinstance(x, tuple) and len(x) > 0 and all(
            isinstance(e, (str, type(None))) for e in x)

    return jax.tree.map(one, axes_tree, shape_tree, is_leaf=is_axes_leaf)


def tree_shardings(axes_tree, shape_tree, ctx: ParallelCtx,
                   for_weights: bool = True):
    specs = tree_pspecs(axes_tree, shape_tree, ctx, for_weights)
    return jax.tree.map(lambda s: NamedSharding(ctx.mesh, s), specs,
                        is_leaf=lambda x: isinstance(x, P))


def batch_spec(ctx: ParallelCtx, batch_size: int, ndim: int) -> P:
    """Spec for a [B, ...] array: shard batch if divisible, else replicate."""
    bsz_axes = tuple(ctx.batch_axes)
    size = ctx.axis_size(bsz_axes)
    if batch_size % size == 0:
        return P(bsz_axes if len(bsz_axes) > 1 else bsz_axes[0],
                 *([None] * (ndim - 1)))
    # try prefix
    for k in range(len(bsz_axes) - 1, 0, -1):
        if batch_size % ctx.axis_size(bsz_axes[:k]) == 0:
            sub = bsz_axes[:k]
            return P(sub if len(sub) > 1 else sub[0],
                     *([None] * (ndim - 1)))
    return P(*([None] * ndim))
