"""Unified model family covering all 10 assigned architectures.

One configurable decoder/enc-dec stack expresses:
  GQA (+qk-norm, sliding-window local:global), MLA, MoE (ragged reference
  or shard_map expert parallelism), Mamba2/SSD and attn:SSM hybrids, and
  an encoder-decoder wrapper with a stubbed modality frontend.

The layer list is grouped into a repeating *pattern* (e.g. gemma3 = 5
local + 1 global, jamba = 7 mamba + 1 attn with MoE on odd slots) so the
whole stack is a `lax.scan` over pattern repetitions with stacked weights
— this keeps HLO size and compile time bounded for the 40-cell dry-run.
Remainder layers ("tail") are applied unrolled.

Steps exposed per architecture (see launch/dryrun.py):
  * train:   tokens -> xent loss (+ MoE aux), grads, AdamW update
  * prefill: tokens -> logits + KV/SSM caches
  * decode:  one token against a seq_len cache (serve_step)
"""
from __future__ import annotations

import dataclasses
import math
from functools import partial
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from . import layers as L
from . import ssm as S
from .layers import Boxed, unbox, isbox

# register Boxed as a pytree so vmap/scan can stack boxed params
jax.tree_util.register_pytree_node(
    Boxed, lambda b: ((b.v,), b.ax), lambda ax, ch: Boxed(ch[0], ax))


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 0                  # 0 -> d_model // n_heads
    # attention
    attn_kind: str = "gqa"             # gqa | mla | none
    qk_norm: bool = False
    rope_theta: float = 1e4
    window: int = 0                    # sliding window (local layers)
    global_every: int = 0              # k>0: every k-th layer is global
    # MLA
    q_lora_rank: int = 0
    kv_lora_rank: int = 0
    mla_nope_dim: int = 0
    mla_rope_dim: int = 0
    # MoE
    n_experts: int = 0
    top_k: int = 0
    moe_every: int = 1                 # 1: all layers; 2: odd layers
    capacity_factor: float = 1.25
    moe_virtual_split: int = 1         # split each expert's d_ff s ways
    # SSM
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_head_dim: int = 64
    ssm_conv: int = 4
    ssm_chunk: int = 256
    attn_every: int = 0                # k>0: attention at i%k==k//2
    # structure
    arch_kind: str = "decoder"         # decoder | encdec
    n_enc_layers: int = 0
    frontend: str = "none"             # none | audio_frames
    # numerics / perf
    param_dtype: Any = jnp.float32
    compute_dtype: Any = jnp.bfloat16
    remat: str = "full"                # full | dots | none
    use_flash_kernel: bool = False
    use_ssd_kernel: bool = False
    scan_unroll: int = 1               # dry-run cost extrapolation knob
    seq_parallel: bool = False         # set by Model when heads don't
                                       # tile the model axis (see __init__)

    @property
    def hd(self):
        return self.head_dim or self.d_model // self.n_heads

    # ---- layer pattern --------------------------------------------------
    def layer_specs(self):
        specs = []
        for i in range(self.n_layers):
            if self.attn_kind == "none":
                kind = "mamba"
            elif self.attn_every:
                kind = ("attn" if i % self.attn_every == self.attn_every // 2
                        else "mamba")
            else:
                kind = "mla" if self.attn_kind == "mla" else "attn"
            window = 0
            if kind == "attn" and self.global_every:
                if i % self.global_every != self.global_every - 1:
                    window = self.window
            moe = bool(self.n_experts) and (
                i % self.moe_every == self.moe_every - 1)
            has_mlp = self.d_ff > 0 and kind != "mamba" or \
                (kind == "mamba" and self.attn_every > 0 and self.d_ff > 0)
            specs.append(dict(kind=kind, window=window, moe=moe,
                              mlp=has_mlp and not moe))
        return specs

    def pattern(self):
        """(pattern slots, n_rep, tail slots)."""
        specs = self.layer_specs()
        p = 1
        for k in (self.global_every, self.attn_every,
                  self.moe_every if self.n_experts else 1):
            if k:
                p = p * k // math.gcd(p, k)
        p = min(p, self.n_layers)
        n_rep = self.n_layers // p
        tail = specs[n_rep * p:]
        # verify periodicity
        for i in range(n_rep * p):
            assert specs[i] == specs[i % p], (i, specs[i], specs[i % p])
        return specs[:p], n_rep, tail


@dataclasses.dataclass(frozen=True)
class DecodeDims:
    """Cache geometry for serve steps."""
    batch: int
    seq: int          # cache length (== shape's seq_len)


# =====================================================================
# single layer
# =====================================================================

def init_layer(key, spec, cfg: ModelConfig, cross: bool = False):
    ks = jax.random.split(key, 5)
    dt = cfg.param_dtype
    p = {"ln1": L.init_rmsnorm(cfg.d_model, dt)}
    if spec["kind"] == "attn":
        p["attn"] = L.init_attention(ks[0], cfg, dt)
    elif spec["kind"] == "mla":
        p["attn"] = L.init_mla(ks[0], cfg, dt)
    else:
        p["ssm"] = S.init_mamba2(ks[0], cfg, dt)
    if cross:
        p["ln_x"] = L.init_rmsnorm(cfg.d_model, dt)
        p["xattn"] = L.init_attention(ks[2], cfg, dt)
    if spec["moe"]:
        p["ln2"] = L.init_rmsnorm(cfg.d_model, dt)
        p["moe"] = L.init_moe(ks[1], cfg, dt)
        if cfg.moe_virtual_split > 1:
            s = cfg.moe_virtual_split
            for nm in ("wi", "wg", "wo"):
                b = p["moe"][nm]
                e = b.v.shape[0]
                if nm == "wo":      # [E, F, D] split F
                    v = b.v.reshape(e, s, b.v.shape[1] // s, b.v.shape[2])
                    v = v.reshape(e * s, b.v.shape[1] // s, b.v.shape[2])
                else:               # [E, D, F] split F
                    v = b.v.reshape(e, b.v.shape[1], s, b.v.shape[2] // s)
                    v = jnp.moveaxis(v, 2, 1).reshape(
                        e * s, b.v.shape[1], b.v.shape[2] // s)
                p["moe"][nm] = Boxed(v, b.ax)
    elif spec["mlp"]:
        p["ln2"] = L.init_rmsnorm(cfg.d_model, dt)
        p["mlp"] = L.init_mlp(ks[1], cfg.d_model, cfg.d_ff, dt)
    return p


def make_moe_apply(cfg: ModelConfig, ctx):
    """Return fn(params, x) -> (y, aux) choosing ragged vs shard_map EP."""
    if ctx is None:
        def ragged(params, x):
            if cfg.moe_virtual_split > 1:
                s = cfg.moe_virtual_split
                e = cfg.n_experts
                pm = dict(params)
                wi, wg, wo = params["wi"], params["wg"], params["wo"]
                f = wi.shape[2] * s
                pm["wi"] = jnp.moveaxis(
                    wi.reshape(e, s, wi.shape[1], wi.shape[2]), 1, 2
                ).reshape(e, wi.shape[1], f)
                pm["wg"] = jnp.moveaxis(
                    wg.reshape(e, s, wg.shape[1], wg.shape[2]), 1, 2
                ).reshape(e, wg.shape[1], f)
                pm["wo"] = wo.reshape(e, f, wo.shape[2])
                return L.moe_ragged(pm, x, cfg)
            return L.moe_ragged(params, x, cfg)
        return ragged

    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P
    mesh = ctx.mesh
    maxis = ctx.model_axis
    m = mesh.shape[maxis]
    e_virt = cfg.n_experts * cfg.moe_virtual_split
    assert e_virt % m == 0, (cfg.name, e_virt, m)

    def apply(params, x):
        b, t, _ = x.shape
        if b * t <= 2048:
            # serving / few tokens: weight-stationary expert parallelism
            return L.moe_ep_stationary(params, x, cfg, ctx)
        from .sharding import batch_spec
        bspec = batch_spec(ctx, b, 3)
        pspec = {
            "router": P(),
            "wi": P(maxis), "wg": P(maxis), "wo": P(maxis),
        }
        fn = shard_map(
            partial(L.moe_ep_local, cfg=cfg, axis_name=maxis,
                    e_par=m, f_par=1),
            mesh=mesh,
            in_specs=(pspec, bspec),
            out_specs=(bspec, P()),
            check_rep=False)
        return fn(params, x)
    return apply


def apply_layer(spec, p, x, cfg: ModelConfig, *, positions, cache,
                cache_pos, enc_out, moe_apply, cross: bool = False,
                build: bool = False, attn_ctx=None):
    new_cache = []
    h = L.rms_norm(p["ln1"], x)
    if spec["kind"] in ("attn", "mla"):
        c_self = cache[0] if cache is not None else None
        if spec["kind"] == "attn":
            out, nc = L.attention(
                p["attn"], h, cfg, positions=positions, cache=c_self,
                cache_pos=cache_pos,
                window=spec["window"] or None,
                use_flash=cfg.use_flash_kernel, build_cache=build,
                ctx=attn_ctx)
        else:
            out, nc = L.mla_attention(p["attn"], h, cfg,
                                      positions=positions, cache=c_self,
                                      cache_pos=cache_pos,
                                      build_cache=build)
        new_cache.append(nc)
    else:
        st = cache[0] if cache is not None else None
        cc = cache[1] if (cache is not None and len(cache) > 1) else None
        out, (ns, ncc) = S.mamba2_block(
            p["ssm"], h, cfg, state=st, conv_cache=cc,
            use_kernel=cfg.use_ssd_kernel, build_cache=build)
        new_cache.append(ns)
        if ncc is not None:
            new_cache.append(ncc)
    x = x + out

    if cross:
        hx = L.rms_norm(p["ln_x"], x)
        # enc_out: either raw encoder states (prefill/train) or
        # precomputed (k, v) cross cache (decode)
        if isinstance(enc_out, tuple):
            xk, xv = enc_out
        else:
            xk = jnp.einsum("btd,dhk->bthk", enc_out, p["xattn"]["wk"])
            xv = jnp.einsum("btd,dhk->bthk", enc_out, p["xattn"]["wv"])
        out, _ = L.attention(p["xattn"], hx, cfg, positions=positions,
                             cross_kv=(xk, xv), causal=False)
        x = x + out

    aux = jnp.zeros((), jnp.float32)
    if spec["moe"]:
        h2 = L.rms_norm(p["ln2"], x)
        out2, aux = moe_apply(p["moe"], h2)
        x = x + out2
    elif spec["mlp"]:
        h2 = L.rms_norm(p["ln2"], x)
        x = x + L.mlp(p["mlp"], h2)
    return x, (tuple(new_cache) if new_cache else None), aux


# =====================================================================
# full model
# =====================================================================

class Model:
    def __init__(self, cfg: ModelConfig, ctx=None):
        # Sequence parallelism: when the q-head count does not tile the
        # model axis (gemma3: 4, starcoder2: 24, minicpm3: 40 vs 16),
        # plain head sharding fails and GSPMD replicates the [B,H,T,T]
        # score tensor per chip.  Sharding the *sequence* across the
        # model axis instead keeps attention distributed (scores carry
        # the q-dim sharding; k/v are all-gathered — tiny by comparison).
        if ctx is not None and cfg.attn_kind in ("gqa", "mla") and \
                cfg.attn_every == 0 and \
                cfg.n_heads % ctx.mesh.shape[ctx.model_axis] != 0:
            cfg = dataclasses.replace(cfg, seq_parallel=True)
        self.cfg = cfg
        self.ctx = ctx            # ParallelCtx or None

    # ---- init -----------------------------------------------------------
    def init(self, key) -> dict:
        cfg = self.cfg
        pat, n_rep, tail = cfg.pattern()
        keys = jax.random.split(key, 8)
        params: dict = {}
        params["embed"] = Boxed(
            L._norm(keys[0], (cfg.vocab, cfg.d_model),
                    dtype=cfg.param_dtype), ("vocab", "embed"))
        params["final_norm"] = L.init_rmsnorm(cfg.d_model, cfg.param_dtype)

        def stack_slot(k, spec, cross=False):
            ks = jax.random.split(k, n_rep)
            return jax.vmap(lambda kk: init_layer(kk, spec, cfg,
                                                  cross=cross))(ks)

        blk_keys = jax.random.split(keys[1], len(pat))
        cross = cfg.arch_kind == "encdec"
        params["blocks"] = [
            _prepend_axis(stack_slot(blk_keys[s], pat[s], cross=cross))
            for s in range(len(pat))]
        tail_keys = jax.random.split(keys[2], max(len(tail), 1))
        params["tail"] = [init_layer(tail_keys[i], tail[i], cfg, cross=cross)
                          for i in range(len(tail))]

        if cfg.arch_kind == "encdec":
            ks_e = jax.random.split(keys[3], cfg.n_enc_layers)
            enc_spec = dict(kind="attn", window=0, moe=False, mlp=True)
            params["enc_blocks"] = _prepend_axis(jax.vmap(
                lambda kk: init_layer(kk, enc_spec, cfg))(ks_e))
            params["enc_norm"] = L.init_rmsnorm(cfg.d_model,
                                                cfg.param_dtype)
        return params

    # ---- shared stacks ----------------------------------------------------
    def _run_blocks(self, params, x, *, positions, caches, cache_pos,
                    enc_out, collect_cache, build=False):
        cfg = self.cfg
        pat, n_rep, tail = cfg.pattern()
        moe_apply = make_moe_apply(cfg, self.ctx)
        cross = cfg.arch_kind == "encdec"

        def block_fn(carry, xs):
            x, aux = carry
            slot_params, slot_caches, slot_enc = xs
            new_caches = []
            for s, spec in enumerate(pat):
                c = slot_caches[s] if slot_caches is not None else None
                e = slot_enc[s] if slot_enc is not None else enc_out
                x, nc, a = apply_layer(
                    spec, slot_params[s], x, cfg, positions=positions,
                    cache=c, cache_pos=cache_pos, enc_out=e,
                    moe_apply=moe_apply, cross=cross, build=build,
                    attn_ctx=self.ctx)
                new_caches.append(nc)
                aux = aux + a
            out_c = tuple(new_caches) if collect_cache else None
            return (x, aux), out_c

        if cfg.remat == "full":
            block = jax.checkpoint(block_fn,
                                   policy=jax.checkpoint_policies.
                                   nothing_saveable)
        elif cfg.remat == "dots":
            block = jax.checkpoint(
                block_fn, policy=jax.checkpoint_policies.
                checkpoint_dots_with_no_batch_dims)
        else:
            block = block_fn

        blk_caches = caches["blocks"] if caches is not None else None
        blk_enc = caches.get("cross_blocks") if (
            caches is not None and cross) else None
        (x, aux), blk_new = jax.lax.scan(
            block, (x, jnp.zeros((), jnp.float32)),
            (params["blocks"], blk_caches, blk_enc),
            unroll=cfg.scan_unroll)

        tail_new = []
        for i, spec in enumerate(tail):
            c = caches["tail"][i] if caches is not None else None
            e = (caches["cross_tail"][i]
                 if caches is not None and cross else enc_out)
            x, nc, a = apply_layer(
                spec, params["tail"][i], x, cfg, positions=positions,
                cache=c, cache_pos=cache_pos, enc_out=e,
                moe_apply=moe_apply, cross=cross, build=build,
                attn_ctx=self.ctx)
            tail_new.append(nc)
            aux = aux + a
        new_caches = (dict(blocks=blk_new, tail=tuple(tail_new))
                      if collect_cache else None)
        return x, aux, new_caches

    def _encode(self, params, frames):
        cfg = self.cfg
        x = self._bshard(frames.astype(cfg.compute_dtype))
        b, t, _ = x.shape
        positions = jnp.broadcast_to(jnp.arange(t)[None], (b, t))
        enc_spec = dict(kind="attn", window=0, moe=False, mlp=True)

        def enc_fn(x, slot_params):
            h = L.rms_norm(slot_params["ln1"], x)
            out, _ = L.attention(slot_params["attn"], h, cfg,
                                 positions=positions, causal=False)
            x = x + out
            h2 = L.rms_norm(slot_params["ln2"], x)
            return x + L.mlp(slot_params["mlp"], h2), None

        x, _ = jax.lax.scan(lambda c, p: enc_fn(c, p), x,
                            params["enc_blocks"], unroll=cfg.scan_unroll)
        return L.rms_norm(params["enc_norm"], x)

    # ---- entry points -----------------------------------------------------
    def _bshard(self, x):
        """Pin the batch (and, in sequence-parallel mode, the seq)
        sharding of an activation (GSPMD propagation can otherwise
        replicate the batch when the embedding's FSDP axis collides with
        the batch axis on the same mesh dim)."""
        if self.ctx is None:
            return x
        from jax.sharding import NamedSharding, PartitionSpec as P
        from .sharding import batch_spec
        spec = batch_spec(self.ctx, x.shape[0], x.ndim)
        if (self.cfg.seq_parallel and x.ndim == 3 and
                x.shape[1] % self.ctx.mesh.shape[self.ctx.model_axis]
                == 0):
            spec = P(spec[0], self.ctx.model_axis, None)
        return jax.lax.with_sharding_constraint(
            x, NamedSharding(self.ctx.mesh, spec))

    def _logits_shard(self, logits):
        """Batch + vocab(model) sharding for logits tensors."""
        if self.ctx is None:
            return logits
        from jax.sharding import NamedSharding, PartitionSpec as P
        from .sharding import batch_spec
        bs = batch_spec(self.ctx, logits.shape[0], logits.ndim)
        parts = list(bs) if len(bs) == logits.ndim else \
            list(bs) + [None] * (logits.ndim - len(bs))
        v = logits.shape[-1]
        m = self.ctx.mesh.shape[self.ctx.model_axis]
        if v % m == 0:
            parts[-1] = self.ctx.model_axis
        return jax.lax.with_sharding_constraint(
            logits, NamedSharding(self.ctx.mesh, P(*parts)))

    def _cast(self, params):
        """Mixed precision: bf16 compute copies of the fp32 masters."""
        cd = self.cfg.compute_dtype
        return jax.tree.map(
            lambda a: a.astype(cd)
            if hasattr(a, "dtype") and a.dtype == jnp.float32 else a,
            params)

    def logits_fn(self, params, batch):
        """Full forward -> logits [B, T, V] (training / prefill math)."""
        cfg = self.cfg
        params = self._cast(params)
        tokens = batch["tokens"]
        b, t = tokens.shape
        x = self._bshard(params["embed"][tokens].astype(cfg.compute_dtype))
        positions = jnp.broadcast_to(jnp.arange(t)[None], (b, t))
        enc_out = None
        if cfg.arch_kind == "encdec":
            enc_out = self._encode(params, batch["frames"])
        x, aux, _ = self._run_blocks(params, x, positions=positions,
                                     caches=None, cache_pos=None,
                                     enc_out=enc_out, collect_cache=False)
        x = L.rms_norm(params["final_norm"], x)
        logits = jnp.einsum("btd,vd->btv", x,
                            params["embed"].astype(cfg.compute_dtype))
        return self._logits_shard(logits), aux

    def loss_fn(self, params, batch):
        logits, aux = self.logits_fn(params, batch)
        labels = batch["labels"]
        logp = jax.nn.log_softmax(logits.astype(jnp.float32), -1)
        ll = jnp.take_along_axis(logp, labels[..., None], -1)[..., 0]
        mask = (labels >= 0).astype(jnp.float32)
        loss = -(ll * mask).sum() / jnp.maximum(mask.sum(), 1.0)
        return loss + 0.01 * aux

    # ---- serving ----------------------------------------------------------
    def prefill(self, params, batch):
        """Full forward that also builds the decode caches."""
        cfg = self.cfg
        params = self._cast(params)
        tokens = batch["tokens"]
        b, t = tokens.shape
        x = self._bshard(params["embed"][tokens].astype(cfg.compute_dtype))
        positions = jnp.broadcast_to(jnp.arange(t)[None], (b, t))
        enc_out = None
        if cfg.arch_kind == "encdec":
            enc_out = self._encode(params, batch["frames"])
        x, aux, caches = self._run_blocks(
            params, x, positions=positions, caches=None, cache_pos=None,
            enc_out=enc_out, collect_cache=True, build=True)
        if cfg.arch_kind == "encdec":
            caches = dict(caches)
            caches["cross_blocks"], caches["cross_tail"] = \
                self._build_cross_caches(params, enc_out)
        x = L.rms_norm(params["final_norm"], x)
        logits = jnp.einsum("bd,vd->bv", x[:, -1],
                            params["embed"].astype(cfg.compute_dtype))
        return self._logits_shard(logits), caches

    def _build_cross_caches(self, params, enc_out):
        cfg = self.cfg
        pat, n_rep, tail = cfg.pattern()

        def kv(p_attn):
            k = jnp.einsum("btd,dhk->bthk", enc_out, p_attn["wk"])
            v = jnp.einsum("btd,dhk->bthk", enc_out, p_attn["wv"])
            return (k, v)

        cross_blocks = tuple(
            jax.vmap(lambda pa: kv(pa), in_axes=(0,))  # stack over n_rep
            (params["blocks"][s]["xattn"]) for s in range(len(pat)))
        cross_tail = tuple(kv(params["tail"][i]["xattn"])
                           for i in range(len(tail)))
        return cross_blocks, cross_tail

    def init_cache(self, dims: DecodeDims):
        """Allocate decode caches for every layer (pattern-aware sizes)."""
        cfg = self.cfg
        pat, n_rep, tail = cfg.pattern()
        b, s = dims.batch, dims.seq
        dt = cfg.compute_dtype

        def one(spec):
            if spec["kind"] == "attn":
                sz = min(s, spec["window"]) if spec["window"] else s
                return ((jnp.zeros((b, sz, cfg.n_kv_heads, cfg.hd), dt),
                         jnp.zeros((b, sz, cfg.n_kv_heads, cfg.hd), dt)),)
            if spec["kind"] == "mla":
                return ((jnp.zeros((b, s, cfg.kv_lora_rank), dt),
                         jnp.zeros((b, s, cfg.mla_rope_dim), dt)),)
            d_in = cfg.ssm_expand * cfg.d_model
            h = d_in // cfg.ssm_head_dim
            return (jnp.zeros((b, h, cfg.ssm_state, cfg.ssm_head_dim),
                              jnp.float32),
                    jnp.zeros((b, cfg.ssm_conv - 1,
                               d_in + 2 * cfg.ssm_state), dt))

        def rep(spec):
            c = one(spec)
            return jax.tree.map(
                lambda a: jnp.broadcast_to(a[None], (n_rep,) + a.shape), c)

        caches = {"blocks": tuple(rep(sp) for sp in pat),
                  "tail": tuple(one(sp) for sp in tail)}
        if cfg.arch_kind == "encdec":
            xkv = lambda: (  # noqa: E731
                jnp.zeros((b, s, cfg.n_kv_heads, cfg.hd), dt),
                jnp.zeros((b, s, cfg.n_kv_heads, cfg.hd), dt))
            caches["cross_blocks"] = tuple(
                jax.tree.map(lambda a: jnp.broadcast_to(
                    a[None], (n_rep,) + a.shape), xkv()) for _ in pat)
            caches["cross_tail"] = tuple(xkv() for _ in tail)
        return caches

    def cache_logical_axes(self, dims: DecodeDims):
        """Logical-axis tree mirroring init_cache()'s structure."""
        cfg = self.cfg
        pat, n_rep, tail = cfg.pattern()

        def one(spec, lead=()):
            if spec["kind"] == "attn":
                kv = lead + ("batch", "seq", "kv", "qkv")
                return ((kv, kv),)
            if spec["kind"] == "mla":
                return ((lead + ("batch", "seq", None),
                         lead + ("batch", "seq", None)),)
            return (lead + ("batch", "heads", None, None),
                    lead + ("batch", None, "mlp"))

        axes = {"blocks": tuple(one(sp, ("layers",)) for sp in pat),
                "tail": tuple(one(sp) for sp in tail)}
        if cfg.arch_kind == "encdec":
            kv = ("batch", "seq", "kv", "qkv")
            axes["cross_blocks"] = tuple(
                ((("layers",) + kv), (("layers",) + kv)) for _ in pat)
            axes["cross_tail"] = tuple((kv, kv) for _ in tail)
        return axes

    def decode_step(self, params, caches, tokens, pos):
        """One serving step: tokens [B,1] + caches at length S -> logits.

        `pos` is the absolute position of the new token; each layer's
        cache ring is updated at `pos % its_length`.
        """
        cfg = self.cfg
        params = self._cast(params)
        b = tokens.shape[0]
        x = self._bshard(params["embed"][tokens].astype(cfg.compute_dtype))
        positions = jnp.full((b, 1), pos, jnp.int32)
        x, aux, new_caches = self._run_blocks(
            params, x, positions=positions, caches=caches,
            cache_pos=pos, enc_out=None, collect_cache=True)
        if cfg.arch_kind == "encdec":   # cross caches are read-only
            new_caches = dict(new_caches)
            new_caches["cross_blocks"] = caches["cross_blocks"]
            new_caches["cross_tail"] = caches["cross_tail"]
        x = L.rms_norm(params["final_norm"], x)
        logits = jnp.einsum("btd,vd->btv", x,
                            params["embed"].astype(cfg.compute_dtype))
        return self._logits_shard(logits), new_caches


def _prepend_axis(stacked):
    """After vmap-stacking boxed params, prepend the 'layers' axis name."""
    return jax.tree.map(
        lambda b: Boxed(b.v, ("layers",) + tuple(b.ax)), stacked,
        is_leaf=isbox)
