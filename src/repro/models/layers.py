"""Model substrate layers: norm, rope, attention (GQA / MLA / sliding
window), SwiGLU MLP, and MoE (ragged-dot reference + shard_map expert
parallelism).

Every `init_*` returns a tree of `Boxed(value, axes)` leaves; `unbox`
splits it into the parameter tree and a parallel tree of *logical axis*
tuples, which `repro.models.sharding` maps to mesh `PartitionSpec`s.
Logical axis vocabulary:
    "embed"  d_model        "mlp"     d_ff           "vocab"  vocabulary
    "heads"  q heads        "kv"      kv heads       "qkv"    per-head dim
    "experts" MoE experts   "layers"  stacked layers  None     replicated
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass
class Boxed:
    v: Any
    ax: tuple


def isbox(x):
    return isinstance(x, Boxed)


def unbox(tree):
    params = jax.tree.map(lambda b: b.v, tree, is_leaf=isbox)
    axes = jax.tree.map(lambda b: b.ax, tree, is_leaf=isbox)
    return params, axes


def _norm(key, shape, scale=0.02, dtype=jnp.float32):
    return (jax.random.normal(key, shape, jnp.float32) * scale).astype(dtype)


# ---------------------------------------------------------------------
# RMSNorm
# ---------------------------------------------------------------------

def init_rmsnorm(d, dtype=jnp.float32):
    return {"w": Boxed(jnp.ones((d,), dtype), ("embed",))}


def rms_norm(params, x, eps=1e-6):
    dt = x.dtype
    x = x.astype(jnp.float32)
    x = x * jax.lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + eps)
    return (x * params["w"].astype(jnp.float32)).astype(dt)


# ---------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------

def rope(x, positions, theta=1e4):
    """x: [..., T, H, hd]; positions: [..., T] int32."""
    hd = x.shape[-1]
    half = hd // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    # ang: [..., T, 1, half]
    ang = positions[..., :, None, None].astype(jnp.float32) * \
        freqs[None, None, :]
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], -1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------
# Attention (GQA, optional qk-norm / sliding window; decode cache)
# ---------------------------------------------------------------------

def init_attention(key, cfg, dtype=jnp.float32):
    d, h, kv, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    ks = jax.random.split(key, 4)
    p = {
        "wq": Boxed(_norm(ks[0], (d, h, hd), dtype=dtype),
                    ("embed", "heads", "qkv")),
        "wk": Boxed(_norm(ks[1], (d, kv, hd), dtype=dtype),
                    ("embed", "kv", "qkv")),
        "wv": Boxed(_norm(ks[2], (d, kv, hd), dtype=dtype),
                    ("embed", "kv", "qkv")),
        "wo": Boxed(_norm(ks[3], (h, hd, d), dtype=dtype),
                    ("heads", "qkv", "embed")),
    }
    if cfg.qk_norm:
        p["qnorm"] = Boxed(jnp.ones((hd,), dtype), (None,))
        p["knorm"] = Boxed(jnp.ones((hd,), dtype), (None,))
    return p


def _head_rms(x, w, eps=1e-6):
    xf = x.astype(jnp.float32)
    xf = xf * jax.lax.rsqrt(jnp.mean(xf * xf, -1, keepdims=True) + eps)
    return (xf * w.astype(jnp.float32)).astype(x.dtype)


def _sdpa(q, k, v, mask, use_flash=False, window=None, causal=True,
          grouped=False):
    """q: [B,Tq,H,hd] k,v: [B,Tk,KV,hd].

    Default (head-sharded mode): KV heads are repeated to the full head
    count *at use* so the scores tensor keeps the q-heads sharding (a
    [b,kv,g,q,s] layout forces GSPMD to gather heads whenever KV doesn't
    tile the model axis).  The KV cache itself stays kv-sized.

    grouped=True (sequence-parallel mode — heads replicated, q-seq
    sharded): the grouped einsum is used instead, avoiding the g-fold KV
    inflation since head sharding is not needed.

    When `use_flash` is set and shapes allow, dispatches to the Pallas
    flash-attention kernel.
    """
    b, tq, h, hd = q.shape
    kvh = k.shape[2]
    g = h // kvh
    if use_flash and tq > 1 and tq % 128 == 0 and k.shape[1] % 128 == 0:
        from repro.kernels.flash_attention import ops as fops
        return fops.flash_attention(q, k, v, causal=causal, window=window)
    if grouped and g > 1:
        qg = q.reshape(b, tq, kvh, g, hd)
        scores = jnp.einsum("bqkgd,bskd->bkgqs", qg, k,
                            preferred_element_type=jnp.float32)
        scores = scores / np.sqrt(hd)
        scores = jnp.where(mask[:, None, None, :, :], scores, -1e30)
        w = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
        out = jnp.einsum("bkgqs,bskd->bqkgd", w, v)
        return out.reshape(b, tq, h, hd)
    if g > 1:
        k = jnp.repeat(k, g, axis=2)
        v = jnp.repeat(v, g, axis=2)
    scores = jnp.einsum("bqhd,bshd->bhqs", q, k,
                        preferred_element_type=jnp.float32)
    scores = scores / np.sqrt(hd)
    scores = jnp.where(mask[:, None, :, :], scores, -1e30)
    w = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
    out = jnp.einsum("bhqs,bshd->bqhd", w, v)
    return out


def attention(params, x, cfg, *, positions, cache=None, cache_pos=None,
              window=None, cross_kv=None, causal=True, use_flash=False,
              build_cache=False, ctx=None):
    """Returns (out [B,T,D], new_cache).

    * training: cache=None, full sequence.
    * prefill: build_cache=True — returns the rope'd (k, v) (clipped to
      the sliding window for local layers) as the decode cache.
    * decode: x is [B,1,D]; cache = (k,v) with [B,S,KV,hd]; the new token
      attends to the S cached entries plus itself and is written into the
      cache ring at `cache_pos % S`.
    * cross attention: cross_kv = (k, v) precomputed from the encoder.
    """
    b, t, d = x.shape
    q = jnp.einsum("btd,dhk->bthk", x, params["wq"])
    if cross_kv is None:
        k = jnp.einsum("btd,dhk->bthk", x, params["wk"])
        v = jnp.einsum("btd,dhk->bthk", x, params["wv"])
    else:
        k, v = cross_kv
    if cfg.qk_norm:
        q = _head_rms(q, params["qnorm"])
        if cross_kv is None:
            k = _head_rms(k, params["knorm"])
    if cross_kv is None:
        q = rope(q, positions, cfg.rope_theta)
        k = rope(k, positions, cfg.rope_theta)

    new_cache = None
    if build_cache:
        w = window or k.shape[1]
        new_cache = (k[:, -w:], v[:, -w:])
    if cache is not None and ctx is not None and t == 1 \
            and cross_kv is None:
        msize = ctx.mesh.shape[ctx.model_axis]
        if (cache[0].shape[1] % msize == 0 and
                cfg.n_kv_heads % msize != 0):
            # seq-sharded cache + non-tiling kv heads: distributed
            # decode attention (cache stays put, stats are psummed)
            out, new_cache = decode_attention_dist(
                params, q, k, v, cache, cache_pos, cfg, ctx)
            out = jnp.einsum("bthk,hkd->btd", out, params["wo"])
            return out, new_cache
    if cache is not None:
        ck, cv = cache
        s = ck.shape[1]
        # append the fresh token at cache_pos (static-shape ring update)
        cache_pos = cache_pos % s
        ck = jax.lax.dynamic_update_slice_in_dim(ck, k.astype(ck.dtype),
                                                 cache_pos, axis=1)
        cv = jax.lax.dynamic_update_slice_in_dim(cv, v.astype(cv.dtype),
                                                 cache_pos, axis=1)
        new_cache = (ck, cv)
        k, v = ck, cv
        # decode: every cache slot is valid — local layers pass a cache
        # pre-sized to their window, so no extra masking is needed
        mask = jnp.ones((1, t, s), bool)
    else:
        tk = k.shape[1]
        # positions are identical across the batch in train/prefill; keep
        # the mask batch-free so it never materializes at global batch
        qpos = positions[:1, :, None]
        if cross_kv is None:
            kpos = positions[:1, None, :]
        else:
            kpos = jnp.arange(tk)[None, None, :]
        if causal:
            mask = qpos >= kpos
            if window is not None:
                mask = mask & (qpos - kpos < window)
        else:
            mask = jnp.ones((1, t, tk), bool)

    out = _sdpa(q, k, v, mask, use_flash=use_flash, window=window,
                causal=causal and cross_kv is None and cache is None,
                grouped=cfg.seq_parallel)
    out = jnp.einsum("bthk,hkd->btd", out, params["wo"])
    return out, new_cache


def decode_attention_dist(params, q, k_new, v_new, cache, pos, cfg, ctx,
                          qk_norm_done=True):
    """Distributed decode attention over a sequence-sharded KV cache.

    When kv-heads don't tile the model axis, GSPMD's default for the
    seq-sharded cache is an all-gather of K and V *per layer per token*
    (GiBs/step).  This shard_map keeps the cache stationary: each model
    shard scores its local cache slice, and only the online-softmax
    statistics (max, denominator) and the [B,1,H,hd] output are psummed.
    The fresh token's k/v is written by the shard that owns the ring slot.

    q: [B,1,H,hd]; k_new/v_new: [B,1,KV,hd]; cache=(ck,cv) [B,S,KV,hd].
    """
    import numpy as np
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P
    from .sharding import batch_spec

    mesh, maxis = ctx.mesh, ctx.model_axis
    b = q.shape[0]
    bspec = batch_spec(ctx, b, 4)
    bd = bspec[0]
    cache_spec = P(bd, maxis, None, None)
    hd = q.shape[-1]

    def body(q, kn, vn, ck, cv, pos):
        i = jax.lax.axis_index(maxis)
        s_loc = ck.shape[1]
        loc = (pos % (s_loc * mesh.shape[maxis]))
        owner = loc // s_loc
        upd_k = jax.lax.dynamic_update_slice_in_dim(
            ck, kn.astype(ck.dtype), loc % s_loc, axis=1)
        upd_v = jax.lax.dynamic_update_slice_in_dim(
            cv, vn.astype(cv.dtype), loc % s_loc, axis=1)
        ck = jnp.where(i == owner, upd_k, ck)
        cv = jnp.where(i == owner, upd_v, cv)
        bq, _, h, _ = q.shape
        kvh = ck.shape[2]
        g = h // kvh
        qg = q.reshape(bq, 1, kvh, g, hd)
        s = jnp.einsum("bqkgd,bskd->bkgqs", qg, ck,
                       preferred_element_type=jnp.float32) / np.sqrt(hd)
        m_loc = s.max(axis=-1, keepdims=True)
        m = jax.lax.pmax(m_loc, maxis)
        p = jnp.exp(s - m)
        denom = jax.lax.psum(p.sum(axis=-1, keepdims=True), maxis)
        o = jnp.einsum("bkgqs,bskd->bqkgd", p.astype(q.dtype), cv)
        o = jax.lax.psum(o.astype(jnp.float32), maxis)
        d = denom[:, :, :, 0, 0]                    # [b, kv, g]
        o = (o / d[:, None, :, :, None]).astype(q.dtype)
        return o.reshape(bq, 1, h, hd), ck, cv

    fn = shard_map(
        body, mesh=mesh,
        in_specs=(batch_spec(ctx, b, 4), batch_spec(ctx, b, 4),
                  batch_spec(ctx, b, 4), cache_spec, cache_spec, P()),
        out_specs=(batch_spec(ctx, b, 4), cache_spec, cache_spec),
        check_rep=False)
    out, ck, cv = fn(q, k_new, v_new, cache[0], cache[1],
                     jnp.asarray(pos, jnp.int32))
    return out, (ck, cv)


# ---------------------------------------------------------------------
# MLA — multi-head latent attention (MiniCPM3 / DeepSeek style)
# ---------------------------------------------------------------------

def init_mla(key, cfg, dtype=jnp.float32):
    d, h = cfg.d_model, cfg.n_heads
    dn, dr = cfg.mla_nope_dim, cfg.mla_rope_dim
    qr, kvr = cfg.q_lora_rank, cfg.kv_lora_rank
    ks = jax.random.split(key, 7)
    return {
        "wdq": Boxed(_norm(ks[0], (d, qr), dtype=dtype), ("embed", None)),
        "wuq": Boxed(_norm(ks[1], (qr, h, dn + dr), dtype=dtype),
                     (None, "heads", "qkv")),
        "wdkv": Boxed(_norm(ks[2], (d, kvr), dtype=dtype), ("embed", None)),
        "wukv": Boxed(_norm(ks[3], (kvr, h, dn + dn), dtype=dtype),
                      (None, "heads", "qkv")),
        "wkr": Boxed(_norm(ks[4], (d, dr), dtype=dtype), ("embed", None)),
        "wo": Boxed(_norm(ks[5], (h, dn, d), dtype=dtype),
                    ("heads", "qkv", "embed")),
        "qnorm": Boxed(jnp.ones((qr,), dtype), (None,)),
        "kvnorm": Boxed(jnp.ones((kvr,), dtype), (None,)),
    }


def mla_attention(params, x, cfg, *, positions, cache=None, cache_pos=None,
                  build_cache=False):
    """MLA with the compressed-KV cache (c_kv + shared k_rope).

    cache = (c_kv [B,S,kvr], k_rope [B,S,dr]) — this is MLA's memory win.
    """
    b, t, d = x.shape
    h, dn, dr = cfg.n_heads, cfg.mla_nope_dim, cfg.mla_rope_dim

    cq = _head_rms(jnp.einsum("btd,dr->btr", x, params["wdq"]),
                   params["qnorm"])
    q = jnp.einsum("btr,rhk->bthk", cq, params["wuq"])
    q_nope, q_rope = q[..., :dn], q[..., dn:]
    q_rope = rope(q_rope, positions, cfg.rope_theta)

    ckv = _head_rms(jnp.einsum("btd,dr->btr", x, params["wdkv"]),
                    params["kvnorm"])
    krope = rope(jnp.einsum("btd,dr->btr", x, params["wkr"])[:, :, None, :],
                 positions, cfg.rope_theta)[:, :, 0, :]

    new_cache = None
    if build_cache:
        new_cache = (ckv, krope)
    if cache is not None:
        c_ckv, c_kr = cache
        cache_pos = cache_pos % c_ckv.shape[1]
        c_ckv = jax.lax.dynamic_update_slice_in_dim(
            c_ckv, ckv.astype(c_ckv.dtype), cache_pos, axis=1)
        c_kr = jax.lax.dynamic_update_slice_in_dim(
            c_kr, krope.astype(c_kr.dtype), cache_pos, axis=1)
        new_cache = (c_ckv, c_kr)
        ckv, krope = c_ckv, c_kr

    kv = jnp.einsum("bsr,rhk->bshk", ckv, params["wukv"])
    k_nope, v = kv[..., :dn], kv[..., dn:]

    s = ckv.shape[1]
    scores = (jnp.einsum("bthk,bshk->bhts", q_nope, k_nope,
                         preferred_element_type=jnp.float32) +
              jnp.einsum("bthk,bsk->bhts", q_rope, krope,
                         preferred_element_type=jnp.float32))
    scores = scores / np.sqrt(dn + dr)
    if cache is None:
        mask = positions[:1, None, :, None] >= positions[:1, None, None, :]
        scores = jnp.where(mask, scores, -1e30)
    w = jax.nn.softmax(scores, -1).astype(x.dtype)
    out = jnp.einsum("bhts,bshk->bthk", w, v)
    out = jnp.einsum("bthk,hkd->btd", out, params["wo"])
    return out, new_cache


# ---------------------------------------------------------------------
# SwiGLU MLP
# ---------------------------------------------------------------------

def init_mlp(key, d, f, dtype=jnp.float32):
    ks = jax.random.split(key, 3)
    return {
        "wi": Boxed(_norm(ks[0], (d, f), dtype=dtype), ("embed", "mlp")),
        "wg": Boxed(_norm(ks[1], (d, f), dtype=dtype), ("embed", "mlp")),
        "wo": Boxed(_norm(ks[2], (f, d), dtype=dtype), ("mlp", "embed")),
    }


def mlp(params, x):
    h = jnp.einsum("btd,df->btf", x, params["wi"])
    g = jnp.einsum("btd,df->btf", x, params["wg"])
    return jnp.einsum("btf,fd->btd", jax.nn.silu(g) * h, params["wo"])


# ---------------------------------------------------------------------
# MoE: top-k routing.
#   * reference path: sort + jax.lax.ragged_dot (exact, dropless)
#   * distributed path: shard_map expert parallelism over the "model"
#     axis with capacity-based selection and a psum combine.
# ---------------------------------------------------------------------

def init_moe(key, cfg, dtype=jnp.float32):
    d, f, e = cfg.d_model, cfg.d_ff, cfg.n_experts
    ks = jax.random.split(key, 4)
    return {
        "router": Boxed(_norm(ks[0], (d, e)), ("embed", None)),
        "wi": Boxed(_norm(ks[1], (e, d, f), dtype=dtype),
                    ("experts", "embed", "mlp")),
        "wg": Boxed(_norm(ks[2], (e, d, f), dtype=dtype),
                    ("experts", "embed", "mlp")),
        "wo": Boxed(_norm(ks[3], (e, f, d), dtype=dtype),
                    ("experts", "mlp", "embed")),
    }


def _router(params, x, cfg):
    logits = jnp.einsum("btd,de->bte", x.astype(jnp.float32),
                        params["router"].astype(jnp.float32))
    probs = jax.nn.softmax(logits, -1)
    top_p, top_e = jax.lax.top_k(probs, cfg.top_k)
    top_p = top_p / jnp.maximum(top_p.sum(-1, keepdims=True), 1e-9)
    # load-balancing auxiliary loss (Switch-style)
    me = probs.mean(axis=(0, 1))
    ce = jnp.zeros_like(me).at[top_e.reshape(-1)].add(
        1.0 / top_e.size)
    aux = cfg.n_experts * jnp.sum(me * ce)
    return top_p, top_e, aux


def moe_ragged(params, x, cfg):
    """Dropless reference using jax.lax.ragged_dot (single-shard oracle)."""
    b, t, d = x.shape
    k, e = cfg.top_k, cfg.n_experts
    top_p, top_e, aux = _router(params, x, cfg)
    xt = x.reshape(b * t, d)
    flat_e = top_e.reshape(-1)                       # [b*t*k]
    order = jnp.argsort(flat_e)
    xr = jnp.repeat(xt, k, axis=0)[order]            # [b*t*k, d]
    group_sizes = jnp.bincount(flat_e, length=e).astype(jnp.int32)
    h = jax.lax.ragged_dot(xr, params["wi"], group_sizes)
    g = jax.lax.ragged_dot(xr, params["wg"], group_sizes)
    y = jax.lax.ragged_dot((jax.nn.silu(g) * h).astype(x.dtype),
                           params["wo"], group_sizes)
    # unsort, weight, combine
    inv = jnp.argsort(order)
    y = y[inv].reshape(b * t, k, d)
    y = (y * top_p.reshape(b * t, k, 1).astype(y.dtype)).sum(1)
    return y.reshape(b, t, d), aux


def moe_ep_local(params, x, cfg, axis_name, e_par, f_par):
    """Body run inside shard_map over the `model` axis.

    Each shard owns E_virt/e_par *virtual* experts (an expert split
    `moe_virtual_split` ways along d_ff when E < mesh model size); the
    psum over the model axis combines both the expert contributions and
    the d_ff partials.  params arrive pre-sliced by shard_map.
    """
    b, t, d = x.shape
    k = cfg.top_k
    s = cfg.moe_virtual_split
    e_loc = params["wi"].shape[0]
    cap = int(min(b * t, max(1, round(b * t * k * cfg.capacity_factor /
                                      cfg.n_experts))))

    top_p, top_e, aux = _router(params, x, cfg)       # router is replicated
    idx = jax.lax.axis_index(axis_name)
    my_e0 = (idx // f_par) * e_loc

    xt = x.reshape(b * t, d)
    pe = top_e.reshape(b * t, k)
    pp = top_p.reshape(b * t, k)
    outs = jnp.zeros((b * t, d), jnp.float32)
    for le in range(e_loc):
        eid = (my_e0 + le) // s                       # real expert id
        w = jnp.where(pe == eid, pp, 0.0).sum(-1)     # [b*t] gate weight
        score = jnp.where(w > 0, w, -1.0)
        _, sel = jax.lax.top_k(score, cap)            # token ids for expert
        gate = w[sel]                                 # [cap]
        xe = xt[sel]                                  # [cap, d]
        h = jnp.einsum("cd,df->cf", xe, params["wi"][le])
        g = jnp.einsum("cd,df->cf", xe, params["wg"][le])
        ye = jnp.einsum("cf,fd->cd", (jax.nn.silu(g) * h).astype(x.dtype),
                        params["wo"][le])
        outs = outs.at[sel].add(ye.astype(jnp.float32) * gate[:, None])
    outs = jax.lax.psum(outs, axis_name)
    # aux is computed from the replicated router => identical on all shards
    return outs.reshape(b, t, d).astype(x.dtype), aux


def moe_ep_stationary(params, x, cfg, ctx):
    """Weight-stationary MoE for serving (few tokens, huge experts).

    Experts shard over the model axis AND their d_ff over the data axis;
    the (tiny) token activations are all-gathered over "data", every
    shard computes its (expert, d_ff-slice) contribution, and a psum over
    "model" + psum_scatter over "data" reassembles the batch-sharded
    output.  Wire bytes per layer: O(tokens x d_model) instead of
    O(expert weights) — the decode fix for grok/qwen3-moe.
    """
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P
    from .sharding import batch_spec

    mesh, maxis, daxis = ctx.mesh, ctx.model_axis, "data"
    b, t, d = x.shape
    bspec = batch_spec(ctx, b, 3)
    batch_on_data = bspec[0] is not None and (
        daxis == bspec[0] or (isinstance(bspec[0], tuple) and
                              daxis in bspec[0]))
    k, s = cfg.top_k, cfg.moe_virtual_split
    dsize = mesh.shape[daxis]

    def body(params, xl):
        if batch_on_data:
            xg = jax.lax.all_gather(xl, daxis, axis=0, tiled=True)
        else:
            xg = xl
        bg = xg.shape[0]
        cap = int(min(bg * t, max(1, round(bg * t * k *
                                           cfg.capacity_factor /
                                           cfg.n_experts))))
        top_p, top_e, aux = _router(params, xg, cfg)
        idx = jax.lax.axis_index(maxis)
        e_loc = params["wi"].shape[0]
        my_e0 = idx * e_loc
        xt = xg.reshape(bg * t, d)
        pe = top_e.reshape(bg * t, k)
        pp = top_p.reshape(bg * t, k)
        outs = jnp.zeros((bg * t, d), jnp.float32)
        for le in range(e_loc):
            eid = (my_e0 + le) // s
            w = jnp.where(pe == eid, pp, 0.0).sum(-1)
            score = jnp.where(w > 0, w, -1.0)
            _, sel = jax.lax.top_k(score, cap)
            gate = w[sel]
            xe = xt[sel]
            h = jnp.einsum("cd,df->cf", xe, params["wi"][le])
            g = jnp.einsum("cd,df->cf", xe, params["wg"][le])
            ye = jnp.einsum("cf,fd->cd",
                            (jax.nn.silu(g) * h).astype(x.dtype),
                            params["wo"][le])
            outs = outs.at[sel].add(ye.astype(jnp.float32) * gate[:, None])
        outs = jax.lax.psum(outs, maxis)
        if batch_on_data:
            outs = jax.lax.psum_scatter(outs, daxis, scatter_dimension=0,
                                        tiled=True)
        else:
            outs = jax.lax.psum(outs, daxis)
        bl = xl.shape[0]
        return outs.reshape(bl, t, d).astype(x.dtype), aux

    pspec = {"router": P(), "wi": P(maxis, None, daxis),
             "wg": P(maxis, None, daxis), "wo": P(maxis, daxis, None)}
    fn = shard_map(body, mesh=mesh, in_specs=(pspec, bspec),
                   out_specs=(bspec, P()), check_rep=False)
    return fn(params, x)
