"""Mamba2 / SSD (state-space duality) blocks — arXiv:2405.21060.

Implements the chunked, matmul-dominant SSD form (TPU/MXU-friendly):
within-chunk attention-like term + inter-chunk state recurrence.  Used by
`mamba2-1.3b` (pure SSM) and `jamba-v0.1-52b` (hybrid).  The per-chunk
core can be dispatched to the Pallas kernel in repro/kernels/ssd_scan.

Decode keeps the recurrent state  S [B, H, P, N]  plus a depthwise-conv
ring cache; one step is O(H*P*N) — this is what makes `long_500k`
(524288-token decode) linear-cost for SSM archs.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from .layers import Boxed, _norm


def init_mamba2(key, cfg, dtype=jnp.float32):
    d = cfg.d_model
    d_in = cfg.ssm_expand * d
    hp = cfg.ssm_head_dim
    h = d_in // hp
    n = cfg.ssm_state
    conv_dim = d_in + 2 * n                     # x, B, C share the conv
    ks = jax.random.split(key, 7)
    # projections kept separate so each output dim shards cleanly (TP)
    return {
        "in_z": Boxed(_norm(ks[0], (d, d_in), dtype=dtype),
                      ("embed", "mlp")),
        "in_x": Boxed(_norm(ks[1], (d, d_in), dtype=dtype),
                      ("embed", "mlp")),
        "in_b": Boxed(_norm(ks[2], (d, n), dtype=dtype), ("embed", None)),
        "in_c": Boxed(_norm(ks[3], (d, n), dtype=dtype), ("embed", None)),
        "in_dt": Boxed(_norm(ks[4], (d, h), dtype=dtype),
                       ("embed", "heads")),
        "conv_w": Boxed(_norm(ks[5], (cfg.ssm_conv, conv_dim), 0.2,
                              dtype=dtype), (None, "mlp")),
        "conv_b": Boxed(jnp.zeros((conv_dim,), dtype), ("mlp",)),
        "a_log": Boxed(jnp.log(jnp.linspace(1.0, 16.0, h)).astype(dtype),
                       ("heads",)),
        "d_skip": Boxed(jnp.ones((h,), dtype), ("heads",)),
        "dt_bias": Boxed(jnp.zeros((h,), dtype), ("heads",)),
        "norm_w": Boxed(jnp.ones((d_in,), dtype), ("mlp",)),
        "out_proj": Boxed(_norm(ks[6], (d_in, d), dtype=dtype),
                          ("mlp", "embed")),
    }


def _gated_norm(y, z, w, eps=1e-6):
    y = y * jax.nn.silu(z.astype(jnp.float32))
    y = y * jax.lax.rsqrt(jnp.mean(y * y, -1, keepdims=True) + eps)
    return y * w.astype(jnp.float32)


def ssd_chunked_core(x, dt, a, b_mat, c_mat, chunk: int,
                     initial_state=None):
    """The SSD algorithm over chunks (pure jnp; Pallas kernel oracle).

    x:  [B, T, H, P]  inputs (already conv'd / activated)
    dt: [B, T, H]     positive step sizes
    a:  [H]           negative decay rates
    b_mat, c_mat: [B, T, N]
    Returns (y [B,T,H,P], final_state [B,H,N,P]).
    """
    bsz, t, h, p = x.shape
    n = b_mat.shape[-1]
    q = chunk
    nc = t // q
    assert t % q == 0, f"T={t} must be a multiple of chunk={q}"

    xr = x.reshape(bsz, nc, q, h, p)
    dtr = dt.reshape(bsz, nc, q, h)
    br = b_mat.reshape(bsz, nc, q, n)
    cr = c_mat.reshape(bsz, nc, q, n)

    da = dtr * a[None, None, None, :]                   # [B,nc,Q,H] (<=0)
    cum = jnp.cumsum(da, axis=2)                        # within chunk
    seg = cum[:, :, :, None, :] - cum[:, :, None, :, :]  # [B,nc,Q,Q,H]
    tri = jnp.tril(jnp.ones((q, q), bool))
    l_mat = jnp.where(tri[None, None, :, :, None], jnp.exp(seg), 0.0)

    # within-chunk (quadratic in Q, matmul-dominant)
    cb = jnp.einsum("bcqn,bckn->bcqk", cr, br,
                    preferred_element_type=jnp.float32)  # [B,nc,Q,Q]
    xdt = xr * dtr[..., None]
    y_diag = jnp.einsum("bcqk,bcqkh,bckhp->bcqhp", cb, l_mat,
                        xdt.astype(jnp.float32))

    # chunk summary states: S_c = sum_j exp(cum_last - cum_j) dt_j B_j x_j
    decay_tail = jnp.exp(cum[:, :, -1:, :] - cum)        # [B,nc,Q,H]
    states = jnp.einsum("bckn,bckh,bckhp->bchnp",
                        br.astype(jnp.float32),
                        (decay_tail * dtr).astype(jnp.float32),
                        xr.astype(jnp.float32))          # [B,nc,H,N,P]
    chunk_decay = jnp.exp(cum[:, :, -1, :])              # [B,nc,H]

    # inter-chunk recurrence (scan over chunks)
    s0 = (jnp.zeros((bsz, h, n, p), jnp.float32) if initial_state is None
          else initial_state.astype(jnp.float32))

    def scan_fn(s_prev, inp):
        st, dec = inp                                    # [B,H,N,P],[B,H]
        s_new = s_prev * dec[:, :, None, None] + st
        return s_new, s_prev

    states_t = jnp.moveaxis(states, 1, 0)                # [nc,B,H,N,P]
    decay_t = jnp.moveaxis(chunk_decay, 1, 0)            # [nc,B,H]
    s_final, s_in = jax.lax.scan(scan_fn, s0, (states_t, decay_t))
    s_in = jnp.moveaxis(s_in, 0, 1)                      # [B,nc,H,N,P]

    y_inter = jnp.einsum("bcqn,bchnp->bcqhp", cr.astype(jnp.float32), s_in)
    y_inter = y_inter * jnp.exp(cum)[..., None]
    y = (y_diag + y_inter).reshape(bsz, t, h, p)
    return y.astype(x.dtype), s_final


def mamba2_block(params, x, cfg, *, state=None, conv_cache=None,
                 use_kernel=False, build_cache=False):
    """Full Mamba2 block.

    * train/prefill: state=None — chunked SSD over the sequence.
    * decode: x [B,1,D]; state [B,H,N,P] and conv_cache [B,K-1,conv_dim]
      are updated recurrently.
    Returns (out, (new_state, new_conv_cache)).
    """
    bsz, t, d = x.shape
    d_in = cfg.ssm_expand * d
    n = cfg.ssm_state
    hp = cfg.ssm_head_dim
    h = d_in // hp
    kc = cfg.ssm_conv

    z = jnp.einsum("btd,dk->btk", x, params["in_z"])
    xin = jnp.einsum("btd,dk->btk", x, params["in_x"])
    bin_ = jnp.einsum("btd,dn->btn", x, params["in_b"])
    cin = jnp.einsum("btd,dn->btn", x, params["in_c"])
    dt = jnp.einsum("btd,dh->bth", x, params["in_dt"])
    xc = jnp.concatenate([xin, bin_, cin], axis=-1)
    dt = jax.nn.softplus(dt.astype(jnp.float32) +
                         params["dt_bias"].astype(jnp.float32))
    a = -jnp.exp(params["a_log"].astype(jnp.float32))

    if state is None:
        # causal depthwise conv along T
        pad = jnp.pad(xc, ((0, 0), (kc - 1, 0), (0, 0)))
        conv = sum(pad[:, i:i + t] * params["conv_w"][i][None, None, :]
                   for i in range(kc)) + params["conv_b"]
        conv = jax.nn.silu(conv)
        xs = conv[..., :d_in].reshape(bsz, t, h, hp)
        bm = conv[..., d_in:d_in + n]
        cm = conv[..., d_in + n:]
        if use_kernel and t % cfg.ssm_chunk == 0:
            from repro.kernels.ssd_scan import ops as sops
            y, s_final = sops.ssd_scan(xs, dt, a, bm, cm,
                                       chunk=cfg.ssm_chunk)
        else:
            chunk = min(cfg.ssm_chunk, t)
            if t % chunk != 0:
                chunk = t
            y, s_final = ssd_chunked_core(xs, dt, a, bm, cm, chunk)
        if build_cache:
            pad_t = max(kc - 1 - t, 0)
            tail_xc = xc[:, max(t - (kc - 1), 0):]
            new_conv_cache = jnp.pad(tail_xc, ((0, 0), (pad_t, 0), (0, 0)))
        else:
            new_conv_cache = None
    else:
        # single-token recurrence
        cc = jnp.concatenate([conv_cache, xc], axis=1)    # [B,K,convdim]
        conv = (jnp.einsum("bkc,kc->bc", cc, params["conv_w"])
                + params["conv_b"])[:, None, :]
        conv = jax.nn.silu(conv)
        xs = conv[..., :d_in].reshape(bsz, 1, h, hp)
        bm = conv[..., d_in:d_in + n]
        cm = conv[..., d_in + n:]
        da = jnp.exp(dt[:, 0] * a[None, :])               # [B,H]
        s = state.astype(jnp.float32)
        upd = jnp.einsum("bn,bhp,bh->bhnp", bm[:, 0].astype(jnp.float32),
                         xs[:, 0].astype(jnp.float32), dt[:, 0])
        s_final = s * da[:, :, None, None] + upd
        y = jnp.einsum("bn,bhnp->bhp", cm[:, 0].astype(jnp.float32),
                       s_final)[:, None]                  # [B,1,H,P]
        new_conv_cache = cc[:, 1:]

    y = y + xs.astype(jnp.float32) * \
        params["d_skip"].astype(jnp.float32)[None, None, :, None]
    y = y.reshape(bsz, t, d_in)
    y = _gated_norm(y, z, params["norm_w"]).astype(x.dtype)
    out = jnp.einsum("btk,kd->btd", y, params["out_proj"])
    return out, (s_final, new_conv_cache)
