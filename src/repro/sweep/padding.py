"""Shape padding for heterogeneous SimSpecs (DESIGN.md §6).

A `SimSpec`'s arrays are sized by its topology: node count N, max port
count P, directed channel count C and link-pipeline ring depth D.  To run
several topologies through ONE compiled program they are padded to a
common `PadShape` and stacked into a `BatchSpec` whose leaves carry a
leading spec axis.

Padding is *inert by construction* — the simulator never lets a padded
lane influence a real one:

  * padded nodes have `inj_weight == 0` (never inject) and all-(-1)
    routing-table rows (never route);
  * padded in/out port columns hold `-1` channel ids, which the step
    function masks everywhere it consults them;
  * padded channels are never written by real traversals (the routing
    table only names real channels), so their link rows stay empty and
    their arrival scatters resolve to the simulator's sacrificial slots;
  * `traffic_cum` pad columns are 1.0, so destination draws (uniform in
    [0, 1)) can never land on a padded node;
  * the injection column of the routing table moves from index P_spec to
    the shared padded index P, and the per-spec `pi = P_spec + 1` scalar
    lets the rotating-priority counter keep the spec's own period.

Together with the simulator's hash-based injection randomness this makes
batched results bitwise-equal to the single-spec path (tested in
tests/test_sweep.py).

The flight recorder (`SimConfig(telemetry=True)`, DESIGN.md §13) rides
on the same discipline in the *output* direction: its per-channel /
per-node counter tensors are sized to the padded shape (sacrificial row
C+1, padded node tails), non-contributing lanes are scatter-routed to
the sacrificial row or weighted 0, and `run_batch` slices every
telemetry leaf back to the spec's own (c, n) before results leave the
batch — so telemetry rows can never name a pad slot, and the sliced
counters are bitwise-equal for any padding of the same spec
(tests/test_obs.py).
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple, Sequence

import numpy as np


@dataclasses.dataclass(frozen=True, order=True)
class PadShape:
    """Common padded dimensions for a batch of SimSpecs."""
    n: int   # nodes
    p: int   # max real ports
    c: int   # directed channels
    d: int   # link pipeline ring depth

    @classmethod
    def of(cls, specs) -> "PadShape":
        return cls(n=max(s.n for s in specs), p=max(s.p for s in specs),
                   c=max(s.c for s in specs), d=max(s.d for s in specs))

    def covers(self, other: "PadShape") -> bool:
        return (self.n >= other.n and self.p >= other.p
                and self.c >= other.c and self.d >= other.d)


class BatchSpec(NamedTuple):
    """Stacked padded spec arrays; every leaf has a leading spec axis S.

    `pi` is the per-spec real port-axis size P_spec+1 (the rotating
    priority period divisor), shaped [S].
    """
    table: np.ndarray        # [S, N, N, P+1] int16
    out_ch: np.ndarray       # [S, N, P] int32
    in_ch: np.ndarray        # [S, N, P] int32
    ch_src: np.ndarray       # [S, C] int32
    ch_dst: np.ndarray       # [S, C] int32
    ch_in_port: np.ndarray   # [S, C] int32
    ch_out_port: np.ndarray  # [S, C] int32
    ch_depth: np.ndarray     # [S, C] int32
    traffic_cum: np.ndarray  # [S, N, N] float32
    inj_weight: np.ndarray   # [S, N] float32
    prod: np.ndarray         # [S, N, N, P] bool (pad region all-False)
    pi: np.ndarray           # [S] int32


def pad_spec(spec, shape: PadShape) -> dict:
    """Pad one SimSpec's arrays to `shape`; returns a dict of leaves."""
    own = PadShape(n=spec.n, p=spec.p, c=spec.c, d=spec.d)
    if not shape.covers(own):
        raise ValueError(f"pad shape {shape} does not cover spec {own}")
    n, p, c = spec.n, spec.p, spec.c
    N, P, C = shape.n, shape.p, shape.c

    table = np.full((N, N, P + 1), -1, np.int16)
    table[:n, :n, :p] = spec.table[:, :, :p]
    table[:n, :n, P] = spec.table[:, :, p]     # injection column -> slot P

    def pad2(a, fill, dtype=np.int32):
        out = np.full((N, P), fill, dtype)
        out[:n, :p] = a
        return out

    def padc(a, fill):
        out = np.full((C,), fill, np.int32)
        out[:c] = a
        return out

    cum = np.ones((N, N), np.float32)
    cum[:n, :n] = spec.traffic_cum
    inj = np.zeros((N,), np.float32)
    inj[:n] = spec.inj_weight
    # productive-ports mask (DESIGN.md §15): pad region all-False, so an
    # adaptive selection can never name a padded destination, node or
    # port — padded lanes fall back to the (all -1) escape table and
    # stay inert exactly like the static path.
    pr = np.zeros((N, N, P), bool)
    pr[:n, :n, :p] = spec.prod
    return dict(
        table=table,
        out_ch=pad2(spec.out_ch, -1), in_ch=pad2(spec.in_ch, -1),
        ch_src=padc(spec.ch_src, 0), ch_dst=padc(spec.ch_dst, 0),
        ch_in_port=padc(spec.ch_in_port, 0),
        ch_out_port=padc(spec.ch_out_port, 0),
        ch_depth=padc(spec.ch_depth, 1),
        traffic_cum=cum, inj_weight=inj, prod=pr,
        pi=np.int32(p + 1))


def stack_specs(specs: Sequence, shape: PadShape | None = None
                ) -> tuple[BatchSpec, PadShape]:
    """Pad every spec to a common shape and stack into a BatchSpec."""
    if not specs:
        raise ValueError("stack_specs needs at least one spec")
    shape = shape or PadShape.of(specs)
    padded = [pad_spec(s, shape) for s in specs]
    leaves = {k: np.stack([p[k] for p in padded]) for k in padded[0]}
    return BatchSpec(**leaves), shape


# =====================================================================
# phase-schedule padding (workload mode, DESIGN.md §9)
# =====================================================================

_END_INF = np.int32(2 ** 30)


class SchedBatch(NamedTuple):
    """Stacked padded `simulator.SchedSpec`s; leading spec axis S.

    Padded phase rows are inert by the same discipline as spec padding:
    their `end` is 2^30, so the phase pointer (#{ends <= t_eff}) never
    counts them for any real cycle; their gain is 0 and their traffic
    rows are all-1.0.  Padded node columns mirror `pad_spec`: inj_w 0,
    cum 1.0.
    """
    cum: np.ndarray       # [S, K, N, N] float32
    inj_w: np.ndarray     # [S, K, N] float32
    gain_on: np.ndarray   # [S, K] float32
    start: np.ndarray     # [S, K] int32
    end: np.ndarray       # [S, K] int32 (padded rows: 2^30)
    on: np.ndarray        # [S, K] int32
    period: np.ndarray    # [S, K] int32
    total: np.ndarray     # [S] int32


def pad_schedule(sched, n_pad: int, k_pad: int) -> dict:
    """Pad one SchedSpec to (k_pad phases, n_pad nodes); dict of leaves."""
    if sched.k > k_pad or sched.n > n_pad:
        raise ValueError(f"pad shape (k={k_pad}, n={n_pad}) does not "
                         f"cover schedule (k={sched.k}, n={sched.n})")
    k, n = sched.k, sched.n
    cum = np.ones((k_pad, n_pad, n_pad), np.float32)
    cum[:k, :n, :n] = sched.cum
    inj_w = np.zeros((k_pad, n_pad), np.float32)
    inj_w[:k, :n] = sched.inj_w

    def padk(a, fill, dtype):
        out = np.full((k_pad,), fill, dtype)
        out[:k] = a
        return out

    return dict(
        cum=cum, inj_w=inj_w,
        gain_on=padk(sched.gain_on, 0.0, np.float32),
        start=padk(sched.start, 0, np.int32),
        end=padk(sched.end, _END_INF, np.int32),
        on=padk(sched.on, 1, np.int32),
        period=padk(sched.period, 1, np.int32),
        total=np.int32(sched.total))


def stack_schedules(scheds: Sequence, n_pad: int, k_pad: int | None = None
                    ) -> tuple[SchedBatch, int]:
    """Pad every schedule to (k_pad, n_pad) and stack into a SchedBatch."""
    if not scheds:
        raise ValueError("stack_schedules needs at least one schedule")
    k_pad = k_pad or max(s.k for s in scheds)
    padded = [pad_schedule(s, n_pad, k_pad) for s in scheds]
    leaves = {k: np.stack([p[k] for p in padded]) for k in padded[0]}
    return SchedBatch(**leaves), k_pad
