"""Shape padding for heterogeneous SimSpecs (DESIGN.md §6).

A `SimSpec`'s arrays are sized by its topology: node count N, max port
count P, directed channel count C and link-pipeline ring depth D.  To run
several topologies through ONE compiled program they are padded to a
common `PadShape` and stacked into a `BatchSpec` whose leaves carry a
leading spec axis.

Padding is *inert by construction* — the simulator never lets a padded
lane influence a real one:

  * padded nodes have `inj_weight == 0` (never inject) and all-(-1)
    routing-table rows (never route);
  * padded in/out port columns hold `-1` channel ids, which the step
    function masks everywhere it consults them;
  * padded channels are never written by real traversals (the routing
    table only names real channels), so their link rows stay empty and
    their arrival scatters resolve to the simulator's sacrificial slots;
  * `traffic_cum` pad columns are 1.0, so destination draws (uniform in
    [0, 1)) can never land on a padded node;
  * the injection column of the routing table moves from index P_spec to
    the shared padded index P, and the per-spec `pi = P_spec + 1` scalar
    lets the rotating-priority counter keep the spec's own period.

Together with the simulator's hash-based injection randomness this makes
batched results bitwise-equal to the single-spec path (tested in
tests/test_sweep.py).
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple, Sequence

import numpy as np


@dataclasses.dataclass(frozen=True, order=True)
class PadShape:
    """Common padded dimensions for a batch of SimSpecs."""
    n: int   # nodes
    p: int   # max real ports
    c: int   # directed channels
    d: int   # link pipeline ring depth

    @classmethod
    def of(cls, specs) -> "PadShape":
        return cls(n=max(s.n for s in specs), p=max(s.p for s in specs),
                   c=max(s.c for s in specs), d=max(s.d for s in specs))

    def covers(self, other: "PadShape") -> bool:
        return (self.n >= other.n and self.p >= other.p
                and self.c >= other.c and self.d >= other.d)


class BatchSpec(NamedTuple):
    """Stacked padded spec arrays; every leaf has a leading spec axis S.

    `pi` is the per-spec real port-axis size P_spec+1 (the rotating
    priority period divisor), shaped [S].
    """
    table: np.ndarray        # [S, N, N, P+1] int16
    out_ch: np.ndarray       # [S, N, P] int32
    in_ch: np.ndarray        # [S, N, P] int32
    ch_src: np.ndarray       # [S, C] int32
    ch_dst: np.ndarray       # [S, C] int32
    ch_in_port: np.ndarray   # [S, C] int32
    ch_out_port: np.ndarray  # [S, C] int32
    ch_depth: np.ndarray     # [S, C] int32
    traffic_cum: np.ndarray  # [S, N, N] float32
    inj_weight: np.ndarray   # [S, N] float32
    pi: np.ndarray           # [S] int32


def pad_spec(spec, shape: PadShape) -> dict:
    """Pad one SimSpec's arrays to `shape`; returns a dict of leaves."""
    own = PadShape(n=spec.n, p=spec.p, c=spec.c, d=spec.d)
    if not shape.covers(own):
        raise ValueError(f"pad shape {shape} does not cover spec {own}")
    n, p, c = spec.n, spec.p, spec.c
    N, P, C = shape.n, shape.p, shape.c

    table = np.full((N, N, P + 1), -1, np.int16)
    table[:n, :n, :p] = spec.table[:, :, :p]
    table[:n, :n, P] = spec.table[:, :, p]     # injection column -> slot P

    def pad2(a, fill, dtype=np.int32):
        out = np.full((N, P), fill, dtype)
        out[:n, :p] = a
        return out

    def padc(a, fill):
        out = np.full((C,), fill, np.int32)
        out[:c] = a
        return out

    cum = np.ones((N, N), np.float32)
    cum[:n, :n] = spec.traffic_cum
    inj = np.zeros((N,), np.float32)
    inj[:n] = spec.inj_weight
    return dict(
        table=table,
        out_ch=pad2(spec.out_ch, -1), in_ch=pad2(spec.in_ch, -1),
        ch_src=padc(spec.ch_src, 0), ch_dst=padc(spec.ch_dst, 0),
        ch_in_port=padc(spec.ch_in_port, 0),
        ch_out_port=padc(spec.ch_out_port, 0),
        ch_depth=padc(spec.ch_depth, 1),
        traffic_cum=cum, inj_weight=inj,
        pi=np.int32(p + 1))


def stack_specs(specs: Sequence, shape: PadShape | None = None
                ) -> tuple[BatchSpec, PadShape]:
    """Pad every spec to a common shape and stack into a BatchSpec."""
    if not specs:
        raise ValueError("stack_specs needs at least one spec")
    shape = shape or PadShape.of(specs)
    padded = [pad_spec(s, shape) for s in specs]
    leaves = {k: np.stack([p[k] for p in padded]) for k in padded[0]}
    return BatchSpec(**leaves), shape
