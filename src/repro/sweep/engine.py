"""Batched multi-topology sweep engine (DESIGN.md §6).

`SweepEngine` turns "evaluate K topologies x R injection rates" from a
per-topology recompile loop into a handful of batched compiled programs:

  1. specs are grouped by *bucketed* padded shape (dims rounded up to
     configurable multiples, batch size rounded up by replicating the
     last spec, rate rows rounded up by repeating the last rate), so
  2. adding one more topology or rate to a sweep usually re-runs the
     SAME executable (`repro.core.simulator.get_batch_runner` caches per
     padded shape; jit caches per batch shape), and
  3. padding invariance (see `repro.sweep.padding`) guarantees results
     are bitwise-equal to the single-spec `simulate` path.

Case-level evaluation moved to the declarative experiment API
(`repro.experiments`, DESIGN.md §10): describe a grid of `Scenario`s,
`plan` it, `execute` it, get a `ResultFrame`.  The old case-level entry
points here (`evaluate_cases`, `evaluate_workload_cases`) remain as
deprecation shims forwarding to that pipeline; `run_specs` /
`run_workloads` stay first-class — they are the primitive layer the
experiment executor lowers onto.
"""
from __future__ import annotations

import dataclasses
import warnings
from typing import NamedTuple, Sequence

import numpy as np

from repro.core import simulator as sim
from repro.core import topology as T
from repro.core import traffic as TR
from repro.core.routing import cached_routing
from repro.core.simulator import SimConfig, SimSpec
from repro.obs.metrics import cache_counters, metrics
from repro.obs.trace import trace

from .padding import PadShape


class SweepCase(NamedTuple):
    """One (topology, size, substrate, traffic) evaluation cell."""
    name: str
    n: int
    substrate: str = "organic"
    pattern: str = "uniform"
    area: float = 74.0
    roles: str = "homogeneous"

    def build(self) -> tuple:
        """(routing, traffic matrix) for this cell, via the shared cache."""
        topo, routing = cached_routing(self.name, self.n, self.substrate,
                                       self.area, self.roles)
        return routing, TR.PATTERNS[self.pattern](topo)

    @property
    def valid(self) -> bool:
        return T.valid_n(self.name, self.n)


def _round_up(x: int, m: int) -> int:
    return -(-x // m) * m if m > 1 else x


@dataclasses.dataclass
class SweepEngine:
    """Padded-batch sweep runner with a compiled-executable cache.

    bucket=False disables shape rounding (every distinct max-shape gets
    its own executable); the default buckets favour executable reuse when
    topologies are added incrementally.
    """
    cfg: SimConfig = SimConfig()
    bucket: bool = True
    s_round: int = 4         # batch axis rounded up to a multiple of this
    r_round: int = 4         # rate axis rounded up to a multiple of this
    n_mult: int = 8          # node-dim bucket
    c_mult: int = 32         # channel-dim bucket
    d_mult: int = 4          # link-ring bucket
    k_round: int = 2         # phase axis (workload mode) bucket

    def __post_init__(self):
        self.stats = dict(runs=0, groups=0, specs=0, compiles=0, reuses=0)

    # ---- shape policy --------------------------------------------------
    def bucket_shape(self, shape: PadShape) -> PadShape:
        if not self.bucket:
            return shape
        return PadShape(n=_round_up(shape.n, self.n_mult),
                        p=shape.p,
                        c=_round_up(shape.c, self.c_mult),
                        d=_round_up(shape.d, self.d_mult))

    # ---- core entry points ---------------------------------------------
    def run_specs(self, specs: Sequence[SimSpec], rates,
                  single_program: bool = False,
                  cfg: SimConfig | None = None) -> list[dict]:
        """Run heterogeneous specs through few batched programs.

        rates: [R] shared or [S, R] per-spec.  Returns one result dict
        per spec (same keys as `simulator.run_batch`), in input order.
        single_program=True pads every spec to one global shape so the
        whole sweep is exactly one compiled program (at the cost of
        padding small-radix topologies to the largest radix present).
        `cfg` overrides the engine's SimConfig for this call only (the
        experiment executor uses it for per-scenario routing modes,
        DESIGN.md §15); the runner cache keys on the config, so
        overrides coexist with the engine default.
        """
        return self._run_grouped(specs, rates, None, single_program, cfg)

    def run_workloads(self, specs: Sequence[SimSpec], schedules, rates,
                      single_program: bool = False,
                      cfg: SimConfig | None = None) -> list[dict]:
        """Run (spec, phase-schedule) pairs through few batched programs.

        schedules: one `simulator.SchedSpec` (or compilable
        `workloads.Schedule`) per spec.  Groups also bucket the phase
        axis (`k_round`) so workloads with similar phase counts share
        executables.  Result dicts gain the per-phase counters of
        `run_batch(..., schedules=...)`.  `cfg` as in `run_specs`.
        """
        if len(schedules) != len(specs):
            raise ValueError(
                f"schedules {len(schedules)} != specs {len(specs)}")
        schedules = [s.compile() if hasattr(s, "compile") else s
                     for s in schedules]
        return self._run_grouped(specs, rates, schedules, single_program,
                                 cfg)

    # keys whose leading axis is NOT the rate grid (never trimmed)
    # result keys whose leading axis is NOT the rate axis — never
    # sliced back to n_rates when rate-padding is trimmed
    _PER_PHASE_KEYS = ("phase_cycles", "window_cycles")

    def _run_grouped(self, specs, rates, schedules, single_program,
                     cfg: SimConfig | None = None):
        cfg = cfg or self.cfg
        s = len(specs)
        rates = np.asarray(rates, np.float32)
        if rates.ndim == 1:
            rates = np.broadcast_to(rates, (s, rates.shape[0])).copy()
        n_rates = rates.shape[1]
        r_pad = _round_up(n_rates, self.r_round) if self.bucket else n_rates

        def k_bucket(i: int) -> int:
            if schedules is None:
                return 0
            k = schedules[i].k
            return _round_up(k, self.k_round) if self.bucket else k

        groups: dict[tuple[PadShape, int], list[int]] = {}
        if single_program:
            key = (self.bucket_shape(PadShape.of(specs)),
                   max(k_bucket(i) for i in range(s)))
            groups[key] = list(range(s))
        else:
            for i, spec in enumerate(specs):
                key = (self.bucket_shape(
                    PadShape(n=spec.n, p=spec.p, c=spec.c, d=spec.d)),
                    k_bucket(i))
                groups.setdefault(key, []).append(i)

        # compile accounting via the metrics registry's monotonic cache
        # counters (DESIGN.md §13): a runner-cache *miss* delta counts
        # new compiled programs exactly.  The old before/after subtraction
        # of sum(entries.values()) shrank when the LRU evicted a runner
        # between the two reads and misattributed compiles.
        before = cache_counters()["cache.runner.misses"]
        results: list = [None] * s
        for (shape, k_pad), idxs in groups.items():
            g_specs = [specs[i] for i in idxs]
            g_scheds = [schedules[i] for i in idxs] \
                if schedules is not None else None
            g_rates = rates[idxs]
            if r_pad > n_rates:
                g_rates = np.concatenate(
                    [g_rates,
                     np.repeat(g_rates[:, -1:], r_pad - n_rates, axis=1)],
                    axis=1)
            s_live = len(g_specs)
            s_pad = _round_up(s_live, self.s_round) \
                if self.bucket else s_live
            while len(g_specs) < s_pad:           # replicate an inert tail
                g_specs.append(g_specs[-1])
                g_rates = np.concatenate([g_rates, g_rates[-1:]], axis=0)
                if g_scheds is not None:
                    g_scheds.append(g_scheds[-1])
            # bucket-fill attrs (DESIGN.md §16): live vs padded batch
            # rows/rates — with the per-spec pad_fill fractions on the
            # results, the complete pad-waste picture for this dispatch
            with trace("sweep.group", cat="sweep", specs=len(g_specs),
                       shape=str(shape), k_pad=k_pad,
                       s_live=s_live, s_pad=s_pad,
                       r_live=n_rates, r_pad=g_rates.shape[1],
                       kind="static" if g_scheds is None else "workload"):
                out = sim.run_batch(g_specs, g_rates, cfg,
                                    pad_shape=shape, schedules=g_scheds,
                                    k_pad=k_pad or None)
            metrics.observe("sweep.bucket_fill", s_live / s_pad)
            for j, i in enumerate(idxs):
                results[i] = {
                    k: (v[:n_rates] if isinstance(v, np.ndarray)
                        and k not in self._PER_PHASE_KEYS else v)
                    for k, v in out[j].items()}
        compiled = cache_counters()["cache.runner.misses"] - before
        self.stats["runs"] += 1
        self.stats["groups"] += len(groups)
        self.stats["specs"] += s
        self.stats["compiles"] += compiled
        self.stats["reuses"] += max(len(groups) - compiled, 0)
        metrics.inc("sweep.runs")
        metrics.inc("sweep.groups", len(groups))
        metrics.inc("sweep.specs", s)
        metrics.inc("sweep.compiles", compiled)
        return results

    # ---- case-level deprecation shims ----------------------------------
    # Case-level evaluation was redesigned into the declarative
    # experiment API (repro.experiments, DESIGN.md §10).  These shims
    # forward to it and reshape the ResultFrame into the legacy
    # list-of-dicts; they will be removed once nothing imports them.

    def _experiment_frame(self, scenarios):
        from repro import experiments as X
        exp = X.Experiment(scenarios, cfg=self.cfg, name="legacy_shim")
        return X.execute(X.plan(exp, engine=self), engine=self)

    def evaluate_cases(self, cases: Sequence[SweepCase],
                       n_rates: int = 6) -> list[dict | None]:
        """DEPRECATED: use `repro.experiments.run` on an `Experiment` of
        static `Scenario`s (see README migration table).

        Simulated saturation for many cells; invalid cells yield None.
        """
        warnings.warn(
            "SweepEngine.evaluate_cases is deprecated; build an "
            "Experiment of Scenarios and call repro.experiments.run",
            DeprecationWarning, stacklevel=2)
        from repro import experiments as X
        frame = self._experiment_frame(
            [X.scenario_from_case(c, rates=X.SaturationGrid(n_rates))
             for c in cases])
        out = []
        for i, case in enumerate(cases):
            res = frame.case_result(i)
            if res is not None:
                res["case"] = case
            out.append(res)
        return out

    def evaluate_workload_cases(self, cases: Sequence[SweepCase],
                                workloads: Sequence, n_rates: int = 5,
                                fit: bool = True) -> list[dict | None]:
        """DEPRECATED: use `repro.experiments.run` on an `Experiment`
        whose Scenarios carry the workloads as their `traffic` (see
        README migration table).

        Returns len(cases) * len(workloads) rows in case-major order;
        invalid cases yield None rows.
        """
        warnings.warn(
            "SweepEngine.evaluate_workload_cases is deprecated; build "
            "an Experiment of workload Scenarios and call "
            "repro.experiments.run", DeprecationWarning, stacklevel=2)
        from repro import experiments as X
        frame = self._experiment_frame(
            [dataclasses.replace(
                X.scenario_from_case(case, traffic=wl,
                                     rates=X.SaturationGrid(n_rates)),
                fit_schedule=fit)
             for case in cases for wl in workloads])
        out = []
        for ci, case in enumerate(cases):
            for wi in range(len(workloads)):
                res = frame.workload_result(ci * len(workloads) + wi)
                if res is not None:
                    res["case"] = case
                out.append(res)
        return out

    def sweep(self, names: Sequence[str], n: int, substrate: str = "organic",
              pattern: str = "uniform", area: float = 74.0,
              roles: str = "homogeneous", n_rates: int = 6) -> list[dict]:
        """Evaluate several topologies at one size in one batched sweep
        (a thin convenience over `repro.experiments.run`)."""
        from repro import experiments as X
        frame = self._experiment_frame(
            [X.Scenario(name, n, substrate, pattern, area, roles,
                        rates=X.SaturationGrid(n_rates))
             for name in names])
        rows = []
        for i, name in enumerate(names):
            res = frame.case_result(i)
            if res is None:
                continue
            rows.append(dict(topology=name, n=n, substrate=substrate,
                             pattern=pattern,
                             sim_saturation=res["sim_saturation"],
                             analytic_saturation=res["analytic_saturation"],
                             latency_at_sat=res["latency_at_sat"]))
        return rows


def default_engine() -> SweepEngine:
    """Process-wide engine for the default SimConfig.  Forwards to the
    experiment executor's per-config registry so legacy callers and the
    declarative pipeline share one engine (and its stats)."""
    from repro.experiments import engine_for
    return engine_for(SimConfig())
