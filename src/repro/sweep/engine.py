"""Batched multi-topology sweep engine (DESIGN.md §6).

`SweepEngine` turns "evaluate K topologies x R injection rates" from a
per-topology recompile loop into a handful of batched compiled programs:

  1. specs are grouped by *bucketed* padded shape (dims rounded up to
     configurable multiples, batch size rounded up by replicating the
     last spec, rate rows rounded up by repeating the last rate), so
  2. adding one more topology or rate to a sweep usually re-runs the
     SAME executable (`repro.core.simulator.get_batch_runner` caches per
     padded shape; jit caches per batch shape), and
  3. padding invariance (see `repro.sweep.padding`) guarantees results
     are bitwise-equal to the single-spec `simulate` path.

The engine also offers case-level evaluation (`evaluate_cases`) used by
`benchmarks/`: it builds routing + traffic per (topology, N, substrate,
pattern) cell, seeds a per-cell rate grid from the analytic channel-load
bound, and reports simulated saturation like
`simulator.saturation_throughput` — but for all cells at once.
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple, Sequence

import numpy as np

from repro.core import simulator as sim
from repro.core import topology as T
from repro.core import traffic as TR
from repro.core.routing import cached_routing
from repro.core.simulator import SimConfig, SimSpec, make_spec

from .padding import PadShape


class SweepCase(NamedTuple):
    """One (topology, size, substrate, traffic) evaluation cell."""
    name: str
    n: int
    substrate: str = "organic"
    pattern: str = "uniform"
    area: float = 74.0
    roles: str = "homogeneous"

    def build(self) -> tuple:
        """(routing, traffic matrix) for this cell, via the shared cache."""
        topo, routing = cached_routing(self.name, self.n, self.substrate,
                                       self.area, self.roles)
        return routing, TR.PATTERNS[self.pattern](topo)

    @property
    def valid(self) -> bool:
        return not (self.name in T.N_CONSTRAINTS
                    and not T.N_CONSTRAINTS[self.name](self.n))


def _round_up(x: int, m: int) -> int:
    return -(-x // m) * m if m > 1 else x


@dataclasses.dataclass
class SweepEngine:
    """Padded-batch sweep runner with a compiled-executable cache.

    bucket=False disables shape rounding (every distinct max-shape gets
    its own executable); the default buckets favour executable reuse when
    topologies are added incrementally.
    """
    cfg: SimConfig = SimConfig()
    bucket: bool = True
    s_round: int = 4         # batch axis rounded up to a multiple of this
    r_round: int = 4         # rate axis rounded up to a multiple of this
    n_mult: int = 8          # node-dim bucket
    c_mult: int = 32         # channel-dim bucket
    d_mult: int = 4          # link-ring bucket
    k_round: int = 2         # phase axis (workload mode) bucket

    def __post_init__(self):
        self.stats = dict(runs=0, groups=0, specs=0, compiles=0, reuses=0)

    # ---- shape policy --------------------------------------------------
    def bucket_shape(self, shape: PadShape) -> PadShape:
        if not self.bucket:
            return shape
        return PadShape(n=_round_up(shape.n, self.n_mult),
                        p=shape.p,
                        c=_round_up(shape.c, self.c_mult),
                        d=_round_up(shape.d, self.d_mult))

    # ---- core entry points ---------------------------------------------
    def run_specs(self, specs: Sequence[SimSpec], rates,
                  single_program: bool = False) -> list[dict]:
        """Run heterogeneous specs through few batched programs.

        rates: [R] shared or [S, R] per-spec.  Returns one result dict
        per spec (same keys as `simulator.run_batch`), in input order.
        single_program=True pads every spec to one global shape so the
        whole sweep is exactly one compiled program (at the cost of
        padding small-radix topologies to the largest radix present).
        """
        return self._run_grouped(specs, rates, None, single_program)

    def run_workloads(self, specs: Sequence[SimSpec], schedules, rates,
                      single_program: bool = False) -> list[dict]:
        """Run (spec, phase-schedule) pairs through few batched programs.

        schedules: one `simulator.SchedSpec` (or compilable
        `workloads.Schedule`) per spec.  Groups also bucket the phase
        axis (`k_round`) so workloads with similar phase counts share
        executables.  Result dicts gain the per-phase counters of
        `run_batch(..., schedules=...)`.
        """
        if len(schedules) != len(specs):
            raise ValueError(
                f"schedules {len(schedules)} != specs {len(specs)}")
        schedules = [s.compile() if hasattr(s, "compile") else s
                     for s in schedules]
        return self._run_grouped(specs, rates, schedules, single_program)

    # keys whose leading axis is NOT the rate grid (never trimmed)
    _PER_PHASE_KEYS = ("phase_cycles",)

    def _run_grouped(self, specs, rates, schedules, single_program):
        s = len(specs)
        rates = np.asarray(rates, np.float32)
        if rates.ndim == 1:
            rates = np.broadcast_to(rates, (s, rates.shape[0])).copy()
        n_rates = rates.shape[1]
        r_pad = _round_up(n_rates, self.r_round) if self.bucket else n_rates

        def k_bucket(i: int) -> int:
            if schedules is None:
                return 0
            k = schedules[i].k
            return _round_up(k, self.k_round) if self.bucket else k

        groups: dict[tuple[PadShape, int], list[int]] = {}
        if single_program:
            key = (self.bucket_shape(PadShape.of(specs)),
                   max(k_bucket(i) for i in range(s)))
            groups[key] = list(range(s))
        else:
            for i, spec in enumerate(specs):
                key = (self.bucket_shape(
                    PadShape(n=spec.n, p=spec.p, c=spec.c, d=spec.d)),
                    k_bucket(i))
                groups.setdefault(key, []).append(i)

        before = sum(sim.runner_cache_info().values())
        results: list = [None] * s
        for (shape, k_pad), idxs in groups.items():
            g_specs = [specs[i] for i in idxs]
            g_scheds = [schedules[i] for i in idxs] \
                if schedules is not None else None
            g_rates = rates[idxs]
            if r_pad > n_rates:
                g_rates = np.concatenate(
                    [g_rates,
                     np.repeat(g_rates[:, -1:], r_pad - n_rates, axis=1)],
                    axis=1)
            s_pad = _round_up(len(g_specs), self.s_round) \
                if self.bucket else len(g_specs)
            while len(g_specs) < s_pad:           # replicate an inert tail
                g_specs.append(g_specs[-1])
                g_rates = np.concatenate([g_rates, g_rates[-1:]], axis=0)
                if g_scheds is not None:
                    g_scheds.append(g_scheds[-1])
            out = sim.run_batch(g_specs, g_rates, self.cfg,
                                pad_shape=shape, schedules=g_scheds,
                                k_pad=k_pad or None)
            for j, i in enumerate(idxs):
                results[i] = {
                    k: (v[:n_rates] if isinstance(v, np.ndarray)
                        and k not in self._PER_PHASE_KEYS else v)
                    for k, v in out[j].items()}
        after = sum(sim.runner_cache_info().values())
        self.stats["runs"] += 1
        self.stats["groups"] += len(groups)
        self.stats["specs"] += s
        self.stats["compiles"] += after - before
        self.stats["reuses"] += max(len(groups) - (after - before), 0)
        return results

    # ---- case-level convenience ----------------------------------------
    def evaluate_cases(self, cases: Sequence[SweepCase],
                       n_rates: int = 6) -> list[dict | None]:
        """Simulated saturation for many cells in few batched programs.

        Per cell: rate grid seeded by the analytic channel-load bound,
        then `sim_saturation` = max delivered throughput over the grid
        (exactly what `saturation_throughput` reports per spec).
        Invalid cells (N-constraint) yield None.
        """
        live = [(i, c) for i, c in enumerate(cases) if c.valid]
        specs, rate_rows, analytic = [], [], []
        for _, case in live:
            routing, tm = case.build()
            a = routing.saturation_rate(tm)
            specs.append(make_spec(routing, tm))
            rate_rows.append(sim.saturation_rate_grid(a, n_rates))
            analytic.append(a)
        out: list = [None] * len(cases)
        if not specs:
            return out
        results = self.run_specs(specs, np.stack(rate_rows))
        for (i, case), res, a in zip(live, results, analytic):
            k = int(np.argmax(res["throughput"]))
            out[i] = dict(case=case,
                          sim_saturation=float(res["throughput"][k]),
                          analytic_saturation=float(a),
                          latency_at_sat=float(res["latency"][k]),
                          sweep=res)
        return out

    def evaluate_workload_cases(self, cases: Sequence[SweepCase],
                                workloads: Sequence, n_rates: int = 5,
                                fit: bool = True) -> list[dict | None]:
        """Cross topologies x workloads in few batched programs.

        workloads: `repro.workloads.Workload`s (or any callable
        `topo -> Schedule`).  Returns len(cases) * len(workloads) rows in
        case-major order; invalid cases yield None rows.  Per row:
        saturation over the rate grid (seeded from the workload's mean
        traffic) plus the per-phase breakdown at the saturating rate.

        fit=True (default) rescales each schedule so one full replay
        covers exactly the measurement window (cycles - warmup) — every
        phase is measured for exactly its share of the window.
        """
        grid: list = [None] * (len(cases) * len(workloads))
        specs, scheds, rate_rows, live = [], [], [], []
        meas = self.cfg.cycles - self.cfg.warmup
        for ci, case in enumerate(cases):
            if not case.valid:
                continue
            topo, routing = cached_routing(case.name, case.n,
                                           case.substrate, case.area,
                                           case.roles)
            for wi, wl in enumerate(workloads):
                schedule = wl.build(topo) if hasattr(wl, "build") \
                    else wl(topo)
                if fit:
                    schedule = schedule.fit(meas)
                mean = schedule.mean_traffic()
                analytic = routing.saturation_rate(mean)
                specs.append(make_spec(routing, mean))
                scheds.append(schedule)
                rate_rows.append(sim.saturation_rate_grid(analytic,
                                                          n_rates))
                live.append((ci * len(workloads) + wi, case, schedule,
                             analytic))
        if not specs:
            return grid
        results = self.run_workloads(specs, scheds, np.stack(rate_rows))
        for (slot, case, schedule, analytic), res in zip(live, results):
            k = int(np.argmax(res["throughput"]))
            grid[slot] = dict(
                case=case, workload=schedule.name,
                sim_saturation=float(res["throughput"][k]),
                analytic_saturation=float(analytic),
                latency_at_sat=float(res["latency"][k]),
                phase_labels=[p.label or str(i) for i, p in
                              enumerate(schedule.phases)],
                throughput_ph=res["throughput_ph"][k],
                latency_ph=res["latency_ph"][k],
                offered_rate_ph=res["offered_rate_ph"][k],
                phase_cycles=res["phase_cycles"], sweep=res)
        return grid

    def sweep(self, names: Sequence[str], n: int, substrate: str = "organic",
              pattern: str = "uniform", area: float = 74.0,
              roles: str = "homogeneous", n_rates: int = 6) -> list[dict]:
        """Evaluate several topologies at one size in one batched sweep."""
        cases = [SweepCase(name, n, substrate, pattern, area, roles)
                 for name in names]
        rows = []
        for case, res in zip(cases, self.evaluate_cases(cases, n_rates)):
            if res is None:
                continue
            rows.append(dict(topology=case.name, n=case.n,
                             substrate=case.substrate, pattern=case.pattern,
                             sim_saturation=res["sim_saturation"],
                             analytic_saturation=res["analytic_saturation"],
                             latency_at_sat=res["latency_at_sat"]))
        return rows


_DEFAULT: SweepEngine | None = None


def default_engine() -> SweepEngine:
    """Process-wide engine so benchmarks share one executable cache."""
    global _DEFAULT
    if _DEFAULT is None:
        _DEFAULT = SweepEngine()
    return _DEFAULT
