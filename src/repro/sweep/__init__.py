"""Batched multi-topology sweep engine (DESIGN.md §6).

Pads heterogeneous `SimSpec`s to a common shape and runs many
topologies x injection rates through one jitted program, with a
compiled-executable cache keyed on the padded shape so adding a topology
to a sweep reuses the existing executable.

    from repro.sweep import SweepEngine
    eng = SweepEngine()
    rows = eng.sweep(["mesh", "hexamesh", "folded_hexa_torus"], n=16)

Workload mode (DESIGN.md §9) batches (topology, phase-schedule) pairs
the same way: `eng.run_workloads(specs, schedules, rates)`.

Case-level evaluation (grids of topologies x traffic x rates) moved to
the declarative experiment API — `repro.experiments` (DESIGN.md §10);
`evaluate_cases` / `evaluate_workload_cases` remain as deprecation
shims forwarding there.
"""
from .engine import SweepCase, SweepEngine, default_engine
from .padding import (BatchSpec, PadShape, SchedBatch, pad_schedule,
                      pad_spec, stack_schedules, stack_specs)

__all__ = ["SweepCase", "SweepEngine", "default_engine", "BatchSpec",
           "PadShape", "pad_spec", "stack_specs", "SchedBatch",
           "pad_schedule", "stack_schedules"]
