"""`python -m repro.analysis` — the static-verification CLI / CI gate.

    # certify every Table III topology (both substrates) at N=36:
    python -m repro.analysis --all-builtin

    # one topology, fault-degraded variants up to k=2, with JAX checks:
    python -m repro.analysis folded_hexa_torus --fault-kmax 2 --jax

    # machine-readable export for the CI artifact:
    python -m repro.analysis --all-builtin -o results/diagnostics.json

Exit status is `Report.gate(fail_on)`: 0 when clean, 1 when any
diagnostic at or above --fail-on severity exists (default: error).
Design-principle findings are warnings — Table III deliberately
violates them — so `--all-builtin` passes unless routing certification
or a JX contract actually breaks.
"""
from __future__ import annotations

import argparse
import sys

from . import ERROR, WARNING, analyze, builtin_names
from .engine import DEFAULT_N
from .principles import FeasibilityCriteria


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="static verification: routing certification, "
                    "design-principle lint, JAX hazard analysis")
    ap.add_argument("names", nargs="*",
                    help="topology generator names (builtin or "
                         "registered)")
    ap.add_argument("--all-builtin", action="store_true",
                    help="analyze every Table III + registered generator")
    ap.add_argument("-n", type=int, default=DEFAULT_N,
                    help=f"chiplet count (default {DEFAULT_N}; "
                         "constrained generators run at the nearest "
                         "supported N)")
    ap.add_argument("--substrate", action="append", default=None,
                    choices=["organic", "glass"],
                    help="substrate(s) to analyze (default: both)")
    ap.add_argument("--fault-kmax", type=int, default=0,
                    help="also certify fault-degraded variants up to "
                         "this many faults (default 0: pristine only)")
    ap.add_argument("--fault-kind", action="append", default=None,
                    help="fault sampler kind(s) (default: random)")
    ap.add_argument("--seed", type=int, action="append", default=None,
                    help="fault sampler seed(s) (default: 0)")
    ap.add_argument("--jax", action="store_true",
                    help="trace the batched simulator and run the JX "
                         "hazard checks (imports jax)")
    ap.add_argument("--max-radix", type=int, default=None,
                    help="override the Principle-3 radix budget")
    ap.add_argument("--min-rate-fraction", type=float, default=None,
                    help="override the substrate rate floor")
    ap.add_argument("-o", "--output", default=None, metavar="PATH",
                    help="write the JSON diagnostics artifact here")
    ap.add_argument("--fail-on", default=ERROR,
                    choices=[ERROR, WARNING],
                    help="exit nonzero when a diagnostic at/above this "
                         "severity exists (default: error)")
    ap.add_argument("-q", "--quiet", action="store_true",
                    help="print only the summary line")
    args = ap.parse_args(argv)

    names = list(args.names)
    if args.all_builtin:
        names += [x for x in builtin_names() if x not in names]
    if not names:
        ap.error("give topology names or --all-builtin")

    crit_kw = {}
    if args.max_radix is not None:
        crit_kw["max_radix"] = args.max_radix
    if args.min_rate_fraction is not None:
        crit_kw["min_rate_fraction"] = args.min_rate_fraction

    rep = analyze(
        names=names, n=args.n,
        substrates=tuple(args.substrate or ("organic", "glass")),
        crit=FeasibilityCriteria(**crit_kw) if crit_kw else None,
        fault_kmax=args.fault_kmax,
        fault_kinds=tuple(args.fault_kind or ("random",)),
        fault_seeds=tuple(args.seed if args.seed is not None else (0,)),
        jax_hazards=args.jax)

    if not args.quiet:
        for d in rep:
            print(d)
    print(rep.summary())
    if args.output:
        rep.to_json(args.output, n=args.n, names=names,
                    fault_kmax=args.fault_kmax)
    return rep.gate(args.fail_on)


if __name__ == "__main__":
    sys.exit(main())
