"""Exhaustive routing verification (DESIGN.md §14).

The paper's deadlock-freedom argument (up*/down* turn prohibition makes
the channel-dependency graph acyclic) was previously spot-checked: a
bool-only `dependency_graph_is_acyclic` sampled by tests.  This module
*certifies* each shipped routing artifact exhaustively and produces a
witness for every violation:

  * **RT001 cdg-cycle** — the *used* channel-dependency graph (an edge
    c1 -> c2 whenever some destination's table entry can chain channel
    c1 into channel c2) must be acyclic.  The witness is the actual
    cycle as a channel list with (src -> dst) node decoding.
  * **RT002 unreachable-pair / RT004 routing-loop** — following the
    table from every (src, dst) pair that the topology connects must
    deliver within a hop bound.  Exhaustive over all N^2 pairs — not
    weighted by a traffic matrix, so zero-traffic pairs are checked
    too (the analytic path-follower skips them).  On fault-degraded
    topologies, pairs involving isolated (dead) chiplets are exempt by
    construction: reachability is required exactly within connected
    components of the surviving structure.
  * **RT003 undeclared-channel** — every non-negative table entry must
    name an output port that carries a declared channel (`out_ch >= 0`
    and within the node's real port count).
  * **RT005 escape-unsafe** — Duato escape condition for the
    minimal-adaptive mode (DESIGN.md §15): every adaptive choice in the
    productive-ports mask must (a) be strictly minimal, (b) name a
    declared channel, and (c) leave the flit in a state — (next node,
    arrival in-port) — from which the escape table (VC 0, the static
    up*/down* table) still delivers to the destination; and the CDG
    restricted to the escape class must stay acyclic.  Witnesses are
    the concrete (dst, node, port) choice that breaks, or the escape-
    class cycle.

`certify_routing` bundles the checks into a `RoutingCertificate`
that `routing.routing_for(topo, certify=True)` caches alongside the
routing, so a structure is certified at most once per process.
"""
from __future__ import annotations

import dataclasses

import numpy as np
import scipy.sparse as sp
import scipy.sparse.csgraph as csgraph

from .diagnostics import Diagnostic, Report, diag


@dataclasses.dataclass(frozen=True)
class RoutingCertificate:
    """Outcome of exhaustive verification of one routing artifact."""
    target: str                 # "name/nN/substrate" label
    acyclic: bool               # CDG is a DAG
    complete: bool              # every connected pair delivered
    declared: bool              # every table entry names a real channel
    n_channels: int
    n_dep_edges: int            # used channel-dependency edges
    n_pairs_checked: int
    max_hops_seen: int
    escape_safe: bool = True    # RT005: adaptive choices keep an escape
    n_adaptive_choices: int = 0  # productive-ports entries verified
    diagnostics: tuple = ()     # the violations (empty == certified)

    @property
    def ok(self) -> bool:
        return self.acyclic and self.complete and self.declared \
            and self.escape_safe


def _target(r) -> str:
    t = r.topo
    return f"{t.name}/n{t.n}/{t.substrate}"


def dependency_edges(r) -> np.ndarray:
    """[M, 2] used channel-dependency edges, derived from the table.

    An edge (c1, c2) means: a packet that arrived over channel c1 can,
    for some destination, be forwarded onto channel c2.  Vectorized
    over (destination, channel) — exhaustive, unlike sampling paths.
    """
    n, C, P = r.topo.n, r.n_channels, r.max_ports
    if C == 0:
        return np.zeros((0, 2), dtype=np.int64)
    d_idx, c_idx = np.meshgrid(np.arange(n), np.arange(C), indexing="ij")
    d_idx, c_idx = d_idx.ravel(), c_idx.ravel()
    v = r.ch_dst[c_idx]                          # node the flit sits at
    p = r.table[d_idx, v, r.ch_in_port[c_idx]].astype(np.int64)
    fwd = p >= 0                                 # not EJECT/-1
    c2 = r.out_ch[v[fwd], np.clip(p[fwd], 0, P - 1)]
    ok = c2 >= 0
    pairs = np.stack([c_idx[fwd][ok], c2[ok]], axis=1)
    return np.unique(pairs, axis=0)


def find_cdg_cycle(edges: np.ndarray, n_channels: int) -> list[int]:
    """A concrete cycle in the dependency graph, or [] if acyclic.

    Iterative DFS with colouring (no recursion limit, no networkx
    dependency on the hot path); returns the cycle as an ordered
    channel list [c0, c1, ..., ck] with an implied edge ck -> c0.
    """
    if len(edges) == 0:
        return []
    order = np.lexsort((edges[:, 1], edges[:, 0]))
    e = edges[order]
    starts = np.searchsorted(e[:, 0], np.arange(n_channels + 1))
    colour = np.zeros(n_channels, dtype=np.int8)   # 0 new 1 open 2 done
    for root in range(n_channels):
        if colour[root]:
            continue
        stack = [(root, int(starts[root]))]
        colour[root] = 1
        path = [root]
        while stack:
            u, i = stack[-1]
            if i >= starts[u + 1]:
                stack.pop()
                path.pop()
                colour[u] = 2
                continue
            stack[-1] = (u, i + 1)
            w = int(e[i, 1])
            if colour[w] == 1:                      # back edge: cycle
                return path[path.index(w):]
            if colour[w] == 0:
                colour[w] = 1
                stack.append((w, int(starts[w])))
                path.append(w)
    return []


def _decode_cycle(r, cycle: list[int]) -> list[tuple]:
    """Channel ids -> (channel, src_node, dst_node) triples."""
    return [(int(c), int(r.ch_src[c]), int(r.ch_dst[c])) for c in cycle]


def check_acyclic(r) -> list[Diagnostic]:
    """RT001 with the actual cycle as witness (empty list == acyclic)."""
    edges = dependency_edges(r)
    cycle = find_cdg_cycle(edges, r.n_channels)
    if not cycle:
        return []
    hops = " -> ".join(f"{s}->{d}" for _, s, d in _decode_cycle(r, cycle))
    return [diag(
        "RT001",
        f"channel-dependency cycle of length {len(cycle)}: {hops} "
        f"(deadlock possible)",
        target=_target(r), cycle=[int(c) for c in cycle],
        cycle_nodes=_decode_cycle(r, cycle), n_dep_edges=len(edges))]


def check_table_channels(r) -> list[Diagnostic]:
    """RT003: every table entry must name a declared output channel."""
    n, P = r.topo.n, r.max_ports
    p = r.table.astype(np.int64)                    # [dst, node, in_port]
    node = np.arange(n)[None, :, None]
    used = p >= 0
    out_of_range = used & (p > P - 1)
    undeclared = used & ~out_of_range & \
        (r.out_ch[node, np.clip(p, 0, P - 1)] < 0)
    bad = out_of_range | undeclared
    if not bad.any():
        return []
    d_, u_, ip_ = np.argwhere(bad)[0]
    return [diag(
        "RT003",
        f"table[dst={d_}, node={u_}, in_port={ip_}] = port "
        f"{int(p[d_, u_, ip_])} has no declared channel at node {u_} "
        f"(out_ch == -1)",
        target=_target(r), n_bad=int(bad.sum()),
        entry=(int(d_), int(u_), int(ip_)),
        port=int(p[d_, u_, ip_]))]


def _required_pairs(r) -> np.ndarray:
    """[N, N] bool: pairs the surviving structure connects (s != d).

    Dead chiplets on fault-degraded topologies have no live links and
    sit in singleton components — no pair involving them is required.
    """
    t = r.topo
    e = np.asarray(t.edges)
    if len(e) == 0:
        return np.zeros((t.n, t.n), dtype=bool)
    data = np.ones(len(e) * 2)
    ij = np.concatenate([e, e[:, ::-1]])
    adj = sp.csr_matrix((data, (ij[:, 0], ij[:, 1])), shape=(t.n, t.n))
    _, comp = csgraph.connected_components(adj)
    deg = np.asarray(adj.sum(axis=1)).ravel()
    live = deg > 0
    same = (comp[:, None] == comp[None, :]) & np.outer(live, live)
    np.fill_diagonal(same, False)
    return same


def check_reachability(r, max_hops: int | None = None
                       ) -> tuple[list[Diagnostic], int, int]:
    """RT002/RT004: follow the table for EVERY connected (s, d) pair.

    Returns (diagnostics, n_pairs_checked, max_hops_seen).  Unlike
    `Routing.paths_channel_loads` this ignores traffic weights (zero-
    traffic pairs are verified too) and reports a witness instead of
    raising.
    """
    t = r.topo
    n, P = t.n, r.max_ports
    req = _required_pairs(r)
    s_idx, d_idx = np.nonzero(req)
    n_pairs = len(s_idx)
    if n_pairs == 0:
        return [], 0, 0
    if max_hops is None:
        max_hops = 4 * n
    cur = s_idx.astype(np.int64).copy()
    in_port = np.full(n_pairs, P, dtype=np.int64)   # injection column
    alive = np.ones(n_pairs, dtype=bool)
    hops = np.zeros(n_pairs, dtype=np.int64)
    out: list[Diagnostic] = []
    for _ in range(max_hops):
        if not alive.any():
            break
        p = r.table[d_idx[alive], cur[alive], in_port[alive]].astype(
            np.int64)
        dead = p == -1
        if dead.any():
            j = np.flatnonzero(alive)[np.argmax(dead)]
            out.append(diag(
                "RT002",
                f"no route for pair ({int(s_idx[j])} -> {int(d_idx[j])}):"
                f" table dead end at node {int(cur[j])}, in_port "
                f"{int(in_port[j])} after {int(hops[j])} hop(s)",
                target=_target(r),
                pair=(int(s_idx[j]), int(d_idx[j])),
                stuck_at=int(cur[j]), in_port=int(in_port[j]),
                n_dead_pairs=int(dead.sum())))
            keep = ~dead
            idx = np.flatnonzero(alive)
            alive[idx[dead]] = False
            if not keep.any():
                continue
            p = p[keep]
        ch = r.out_ch[cur[alive], np.clip(p, 0, P - 1)]
        step_ok = (p >= 0) & (ch >= 0)
        # undeclared channels already covered by RT003; drop those pairs
        idx = np.flatnonzero(alive)
        alive[idx[~step_ok]] = False
        if not step_ok.any():
            continue
        ch = ch[step_ok]
        idx = idx[step_ok]
        cur[idx] = r.ch_dst[ch]
        in_port[idx] = r.ch_in_port[ch]
        hops[idx] += 1
        arrived = cur[idx] == d_idx[idx]
        alive[idx[arrived]] = False
    if alive.any():
        j = int(np.flatnonzero(alive)[0])
        out.append(diag(
            "RT004",
            f"pair ({int(s_idx[j])} -> {int(d_idx[j])}) still in flight "
            f"after {max_hops} hops (livelock); currently at node "
            f"{int(cur[j])}",
            target=_target(r), pair=(int(s_idx[j]), int(d_idx[j])),
            at_node=int(cur[j]), n_looping=int(alive.sum()),
            hop_bound=max_hops))
    return out, n_pairs, int(hops.max()) if n_pairs else 0


def check_escape(r, max_hops: int | None = None
                 ) -> tuple[list[Diagnostic], int]:
    """RT005: Duato escape condition for minimal-adaptive routing.

    Verifies, exhaustively over every entry of the productive-ports
    mask (`routing.productive_ports`, DESIGN.md §15):

      * **minimality** — the port's downstream node is strictly one hop
        closer to the destination (the adaptive class never lengthens a
        path, so hop-count livelock is impossible);
      * **declared channel** — the port carries a real channel;
      * **escape deliverability** — from the post-hop state (next node
        w, arrival in-port q), following the *escape* table (the static
        up*/down* class, VC 0) delivers to the destination within the
        hop bound.  This is the in-port-indexed state the simulator's
        escape fallback actually consults, so certifying it certifies
        the exact drain every buffered adaptive flit falls back to.

    Plus the escape-class CDG acyclicity: the escape class routes by
    the same static table, so its dependency graph is
    `dependency_edges(r)` — a cycle there breaks the Duato argument
    even if every individual choice can still reach an escape entry.

    Returns (diagnostics, n_adaptive_choices).
    """
    from repro.core.routing import productive_ports

    t = r.topo
    n, P = t.n, r.max_ports
    out: list[Diagnostic] = []
    prod = productive_ports(r)
    d_idx, u_idx, p_idx = np.nonzero(prod)
    n_choices = len(d_idx)
    if n_choices == 0:
        return out, 0

    # (a) minimality of every masked port
    hops = csgraph.shortest_path(t.adjacency(), unweighted=True)
    ch = r.out_ch[u_idx, p_idx].astype(np.int64)
    undeclared = ch < 0
    if undeclared.any():
        j = int(np.argmax(undeclared))
        out.append(diag(
            "RT005",
            f"productive port (dst={int(d_idx[j])}, node={int(u_idx[j])},"
            f" port={int(p_idx[j])}) has no declared channel",
            target=_target(r), n_bad=int(undeclared.sum()),
            choice=(int(d_idx[j]), int(u_idx[j]), int(p_idx[j]))))
    ok = ~undeclared
    w = np.where(ok, r.ch_dst[np.clip(ch, 0, max(r.n_channels - 1, 0))],
                 0)
    hw = hops[w, d_idx]
    hu = hops[u_idx, d_idx]
    non_min = ok & ~(np.isfinite(hw) & np.isfinite(hu) & (hw + 1 == hu))
    if non_min.any():
        j = int(np.argmax(non_min))
        out.append(diag(
            "RT005",
            f"productive port (dst={int(d_idx[j])}, node={int(u_idx[j])},"
            f" port={int(p_idx[j])}) is not minimal: next node "
            f"{int(w[j])} is {hw[j]:.0f} hop(s) from the destination, "
            f"node {int(u_idx[j])} is {hu[j]:.0f}",
            target=_target(r), n_bad=int(non_min.sum()),
            choice=(int(d_idx[j]), int(u_idx[j]), int(p_idx[j])),
            next_node=int(w[j])))
    ok &= ~non_min

    # (c) escape deliverability from every post-hop (w, q, dst) state
    if max_hops is None:
        max_hops = 4 * n
    live = ok & (w != d_idx)            # arrival at dst needs no escape
    idx0 = np.flatnonzero(live)
    cur = w[idx0].copy()
    q = r.ch_in_port[ch[idx0]].astype(np.int64)
    dst = d_idx[idx0]
    alive = np.ones(len(idx0), dtype=bool)
    for _ in range(max_hops):
        if not alive.any():
            break
        p = r.table[dst[alive], cur[alive], q[alive]].astype(np.int64)
        c2 = r.out_ch[cur[alive], np.clip(p, 0, P - 1)]
        step_ok = (p >= 0) & (c2 >= 0)
        idx = np.flatnonzero(alive)
        if (~step_ok).any():            # dead end: escape lost
            j = int(idx0[idx[np.argmax(~step_ok)]])
            out.append(diag(
                "RT005",
                f"adaptive choice (dst={int(d_idx[j])}, "
                f"node={int(u_idx[j])}, port={int(p_idx[j])}) loses its "
                f"escape: static table dead-ends at node "
                f"{int(cur[idx[np.argmax(~step_ok)]])} before reaching "
                f"the destination",
                target=_target(r),
                choice=(int(d_idx[j]), int(u_idx[j]), int(p_idx[j])),
                n_bad=int((~step_ok).sum())))
            alive[idx[~step_ok]] = False
            if not step_ok.any():
                continue
        idx = idx[step_ok]
        c2 = c2[step_ok]
        cur[idx] = r.ch_dst[c2]
        q[idx] = r.ch_in_port[c2]
        alive[idx[cur[idx] == dst[idx]]] = False
    if alive.any():
        j = int(idx0[np.flatnonzero(alive)[0]])
        out.append(diag(
            "RT005",
            f"adaptive choice (dst={int(d_idx[j])}, node={int(u_idx[j])},"
            f" port={int(p_idx[j])}): escape path still in flight after "
            f"{max_hops} hops (escape livelock)",
            target=_target(r),
            choice=(int(d_idx[j]), int(u_idx[j]), int(p_idx[j])),
            n_looping=int(alive.sum()), hop_bound=max_hops))

    # escape-class CDG acyclicity (same table => same dependency edges)
    edges = dependency_edges(r)
    cycle = find_cdg_cycle(edges, r.n_channels)
    if cycle:
        hop_s = " -> ".join(f"{s}->{d}"
                            for _, s, d in _decode_cycle(r, cycle))
        out.append(diag(
            "RT005",
            f"escape-class channel-dependency cycle of length "
            f"{len(cycle)}: {hop_s} (the escape drain can deadlock)",
            target=_target(r), cycle=[int(c) for c in cycle],
            cycle_nodes=_decode_cycle(r, cycle)))
    return out, n_choices


def certify_routing(r) -> RoutingCertificate:
    """Run all exhaustive checks and bundle the certificate."""
    cyc = check_acyclic(r)
    decl = check_table_channels(r)
    reach, n_pairs, max_hops = check_reachability(r)
    esc, n_choices = check_escape(r)
    edges = dependency_edges(r)
    return RoutingCertificate(
        target=_target(r),
        acyclic=not cyc,
        complete=not any(d.code in ("RT002", "RT004") for d in reach),
        declared=not decl,
        n_channels=r.n_channels, n_dep_edges=len(edges),
        n_pairs_checked=n_pairs, max_hops_seen=max_hops,
        escape_safe=not esc, n_adaptive_choices=n_choices,
        diagnostics=tuple(cyc + decl + reach + esc))


def verify_routing(r, report: Report | None = None) -> RoutingCertificate:
    """Certify `r`, appending its diagnostics to `report` if given."""
    cert = certify_routing(r)
    if report is not None:
        report.record("routing", cert.target)
        report.extend(cert.diagnostics)
    return cert
