"""Static verification layer (DESIGN.md §14).

    from repro.analysis import analyze
    rep = analyze(names=["folded_hexa_torus"], n=36, fault_kmax=2)
    assert rep.ok
    rep.to_json("results/diagnostics.json")

    # CLI / CI gate:
    #   python -m repro.analysis --all-builtin

Three analyzer families behind one front door, all speaking structured
`Diagnostic` records with stable codes (see `diagnostics.CODES`):

  * `routing_verify` — exhaustive deadlock/reachability certification
    of routing artifacts (RT codes; witness = the actual CDG cycle);
  * `principles` — the paper's design principles as shared lint (DP
    codes; the synth prefilter and planner skip logic are shims over
    this module, with byte-identical legacy messages);
  * `jaxpr_hazards` — static hazards of the batched JAX simulator (JX
    codes: int32 overflow bounds, sacrificial-slot padding contract,
    recompile storms, host syncs, dtype promotions).

`jaxpr_hazards` (and the jax-touching parts of the engine) import jax
lazily, so lint/certification work in jax-free contexts.
"""
from .diagnostics import (CODES, ERROR, INFO, WARNING, Diagnostic,
                          Report, diag)
from .engine import (DEFAULT_N, analyze, analyze_jax, analyze_topology,
                     builtin_names)
from .principles import (FeasibilityCriteria, check_n_constraint,
                         diagnose, lint_topology, max_feasible_link_mm)
from .routing_verify import (RoutingCertificate, certify_routing,
                             check_acyclic, check_reachability,
                             check_table_channels, dependency_edges,
                             find_cdg_cycle, verify_routing)

__all__ = [
    "CODES", "ERROR", "WARNING", "INFO", "Diagnostic", "Report", "diag",
    "analyze", "analyze_topology", "analyze_jax", "builtin_names",
    "DEFAULT_N",
    "FeasibilityCriteria", "diagnose", "lint_topology",
    "check_n_constraint", "max_feasible_link_mm",
    "RoutingCertificate", "certify_routing", "verify_routing",
    "check_acyclic", "check_reachability", "check_table_channels",
    "dependency_edges", "find_cdg_cycle",
]
