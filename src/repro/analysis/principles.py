"""Design-principle lint (DESIGN.md §14): Principles 1–3 as diagnostics.

This is the *canonical* home of the paper's feasibility constraints.
They used to live as bare strings split between `synth/feasibility.py`
(the search prefilter) and `experiments/plan.py` (the planner's
N-constraint skip logic); now one implementation produces structured
`Diagnostic`s with stable DP-family codes, and those two call sites are
shims over it.  Message strings are kept **byte-identical** to the
legacy ones — the synth rejection ledger and planner skip rows are
pinned by tests and downstream CSV diffs.

Severity is `warning`, not `error`: a DP violation marks an
*infeasible design*, not broken code.  Table III deliberately includes
topologies that violate the rate floor at scale (that is the paper's
argument for folding), so `--all-builtin` must certify them
deadlock-free (no RT errors) while still surfacing the DP lint.
"""
from __future__ import annotations

import dataclasses
import functools

import numpy as np

from repro.core import costmodel as cm
from repro.core import linkmodel as lm
from repro.core.topology import Topology, valid_n

from .diagnostics import Diagnostic, Report, diag


@dataclasses.dataclass(frozen=True)
class FeasibilityCriteria:
    """The paper's constraint knobs (defaults match the benchmark grid)."""
    max_link_range: int = 1          # Principle 2
    min_rate_fraction: float = 0.25  # substrate floor on the Fig.-2 curve
    max_radix: int | None = 8        # Principle 3: per-chiplet PHY budget
    min_data_wires: int = 1          # Principle 3: wires left per link
    max_wire_cost_mm: float | None = None

    def max_link_mm(self, substrate: str) -> float:
        return max_feasible_link_mm(substrate, self.min_rate_fraction)


@functools.lru_cache(maxsize=64)
def max_feasible_link_mm(substrate: str,
                         min_rate_fraction: float) -> float:
    """Longest link (mm) that still meets the rate floor on this
    substrate — the inverse of the monotone tail of the Fig.-2 curve,
    read off a fine grid (cached: `diagnose` calls this once per
    generated candidate)."""
    grid = np.linspace(0.0, lm.MAX_LINK_LENGTH_MM, 7001)
    ok = grid[lm.rate_fraction(grid, substrate) >= min_rate_fraction]
    return float(ok.max()) if len(ok) else 0.0


def _label(topo: Topology) -> str:
    return f"{topo.name}/n{topo.n}/{topo.substrate}"


def diagnose(topo: Topology,
             crit: FeasibilityCriteria = FeasibilityCriteria()
             ) -> list[Diagnostic]:
    """DP001–DP005 for one candidate; empty list == feasible.

    Check order and message text mirror the legacy
    `synth.feasibility.check` exactly — its return value is now
    `[d.message for d in diagnose(...)]`.
    """
    out: list[Diagnostic] = []
    t = _label(topo)
    ranges = topo.link_ranges()
    if len(ranges) and int(ranges.max()) > crit.max_link_range:
        out.append(diag(
            "DP001",
            f"link-range {int(ranges.max())} > "
            f"{crit.max_link_range} (Principle 2)",
            target=t, link_range=int(ranges.max()),
            budget=crit.max_link_range,
            n_over=int((ranges > crit.max_link_range).sum())))
    cap = crit.max_link_mm(topo.substrate)
    lmax = topo.max_link_length_mm()
    if lmax > cap + 1e-9:
        out.append(diag(
            "DP002",
            f"max link {lmax:.1f} mm > {cap:.1f} mm "
            f"({topo.substrate} rate floor "
            f"{crit.min_rate_fraction:g})",
            target=t, max_link_mm=float(lmax), cap_mm=float(cap),
            substrate=topo.substrate,
            min_rate_fraction=crit.min_rate_fraction))
    if crit.max_radix is not None and topo.radix > crit.max_radix:
        out.append(diag(
            "DP003",
            f"radix {topo.radix} > {crit.max_radix} "
            "(Principle 3)",
            target=t, radix=int(topo.radix), budget=crit.max_radix))
    if cm.data_wires(topo) < crit.min_data_wires:
        out.append(diag(
            "DP004",
            f"data wires {cm.data_wires(topo)} < "
            f"{crit.min_data_wires} at radix {topo.radix} "
            "(Principle 3)",
            target=t, data_wires=int(cm.data_wires(topo)),
            minimum=crit.min_data_wires, radix=int(topo.radix)))
    if crit.max_wire_cost_mm is not None and \
            cm.wire_cost_mm(topo) > crit.max_wire_cost_mm:
        out.append(diag(
            "DP005",
            f"wire cost {cm.wire_cost_mm(topo):.0f} wire-mm "
            f"> {crit.max_wire_cost_mm:.0f}",
            target=t, wire_cost_mm=float(cm.wire_cost_mm(topo)),
            budget=crit.max_wire_cost_mm))
    return out


def check_n_constraint(name: str, n: int) -> list[Diagnostic]:
    """DP006 with the planner's exact skip string; empty == supported."""
    if valid_n(name, n):
        return []
    return [diag(
        "DP006",
        f"{name} does not support N={n} (topology.N_CONSTRAINTS)",
        target=f"{name}/n{n}", name=name, n=n)]


def lint_topology(topo: Topology,
                  crit: FeasibilityCriteria = FeasibilityCriteria(),
                  report: Report | None = None) -> list[Diagnostic]:
    """All DP checks for a built topology, optionally into `report`."""
    out = diagnose(topo, crit)
    if report is not None:
        report.record("principles", _label(topo))
        report.extend(out)
    return out
