"""Static JAX hazard analysis of the batched simulator (DESIGN.md §14).

The batched step's correctness rests on contracts that bitwise tests
probe but never *inspect*: int32 counters must not overflow at the
configured cycle count, every scatter fed by padded lanes must land on
a sacrificial slot (buffer slot B, channel row C), a sweep should not
compile one executable per topology, and the traced program should
contain no host callbacks or silent dtype promotions.  This module
checks those contracts statically:

  * **JX001 int32-overflow** — closed-form worst-case bounds for every
    int32 accumulator in `SimState` given `SimConfig`; flagged when a
    bound reaches 2^31.  The dominant term is the summed-latency
    counter: each ejection contributes up to ``cycles`` and a node can
    eject from all P+1 ports each measured cycle, so
    ``lat_node <= measured * (P+1) * cycles`` — overflow near
    ``cycles ~ 46341`` even at one ejection per cycle.
  * **JX002 pad-slot-write** — the padding contract of
    `sweep.padding.pad_spec`, checked by inspecting the actual stacked
    `BatchSpec` leaves: padded table/out_ch/in_ch entries must be -1
    (so pad lanes route nowhere and scatters are redirected to row C /
    slot B), padded channel endpoints 0, depths >= 1, pad traffic rows
    1.0 and pad injection weights 0.  Any violation means a scatter
    index can reach a *live* slot of another spec.
  * **JX003 recompile-hazard** — distinct padded shapes in one spec
    collection; each distinct (shape, kmax) is a separate compiled
    executable, so a heterogeneous sweep without bucketing compiles
    once per topology (the ROADMAP's warm-path regression).
  * **JX004 host-sync** / **JX005 dtype-promotion** — a recursive walk
    of the traced jaxpr (`core.simulator.trace_batch`; abstract
    evaluation only, nothing is compiled or run) looking for host
    callback primitives inside the scan and for widening
    `convert_element_type` ops or 64-bit avals.
"""
from __future__ import annotations

import numpy as np

from .diagnostics import Diagnostic, Report, diag

INT32_MAX = 2 ** 31


# =====================================================================
# JX001 — int32 counter overflow bounds
# =====================================================================

def counter_bounds(n: int, p: int, cfg, telemetry: bool | None = None
                   ) -> dict[str, int]:
    """Worst-case value of each int32 `SimState` accumulator.

    n, p are the (padded) node count and max real port count; the
    injection port makes the per-node port axis p+1 wide.  Bounds are
    deliberately loose upper bounds — a flagged config *may* survive in
    practice, an unflagged one provably cannot overflow.
    """
    meas = max(cfg.cycles - cfg.warmup, 0)
    pi = p + 1
    bounds = {
        # one event per node per cycle
        "delivered": meas * n,
        "offered": meas * n,
        "accepted": meas * n,
        # each ejection's latency <= cycles; up to pi ejections per
        # node per cycle
        "lat_node": meas * pi * cfg.cycles,
    }
    if telemetry if telemetry is not None else getattr(
            cfg, "telemetry", False):
        v, b = cfg.n_vcs, cfg.buf_depth
        bounds.update(
            tel_busy=meas,                   # one traversal per channel
            tel_stall=meas * pi * v,         # all lanes starve same ch
            tel_occ=meas * b,                # occupancy <= buf depth
            tel_inj=meas,
            tel_eject=meas * pi,
            tel_hist=meas * n * pi,          # all ejections in one bin
        )
    return bounds


def check_overflow(n: int, p: int, cfg, target: str = "",
                   report: Report | None = None) -> list[Diagnostic]:
    """JX001 for every counter whose worst-case bound reaches 2^31."""
    out = []
    for name, bound in counter_bounds(n, p, cfg).items():
        if bound >= INT32_MAX:
            out.append(diag(
                "JX001",
                f"int32 counter '{name}' worst-case bound {bound:,} >= "
                f"2^31 at cycles={cfg.cycles} (warmup={cfg.warmup}, "
                f"N={n}, P={p}); simulated metrics could silently wrap",
                target=target, counter=name, bound=int(bound),
                cycles=int(cfg.cycles), warmup=int(cfg.warmup),
                n=int(n), p=int(p)))
    if report is not None:
        report.record("overflow", target or f"n{n}/p{p}")
        report.extend(out)
    return out


# =====================================================================
# JX002 — sacrificial-slot padding contract
# =====================================================================

def check_padding_contract(batch, specs, target: str = "",
                           report: Report | None = None
                           ) -> list[Diagnostic]:
    """JX002: inspect stacked `BatchSpec` leaves against `pad_spec`'s
    contract, per spec.  `specs` supplies each row's real (n, p, c)."""
    out: list[Diagnostic] = []
    S = batch.table.shape[0]
    N, P = batch.out_ch.shape[1], batch.out_ch.shape[2]
    C = batch.ch_src.shape[1]

    def bad(i, leaf, mask, expect):
        arr = getattr(batch, leaf)[i]
        viol = np.asarray(mask & ~expect)
        if not viol.any():
            return
        idx = tuple(int(x) for x in np.argwhere(viol)[0])
        out.append(diag(
            "JX002",
            f"spec {i} leaf '{leaf}' violates the sacrificial-slot "
            f"padding contract at index {idx} (value "
            f"{arr[idx].item()!r}, {int(viol.sum())} violation(s)); a "
            f"scatter fed by this lane can touch a live slot",
            target=target, spec=i, leaf=leaf, index=idx,
            value=arr[idx].item(), n_bad=int(viol.sum())))

    for i in range(min(S, len(specs))):
        s = specs[i]
        n, p, c = s.n, s.p, s.c
        # pad masks per leaf
        tbl = batch.table[i]
        m = np.zeros(tbl.shape, bool)
        m[n:] = True
        m[:, n:] = True
        m[:n, :n, p:P] = True           # injection col lives at slot P
        bad(i, "table", m, tbl == -1)
        for leaf in ("out_ch", "in_ch"):
            a = getattr(batch, leaf)[i]
            m = np.zeros(a.shape, bool)
            m[n:] = True
            m[:, p:] = True
            bad(i, leaf, m, a == -1)
            # live entries must index a real channel of THIS spec: a
            # declared out_ch >= c would scatter into another spec's
            # channel rows after padding
            live = ~m & (a >= 0)
            bad(i, leaf, live, a < c)
        mc = np.zeros((C,), bool)
        mc[c:] = True
        for leaf, fill in (("ch_src", 0), ("ch_dst", 0),
                           ("ch_in_port", 0), ("ch_out_port", 0)):
            a = getattr(batch, leaf)[i]
            bad(i, leaf, mc, a == fill)
        bad(i, "ch_dst", ~mc, batch.ch_dst[i] < n)
        bad(i, "ch_in_port", ~mc, batch.ch_in_port[i] < p)
        bad(i, "ch_depth", mc, batch.ch_depth[i] == 1)
        bad(i, "ch_depth", np.ones((C,), bool), batch.ch_depth[i] >= 1)
        cum = batch.traffic_cum[i]
        m = np.zeros(cum.shape, bool)
        m[n:] = True
        m[:, n:] = True
        bad(i, "traffic_cum", m, cum == 1.0)
        inj = batch.inj_weight[i]
        m = np.zeros(inj.shape, bool)
        m[n:] = True
        bad(i, "inj_weight", m, inj == 0.0)
        # productive-ports mask (DESIGN.md §15): the pad region must be
        # all-False so an adaptive selection can never name a padded
        # destination, node or port
        pr = batch.prod[i]
        m = np.zeros(pr.shape, bool)
        m[n:] = True
        m[:, n:] = True
        m[:, :, p:] = True
        bad(i, "prod", m, ~pr)
    if report is not None:
        report.record("padding", target or f"batch[{S}]")
        report.extend(out)
    return out


# =====================================================================
# JX003 — recompile hazards (distinct shapes per executable)
# =====================================================================

def check_recompiles(shapes, target: str = "", bucketed=None,
                     report: Report | None = None) -> list[Diagnostic]:
    """JX003 when a spec collection spans several padded shapes.

    `shapes`: one `PadShape` per spec.  Each distinct shape compiles a
    separate executable; pass `bucketed` (the shapes after
    `SweepEngine.bucket_shape`) to show how many compiles bucketing
    would save.
    """
    distinct = sorted(set(shapes))
    out: list[Diagnostic] = []
    if len(distinct) > 1:
        n_b = len(set(bucketed)) if bucketed is not None else None
        msg = (f"{len(list(shapes))} spec(s) span {len(distinct)} "
               f"distinct padded shapes -> {len(distinct)} compiled "
               f"executables")
        if n_b is not None and n_b < len(distinct):
            msg += f"; shape bucketing would reduce this to {n_b}"
        out.append(diag(
            "JX003", msg, target=target,
            n_shapes=len(distinct),
            shapes=[tuple(dataclass_astuple(s)) for s in distinct],
            n_bucketed=n_b))
    if report is not None:
        report.record("recompile", target or f"{len(list(shapes))} specs")
        report.extend(out)
    return out


def dataclass_astuple(shape) -> tuple:
    return (shape.n, shape.p, shape.c, shape.d)


# =====================================================================
# JX004 / JX005 — jaxpr walking
# =====================================================================

_HOST_SYNC_PRIMS = {
    "pure_callback", "io_callback", "debug_callback", "debug_print",
    "host_callback_call", "outside_call", "infeed", "outfeed",
}


def iter_eqns(jaxpr):
    """Depth-first walk over all equations, descending into call/scan/
    cond/pjit sub-jaxprs (accepts a ClosedJaxpr or a Jaxpr)."""
    jaxpr = getattr(jaxpr, "jaxpr", jaxpr)
    for eqn in jaxpr.eqns:
        yield eqn
        for v in eqn.params.values():
            for sub in _sub_jaxprs(v):
                yield from iter_eqns(sub)


def _sub_jaxprs(v):
    if hasattr(v, "eqns") or hasattr(v, "jaxpr"):
        yield v
    elif isinstance(v, (list, tuple)):
        for x in v:
            yield from _sub_jaxprs(x)


def iter_avals(jaxpr):
    jaxpr = getattr(jaxpr, "jaxpr", jaxpr)
    for v in list(jaxpr.invars) + list(jaxpr.outvars):
        if hasattr(v, "aval"):
            yield v.aval
    for eqn in iter_eqns(jaxpr):
        for v in list(eqn.invars) + list(eqn.outvars):
            if hasattr(v, "aval"):
                yield v.aval


def check_host_sync(jaxpr, target: str = "",
                    report: Report | None = None) -> list[Diagnostic]:
    """JX004: host callback primitives anywhere in the traced step."""
    hits: dict[str, int] = {}
    for eqn in iter_eqns(jaxpr):
        name = eqn.primitive.name
        if name in _HOST_SYNC_PRIMS:
            hits[name] = hits.get(name, 0) + 1
    out = [diag(
        "JX004",
        f"traced step contains host callback primitive '{name}' "
        f"(x{count}) — a device sync point inside the scan",
        target=target, primitive=name, count=count)
        for name, count in sorted(hits.items())]
    if report is not None:
        report.record("host-sync", target or "jaxpr")
        report.extend(out)
    return out


def _width(dtype) -> int:
    return np.dtype(dtype).itemsize


def check_dtype_promotions(jaxpr, target: str = "",
                           report: Report | None = None
                           ) -> list[Diagnostic]:
    """JX005: widening convert_element_type ops and 64-bit avals.

    Intentional int32<->float32 casts (`astype` in the step) keep the
    item width; a *widening* convert or any f64/i64 aval means x64
    leaked in or a Python scalar promoted an array — both double
    memory traffic silently.
    """
    out: list[Diagnostic] = []
    widenings: dict[tuple, int] = {}
    for eqn in iter_eqns(jaxpr):
        if eqn.primitive.name != "convert_element_type":
            continue
        new = eqn.params.get("new_dtype")
        src = getattr(getattr(eqn.invars[0], "aval", None), "dtype", None)
        if src is None or new is None:
            continue
        # narrow->32-bit widenings (int16 table -> int32 index) are the
        # deliberate storage/compute split; promotion TO 64-bit is the
        # silent hazard
        if _width(new) > _width(src) and _width(new) >= 8:
            key = (str(np.dtype(src)), str(np.dtype(new)))
            widenings[key] = widenings.get(key, 0) + 1
    for (src, new), count in sorted(widenings.items()):
        out.append(diag(
            "JX005",
            f"traced step widens {src} -> {new} (x{count}) — silent "
            f"dtype promotion",
            target=target, src=src, dst=new, count=count))
    wide = {}
    for aval in iter_avals(jaxpr):
        dt = getattr(aval, "dtype", None)
        if dt is not None and np.dtype(dt).itemsize >= 8 and \
                np.dtype(dt).kind in "fiuc":
            wide[str(np.dtype(dt))] = wide.get(str(np.dtype(dt)), 0) + 1
    for dt, count in sorted(wide.items()):
        out.append(diag(
            "JX005",
            f"traced step carries {count} {dt} intermediate(s) — 64-bit "
            f"mode leaked into the batched path",
            target=target, dtype=dt, count=count))
    if report is not None:
        report.record("dtype", target or "jaxpr")
        report.extend(out)
    return out


# =====================================================================
# front door
# =====================================================================

def analyze_batch(specs, rates, cfg=None, *, schedules=None,
                  target: str = "", report: Report | None = None,
                  trace: bool = True) -> Report:
    """Run all JX checks on one batch of SimSpecs.

    Traces the real runner abstractly (`simulator.trace_batch`) for the
    jaxpr-level checks (skippable with trace=False — tracing a large
    config costs a few seconds), and inspects the padded arrays and
    counter bounds directly.
    """
    from repro.core import simulator as sim
    from repro.sweep.padding import PadShape, stack_specs

    cfg = cfg or sim.SimConfig()
    report = report if report is not None else Report()
    shapes = [PadShape(n=s.n, p=s.p, c=s.c, d=s.d) for s in specs]
    batch, shape = stack_specs(specs)
    # dispatched one-batch-at-a-time these specs would compile one
    # executable per distinct shape; stacking pads them to `shape`
    check_recompiles(shapes, target=target,
                     bucketed=[shape] * len(shapes), report=report)
    check_overflow(shape.n, shape.p, cfg, target=target, report=report)
    check_padding_contract(batch, specs, target=target, report=report)
    if trace:
        jaxpr, _, _ = sim.trace_batch(specs, rates, cfg,
                                      schedules=schedules)
        check_host_sync(jaxpr, target=target, report=report)
        check_dtype_promotions(jaxpr, target=target, report=report)
    return report
