"""The `analyze(...)` front door (DESIGN.md §14).

One call runs the three analyzer families over a set of targets and
returns a `Report`:

    from repro.analysis import analyze
    rep = analyze(names=["folded_hexa_torus", "mesh"], n=36,
                  fault_kmax=2)
    assert rep.ok                    # no error-severity diagnostics
    rep.to_json("results/diagnostics.json")

Per target the engine (1) lints the built topology against the design
principles (DP codes), (2) certifies its routing exhaustively —
pristine and fault-degraded variants (RT codes, certificate cached on
the routing via `routing_for(certify=True)`), and (3) optionally
traces the batched simulator for JAX hazards (JX codes,
`jax_hazards=True`; off by default because tracing imports and touches
jax).  Every step bumps `analysis.*` counters on the process metrics
registry.
"""
from __future__ import annotations

from repro.core import topology as T
from repro.core import traffic as tr
from repro.core.routing import routing_for
from repro.obs.metrics import metrics

from .diagnostics import Report
from .principles import (FeasibilityCriteria, check_n_constraint,
                         lint_topology)

#: default CLI/CI chiplet count — the paper's N=36 headline scale
DEFAULT_N = 36


def analyze_topology(topo, *, crit: FeasibilityCriteria | None = None,
                     fault_kmax: int = 0, fault_kinds: tuple = ("random",),
                     fault_seeds: tuple = (0,),
                     report: Report | None = None) -> Report:
    """Lint + certify one built topology and its fault variants."""
    from repro.faults import apply_variant, iter_fault_variants

    from .routing_verify import verify_routing

    report = report if report is not None else Report()
    lint_topology(topo, crit or FeasibilityCriteria(), report=report)
    for label, fs in iter_fault_variants(topo, fault_kmax,
                                         kinds=fault_kinds,
                                         seeds=fault_seeds):
        degraded = apply_variant(topo, fs)
        r = routing_for(degraded, certify=True)
        report.record("routing", f"{r.cert.target}[{label}]")
        report.extend(r.cert.diagnostics)
        metrics.inc("analysis.certified")
        if not r.cert.ok:
            metrics.inc("analysis.cert_failures")
    metrics.inc("analysis.targets")
    return report


def analyze_jax(topos, *, cfg=None, rates=(0.1,),
                report: Report | None = None) -> Report:
    """JX hazards for the batch the given topologies would run as."""
    from repro.core.simulator import make_spec

    from .jaxpr_hazards import analyze_batch

    report = report if report is not None else Report()
    specs = [make_spec(routing_for(t), tr.uniform(t)) for t in topos]
    label = f"batch[{len(specs)}]"
    analyze_batch(specs, list(rates), cfg, target=label, report=report)
    metrics.inc("analysis.jax_batches")
    return report


def analyze(names=None, topos=None, *, n: int = DEFAULT_N,
            substrates: tuple = ("organic", "glass"),
            crit: FeasibilityCriteria | None = None,
            fault_kmax: int = 0, fault_kinds: tuple = ("random",),
            fault_seeds: tuple = (0,), jax_hazards: bool = False,
            cfg=None, report: Report | None = None) -> Report:
    """Analyze named generators and/or pre-built topologies.

    names: generator names (builtin or registered); each is built at
    the nearest supported chiplet count to `n` per substrate, with a
    DP006 lint when `n` itself is unsupported (e.g. hypercube at 36
    runs at 32).  topos: already-built `Topology` objects, analyzed
    as-is.  Returns one `Report` across all targets.
    """
    report = report if report is not None else Report()
    built = list(topos or [])
    for name in names or []:
        report.extend(check_n_constraint(name, n))
        n_eff = T.nearest_valid_n(name, n)
        for substrate in substrates:
            built.append(T.build(name, n_eff, substrate=substrate))
    for topo in built:
        analyze_topology(topo, crit=crit, fault_kmax=fault_kmax,
                         fault_kinds=fault_kinds, fault_seeds=fault_seeds,
                         report=report)
    if jax_hazards and built:
        # one batch per substrate: specs that would actually be padded
        # and dispatched together
        for substrate in sorted({t.substrate for t in built}):
            group = [t for t in built if t.substrate == substrate]
            analyze_jax(group, cfg=cfg, report=report)
    metrics.inc("analysis.diagnostics", len(report))
    return report


def builtin_names() -> list[str]:
    """Table III generators + currently registered custom generators."""
    return sorted(T.GENERATORS) + sorted(T.CUSTOM_GENERATORS)
