"""Structured diagnostics (DESIGN.md §14): records, codes, reports.

Every analyzer in `repro.analysis` speaks one vocabulary: a
`Diagnostic` is a stable machine-readable code (`RT001`, `DP002`,
`JX003`, ...), a severity, a human message, the artifact it is about
(`target`), and — crucially — a concrete *witness*: the actual
channel-dependency cycle, the offending edge, the overflowing counter
bound.  A claim without a witness is a lint; a claim with one is a
certificate of the violation.

Code families (the full registry is `CODES`):

  * ``RT``  — routing verification (deadlock / reachability / table
    well-formedness).  Violations are correctness bugs: severity
    ``error``.
  * ``DP``  — the paper's design principles (link range, substrate
    rate floor, radix/wire budget) plus generator N-constraints.
    These describe *infeasible designs*, not broken code, so their
    default severity is ``warning`` — Table III deliberately contains
    topologies that violate them (that is the paper's argument).
  * ``JX``  — JAX-side hazards of the batched simulator (int32
    counter overflow, pad-slot scatter escapes, recompilation storms,
    host sync points, dtype promotions).
  * ``FT`` / ``EX`` — planner/executor outcomes (rejected fault sets,
    failed chunks) so `ResultFrame` skip rows carry the same codes.

Severities order ``error > warning > info``; `Report.gate()` is the CI
gate: it fails when any diagnostic at or above the threshold exists.
"""
from __future__ import annotations

import dataclasses
from typing import Iterable, Iterator

ERROR, WARNING, INFO = "error", "warning", "info"
_SEV_RANK = {ERROR: 2, WARNING: 1, INFO: 0}

#: code -> (slug, default severity, one-line description)
CODES: dict[str, tuple[str, str, str]] = {
    # ---- routing verification (repro.analysis.routing_verify) --------
    "RT001": ("cdg-cycle", ERROR,
              "channel-dependency graph has a cycle (deadlock possible)"),
    "RT002": ("unreachable-pair", ERROR,
              "a connected (src, dst) pair has no route in the table"),
    "RT003": ("undeclared-channel", ERROR,
              "a routing-table entry names a port with no declared "
              "channel"),
    "RT004": ("routing-loop", ERROR,
              "table following exceeded the hop bound (livelock)"),
    "RT005": ("escape-unsafe", ERROR,
              "an adaptive routing choice loses its deadlock-free "
              "escape path"),
    # ---- design principles (repro.analysis.principles) ---------------
    "DP001": ("link-range", WARNING,
              "link range exceeds the Principle-2 budget"),
    "DP002": ("rate-floor", WARNING,
              "longest link falls below the substrate's Fig.-2 rate "
              "floor"),
    "DP003": ("radix", WARNING,
              "radix exceeds the Principle-3 per-chiplet PHY budget"),
    "DP004": ("wire-budget", WARNING,
              "per-link data wires fall below the Principle-3 minimum"),
    "DP005": ("wire-cost", WARNING,
              "total substrate wire cost exceeds the configured bound"),
    "DP006": ("n-constraint", WARNING,
              "generator does not support the requested N "
              "(topology.N_CONSTRAINTS)"),
    # ---- jaxpr hazards (repro.analysis.jaxpr_hazards) ----------------
    "JX001": ("int32-overflow", ERROR,
              "an int32 counter's worst-case bound overflows at the "
              "configured cycle count"),
    "JX002": ("pad-slot-write", ERROR,
              "a padded array region violates the sacrificial-slot "
              "contract (a scatter can touch a live slot)"),
    "JX003": ("recompile-hazard", WARNING,
              "distinct avals / padded shapes force extra executable "
              "compiles"),
    "JX004": ("host-sync", WARNING,
              "the traced step contains a host callback (device sync "
              "point inside the scan)"),
    "JX005": ("dtype-promotion", WARNING,
              "the traced step silently promotes or demotes a dtype"),
    # ---- pipeline outcomes (experiments planner / executor) ----------
    "FT001": ("fault-rejected", WARNING,
              "fault set cannot be applied (disconnects survivors or "
              "names a missing link)"),
    "EX001": ("chunk-failed", ERROR,
              "an execution chunk raised and was skipped"),
}


@dataclasses.dataclass(frozen=True)
class Diagnostic:
    """One finding: stable code + severity + location + witness."""
    code: str                   # registry key, e.g. "RT001"
    message: str                # human-readable, legacy-string exact
    target: str = ""            # what it is about (topology/spec label)
    severity: str = ""          # "" = the code's default severity
    witness: tuple = ()         # ((key, value), ...) concrete evidence

    def __post_init__(self):
        if self.code not in CODES:
            raise KeyError(f"unknown diagnostic code {self.code!r}; "
                           f"register it in analysis.diagnostics.CODES")
        if not self.severity:
            object.__setattr__(self, "severity", CODES[self.code][1])
        if self.severity not in _SEV_RANK:
            raise ValueError(f"unknown severity {self.severity!r}")
        object.__setattr__(self, "witness", tuple(
            (str(k), v) for k, v in self.witness))

    @property
    def slug(self) -> str:
        return CODES[self.code][0]

    @property
    def label(self) -> str:
        """'RT001 cdg-cycle' — the stable display form."""
        return f"{self.code} {self.slug}"

    def witness_dict(self) -> dict:
        return dict(self.witness)

    def to_dict(self) -> dict:
        return dict(code=self.code, slug=self.slug,
                    severity=self.severity, target=self.target,
                    message=self.message,
                    witness=self.witness_dict() or None)

    def __str__(self) -> str:
        where = f" [{self.target}]" if self.target else ""
        return f"{self.severity:7s} {self.label}{where}: {self.message}"


def diag(code: str, message: str, target: str = "",
         severity: str = "", **witness) -> Diagnostic:
    """Build a `Diagnostic`; witness kwargs become the witness pairs."""
    return Diagnostic(code=code, message=message, target=target,
                      severity=severity,
                      witness=tuple(witness.items()))


class Report:
    """An ordered collection of diagnostics with gate/summary helpers."""

    def __init__(self, diagnostics: Iterable[Diagnostic] = ()):
        self.diagnostics: list[Diagnostic] = list(diagnostics)
        #: analyzed-artifact ledger: (kind, label) pairs, so "zero
        #: diagnostics" is distinguishable from "analyzed nothing"
        self.analyzed: list[tuple[str, str]] = []

    # ---- collection ---------------------------------------------------
    def extend(self, diagnostics: Iterable[Diagnostic]) -> "Report":
        self.diagnostics.extend(diagnostics)
        return self

    def add(self, d: Diagnostic) -> "Report":
        self.diagnostics.append(d)
        return self

    def record(self, kind: str, label: str) -> None:
        self.analyzed.append((kind, label))

    def __len__(self) -> int:
        return len(self.diagnostics)

    def __iter__(self) -> Iterator[Diagnostic]:
        return iter(self.diagnostics)

    # ---- queries ------------------------------------------------------
    def at_least(self, severity: str) -> list[Diagnostic]:
        r = _SEV_RANK[severity]
        return [d for d in self.diagnostics
                if _SEV_RANK[d.severity] >= r]

    def errors(self) -> list[Diagnostic]:
        return self.at_least(ERROR)

    def warnings(self) -> list[Diagnostic]:
        return [d for d in self.diagnostics if d.severity == WARNING]

    def by_code(self, code: str) -> list[Diagnostic]:
        return [d for d in self.diagnostics if d.code == code]

    def counts(self) -> dict:
        out: dict[str, int] = {}
        for d in self.diagnostics:
            out[d.code] = out.get(d.code, 0) + 1
        return out

    @property
    def ok(self) -> bool:
        """No error-severity diagnostics (warnings/infos allowed)."""
        return not self.errors()

    def gate(self, fail_on: str = ERROR) -> int:
        """CI exit code: 1 if any diagnostic at/above `fail_on`."""
        return 1 if self.at_least(fail_on) else 0

    # ---- presentation -------------------------------------------------
    def summary(self) -> str:
        sev = {ERROR: 0, WARNING: 0, INFO: 0}
        for d in self.diagnostics:
            sev[d.severity] += 1
        per_code = " ".join(f"{c}x{n}"
                            for c, n in sorted(self.counts().items()))
        return (f"{len(self.analyzed)} artifact(s) analyzed: "
                f"{sev[ERROR]} error(s), {sev[WARNING]} warning(s), "
                f"{sev[INFO]} info" + (f"  [{per_code}]" if per_code
                                       else ""))

    def to_rows(self) -> list[dict]:
        return [d.to_dict() for d in self.diagnostics]

    def to_json(self, path: str, **meta) -> None:
        """Versioned JSON artifact (experiments.io discipline) for the
        CI gate: {schema_version, meta, counts, analyzed, rows}."""
        from repro.experiments import io as xio
        xio.write_json(path, self.to_rows(), meta=dict(
            kind="diagnostics", counts=self.counts(),
            n_errors=len(self.errors()),
            analyzed=[list(a) for a in self.analyzed], **meta))
