"""Experiment executor (DESIGN.md §10): Plan -> batched runs -> frame.

Runs each plan bucket through the shared `SweepEngine` — static buckets
via `run_specs`, workload buckets via `run_workloads`, analytic buckets
without any simulation — and assembles a `ResultFrame` with one row per
scenario in experiment order.

Scale/robustness knobs:

  * `chunk_size` streams a bucket in chunks of that many scenarios
    instead of one monolithic batch — bounds device memory for huge
    grids and gives `progress` callbacks something to report between
    compiled runs (the engine's executable cache makes the chunks share
    one compiled program per bucket shape);
  * `on_error="skip"` isolates partial failures: a chunk that raises
    marks only its own scenarios `status="failed"` (with the error
    message in the row) and the rest of the experiment completes;
  * engines are shared per `SimConfig` (`engine_for`), so every
    experiment, benchmark and deprecation shim in a process reuses one
    compiled-executable cache.
"""
from __future__ import annotations

from typing import Callable

import numpy as np

from repro.core.simulator import SimConfig
from repro.sweep.engine import SweepEngine

from .frame import ResultFrame, _identity_row, scenario_row
from .plan import Bucket, Plan, plan as make_plan
from .scenario import Experiment

_ENGINES: dict[SimConfig, SweepEngine] = {}


def engine_for(cfg: SimConfig = SimConfig()) -> SweepEngine:
    """Process-wide engine per SimConfig (shared executable cache)."""
    if cfg not in _ENGINES:
        _ENGINES[cfg] = SweepEngine(cfg=cfg)
    return _ENGINES[cfg]


def _chunks(items: list, size: int | None):
    if not size or size >= len(items):
        yield items
        return
    for i in range(0, len(items), size):
        yield items[i:i + size]


def _run_chunk(engine: SweepEngine, bucket: Bucket, chunk: list,
               single_program: bool = False) -> list:
    """One engine call for `chunk`; returns raw result dicts in order."""
    if bucket.key.kind == "analytic":
        return [None] * len(chunk)
    rates = np.stack([ps.rates for ps in chunk]).astype(np.float32)
    specs = [ps.spec for ps in chunk]
    if bucket.key.kind == "workload":
        return engine.run_workloads(specs, [ps.sched_spec for ps in chunk],
                                    rates, single_program=single_program)
    return engine.run_specs(specs, rates, single_program=single_program)


def execute(pl: Plan, engine: SweepEngine | None = None,
            chunk_size: int | None = None,
            progress: Callable[[int, int, object], None] | None = None,
            on_error: str = "raise") -> ResultFrame:
    """Run a plan and return the `ResultFrame` (scenario order)."""
    if on_error not in ("raise", "skip"):
        raise ValueError(f"on_error must be 'raise' or 'skip', "
                         f"got {on_error!r}")
    exp = pl.experiment
    engine = engine or engine_for(exp.cfg)
    n = len(exp.scenarios)
    results: list = [None] * n
    planned: list = [None] * n
    rows: list = [None] * n
    errors: list = []
    for i, reason in pl.skipped:
        rows[i] = _identity_row(exp, exp.scenarios[i], "invalid", reason)
    total, done = pl.n_planned, 0
    for bucket in pl.buckets:
        for chunk in _chunks(bucket.items, chunk_size):
            try:
                out = _run_chunk(engine, bucket, chunk,
                                 single_program=pl.single_program)
            except Exception as e:           # noqa: BLE001 — isolate chunk
                if on_error == "raise":
                    raise
                msg = f"{type(e).__name__}: {e}"
                for ps in chunk:
                    planned[ps.index] = ps
                    errors.append((ps.index, msg))
                    rows[ps.index] = _identity_row(exp, ps.scenario,
                                                   "failed", msg)
                out = None
            if out is not None:
                for ps, res in zip(chunk, out):
                    planned[ps.index] = ps
                    results[ps.index] = res
                    rows[ps.index] = scenario_row(exp, ps, res)
            done += len(chunk)
            if progress is not None:
                progress(done, total, bucket.key)
    return ResultFrame(experiment=exp, rows=rows, results=results,
                       planned=planned, errors=errors)


def run(experiment: Experiment, engine: SweepEngine | None = None,
        chunk_size: int | None = None,
        progress: Callable[[int, int, object], None] | None = None,
        on_error: str = "raise",
        single_program: bool = False) -> ResultFrame:
    """The one front door: plan + execute in one call.

        frame = repro.experiments.run(Experiment([...], cfg=...))

    See `plan()` to inspect bucketing (and `single_program`) first,
    `execute()` for the streaming/failure knobs.
    """
    engine = engine or engine_for(experiment.cfg)
    return execute(make_plan(experiment, engine,
                             single_program=single_program),
                   engine=engine, chunk_size=chunk_size,
                   progress=progress, on_error=on_error)
