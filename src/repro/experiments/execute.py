"""Experiment executor (DESIGN.md §10): Plan -> batched runs -> frame.

Runs each plan bucket through the shared `SweepEngine` — static buckets
via `run_specs`, workload buckets via `run_workloads`, analytic buckets
without any simulation — and assembles a `ResultFrame` with one row per
scenario in experiment order.

Scale/robustness knobs:

  * `chunk_size` streams a bucket in chunks of that many scenarios
    instead of one monolithic batch — bounds device memory for huge
    grids and gives `progress` callbacks something to report between
    compiled runs (the engine's executable cache makes the chunks share
    one compiled program per bucket shape);
  * `on_error="skip"` isolates partial failures: a chunk that raises
    marks only its own scenarios `status="failed"` (with the error
    message in the row), logs an `execute.chunk_failed` metrics event
    with the skip reason (`repro.obs.metrics`), and the rest of the
    experiment completes;
  * engines are shared per `SimConfig` (`engine_for`), so every
    experiment, benchmark and deprecation shim in a process reuses one
    compiled-executable cache.

Observability (DESIGN.md §13): execution is span-traced (`execute` /
per-chunk `execute.chunk` spans nest over the engine's `sweep.group`
and the simulator's `sim.dispatch`/`sim.wait` spans), and the progress
callback can opt into per-chunk timing: a 4-parameter callback
`progress(done, total, key, info)` receives an `info` dict with
`elapsed_s`, `compiled` (runner-cache misses this chunk), `scenarios`
and `status`; the historical 3-parameter `progress(done, total, key)`
form keeps working unchanged.
"""
from __future__ import annotations

import inspect
import time
from typing import Callable

import numpy as np

from repro.core.simulator import SimConfig
from repro.obs.metrics import cache_counters, metrics
from repro.obs.trace import trace
from repro.sweep.engine import SweepEngine

from .frame import ResultFrame, _identity_row, scenario_row
from .plan import Bucket, Plan, plan as make_plan
from .scenario import Experiment

_ENGINES: dict[SimConfig, SweepEngine] = {}


def engine_for(cfg: SimConfig = SimConfig()) -> SweepEngine:
    """Process-wide engine per SimConfig (shared executable cache)."""
    if cfg not in _ENGINES:
        _ENGINES[cfg] = SweepEngine(cfg=cfg)
    return _ENGINES[cfg]


def _chunks(items: list, size: int | None):
    if not size or size >= len(items):
        yield items
        return
    for i in range(0, len(items), size):
        yield items[i:i + size]


def _progress_arity(cb) -> int:
    """How many positional args `cb` accepts (legacy callbacks take 3:
    done, total, key; observability-aware ones take 4: ..., info)."""
    try:
        params = [p for p in inspect.signature(cb).parameters.values()
                  if p.kind in (p.POSITIONAL_ONLY,
                                p.POSITIONAL_OR_KEYWORD)]
        var = any(p.kind == p.VAR_POSITIONAL
                  for p in inspect.signature(cb).parameters.values())
        return 4 if var or len(params) >= 4 else 3
    except (TypeError, ValueError):      # builtins / C callables
        return 3


def _run_chunk(engine: SweepEngine, bucket: Bucket, chunk: list,
               single_program: bool = False) -> list:
    """One engine call for `chunk`; returns raw result dicts in order."""
    if bucket.key.kind == "analytic":
        return [None] * len(chunk)
    rates = np.stack([ps.rates for ps in chunk]).astype(np.float32)
    specs = [ps.spec for ps in chunk]
    # per-scenario routing overrides (Scenario.routing, DESIGN.md §15):
    # the bucket key carries the effective mode, so one engine serves
    # both — only the SimConfig handed to run_batch changes, and the
    # engine's runner cache keys on it
    cfg = engine.cfg if bucket.key.routing == engine.cfg.routing \
        else engine.cfg._replace(routing=bucket.key.routing)
    if bucket.key.kind == "workload":
        return engine.run_workloads(specs, [ps.sched_spec for ps in chunk],
                                    rates, single_program=single_program,
                                    cfg=cfg)
    return engine.run_specs(specs, rates, single_program=single_program,
                            cfg=cfg)


def execute(pl: Plan, engine: SweepEngine | None = None,
            chunk_size: int | None = None,
            progress: Callable[[int, int, object], None] | None = None,
            on_error: str = "raise") -> ResultFrame:
    """Run a plan and return the `ResultFrame` (scenario order)."""
    if on_error not in ("raise", "skip"):
        raise ValueError(f"on_error must be 'raise' or 'skip', "
                         f"got {on_error!r}")
    exp = pl.experiment
    engine = engine or engine_for(exp.cfg)
    n = len(exp.scenarios)
    results: list = [None] * n
    planned: list = [None] * n
    rows: list = [None] * n
    errors: list = []
    for i, reason in pl.skipped:
        rows[i] = _identity_row(exp, exp.scenarios[i], "invalid", reason,
                                diag_code=pl.skip_codes.get(i, ""))
    total, done = pl.n_planned, 0
    arity = _progress_arity(progress) if progress is not None else 0
    with trace("experiment.execute", cat="experiments",
               experiment=exp.name, scenarios=n,
               buckets=len(pl.buckets)):
        for bucket in pl.buckets:
            for chunk in _chunks(bucket.items, chunk_size):
                t0 = time.perf_counter()
                misses0 = cache_counters()["cache.runner.misses"]
                status = "ok"
                with trace("execute.chunk", cat="experiments",
                           kind=bucket.key.kind,
                           scenarios=len(chunk)) as sp:
                    try:
                        out = _run_chunk(engine, bucket, chunk,
                                         single_program=pl.single_program)
                    except Exception as e:   # noqa: BLE001 — isolate chunk
                        if on_error == "raise":
                            raise
                        status = "failed"
                        msg = f"{type(e).__name__}: {e}"
                        sp.set(error=msg)
                        # a skipped chunk is never silent: the skip
                        # reason lands in the metrics event log too
                        metrics.event(
                            "execute.chunk_failed", experiment=exp.name,
                            reason=msg, scenarios=len(chunk),
                            bucket=str(bucket.key),
                            indices=[ps.index for ps in chunk])
                        for ps in chunk:
                            planned[ps.index] = ps
                            errors.append((ps.index, msg))
                            rows[ps.index] = _identity_row(
                                exp, ps.scenario, "failed", msg,
                                diag_code="EX001")
                        out = None
                if out is not None:
                    for ps, res in zip(chunk, out):
                        planned[ps.index] = ps
                        results[ps.index] = res
                        rows[ps.index] = scenario_row(exp, ps, res)
                done += len(chunk)
                if progress is not None:
                    if arity >= 4:
                        info = dict(
                            elapsed_s=time.perf_counter() - t0,
                            compiled=cache_counters()
                            ["cache.runner.misses"] - misses0,
                            scenarios=len(chunk), status=status)
                        progress(done, total, bucket.key, info)
                    else:
                        progress(done, total, bucket.key)
    return ResultFrame(experiment=exp, rows=rows, results=results,
                       planned=planned, errors=errors)


def run(experiment: Experiment, engine: SweepEngine | None = None,
        chunk_size: int | None = None,
        progress: Callable[[int, int, object], None] | None = None,
        on_error: str = "raise",
        single_program: bool = False) -> ResultFrame:
    """The one front door: plan + execute in one call.

        frame = repro.experiments.run(Experiment([...], cfg=...))

    See `plan()` to inspect bucketing (and `single_program`) first,
    `execute()` for the streaming/failure knobs.
    """
    engine = engine or engine_for(experiment.cfg)
    return execute(make_plan(experiment, engine,
                             single_program=single_program),
                   engine=engine, chunk_size=chunk_size,
                   progress=progress, on_error=on_error)
