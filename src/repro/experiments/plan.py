"""Experiment planner (DESIGN.md §10): Scenario -> engine-ready buckets.

`plan(experiment)` resolves every scenario against the real registries
— N-constraints from `topology.N_CONSTRAINTS`, routing via the shared
`cached_routing`, traffic patterns / workload schedules, per-scenario
rate grids — and groups the survivors into *buckets* that lower 1:1
onto `SweepEngine` padded batches:

  * bucket key = (kind, R, bucketed PadShape, bucketed phase count),
    mirroring the engine's own shape-rounding policy so one bucket is
    one engine group (one compiled program, typically reused);
  * static scenarios and workload scenarios flow through the same
    pipeline — a workload scenario simply carries a compiled
    `SchedSpec` next to its `SimSpec` (its spec's traffic matrix is the
    schedule's time-averaged demand, used only for analytic seeding);
  * invalid scenarios are *skipped with a reason*, never silently
    dropped — the executor emits a `status="invalid"` row for each.

Planning is cheap (no simulation) and deterministic; the plan can be
inspected (`Plan.describe()`) before committing to execution.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core import placement as pl
from repro.core import topology as T
from repro.core import traffic as TR
from repro.core.routing import cached_routing, routing_for
from repro.faults import FaultError
from repro.core.simulator import SimSpec, make_spec
from repro.obs.trace import trace
from repro.sweep.engine import SweepEngine, _round_up
from repro.sweep.padding import PadShape

from .scenario import CustomTraffic, Experiment, Scenario


@dataclasses.dataclass
class PlannedScenario:
    """One validated, resolved scenario, ready for the engine."""
    index: int                  # position in experiment.scenarios
    scenario: Scenario
    topo: object
    routing: object
    traffic: np.ndarray         # static matrix, or schedule mean demand
    analytic: float             # channel-load saturation bound
    spec: SimSpec | None        # None on the analytic backend
    schedule: object | None     # fitted workloads.Schedule (labels)
    sched_spec: object | None   # compiled simulator.SchedSpec
    rates: np.ndarray | None    # [R] resolved offered-rate grid


@dataclasses.dataclass(frozen=True)
class BucketKey:
    kind: str                   # "static" | "workload" | "analytic"
    n_rates: int
    shape: PadShape | None      # engine-bucketed padded shape
    k_pad: int                  # bucketed phase-axis size (0 = static)
    #: effective routing mode ("static" | "adaptive"); part of the key
    #: because the two modes compile different programs (DESIGN.md §15)
    routing: str = "static"


@dataclasses.dataclass
class Bucket:
    key: BucketKey
    items: list


@dataclasses.dataclass
class Plan:
    experiment: Experiment
    buckets: list
    skipped: list               # [(scenario index, reason)]
    single_program: bool = False
    #: scenario index -> diagnostic code for each skip (DESIGN.md §14);
    #: `skipped` keeps its legacy (index, reason) shape, the code rides
    #: here so `ResultFrame` invalid rows carry a machine-readable
    #: `diag_code` alongside the byte-identical reason string
    skip_codes: dict = dataclasses.field(default_factory=dict)

    @property
    def n_planned(self) -> int:
        return sum(len(b.items) for b in self.buckets)

    def describe(self) -> str:
        lines = [f"plan[{self.experiment.name}]: "
                 f"{len(self.experiment)} scenarios -> "
                 f"{self.n_planned} planned in {len(self.buckets)} "
                 f"bucket(s), {len(self.skipped)} skipped"]
        for b in self.buckets:
            k = b.key
            shape = (f"N{k.shape.n} P{k.shape.p} C{k.shape.c} D{k.shape.d}"
                     if k.shape else "-")
            lines.append(f"  [{k.kind:8s}] R={k.n_rates} K={k.k_pad} "
                         f"routing={k.routing} shape=({shape}) "
                         f"x{len(b.items)}")
        for i, reason in self.skipped:
            lines.append(f"  skip #{i}: {reason}")
        return "\n".join(lines)


def resolve_topology(scenario: Scenario):
    """(topo, routing) for a scenario's topology source.

    Registry names go through `cached_routing`; `Topology` objects and
    generator callables are validated here and routed via the
    structural-hash cache (`routing_for`) — name collisions between
    synthesized candidates are harmless by construction.

    A `Topology` object keeps its own substrate/area unless the
    scenario names them explicitly (`Scenario.resolved_substrate`), in
    which case it is re-stamped; a non-default `roles` scheme is
    re-applied to it so the result row's `roles` column always
    describes the traffic actually run — with the default scheme the
    object's own (possibly hand-assigned) roles are kept.

    A degraded scenario (`Scenario.faults` non-empty) resolves its base
    topology the same way, then lowers the fault set onto it
    (`FaultSet.apply`: masked edge list, survivors-connected check) and
    routes the *degraded* structure — `routing_for` keys on the
    structural hash, so pristine and every distinct fault mask each get
    their own cached routing, and an empty fault set shares the
    pristine entry bitwise.
    """
    s = scenario
    substrate, area = s.resolved_substrate, s.resolved_area
    if isinstance(s.topology, str):
        if not s.degraded:
            return cached_routing(s.topology, s.n, substrate, area,
                                  s.roles)
        # fault path: build the (cheap) base topology without routing
        # the pristine structure — only the degraded one is simulated
        topo = s.faults.apply(
            T.build(s.topology, s.n, substrate=substrate,
                    chiplet_area_mm2=area, roles_scheme=s.roles))
        return topo, routing_for(topo)
    src = s.topology if isinstance(s.topology, T.Topology) \
        else s.topology(s.n)            # generator callable
    if isinstance(src, T.Topology):
        topo = src
        if topo.n != s.n:
            raise ValueError(f"scenario n={s.n} != topology n={topo.n} "
                             f"({topo.name})")
        if topo.substrate != substrate or \
                topo.chiplet_area_mm2 != area:
            topo = dataclasses.replace(topo, substrate=substrate,
                                       chiplet_area_mm2=area)
        if s.roles != "homogeneous":
            topo = dataclasses.replace(
                topo, roles=pl.assign_roles(topo.pos, s.roles))
        T.validate_edges(topo.n, topo.edges, name=topo.name)
    else:                               # generator returned (name, pos, edges)
        name, pos, edges = src
        topo = T.make_topology(name, pos, edges, substrate=substrate,
                               chiplet_area_mm2=area,
                               roles_scheme=s.roles)
        if topo.n != s.n:
            raise ValueError(f"scenario n={s.n} != generated n={topo.n} "
                             f"({topo.name})")
    if s.degraded:
        topo = s.faults.apply(topo)
    return topo, routing_for(topo)


def _resolve_traffic(scenario: Scenario, topo, meas: int):
    """(static matrix | schedule mean, fitted Schedule | None).

    On a degraded scenario with dead chiplets, static matrices and
    every schedule phase are masked (`FaultSet.mask_traffic`): dead
    chiplets neither inject nor receive, and survivors' destination
    rows are renormalized.  Link-only fault sets leave traffic
    untouched (masking is a no-op without dead chiplets)."""
    tr = scenario.traffic
    fs = scenario.faults if scenario.degraded else None
    if isinstance(tr, str):
        if tr not in TR.PATTERNS:
            raise KeyError(f"unknown traffic pattern {tr!r}; choose from "
                           f"{sorted(TR.PATTERNS)} or pass a Workload")
        tm = TR.PATTERNS[tr](topo)
        return (fs.mask_traffic(tm) if fs is not None else tm), None
    if isinstance(tr, CustomTraffic):
        tm = np.asarray(tr.build(topo), np.float64)
        return (fs.mask_traffic(tm) if fs is not None else tm), None
    schedule = tr.build(topo) if hasattr(tr, "build") else tr(topo)
    if not hasattr(schedule, "mean_traffic"):
        raise TypeError(
            f"traffic callable {getattr(tr, 'name', tr)!r} returned "
            f"{type(schedule).__name__}, not a workloads.Schedule; wrap "
            "plain topo -> matrix builders in experiments.CustomTraffic")
    if fs is not None:
        schedule = fs.mask_schedule(schedule)
    if scenario.fit_schedule:
        schedule = schedule.fit(meas)
    return schedule.mean_traffic(), schedule


def plan(experiment: Experiment, engine: SweepEngine | None = None,
         single_program: bool = False) -> Plan:
    """Validate + resolve every scenario and bucket them for execution.

    `engine` only contributes its shape-bucketing policy (so the plan's
    buckets coincide with the engine groups executed later); planning
    never compiles or runs anything.

    single_program=True coalesces all scenarios of one (kind, R, phase
    bucket) into a single bucket that the executor runs as ONE compiled
    program padded to the group's max shape (the engine's
    `run_specs(..., single_program=True)` mode) — fewer compiles at the
    cost of padding small topologies to the largest shape present.
    """
    engine = engine or SweepEngine(cfg=experiment.cfg)
    meas = experiment.cfg.cycles - experiment.cfg.warmup
    sim_backend = experiment.backend == "sim"
    buckets: dict[BucketKey, Bucket] = {}
    skipped: list = []
    skip_codes: dict = {}
    with trace("experiment.plan", cat="experiments",
               experiment=experiment.name,
               scenarios=len(experiment.scenarios)):
        for i, s in enumerate(experiment.scenarios):
            if not s.valid:
                skipped.append((i, f"{s.topology_name} does not support "
                                   f"N={s.n} (topology.N_CONSTRAINTS)"))
                skip_codes[i] = "DP006"
                continue
            try:
                topo, routing = resolve_topology(s)
            except FaultError as e:
                # un-applyable fault set (disconnects the survivors,
                # names a non-existent link, ...): skip with the
                # sampler-actionable reason rather than aborting the grid
                skipped.append((i, f"fault set rejected: {e}"))
                skip_codes[i] = "FT001"
                continue
            tm, schedule = _resolve_traffic(s, topo, meas)
            analytic = routing.saturation_rate(tm)
            eff = s.effective_routing(experiment.cfg)
            spec = sched_spec = rates = None
            if sim_backend:
                spec = make_spec(routing, tm)
                sched_spec = schedule.compile() \
                    if schedule is not None else None
                rates = np.asarray(
                    s.rates.resolve(analytic, routing=eff), np.float64)
                shape = engine.bucket_shape(
                    PadShape(n=spec.n, p=spec.p, c=spec.c, d=spec.d))
                k = sched_spec.k if sched_spec is not None else 0
                k_pad = _round_up(k, engine.k_round) \
                    if engine.bucket and k else k
                key = BucketKey(kind=s.kind, n_rates=len(rates),
                                shape=shape, k_pad=k_pad, routing=eff)
            else:
                key = BucketKey(kind="analytic", n_rates=0, shape=None,
                                k_pad=0, routing=eff)
            ps = PlannedScenario(index=i, scenario=s, topo=topo,
                                 routing=routing, traffic=tm,
                                 analytic=float(analytic), spec=spec,
                                 schedule=schedule, sched_spec=sched_spec,
                                 rates=rates)
            buckets.setdefault(key,
                               Bucket(key=key, items=[])).items.append(ps)
    out = list(buckets.values())
    if single_program and sim_backend:
        merged: dict[tuple, Bucket] = {}
        for b in out:
            # routing is part of the merge key: the two modes compile
            # different programs, so they can never share one executable
            mk = (b.key.kind, b.key.n_rates, b.key.routing)
            if mk not in merged:
                merged[mk] = Bucket(key=b.key, items=list(b.items))
            else:
                m = merged[mk]
                specs = [ps.spec for ps in m.items + b.items]
                m.key = BucketKey(
                    kind=b.key.kind, n_rates=b.key.n_rates,
                    shape=engine.bucket_shape(PadShape.of(specs)),
                    k_pad=max(m.key.k_pad, b.key.k_pad),
                    routing=b.key.routing)
                m.items += b.items
        out = list(merged.values())
    return Plan(experiment=experiment, buckets=out, skipped=skipped,
                single_program=single_program, skip_codes=skip_codes)
