"""Versioned result writers (DESIGN.md §10): one CSV/JSON code path.

Every benchmark and the `ResultFrame` writers funnel through here, so
all artifacts under results/ share one column discipline:

  * a `schema_version` column (first) stamps the row format — bump
    `SCHEMA_VERSION` on any breaking change to how rows are derived;
  * column order is stable: either the caller's explicit `columns`, or
    first-seen order across all rows (so adding a field to later rows
    cannot silently reshuffle a header);
  * missing values are written as empty cells, not `"None"`.

`benchmarks.common.write_csv` forwards here — the per-benchmark CSV
writers it replaced each had their own column-ordering quirks.
"""
from __future__ import annotations

import json
import os
from typing import Sequence

import numpy as np

#: bump on any breaking change to result-row derivation or layout
#: v2: fault columns (faults, failed_links, failed_chiplets) joined the
#: stable tidy-row layout (DESIGN.md §12)
#: v3: flight-recorder telemetry (DESIGN.md §13) — tidy rows gain
#: link_util_p95 / link_util_max / link_gini, and per-link heatmap
#: artifacts (obs.flight.LINK_COLUMNS, obs.report.SUMMARY_COLUMNS)
#: share this stamp
#: v4: static-analysis diagnostics (DESIGN.md §14) — tidy rows gain a
#: machine-readable `diag_code` column (DP006/FT001 skips, EX001 failed
#: chunks), synth rows carry rejection codes, and `Report.to_json`
#: diagnostics artifacts share this stamp
#: v5: adaptive routing (DESIGN.md §15) — tidy rows gain a `routing`
#: column (effective mode per scenario), per-link heatmap rows gain
#: `occ_escape` / `occ_adaptive` (escape-vs-adaptive VC-class occupancy)
#: v6: performance observability (DESIGN.md §16) — tidy rows gain
#: pad-waste columns (`pad_fill_state` / `pad_fill_chan` /
#: `pad_fill_phase`), windowed-telemetry time-heatmap artifacts
#: (obs.flight.WINDOW_COLUMNS, obs.report.WINDOW_SUMMARY_COLUMNS) share
#: this stamp, and sweep_speedup.csv splits warm host vs device time.
#: (BENCH_<name>.json files carry their own `bench_schema_version` —
#: see repro.obs.bench.)
SCHEMA_VERSION = 6


def stable_columns(rows: Sequence[dict],
                   columns: Sequence[str] | None = None) -> list:
    """schema_version + explicit columns, or first-seen union order."""
    if columns is None:
        seen: dict = {}
        for r in rows:
            for k in r:
                seen.setdefault(k, None)
        columns = list(seen)
    cols = [c for c in columns if c != "schema_version"]
    return ["schema_version"] + cols


def _cell(value) -> str:
    if value is None:
        return ""
    if isinstance(value, (np.floating, np.integer)):
        value = value.item()
    s = str(value)
    if any(c in s for c in ',"\n\r'):      # RFC-4180 quoting
        s = '"' + s.replace('"', '""') + '"'
    return s


def write_csv(path: str, rows: Sequence[dict],
              columns: Sequence[str] | None = None) -> list:
    """Write tidy rows with a stable, versioned header; returns the
    column order used.  Falsy rows (None placeholders) are dropped."""
    rows = [r for r in rows if r]
    if not rows:
        return []
    cols = stable_columns(rows, columns)
    os.makedirs(os.path.dirname(os.path.abspath(path)), exist_ok=True)
    with open(path, "w") as f:
        f.write(",".join(cols) + "\n")
        for r in rows:
            f.write(",".join(_cell(r.get(c, SCHEMA_VERSION
                                         if c == "schema_version" else
                                         None))
                             for c in cols) + "\n")
    print(f"[io] wrote {path} ({len(rows)} rows, schema v{SCHEMA_VERSION})")
    return cols


def write_json(path: str, rows: Sequence[dict],
               meta: dict | None = None) -> None:
    """Write rows as a versioned JSON document: {schema_version, meta
    fields, rows}.  numpy scalars/arrays are converted to plain JSON."""
    def default(o):
        if isinstance(o, (np.floating, np.integer)):
            return o.item()
        if isinstance(o, np.ndarray):
            return o.tolist()
        return str(o)

    doc = dict(schema_version=SCHEMA_VERSION, **(meta or {}),
               rows=[r for r in rows if r])
    os.makedirs(os.path.dirname(os.path.abspath(path)), exist_ok=True)
    with open(path, "w") as f:
        json.dump(doc, f, indent=1, default=default)
    print(f"[io] wrote {path} ({len(doc['rows'])} rows, "
          f"schema v{SCHEMA_VERSION})")


def read_json(path: str) -> dict:
    with open(path) as f:
        doc = json.load(f)
    if doc.get("schema_version") != SCHEMA_VERSION:
        raise ValueError(f"{path}: schema_version "
                         f"{doc.get('schema_version')!r} != "
                         f"{SCHEMA_VERSION} (regenerate the artifact)")
    return doc
