"""Declarative experiment API (DESIGN.md §10): the one front door.

    import repro.experiments as X

    exp = X.Experiment.grid(
        topologies=["mesh", "folded_hexa_torus"], sizes=[16, 64],
        substrates=["organic", "glass"],
        traffics=["uniform", my_workload],          # static + workload
        rates=X.SaturationGrid(6), cfg=SimConfig(...))
    frame = X.run(exp)                              # plan + execute
    frame.to_csv("results/my_grid.csv")             # versioned schema

`Scenario -> plan -> execute -> ResultFrame` replaces the six ad-hoc
sweep entry points that grew across PR 1–2 (`simulate`, `run_batch`,
`run_workloads`, `evaluate_many`, `evaluate_cases`,
`evaluate_workload_cases`): `simulate`/`run_batch`/`run_workloads`
remain the *primitive* layer this API lowers onto, while the three
case-level entry points are deprecation shims forwarding here.

The pipeline reproduces the legacy paths bitwise on identical grids
(tests/test_experiments.py): planning resolves the same routing cache,
traffic registries and rate grids; execution lowers onto the same
padded `SweepEngine` batches, whose padding invariance makes results
independent of how scenarios are grouped.

Observability (DESIGN.md §13): run with `SimConfig(telemetry=True)` and
the frame carries per-link flight-recorder counters — tidy rows gain
`link_util_p95` / `link_util_max` / `link_gini`, and
`ResultFrame.link_rows` / `all_link_rows` / `to_link_csv` render the
per-channel heatmap (see `repro.obs`).  Planning and execution are
span-traced (`repro.obs.trace`); enable tracing and call
`save_chrome_trace` for a Perfetto-loadable phase breakdown.
"""
from .execute import engine_for, execute, run
from .frame import COLUMNS, ResultFrame, scenario_row
from .io import SCHEMA_VERSION, read_json, write_csv, write_json
from .plan import (Bucket, BucketKey, Plan, PlannedScenario, plan,
                   resolve_topology)
from .scenario import (CustomTraffic, Experiment, ExplicitRates,
                       RatePolicy, SaturationGrid, Scenario,
                       scenario_from_case)

__all__ = [
    "Scenario", "Experiment", "CustomTraffic", "SaturationGrid",
    "ExplicitRates", "RatePolicy", "scenario_from_case",
    "plan", "Plan", "PlannedScenario", "Bucket", "BucketKey",
    "resolve_topology",
    "execute", "run", "engine_for",
    "ResultFrame", "COLUMNS", "scenario_row",
    "SCHEMA_VERSION", "write_csv", "write_json", "read_json",
]
