"""Declarative experiment descriptions (DESIGN.md §10).

A `Scenario` names ONE evaluation cell of the paper's grids — a
topology at a size, on a substrate, under a traffic source, swept over
an injection-rate policy.  An `Experiment` is an ordered list of
scenarios sharing one `SimConfig` (and a backend: the cycle-accurate
simulator or the analytic channel-load model).  Nothing here runs
anything: `repro.experiments.plan` lowers an experiment onto the
batched sweep engine and `repro.experiments.execute` runs the plan.

Traffic sources (the `traffic` field) come in three flavours:

  * a `str` — a named static pattern from `repro.core.traffic.PATTERNS`
    ("uniform", "tornado", ...);
  * a `CustomTraffic` — a named `topo -> [N, N] matrix` builder for
    static matrices that are not registry patterns (e.g. one region of
    a Netrace-like trace);
  * a `repro.workloads.Workload` (or any callable `topo -> Schedule`)
    — a time-varying phase schedule replayed by the simulator
    (DESIGN.md §9).

Rate policies say which offered rates the sweep visits:

  * `SaturationGrid(n_rates)` — a grid bracketing the scenario's
    analytic channel-load bound (resolved per scenario at plan time,
    exactly `simulator.saturation_rate_grid`);
  * `ExplicitRates(rates)` — a fixed grid shared verbatim.
"""
from __future__ import annotations

import dataclasses
import itertools
from typing import Callable, Sequence

import numpy as np

from repro.core import topology as T
from repro.core.simulator import (SimConfig, routing_headroom,
                                  saturation_rate_grid)


# ---------------------------------------------------------------------
# rate policies
# ---------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class SaturationGrid:
    """Offered-rate grid seeded from the analytic saturation bound.

    `headroom` overrides the grid's ceiling multiplier above the static
    analytic bound; None picks the routing-mode default (static 2x,
    adaptive 3x — adaptive sweeps can exceed the static bound, see
    DESIGN.md §15), so the same policy object works for both modes.
    """
    n_rates: int = 6
    headroom: float | None = None

    def resolve(self, analytic: float,
                routing: str = "static") -> np.ndarray:
        h = self.headroom if self.headroom is not None \
            else routing_headroom(routing)
        return saturation_rate_grid(analytic, self.n_rates, headroom=h)

    def describe(self) -> str:
        if self.headroom is not None:
            return f"saturation_grid({self.n_rates},x{self.headroom:g})"
        return f"saturation_grid({self.n_rates})"


@dataclasses.dataclass(frozen=True)
class ExplicitRates:
    """A fixed offered-rate grid, used verbatim for the scenario."""
    rates: tuple = ()

    def __post_init__(self):
        object.__setattr__(
            self, "rates",
            tuple(float(r) for r in np.ravel(np.asarray(self.rates))))
        if not self.rates:
            raise ValueError("ExplicitRates needs at least one rate")

    def resolve(self, analytic: float,
                routing: str = "static") -> np.ndarray:
        return np.asarray(self.rates, np.float64)

    def describe(self) -> str:
        return "rates(" + ",".join(f"{r:g}" for r in self.rates) + ")"


RatePolicy = SaturationGrid | ExplicitRates


# ---------------------------------------------------------------------
# traffic sources
# ---------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class CustomTraffic:
    """A named static-traffic builder: `build(topo) -> [N, N]` matrix."""
    name: str
    build: Callable


def traffic_kind(traffic) -> str:
    """'static' for named patterns / CustomTraffic, 'workload' for
    schedule builders (`Workload` or bare `topo -> Schedule`)."""
    if isinstance(traffic, (str, CustomTraffic)):
        return "static"
    if hasattr(traffic, "build") or callable(traffic):
        return "workload"
    raise TypeError(f"unsupported traffic source {traffic!r}")


def traffic_name(traffic) -> str:
    if isinstance(traffic, str):
        return traffic
    name = getattr(traffic, "name", "")
    return str(name) if name else getattr(traffic, "__name__", "custom")


# ---------------------------------------------------------------------
# Scenario / Experiment
# ---------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class Scenario:
    """One evaluation cell: topology x substrate x traffic x rates.

    `topology` is a registry name (built-in Table III or
    `topology.register_topology`-ed), a first-class `Topology` object
    (e.g. a synthesized candidate from `repro.synth`), or a generator
    callable `n -> Topology | (name, pos, edges)`.  Non-string
    topologies are validated and routed at plan time via the
    structural-hash routing cache, so arbitrarily many synthesized
    scenarios can share names without colliding.

    `substrate`/`area` default to None = *inherit*: a `Topology`
    object keeps its own substrate and chiplet area (a glass candidate
    stays glass), registry names and generator callables fall back to
    the paper defaults (organic, 74 mm^2).  Pass explicit values to
    re-stamp a `Topology` onto a different substrate.

    `faults` (a `repro.faults.FaultSet`, DESIGN.md §12) degrades the
    resolved topology before routing: dead links and dead chiplets'
    links are masked out of the edge list, deadlock-free routing is
    rebuilt for the degraded structure (the structural-hash routing
    cache keys it separately from the pristine topology), and traffic
    to/from dead chiplets is masked.  `faults=None` and an *empty*
    `FaultSet` are bitwise identical to each other — the zero-fault
    path is exactly the pristine path.
    """
    topology: object                 # str | Topology | callable(n)
    n: int
    substrate: str | None = None     # None = inherit / organic
    traffic: object = "uniform"      # str | CustomTraffic | Workload
    area: float | None = None        # None = inherit / 74.0
    roles: str = "homogeneous"
    rates: RatePolicy = SaturationGrid()
    fit_schedule: bool = True        # fit workloads to the meas. window
    faults: object = None            # repro.faults.FaultSet | None
    routing: str | None = None       # None = inherit Experiment cfg
    tags: tuple = ()                 # extra ((column, value), ...) pairs

    def __post_init__(self):
        from .frame import COLUMNS   # deferred: frame imports scenario
        bad = [k for k, _ in self.tags if k in COLUMNS]
        if bad:
            raise ValueError(f"tags {bad} collide with reserved result "
                             f"columns; pick different tag names")
        if self.routing not in (None, "static", "adaptive"):
            raise ValueError(f"unknown routing mode {self.routing!r}; "
                             f"choose 'static', 'adaptive' or None "
                             f"(inherit the experiment SimConfig)")
        if self.faults is not None:
            from repro.faults import FaultSet   # deferred: optional layer
            if not isinstance(self.faults, FaultSet):
                raise TypeError(
                    f"faults must be a repro.faults.FaultSet (or None), "
                    f"got {type(self.faults).__name__}; build one with "
                    f"faults.sample_faults(topo, k, kind)")

    @property
    def kind(self) -> str:
        return traffic_kind(self.traffic)

    @property
    def traffic_name(self) -> str:
        return traffic_name(self.traffic)

    @property
    def topology_name(self) -> str:
        """Label for result rows: the registry name, a `Topology`'s own
        name, or a generator callable's name attribute."""
        t = self.topology
        if isinstance(t, str):
            return t
        name = getattr(t, "name", "")
        return str(name) if name else getattr(t, "__name__", "custom")

    @property
    def resolved_substrate(self) -> str:
        if self.substrate is not None:
            return self.substrate
        if isinstance(self.topology, T.Topology):
            return self.topology.substrate
        return "organic"

    @property
    def resolved_area(self) -> float:
        if self.area is not None:
            return self.area
        if isinstance(self.topology, T.Topology):
            return self.topology.chiplet_area_mm2
        return 74.0

    @property
    def valid(self) -> bool:
        return not isinstance(self.topology, str) \
            or T.valid_n(self.topology, self.n)

    @property
    def degraded(self) -> bool:
        """True when a non-empty fault set degrades this scenario."""
        return self.faults is not None and not self.faults.empty

    @property
    def fault_name(self) -> str:
        return self.faults.name if self.degraded else "none"

    def effective_routing(self, cfg: SimConfig) -> str:
        """Routing mode this scenario runs under a given SimConfig:
        its own `routing` override, else the config's."""
        return self.routing if self.routing is not None else cfg.routing

    @property
    def label(self) -> str:
        base = (f"{self.topology_name}/n{self.n}/"
                f"{self.resolved_substrate}/{self.traffic_name}")
        return f"{base}/{self.fault_name}" if self.degraded else base


def scenario_from_case(case, traffic=None,
                       rates: RatePolicy = SaturationGrid()) -> Scenario:
    """Adapt a legacy `sweep.SweepCase` (its pattern, or an explicit
    workload riding on its placement) into a Scenario."""
    return Scenario(topology=case.name, n=case.n, substrate=case.substrate,
                    traffic=case.pattern if traffic is None else traffic,
                    area=case.area, roles=case.roles, rates=rates)


@dataclasses.dataclass
class Experiment:
    """An ordered list of scenarios sharing one SimConfig + backend."""
    scenarios: Sequence[Scenario]
    cfg: SimConfig = SimConfig()
    name: str = "experiment"
    backend: str = "sim"             # "sim" | "analytic"

    def __post_init__(self):
        self.scenarios = list(self.scenarios)
        if self.backend not in ("sim", "analytic"):
            raise ValueError(f"unknown backend {self.backend!r}")

    def __len__(self) -> int:
        return len(self.scenarios)

    def __iter__(self):
        return iter(self.scenarios)

    @classmethod
    def grid(cls, topologies: Sequence[str], sizes: Sequence[int],
             substrates: Sequence[str] = ("organic",),
             traffics: Sequence = ("uniform",),
             areas: Sequence[float] = (74.0,),
             roles: Sequence[str] = ("homogeneous",),
             rates: RatePolicy = SaturationGrid(),
             cfg: SimConfig = SimConfig(), name: str = "grid",
             backend: str = "sim") -> "Experiment":
        """Product grid in (area, substrate, role, traffic, topology,
        size) major-to-minor order — the figure benches' loop order."""
        scens = [Scenario(topology=t, n=n, substrate=sub, traffic=tr,
                          area=a, roles=ro, rates=rates)
                 for a, sub, ro, tr, t, n in itertools.product(
                     areas, substrates, roles, traffics, topologies, sizes)]
        return cls(scens, cfg=cfg, name=name, backend=backend)
