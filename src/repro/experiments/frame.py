"""Typed experiment results (DESIGN.md §10): the `ResultFrame`.

One tidy row per scenario — identity fields (topology, n, substrate,
traffic, ...), a status ("ok" / "invalid" / "failed"), the analytic and
simulated saturation, and the paper's §V-B cost-model derivations
(absolute Gb/s through the substrate wires, latency in ns, PHY area,
power) — in the experiment's scenario order, plus the raw per-scenario
engine result dicts for anything a tidy row can't hold (full rate
sweeps, per-phase counters).

The tidy columns are stable and versioned: `to_csv` / `to_json` write
through `repro.experiments.io`, which stamps every artifact with
`schema_version`.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core import costmodel as cm
from repro.core.simulator import zero_load_latency

from . import io as xio
from .plan import PlannedScenario
from .scenario import Experiment, Scenario

#: stable tidy-row column order (scenario tags append after these)
COLUMNS = (
    "experiment", "backend", "status", "topology", "n", "substrate",
    "roles", "area_mm2", "traffic", "kind", "rates", "routing",
    "faults", "failed_links", "failed_chiplets",
    "analytic_saturation", "sim_saturation", "rel_throughput",
    "abs_throughput_gbps", "latency_ns", "avg_hops", "chiplet_area_mm2",
    "phy_area_frac", "power_w", "max_link_mm", "radix",
    "link_util_p95", "link_util_max", "link_gini",
    "pad_fill_state", "pad_fill_chan", "pad_fill_phase",
    "error", "diag_code",
)


def _identity_row(exp: Experiment, s: Scenario, status: str,
                  error: str = "", diag_code: str = "") -> dict:
    row = dict.fromkeys(COLUMNS)
    fs = s.faults if s.degraded else None
    row.update(experiment=exp.name, backend=exp.backend, status=status,
               topology=s.topology_name, n=s.n,
               substrate=s.resolved_substrate, roles=s.roles,
               area_mm2=s.resolved_area, traffic=s.traffic_name,
               kind=s.kind, rates=s.rates.describe(),
               routing=s.effective_routing(exp.cfg),
               faults=s.fault_name,
               failed_links=fs.n_links if fs else 0,
               failed_chiplets=fs.n_chiplets if fs else 0, error=error,
               diag_code=diag_code)
    row.update(dict(s.tags))
    return row


def scenario_row(exp: Experiment, ps: PlannedScenario,
                 res: dict | None) -> dict:
    """Tidy row for one executed scenario (res=None: analytic backend).

    Mirrors the legacy `benchmarks.common._cell_row` derivation exactly:
    the scenario's relative saturation (simulated plateau, or the
    analytic channel-load bound) and latency feed the §V-B cost model
    at the traffic's average hop count.
    """
    row = _identity_row(exp, ps.scenario, "ok")
    if res is not None:
        k = int(np.argmax(res["throughput"]))
        t_r = float(res["throughput"][k])
        lat = float(res["latency"][k])
        row["sim_saturation"] = t_r
        if "pad_fill" in res:            # pad-waste accounting (§16)
            pf = res["pad_fill"]
            row.update(pad_fill_state=round(float(pf["state"]), 4),
                       pad_fill_chan=round(float(pf["chan"]), 4),
                       pad_fill_phase=round(float(pf["phase"]), 4))
        if "link_util" in res:           # flight recorder was on
            from repro.obs.report import gini
            util = np.asarray(res["link_util"][k], np.float64)
            if util.size:
                row.update(
                    link_util_p95=round(
                        float(np.percentile(util, 95)), 6),
                    link_util_max=round(float(util.max()), 6),
                    link_gini=round(gini(util), 6))
    else:
        t_r = ps.analytic
        lat = zero_load_latency(ps.routing, ps.traffic)
    _, hops, _ = ps.routing.paths_channel_loads(ps.traffic)
    w = ps.traffic / max(ps.traffic.sum(), 1e-12)
    avg_hops = float((hops * w).sum())
    rep = cm.report(ps.topo, t_r, avg_hops, lat)
    row.update(analytic_saturation=ps.analytic,
               rel_throughput=rep.rel_throughput,
               abs_throughput_gbps=rep.abs_throughput_gbps,
               latency_ns=rep.avg_latency_ns, avg_hops=avg_hops,
               chiplet_area_mm2=rep.area_mm2,
               phy_area_frac=rep.phy_area_fraction, power_w=rep.power_w,
               max_link_mm=rep.max_link_mm, radix=rep.radix)
    return row


@dataclasses.dataclass
class ResultFrame:
    """Execution results in experiment order (one slot per scenario)."""
    experiment: Experiment
    rows: list                       # tidy dict per scenario
    results: list                    # raw engine dict | None per scenario
    planned: list                    # PlannedScenario | None per scenario
    errors: list                     # [(scenario index, message)]

    def __len__(self) -> int:
        return len(self.rows)

    def __iter__(self):
        return iter(self.rows)

    @property
    def columns(self) -> tuple:
        extra = [k for r in self.rows for k in r if k not in COLUMNS]
        seen: dict = {}
        for k in extra:
            seen.setdefault(k, None)
        return COLUMNS + tuple(seen)

    def ok(self) -> list:
        return [r for r in self.rows if r["status"] == "ok"]

    def select(self, **eq) -> list:
        """Tidy rows matching all field==value constraints."""
        return [r for r in self.rows
                if all(r.get(k) == v for k, v in eq.items())]

    def best(self, metric: str = "abs_throughput_gbps", **eq) -> dict:
        rows = [r for r in (self.select(**eq) if eq else self.rows)
                if r["status"] == "ok" and r.get(metric) is not None]
        if not rows:
            raise ValueError(f"no ok rows match {eq} with {metric!r}")
        return max(rows, key=lambda r: r[metric])

    # ---- legacy-shaped per-scenario views -----------------------------
    def case_result(self, i: int) -> dict | None:
        """`SweepEngine.evaluate_cases`-shaped dict for scenario i."""
        ps, res = self.planned[i], self.results[i]
        if ps is None or res is None:
            return None
        k = int(np.argmax(res["throughput"]))
        return dict(case=ps.scenario,
                    sim_saturation=float(res["throughput"][k]),
                    analytic_saturation=ps.analytic,
                    latency_at_sat=float(res["latency"][k]), sweep=res)

    def workload_result(self, i: int) -> dict | None:
        """`evaluate_workload_cases`-shaped dict for scenario i."""
        out = self.case_result(i)
        ps = self.planned[i]
        if out is None or ps.schedule is None:
            return out
        res = self.results[i]
        k = int(np.argmax(res["throughput"]))
        out.update(workload=ps.schedule.name,
                   phase_labels=[p.label or str(j) for j, p in
                                 enumerate(ps.schedule.phases)],
                   throughput_ph=res["throughput_ph"][k],
                   latency_ph=res["latency_ph"][k],
                   offered_rate_ph=res["offered_rate_ph"][k],
                   phase_cycles=res["phase_cycles"])
        return out

    # ---- flight-recorder views (DESIGN.md §13) ------------------------
    def link_rows(self, i: int, rate_index: int | None = None) -> list:
        """Tidy per-link telemetry rows for scenario i (requires the
        experiment to have run with `SimConfig(telemetry=True)`)."""
        from repro.obs.flight import link_rows as _rows
        ps, res = self.planned[i], self.results[i]
        if ps is None or res is None:
            return []
        cfg = self.experiment.cfg
        return _rows(ps, res, cfg.cycles - cfg.warmup,
                     experiment=self.experiment.name,
                     rate_index=rate_index)

    def all_link_rows(self, rate_index: int | None = None) -> list:
        """Per-link rows for every ok scenario, in scenario order."""
        out: list = []
        for i in range(len(self.rows)):
            out.extend(self.link_rows(i, rate_index=rate_index))
        return out

    def to_link_csv(self, path: str,
                    rate_index: int | None = None) -> None:
        """Write the per-link heatmap CSV (schema v3) for this frame."""
        from repro.obs.flight import LINK_COLUMNS
        rows = self.all_link_rows(rate_index=rate_index)
        extra = [k for r in rows for k in r if k not in LINK_COLUMNS]
        seen: dict = {}
        for k in extra:
            seen.setdefault(k, None)
        xio.write_csv(path, rows, columns=list(LINK_COLUMNS) + list(seen))

    # ---- windowed-telemetry views (DESIGN.md §16) ---------------------
    def window_rows(self, i: int, rate_index: int | None = None) -> list:
        """Tidy per-(time-window, link) rows for scenario i (requires
        `SimConfig(telemetry=True, telemetry_windows=W)`)."""
        from repro.obs.flight import window_rows as _rows
        ps, res = self.planned[i], self.results[i]
        if ps is None or res is None:
            return []
        return _rows(ps, res, experiment=self.experiment.name,
                     rate_index=rate_index)

    def all_window_rows(self, rate_index: int | None = None) -> list:
        """Per-(window, link) rows for every ok scenario, in order."""
        out: list = []
        for i in range(len(self.rows)):
            out.extend(self.window_rows(i, rate_index=rate_index))
        return out

    def to_window_csv(self, path: str,
                      rate_index: int | None = None) -> None:
        """Write the time-heatmap CSV (per window x link) for this
        frame — the artifact that shows hotspot drift over time."""
        from repro.obs.flight import WINDOW_COLUMNS
        rows = self.all_window_rows(rate_index=rate_index)
        extra = [k for r in rows for k in r if k not in WINDOW_COLUMNS]
        seen: dict = {}
        for k in extra:
            seen.setdefault(k, None)
        xio.write_csv(path, rows,
                      columns=list(WINDOW_COLUMNS) + list(seen))

    # ---- versioned writers --------------------------------------------
    def to_csv(self, path: str, include_failures: bool = False) -> None:
        rows = self.rows if include_failures else self.ok()
        xio.write_csv(path, rows, columns=self.columns)

    def to_json(self, path: str, include_failures: bool = False) -> None:
        rows = self.rows if include_failures else self.ok()
        xio.write_json(path, rows, meta=dict(
            experiment=self.experiment.name,
            backend=self.experiment.backend,
            n_scenarios=len(self.experiment),
            columns=list(self.columns)))
