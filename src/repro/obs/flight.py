"""Flight-recorder post-processing: telemetry tensors -> tidy link rows.

`SimConfig(telemetry=True)` makes `run_batch` return per-directed-
channel counter arrays (DESIGN.md §13).  This module renders them as
tidy rows — one row per directed channel of the *simulated* structure,
plus one `status="dead"` row per direction of every fault-masked link —
so the load distribution that explains the paper's results (folding
spreads channel load; Mesh/Torus concentrate it) is a first-class,
versioned artifact instead of an aggregate.

Row discipline:

  * sacrificial and padded lanes never appear: `run_batch` slices the
    counter tensors to the spec's own channel/node counts before they
    reach this module;
  * a degraded scenario reports its surviving channels from the
    *degraded* routing (they carry the traffic) and its dead links from
    the fault set — explicitly failed links, plus every base-topology
    link incident to a dead chiplet;
  * `util` is busy cycles / measured cycles in [0, 1]; `occ_mean` is
    the mean number of buffered flits at the channel's downstream input
    port over the measured window;
  * `occ_escape` / `occ_adaptive` split `occ_mean` by VC class
    (DESIGN.md §15): VC 0 is the deadlock-free escape drain, VCs 1..V-1
    are the adaptive class — under `routing="static"` the adaptive
    column still reports the static occupancy of those lanes.
"""
from __future__ import annotations

import numpy as np

#: stable tidy-row column order for per-link rows (scenario tags append)
LINK_COLUMNS = (
    "experiment", "topology", "n", "substrate", "traffic", "faults",
    "status", "rate", "channel", "src", "dst", "len_mm", "depth_cycles",
    "busy", "util", "stalls", "occ_mean", "occ_escape", "occ_adaptive",
)


def _base_topology(scenario):
    """The pristine topology a degraded scenario was derived from, or
    None when it cannot be rebuilt (exotic generator callables)."""
    from repro.core import topology as T
    t = scenario.topology
    try:
        if isinstance(t, str):
            return T.build(t, scenario.n,
                           substrate=scenario.resolved_substrate,
                           chiplet_area_mm2=scenario.resolved_area,
                           roles_scheme=scenario.roles)
        if isinstance(t, T.Topology):
            return t
        src = t(scenario.n)
        if isinstance(src, T.Topology):
            return src
        name, pos, edges = src
        return T.make_topology(name, pos, edges)
    except Exception:                     # noqa: BLE001 — best effort
        return None


def dead_links(scenario) -> list[tuple[int, int]]:
    """Undirected (u, v) pairs masked out by the scenario's fault set:
    the explicitly failed links plus every base-topology link incident
    to a dead chiplet.  Pristine scenarios have none."""
    if not getattr(scenario, "degraded", False):
        return []
    fs = scenario.faults
    dead = set(fs.links)
    if fs.chiplets:
        base = _base_topology(scenario)
        if base is not None:
            dc = set(fs.chiplets)
            for a, b in np.sort(np.asarray(base.edges, np.int64), axis=1):
                if int(a) in dc or int(b) in dc:
                    dead.add((int(a), int(b)))
    return sorted(dead)


def link_rows(planned, res: dict, meas: int, *, experiment: str = "",
              rate_index: int | None = None) -> list[dict]:
    """Tidy per-link rows for one executed scenario.

    planned: a `repro.experiments.plan.PlannedScenario` (duck-typed:
    needs `.scenario`, `.routing`, `.spec`); res: its engine result
    dict carrying the `simulator.TELEMETRY_KEYS`; meas: measured cycles
    (`cfg.cycles - cfg.warmup`).  rate_index picks the offered-rate row
    (default: the saturation plateau, argmax delivered throughput —
    the same row the tidy scenario metrics report).
    """
    if "link_busy" not in res:
        raise ValueError(
            "result carries no telemetry — run with "
            "SimConfig(telemetry=True) to record the flight data")
    s = planned.scenario
    routing = planned.routing
    k = int(np.argmax(res["throughput"])) if rate_index is None \
        else int(rate_index)
    rate = float(res["rate"][k])
    busy = np.asarray(res["link_busy"][k])          # [c]
    stall = np.asarray(res["link_stall"][k])        # [c]
    occ = np.asarray(res["link_occ_sum"][k])        # [c, V]
    util = busy / float(max(meas, 1))
    occ_mean = occ.sum(axis=1) / float(max(meas, 1))
    occ_esc = occ[:, 0] / float(max(meas, 1))
    occ_ad = occ[:, 1:].sum(axis=1) / float(max(meas, 1))
    depth = planned.spec.ch_depth if planned.spec is not None else None
    tags = dict(s.tags)

    def row(**kw):
        r = dict.fromkeys(LINK_COLUMNS)
        r.update(experiment=experiment, topology=s.topology_name, n=s.n,
                 substrate=s.resolved_substrate, traffic=s.traffic_name,
                 faults=s.fault_name, rate=rate, **kw)
        r.update(tags)
        return r

    rows = [row(status="ok", channel=c,
                src=int(routing.ch_src[c]), dst=int(routing.ch_dst[c]),
                len_mm=round(float(routing.ch_len_mm[c]), 3),
                depth_cycles=int(depth[c]) if depth is not None else None,
                busy=int(busy[c]), util=round(float(util[c]), 6),
                stalls=int(stall[c]),
                occ_mean=round(float(occ_mean[c]), 4),
                occ_escape=round(float(occ_esc[c]), 4),
                occ_adaptive=round(float(occ_ad[c]), 4))
            for c in range(len(busy))]
    for u, v in dead_links(s):
        for a, b in ((u, v), (v, u)):
            rows.append(row(status="dead", channel=-1, src=a, dst=b,
                            busy=0, util=0.0, stalls=0, occ_mean=0.0,
                            occ_escape=0.0, occ_adaptive=0.0))
    return rows


#: stable tidy-row column order for per-(window, link) rows
WINDOW_COLUMNS = (
    "experiment", "topology", "n", "substrate", "traffic", "faults",
    "rate", "window", "t_start", "t_end", "cycles", "channel", "src",
    "dst", "busy", "util", "stalls", "occ_mean", "occ_escape",
    "occ_adaptive",
)


def window_rows(planned, res: dict, *, experiment: str = "",
                rate_index: int | None = None) -> list[dict]:
    """Tidy per-(time-window, link) rows for one executed scenario.

    Same duck-typed inputs as `link_rows`, but the result must carry
    the windowed counters (`SimConfig(telemetry_windows=W)`,
    DESIGN.md §16).  One row per (window, directed channel); `t_start`/
    `t_end` are measured-window cycle offsets (warmup excluded), so a
    drift schedule's hotspot migration reads directly off consecutive
    windows of the same channel.  Utilisation and occupancy normalize
    by each window's own cycle count — windows need not divide the
    measured span evenly.
    """
    if "link_busy_w" not in res:
        raise ValueError(
            "result carries no windowed telemetry — run with "
            "SimConfig(telemetry=True, telemetry_windows=W)")
    s = planned.scenario
    routing = planned.routing
    k = int(np.argmax(res["throughput"])) if rate_index is None \
        else int(rate_index)
    rate = float(res["rate"][k])
    busy = np.asarray(res["link_busy_w"][k])        # [W, c]
    stall = np.asarray(res["link_stall_w"][k])      # [W, c]
    occ = np.asarray(res["link_occ_w"][k])          # [W, c, V]
    wc = np.asarray(res["window_cycles"])           # [W]
    edges = np.concatenate([[0], np.cumsum(wc)])
    tags = dict(s.tags)
    rows = []
    for w in range(len(wc)):
        cyc = float(max(int(wc[w]), 1))
        for c in range(busy.shape[1]):
            r = dict.fromkeys(WINDOW_COLUMNS)
            r.update(experiment=experiment, topology=s.topology_name,
                     n=s.n, substrate=s.resolved_substrate,
                     traffic=s.traffic_name, faults=s.fault_name,
                     rate=rate, window=w, t_start=int(edges[w]),
                     t_end=int(edges[w + 1]), cycles=int(wc[w]),
                     channel=c, src=int(routing.ch_src[c]),
                     dst=int(routing.ch_dst[c]), busy=int(busy[w, c]),
                     util=round(float(busy[w, c]) / cyc, 6),
                     stalls=int(stall[w, c]),
                     occ_mean=round(float(occ[w, c].sum()) / cyc, 4),
                     occ_escape=round(float(occ[w, c, 0]) / cyc, 4),
                     occ_adaptive=round(
                         float(occ[w, c, 1:].sum()) / cyc, 4))
            r.update(tags)
            rows.append(r)
    return rows
