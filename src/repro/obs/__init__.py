"""Opt-in observability (DESIGN.md §13): tracing, metrics, flight data.

Two halves, both off by default and bitwise-inert when off:

  * **host-side**: `trace(...)` spans (Chrome-trace/Perfetto JSON via
    `save_chrome_trace`) and a process-wide `metrics` registry (counters,
    events, JSONL log) that also absorbs the simulator/routing cache
    hit/miss/eviction counters;
  * **in-sim**: the flight recorder — `SimConfig(telemetry=True)` makes
    the batched simulator carry per-link/per-port counter tensors
    through the scan; `obs.flight` turns them into tidy per-link rows
    and `obs.report` into link-load heatmap/summary CSVs.
"""
from .trace import (Span, clear_trace, disable_tracing, enable_tracing,  # noqa
                    get_spans, save_chrome_trace, trace, tracing_enabled)
from .metrics import (MetricsRegistry, cache_counters, metrics)  # noqa
from .flight import link_rows, LINK_COLUMNS  # noqa
from .report import gini, link_load_summary, write_link_reports  # noqa
