"""Opt-in observability (DESIGN.md §13): tracing, metrics, flight data.

Two halves, both off by default and bitwise-inert when off:

  * **host-side**: `trace(...)` spans (Chrome-trace/Perfetto JSON via
    `save_chrome_trace`) and a process-wide `metrics` registry (counters,
    events, JSONL log) that also absorbs the simulator/routing cache
    hit/miss/eviction counters;
  * **in-sim**: the flight recorder — `SimConfig(telemetry=True)` makes
    the batched simulator carry per-link/per-port counter tensors
    through the scan; `obs.flight` turns them into tidy per-link rows
    and `obs.report` into link-load heatmap/summary CSVs.

PR 10 (DESIGN.md §16) adds the performance half: time-windowed
telemetry (`SimConfig(telemetry_windows=W)` -> `window_rows` /
`write_window_reports` time-heatmaps), opt-in XLA cost/memory
profiling per compiled runner (`obs.profile`), and the structured
benchmark harness + regression gate (`obs.bench`,
`python -m repro.obs.bench compare`).
"""
from .trace import (Span, clear_trace, disable_tracing, enable_tracing,  # noqa
                    get_spans, save_chrome_trace, span_summary, trace,
                    tracing_enabled)
from .metrics import (MetricsRegistry, cache_counters, metrics)  # noqa
from .flight import link_rows, window_rows, LINK_COLUMNS, WINDOW_COLUMNS  # noqa
from .report import (gini, link_load_summary, window_summary,  # noqa
                     write_link_reports, write_window_reports)
from .profile import (ProfileRegistry, clear_profiles, disable_profiling,  # noqa
                      enable_profiling, get_profiles, profiling_enabled)
from .bench import (BENCH_SCHEMA_VERSION, bench_doc, compare,  # noqa
                    load_bench, write_bench)
