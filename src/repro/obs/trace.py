"""Lightweight span tracing (DESIGN.md §13): `with trace("name"): ...`.

A span is one timed region of host-side work — planning, bucketing,
compilation, device execution.  Spans are recorded in-process by a
thread-safe collector and exported as Chrome-trace/Perfetto JSON
(`save_chrome_trace`), the format `chrome://tracing`, Perfetto UI and
`speedscope` all read.

Design constraints, in order:

  * **off is free**: tracing is disabled by default and a disabled
    `trace(...)` does no clock reads, no allocation beyond a shared
    no-op span, and takes no lock — it is safe to leave on hot paths;
  * **timing is honest**: `perf_counter_ns` (monotonic), duration is
    measured around the `with` body only, and nothing here ever
    synchronizes a device — callers that want dispatch/wait splits do
    the `block_until_ready` themselves in a second span;
  * **thread-safe**: spans carry the recording thread's id and the
    collector appends under a lock, so worker threads can trace freely.

Spans nest lexically ("X" phase events; the viewer reconstructs the
stack per thread from the timestamps).  Attributes are free-form
key/values: pass them at open (`trace("run", shape=str(s))`) or attach
mid-span (`with trace("run") as sp: sp.set(cold=True)`).
"""
from __future__ import annotations

import json
import os
import threading
import time
from dataclasses import dataclass, field


@dataclass
class Span:
    """One recorded region; `ts`/`dur` are perf_counter nanoseconds."""
    name: str
    cat: str = ""
    ts: int = 0
    dur: int = 0
    tid: int = 0
    args: dict = field(default_factory=dict)

    def set(self, **attrs) -> "Span":
        """Attach attributes to a live (or finished) span."""
        self.args.update(attrs)
        return self


class _SpanCM:
    """Context manager recording one span into a tracer."""
    __slots__ = ("_tracer", "_span")

    def __init__(self, tracer: "Tracer", span: Span):
        self._tracer = tracer
        self._span = span

    def __enter__(self) -> Span:
        self._span.ts = time.perf_counter_ns()
        return self._span

    def __exit__(self, exc_type, exc, tb) -> None:
        sp = self._span
        sp.dur = time.perf_counter_ns() - sp.ts
        if exc_type is not None:
            sp.args.setdefault("error", exc_type.__name__)
        self._tracer._record(sp)


class _NullCM:
    """Shared no-op for disabled tracers: no clock, no lock, no append."""
    __slots__ = ()
    _SPAN = Span(name="")         # .set() works but goes nowhere visible

    def __enter__(self) -> Span:
        return self._SPAN

    def __exit__(self, exc_type, exc, tb) -> None:
        return None


_NULL = _NullCM()


class Tracer:
    """Thread-safe span collector; one process-wide instance below."""

    def __init__(self):
        self._lock = threading.Lock()
        self._spans: list[Span] = []
        self._enabled = False

    # ---- lifecycle -----------------------------------------------------
    def enable(self) -> None:
        self._enabled = True

    def disable(self) -> None:
        self._enabled = False

    @property
    def enabled(self) -> bool:
        return self._enabled

    def clear(self) -> None:
        with self._lock:
            self._spans = []

    # ---- recording -----------------------------------------------------
    def trace(self, name: str, cat: str = "", **attrs):
        if not self._enabled:
            return _NULL
        return _SpanCM(self, Span(name=name, cat=cat,
                                  tid=threading.get_ident(), args=attrs))

    def _record(self, span: Span) -> None:
        with self._lock:
            self._spans.append(span)

    def spans(self) -> list[Span]:
        with self._lock:
            return list(self._spans)

    # ---- export --------------------------------------------------------
    def chrome_events(self) -> list[dict]:
        """Complete ("X") events, microsecond timestamps, one per span.

        Sorted by start time: spans are *recorded* at close (children
        before parents), but trace viewers reconstruct per-thread nesting
        from event order and timestamps, so parents must come first for
        correct nested-span attribution."""
        pid = os.getpid()
        return sorted(
            (dict(name=s.name, cat=s.cat or "repro", ph="X",
                  ts=s.ts / 1e3, dur=s.dur / 1e3, pid=pid, tid=s.tid,
                  args={k: _jsonable(v) for k, v in s.args.items()})
             for s in self.spans()),
            key=lambda e: (e["tid"], e["ts"], -e["dur"]))

    def save_chrome_trace(self, path: str, metadata: dict | None = None
                          ) -> int:
        """Write the Chrome-trace JSON document; returns #events."""
        events = self.chrome_events()
        doc = dict(traceEvents=events, displayTimeUnit="ms",
                   metadata=metadata or {})
        d = os.path.dirname(os.path.abspath(path))
        os.makedirs(d, exist_ok=True)
        with open(path, "w") as f:
            json.dump(doc, f, indent=1)
        print(f"[obs] wrote {path} ({len(events)} spans)")
        return len(events)


def _jsonable(v):
    if isinstance(v, (str, int, float, bool)) or v is None:
        return v
    return str(v)


def span_summary(spans) -> dict[str, dict]:
    """Aggregate a span list by name: {name: {count, total_s, max_s}}.

    The compact per-phase rollup the benchmark harness embeds in BENCH
    JSON (DESIGN.md §16) — how many times each phase ran and where the
    wall-clock went, without shipping the full trace."""
    out: dict[str, dict] = {}
    for s in spans:
        agg = out.setdefault(s.name, dict(count=0, total_s=0.0, max_s=0.0))
        agg["count"] += 1
        dur = s.dur / 1e9
        agg["total_s"] += dur
        agg["max_s"] = max(agg["max_s"], dur)
    for agg in out.values():
        agg["total_s"] = round(agg["total_s"], 6)
        agg["max_s"] = round(agg["max_s"], 6)
    return out


# ---------------------------------------------------------------------
# process-wide default tracer + module-level convenience API
# ---------------------------------------------------------------------

TRACER = Tracer()


def trace(name: str, cat: str = "", **attrs):
    """`with trace("phase", key=val) as sp:` — record one span."""
    return TRACER.trace(name, cat, **attrs)


def enable_tracing() -> None:
    TRACER.enable()


def disable_tracing() -> None:
    TRACER.disable()


def tracing_enabled() -> bool:
    return TRACER.enabled


def clear_trace() -> None:
    TRACER.clear()


def get_spans() -> list[Span]:
    return TRACER.spans()


def save_chrome_trace(path: str, metadata: dict | None = None) -> int:
    return TRACER.save_chrome_trace(path, metadata)
