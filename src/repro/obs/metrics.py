"""Unified metrics registry (DESIGN.md §13): counters, events, JSONL.

One process-wide `metrics` instance gathers the host-side numbers that
used to live in ad-hoc dicts: sweep-engine run/compile stats, executor
chunk outcomes, synthesis generation counts.  Three primitives:

  * `inc(name, n)` — monotonic counters (thread-safe);
  * `observe(name, value)` — running count/sum/min/max of a value
    (wall-clock seconds, batch sizes, ...);
  * `event(name, **fields)` — an append-only structured log entry,
    wall-clock stamped, optionally mirrored to a JSONL sink file
    (`set_sink`), so failures and skips are never silent.

`snapshot()` additionally absorbs the two LRU caches that predate this
registry — `simulator.runner_cache_info()` and
`routing.routing_cache_info()` — under `cache.runner.*` /
`cache.routing.*` keys, and `cache_counters()` exposes just those
monotonic hit/miss/eviction counters for before/after deltas (the
sweep engine counts compiles this way: a *miss* delta counts new
compiled programs exactly, where the old sum-of-entries subtraction
could be shrunk by an LRU eviction between the two reads and
misattribute compiles).
"""
from __future__ import annotations

import json
import os
import threading
import time


class MetricsRegistry:
    """Thread-safe counters + observations + structured event log."""

    def __init__(self):
        self._lock = threading.Lock()
        self._counters: dict[str, float] = {}
        self._observations: dict[str, dict] = {}
        self._events: list[dict] = []
        self._sink: str | None = None
        self._buffered = False
        self._pending: list[str] = []

    # ---- counters ------------------------------------------------------
    def inc(self, name: str, value: float = 1) -> None:
        with self._lock:
            self._counters[name] = self._counters.get(name, 0) + value

    def get(self, name: str, default: float = 0) -> float:
        with self._lock:
            return self._counters.get(name, default)

    # ---- observations --------------------------------------------------
    def observe(self, name: str, value: float) -> None:
        with self._lock:
            o = self._observations.get(name)
            if o is None:
                o = self._observations[name] = dict(
                    count=0, sum=0.0, min=value, max=value)
            o["count"] += 1
            o["sum"] += value
            o["min"] = min(o["min"], value)
            o["max"] = max(o["max"], value)

    # ---- events --------------------------------------------------------
    def set_sink(self, path: str | None, *, buffered: bool = False
                 ) -> None:
        """Mirror every subsequent event to `path` as one JSON line.

        buffered=True holds lines in memory until `flush()` /
        `close_sink()` — one write syscall per flush instead of per
        event, and nothing hits disk for a sink that is reset before
        flushing.  Switching sinks flushes the old one first so no
        buffered event is ever silently dropped.
        """
        if path is not None:
            d = os.path.dirname(os.path.abspath(path))
            os.makedirs(d, exist_ok=True)
        self.flush()
        with self._lock:
            self._sink = path
            self._buffered = buffered

    def event(self, name: str, **fields) -> dict:
        e = dict(event=name, t=time.time(), **fields)
        line = None
        with self._lock:
            self._events.append(e)
            sink = self._sink
            if sink is not None:
                line = json.dumps(e, default=str)
                if getattr(self, "_buffered", False):
                    self._pending.append(line)
                    line = None
        if line is not None:
            with open(sink, "a") as f:
                f.write(line + "\n")
        return e

    def flush(self) -> int:
        """Write buffered event lines to the sink; returns #flushed."""
        with self._lock:
            sink, pending = self._sink, self._pending
            self._pending = []
        if sink is None or not pending:
            return 0
        with open(sink, "a") as f:
            f.write("\n".join(pending) + "\n")
        return len(pending)

    def close_sink(self) -> None:
        """Flush any buffered lines, then detach the sink."""
        self.flush()
        with self._lock:
            self._sink = None
            self._buffered = False

    def events(self, name: str | None = None) -> list[dict]:
        with self._lock:
            evs = list(self._events)
        return evs if name is None else [e for e in evs
                                         if e["event"] == name]

    def save_jsonl(self, path: str) -> int:
        """Write the full event log (one JSON object per line)."""
        evs = self.events()
        d = os.path.dirname(os.path.abspath(path))
        os.makedirs(d, exist_ok=True)
        with open(path, "w") as f:
            for e in evs:
                f.write(json.dumps(e, default=str) + "\n")
        print(f"[obs] wrote {path} ({len(evs)} events)")
        return len(evs)

    # ---- snapshots -----------------------------------------------------
    def snapshot(self) -> dict:
        """Counters + observations + absorbed cache counters."""
        with self._lock:
            out = dict(self._counters)
            out.update({k: dict(v) for k, v in self._observations.items()})
        out.update(cache_counters())
        return out

    def with_prefix(self, prefix: str) -> dict:
        """Counter/observation snapshot filtered to one namespace
        (e.g. "analysis." for the static-verifier counters) — cheap to
        assert on in tests without wading through cache counters."""
        return {k: v for k, v in self.snapshot().items()
                if k.startswith(prefix)}

    def reset(self) -> None:
        """Return the registry to a pristine state: counters,
        observations and events cleared AND the sink detached (buffered
        lines flushed first).  A test or engine that `reset()`s can no
        longer leak events into a sink file another run attached —
        snapshot isolation between runs in one process."""
        self.close_sink()
        with self._lock:
            self._counters.clear()
            self._observations.clear()
            self._events.clear()


def cache_counters() -> dict:
    """Monotonic hit/miss/eviction counters of the two pre-registry
    LRUs, flattened under stable keys.  Misses count cache *builds*
    (compiled runners / routed structures), so a before/after miss
    delta counts new work exactly — immune to concurrent evictions,
    unlike differencing the caches' entry sums."""
    from repro.core.routing import routing_cache_info
    from repro.core.simulator import runner_cache_info
    r = runner_cache_info()
    t = routing_cache_info()
    return {
        "cache.runner.hits": r["hits"],
        "cache.runner.misses": r["misses"],
        "cache.runner.evictions": r["evictions"],
        "cache.runner.size": r["size"],
        "cache.routing.hits": t["hits"],
        "cache.routing.misses": t["misses"],
        "cache.routing.evictions": t["evictions"],
        "cache.routing.size": t["size"],
    }


#: process-wide registry (import `from repro.obs import metrics`)
metrics = MetricsRegistry()
