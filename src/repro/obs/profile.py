"""Compile-time cost/memory profiling for batched runners (DESIGN.md §16).

`run_batch` asks XLA what each compiled executable costs — analytic
FLOPs / bytes-accessed from `cost_analysis()` and the buffer breakdown
from `memory_analysis()` — and records one profile per runner-cache key
(padded shape + SimConfig + alloc impl + kmax + backend).  The key
mirrors `get_batch_runner`'s cache key on purpose: the executable is a
function of the *padded* shape, not of any individual topology, so the
profile answers "what does this PadShape cost to run", which is exactly
the denominator the pad-waste investigation divides live work by.

Design constraints, matching `obs.trace`:

  * **off is free**: profiling is disabled by default and the hot-path
    check is one attribute read; nothing is lowered or compiled unless
    a caller opted in;
  * **never in timed regions**: `lower().compile()` does NOT share the
    jit call cache (verified on jax 0.4.37: the AOT compile leaves
    `_cache_size()` at 0), so a capture costs a full second compile.
    Benchmarks therefore profile in a separate untimed pass — the
    registry exists so they only pay that once per executable;
  * **robust to backend gaps**: `cost_analysis`/`memory_analysis` are
    best-effort across backends; missing fields record as None rather
    than raising mid-experiment.
"""
from __future__ import annotations

import threading
import time

__all__ = [
    "ProfileRegistry", "PROFILER", "profiling_enabled",
    "enable_profiling", "disable_profiling", "clear_profiles",
    "get_profiles", "record_runner_profile",
]


def _cost_fields(compiled) -> dict:
    """Flatten `cost_analysis()` to {flops, bytes_accessed, transcendentals}.

    jax 0.4.x returns a list with one properties-dict per computation
    (keys like 'flops', 'bytes accessed'); newer versions return the
    dict directly.  Sum across computations, None when absent.
    """
    try:
        ca = compiled.cost_analysis()
    except Exception:
        return dict(flops=None, bytes_accessed=None, transcendentals=None)
    if isinstance(ca, dict):
        ca = [ca]
    out = dict(flops=None, bytes_accessed=None, transcendentals=None)
    names = dict(flops="flops", bytes_accessed="bytes accessed",
                 transcendentals="transcendentals")
    for props in ca or []:
        for field, key in names.items():
            v = props.get(key)
            if v is not None:
                out[field] = (out[field] or 0.0) + float(v)
    return out


def _memory_fields(compiled) -> dict:
    """Buffer breakdown from `memory_analysis()` (CompiledMemoryStats)."""
    try:
        ma = compiled.memory_analysis()
    except Exception:
        ma = None
    fields = dict(temp_bytes="temp_size_in_bytes",
                  argument_bytes="argument_size_in_bytes",
                  output_bytes="output_size_in_bytes",
                  generated_code_bytes="generated_code_size_in_bytes")
    return {name: (int(getattr(ma, attr)) if ma is not None
                   and getattr(ma, attr, None) is not None else None)
            for name, attr in fields.items()}


class ProfileRegistry:
    """Thread-safe once-per-executable profile store."""

    def __init__(self):
        self._lock = threading.Lock()
        self._profiles: dict = {}
        self._enabled = False

    # ---- lifecycle -----------------------------------------------------
    def enable(self) -> None:
        self._enabled = True

    def disable(self) -> None:
        self._enabled = False

    @property
    def enabled(self) -> bool:
        return self._enabled

    def clear(self) -> None:
        with self._lock:
            self._profiles = {}

    # ---- capture -------------------------------------------------------
    def capture(self, key: tuple, runner, args) -> dict:
        """Profile one jitted runner, once per key (cached thereafter).

        AOT-lowers and compiles `runner(*args)` — a real compile, so
        call this outside any timed region — and records the XLA cost
        and memory analyses plus the compile wall-clock.
        """
        with self._lock:
            prof = self._profiles.get(key)
        if prof is not None:
            return prof
        t0 = time.perf_counter()
        compiled = runner.lower(*args).compile()
        compile_s = time.perf_counter() - t0
        prof = dict(key=[_jsonable(k) for k in key],
                    compile_s=round(compile_s, 4),
                    **_cost_fields(compiled), **_memory_fields(compiled))
        with self._lock:
            self._profiles.setdefault(key, prof)
        return prof

    def profiles(self) -> list[dict]:
        """All captured profiles (insertion order)."""
        with self._lock:
            return list(self._profiles.values())


def _jsonable(v):
    if isinstance(v, (str, int, float, bool)) or v is None:
        return v
    return str(v)


# ---------------------------------------------------------------------
# process-wide default registry + module-level convenience API
# ---------------------------------------------------------------------

PROFILER = ProfileRegistry()


def profiling_enabled() -> bool:
    return PROFILER.enabled


def enable_profiling() -> None:
    PROFILER.enable()


def disable_profiling() -> None:
    PROFILER.disable()


def clear_profiles() -> None:
    PROFILER.clear()


def get_profiles() -> list[dict]:
    return PROFILER.profiles()


def record_runner_profile(shape, cfg, alloc_impl: str, kmax: int,
                          runner, args) -> dict:
    """Profile a batched runner under its runner-cache key.

    Called by `run_batch` when profiling is enabled; the key mirrors
    `get_batch_runner` so one profile per compiled executable, however
    many topologies share it.
    """
    import jax
    key = (shape.n, shape.p, shape.c, shape.d, cfg, alloc_impl, kmax,
           jax.default_backend())
    return PROFILER.capture(key, runner, args)
