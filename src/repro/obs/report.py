"""Link-load reports (DESIGN.md §13): heatmap rows -> distribution stats.

The paper's central argument is about load *distribution* — folding
spreads channel load where Mesh/Torus concentrate it — so the summary
a heatmap CSV needs is exactly the distribution shape: percentiles of
per-channel utilization plus a Gini imbalance index per topology cell.
Gini 0 = perfectly balanced channels, ->1 = all load on few channels;
a flatter (lower-Gini) distribution at equal throughput is the
mechanism behind every FoldedHexaTorus win in results/*.csv.
"""
from __future__ import annotations

import numpy as np

#: identity fields that define one summary cell
GROUP_KEYS = ("experiment", "topology", "n", "substrate", "traffic",
              "faults")

SUMMARY_COLUMNS = GROUP_KEYS + (
    "rate", "n_links", "n_dead", "busy_total", "stall_total",
    "util_mean", "util_p50", "util_p95", "util_max", "gini",
)


def gini(x) -> float:
    """Gini coefficient of a non-negative load vector (0 = balanced)."""
    x = np.sort(np.asarray(x, np.float64))
    n = x.size
    tot = x.sum()
    if n == 0 or tot <= 0:
        return 0.0
    # mean absolute difference via the sorted-rank identity
    ranks = np.arange(1, n + 1)
    return float((2.0 * (ranks * x).sum() - (n + 1) * tot) / (n * tot))


def link_load_summary(rows) -> list[dict]:
    """One distribution-stats row per (topology, n, substrate, traffic,
    faults) cell of tidy per-link rows (`obs.flight.link_rows`).  Dead
    rows count toward `n_dead` only; percentiles and Gini are over the
    surviving channels' utilization."""
    groups: dict[tuple, list[dict]] = {}
    for r in rows:
        groups.setdefault(tuple(r.get(k) for k in GROUP_KEYS),
                          []).append(r)
    out = []
    for key, grp in groups.items():
        ok = [r for r in grp if r["status"] == "ok"]
        util = np.asarray([r["util"] for r in ok], np.float64)
        row = dict(zip(GROUP_KEYS, key))
        row.update(
            rate=ok[0]["rate"] if ok else None,
            n_links=len(ok),
            n_dead=sum(1 for r in grp if r["status"] == "dead"),
            busy_total=int(sum(r["busy"] for r in ok)),
            stall_total=int(sum(r["stalls"] for r in ok)),
            util_mean=round(float(util.mean()), 6) if ok else 0.0,
            util_p50=round(float(np.percentile(util, 50)), 6)
            if ok else 0.0,
            util_p95=round(float(np.percentile(util, 95)), 6)
            if ok else 0.0,
            util_max=round(float(util.max()), 6) if ok else 0.0,
            gini=round(gini(util), 6))
        out.append(row)
    return out


def write_link_reports(heatmap_path: str, summary_path: str,
                       rows) -> list[dict]:
    """Write the per-link heatmap CSV and its distribution summary CSV
    through the versioned writers; returns the summary rows."""
    from repro.experiments import io as xio   # deferred: import cycle
    from .flight import LINK_COLUMNS
    extra = [k for r in rows for k in r if k not in LINK_COLUMNS]
    seen: dict = {}
    for k in extra:
        seen.setdefault(k, None)
    xio.write_csv(heatmap_path, rows,
                  columns=list(LINK_COLUMNS) + list(seen))
    summary = link_load_summary(rows)
    xio.write_csv(summary_path, summary, columns=list(SUMMARY_COLUMNS))
    return summary


WINDOW_SUMMARY_COLUMNS = GROUP_KEYS + (
    "rate", "window", "t_start", "t_end", "cycles", "n_links",
    "busy_total", "stall_total", "util_mean", "util_p95", "util_max",
    "gini", "occ_escape_mean", "occ_adaptive_mean",
)


def window_summary(rows) -> list[dict]:
    """One distribution-stats row per (cell, time window) of tidy
    per-(window, link) rows (`obs.flight.window_rows`) — the time-
    resolved version of `link_load_summary`.  Reading `gini` down a
    cell's windows shows imbalance evolving (a `hotspot_drift` schedule
    makes it oscillate as the hotspot moves); `occ_escape_mean` vs
    `occ_adaptive_mean` shows when adaptive VCs absorb the load spike
    (DESIGN.md §16)."""
    groups: dict[tuple, list[dict]] = {}
    for r in rows:
        key = tuple(r.get(k) for k in GROUP_KEYS) + (r["window"],)
        groups.setdefault(key, []).append(r)
    out = []
    for key, grp in sorted(groups.items(),
                           key=lambda kv: tuple(map(str, kv[0]))):
        util = np.asarray([r["util"] for r in grp], np.float64)
        row = dict(zip(GROUP_KEYS, key[:-1]))
        row.update(
            rate=grp[0]["rate"], window=key[-1],
            t_start=grp[0]["t_start"], t_end=grp[0]["t_end"],
            cycles=grp[0]["cycles"], n_links=len(grp),
            busy_total=int(sum(r["busy"] for r in grp)),
            stall_total=int(sum(r["stalls"] for r in grp)),
            util_mean=round(float(util.mean()), 6),
            util_p95=round(float(np.percentile(util, 95)), 6),
            util_max=round(float(util.max()), 6),
            gini=round(gini(util), 6),
            occ_escape_mean=round(float(np.mean(
                [r["occ_escape"] for r in grp])), 4),
            occ_adaptive_mean=round(float(np.mean(
                [r["occ_adaptive"] for r in grp])), 4))
        out.append(row)
    return out


def write_window_reports(heatmap_path: str, summary_path: str,
                         rows) -> list[dict]:
    """Write the per-(window, link) time-heatmap CSV and its per-window
    distribution summary CSV; returns the summary rows."""
    from repro.experiments import io as xio   # deferred: import cycle
    from .flight import WINDOW_COLUMNS
    extra = [k for r in rows for k in r if k not in WINDOW_COLUMNS]
    seen: dict = {}
    for k in extra:
        seen.setdefault(k, None)
    xio.write_csv(heatmap_path, rows,
                  columns=list(WINDOW_COLUMNS) + list(seen))
    summary = window_summary(rows)
    xio.write_csv(summary_path, summary,
                  columns=list(WINDOW_SUMMARY_COLUMNS))
    return summary
