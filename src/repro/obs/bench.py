"""Structured benchmark results + regression gate (DESIGN.md §16).

Every benchmark under `benchmarks/` reports through this module so the
repo accumulates a machine-readable performance trajectory instead of
print statements: one versioned `results/BENCH_<name>.json` per bench,
carrying machine/JAX metadata, the bench's scalar metrics (cold/warm
wall-clock, derived throughput numbers), per-metric better-direction
hints, span summaries, XLA cost/memory profiles and pad-waste
fractions.  `compare` diffs two BENCH files metric-by-metric and exits
nonzero past a configurable regression threshold — the CI gate that
keeps "0.82x warm" from silently becoming 0.5x.

Document schema (`bench_schema_version`, independent of the CSV
`schema_version` in `experiments.io` — BENCH files version their own
layout):

    {
      "bench_schema_version": 1,
      "name": "sweep", "mode": "smoke",
      "created_utc": "...", "machine": {...},
      "metrics":    {"batched_warm_s": 0.61, ...},   # scalars only
      "directions": {"warm_speedup": "higher", ...}, # default "lower"
      "spans":    {name: {count, total_s, max_s}},   # optional
      "profiles": [{flops, bytes_accessed, ...}],    # optional
      "extra":    {...}                              # free-form
    }

CLI:

    python -m repro.obs.bench run <name> [bench args...]
    python -m repro.obs.bench compare OLD NEW [--fail-over PCT]
                                              [--warn-only]
"""
from __future__ import annotations

import json
import os
import sys
import time

BENCH_SCHEMA_VERSION = 1

#: default regression threshold: a metric moving >25% in its worse
#: direction fails `compare` (override with --fail-over)
DEFAULT_FAIL_OVER_PCT = 25.0

__all__ = [
    "BENCH_SCHEMA_VERSION", "DEFAULT_FAIL_OVER_PCT", "machine_metadata",
    "bench_doc", "bench_path", "write_bench", "load_bench", "compare",
    "format_compare", "main",
]


def machine_metadata() -> dict:
    """Where this BENCH file came from: host/python/jax/backend."""
    import platform

    import jax
    return dict(
        platform=platform.platform(),
        machine=platform.machine(),
        python=platform.python_version(),
        jax=jax.__version__,
        backend=jax.default_backend(),
        device_count=jax.device_count(),
        cpu_count=os.cpu_count(),
    )


def bench_doc(name: str, metrics: dict, *, directions: dict | None = None,
              mode: str = "full", spans: dict | None = None,
              profiles: list | None = None,
              extra: dict | None = None) -> dict:
    """Assemble one BENCH document.  `metrics` must be scalar-valued —
    those are what `compare` diffs; everything non-scalar goes in
    `extra`.  `directions` marks metrics where bigger is better
    (e.g. speedups); unlisted metrics default to "lower"."""
    bad = {k: v for k, v in metrics.items()
           if v is not None and not isinstance(v, (int, float))}
    if bad:
        raise TypeError(f"non-scalar metrics {sorted(bad)}; put "
                        "structured payloads in extra=")
    for k, d in (directions or {}).items():
        if d not in ("lower", "higher"):
            raise ValueError(f"direction for {k!r} must be "
                             f"'lower' or 'higher', got {d!r}")
    return dict(
        bench_schema_version=BENCH_SCHEMA_VERSION,
        name=name, mode=mode,
        created_utc=time.strftime("%Y-%m-%dT%H:%M:%SZ", time.gmtime()),
        machine=machine_metadata(),
        metrics=dict(metrics),
        directions=dict(directions or {}),
        spans=spans or {},
        profiles=profiles or [],
        extra=extra or {},
    )


def bench_path(name: str, results_dir: str = "results") -> str:
    return os.path.join(results_dir, f"BENCH_{name}.json")


def write_bench(doc: dict, results_dir: str = "results") -> str:
    """Write a BENCH document to `results/BENCH_<name>.json`."""
    path = bench_path(doc["name"], results_dir)
    os.makedirs(os.path.dirname(os.path.abspath(path)), exist_ok=True)

    def default(o):
        try:
            import numpy as np
            if isinstance(o, (np.floating, np.integer)):
                return o.item()
            if isinstance(o, np.ndarray):
                return o.tolist()
        except ImportError:
            pass
        return str(o)

    with open(path, "w") as f:
        json.dump(doc, f, indent=1, default=default)
    print(f"[bench] wrote {path} ({len(doc['metrics'])} metrics, "
          f"bench schema v{BENCH_SCHEMA_VERSION})")
    return path


def load_bench(path: str) -> dict:
    with open(path) as f:
        doc = json.load(f)
    v = doc.get("bench_schema_version")
    if v != BENCH_SCHEMA_VERSION:
        raise ValueError(f"{path}: bench_schema_version {v!r} != "
                         f"{BENCH_SCHEMA_VERSION} (regenerate)")
    return doc


def compare(old: dict, new: dict,
            fail_over_pct: float = DEFAULT_FAIL_OVER_PCT) -> list[dict]:
    """Metric-by-metric diff of two BENCH documents.

    Returns one row per metric: {metric, old, new, delta_pct,
    direction, status} with status in {"ok", "regressed", "improved",
    "new", "removed"}.  A metric regressed when it moved more than
    `fail_over_pct` percent in its worse direction (direction hints
    come from the NEW doc, defaulting to "lower"-is-better)."""
    rows = []
    dirs = new.get("directions", {})
    om, nm = old.get("metrics", {}), new.get("metrics", {})
    for k in sorted(set(om) | set(nm)):
        direction = dirs.get(k, "lower")
        if k not in nm:
            rows.append(dict(metric=k, old=om[k], new=None,
                             delta_pct=None, direction=direction,
                             status="removed"))
            continue
        if k not in om or om[k] is None or nm[k] is None:
            rows.append(dict(metric=k, old=om.get(k), new=nm[k],
                             delta_pct=None, direction=direction,
                             status="new"))
            continue
        o, n = float(om[k]), float(nm[k])
        delta = (n - o) / abs(o) * 100.0 if o != 0 else \
            (0.0 if n == 0 else None)
        worse = delta is not None and (
            delta > fail_over_pct if direction == "lower"
            else delta < -fail_over_pct)
        better = delta is not None and (
            delta < -fail_over_pct if direction == "lower"
            else delta > fail_over_pct)
        rows.append(dict(
            metric=k, old=om[k], new=nm[k],
            delta_pct=None if delta is None else round(delta, 2),
            direction=direction,
            status="regressed" if worse else
                   "improved" if better else "ok"))
    return rows


def format_compare(rows: list[dict]) -> str:
    """Human-readable compare table (one line per metric)."""
    lines = [f"{'metric':<28} {'old':>12} {'new':>12} "
             f"{'delta%':>8}  status"]
    for r in rows:
        delta = "" if r["delta_pct"] is None else f"{r['delta_pct']:+.1f}"
        fmt = lambda v: "" if v is None else (
            f"{v:.4g}" if isinstance(v, float) else str(v))
        mark = {"regressed": " <-- REGRESSION",
                "improved": " (improved)"}.get(r["status"], "")
        lines.append(f"{r['metric']:<28} {fmt(r['old']):>12} "
                     f"{fmt(r['new']):>12} {delta:>8}  "
                     f"{r['status']}{mark}")
    return "\n".join(lines)


# ---------------------------------------------------------------------
# CLI
# ---------------------------------------------------------------------

def _cmd_compare(argv: list[str]) -> int:
    import argparse
    ap = argparse.ArgumentParser(
        prog="python -m repro.obs.bench compare",
        description="Diff two BENCH_<name>.json files; exit 1 on "
                    "regression past the threshold.")
    ap.add_argument("old", help="baseline BENCH json")
    ap.add_argument("new", help="candidate BENCH json")
    ap.add_argument("--fail-over", type=float,
                    default=DEFAULT_FAIL_OVER_PCT, metavar="PCT",
                    help="regression threshold in percent "
                         f"(default {DEFAULT_FAIL_OVER_PCT})")
    ap.add_argument("--warn-only", action="store_true",
                    help="report regressions but always exit 0")
    ns = ap.parse_args(argv)
    try:
        old, new = load_bench(ns.old), load_bench(ns.new)
    except (OSError, ValueError) as e:
        print(f"[bench] compare failed: {e}", file=sys.stderr)
        return 2
    rows = compare(old, new, ns.fail_over)
    print(f"[bench] {old['name']}: {ns.old} -> {ns.new} "
          f"(fail-over {ns.fail_over}%)")
    print(format_compare(rows))
    n_reg = sum(r["status"] == "regressed" for r in rows)
    if n_reg:
        msg = f"[bench] {n_reg} metric(s) regressed past {ns.fail_over}%"
        if ns.warn_only:
            print(msg + " (warn-only)")
            return 0
        print(msg, file=sys.stderr)
        return 1
    print("[bench] no regressions")
    return 0


def _cmd_run(argv: list[str]) -> int:
    """Dispatch to a bench module: `run sweep --smoke` runs
    `benchmarks.sweep_bench` with the remaining args."""
    if not argv:
        print("usage: python -m repro.obs.bench run <name> [args...]",
              file=sys.stderr)
        return 2
    name, rest = argv[0], argv[1:]
    import importlib
    import runpy
    mod = f"benchmarks.{name}_bench" if not name.endswith("_bench") \
        else f"benchmarks.{name}"
    try:
        importlib.import_module("benchmarks")
    except ImportError as e:
        print(f"[bench] cannot import benchmarks package: {e}",
              file=sys.stderr)
        return 2
    old_argv = sys.argv
    sys.argv = [mod] + rest
    try:
        runpy.run_module(mod, run_name="__main__")
    except SystemExit as e:
        return int(e.code or 0)
    finally:
        sys.argv = old_argv
    return 0


def main(argv: list[str] | None = None) -> int:
    argv = sys.argv[1:] if argv is None else argv
    if not argv or argv[0] in ("-h", "--help"):
        print(__doc__)
        return 0 if argv else 2
    cmd, rest = argv[0], argv[1:]
    if cmd == "compare":
        return _cmd_compare(rest)
    if cmd == "run":
        return _cmd_run(rest)
    print(f"[bench] unknown subcommand {cmd!r} "
          "(expected: run, compare)", file=sys.stderr)
    return 2


if __name__ == "__main__":
    raise SystemExit(main())
