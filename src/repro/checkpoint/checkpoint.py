"""Sharded, crash-safe, elastic checkpointing (no external deps).

Layout:
    <dir>/step_<N>/manifest.json     tree structure, shapes, dtypes
    <dir>/step_<N>/<leaf_id>.npy     one file per leaf (per host in a
                                     multi-host run — files are keyed by
                                     process index)

Properties needed at 1000+-node scale:
  * atomic commit — writes go to step_<N>.tmp, renamed only after fsync,
    so a failed node never leaves a half-checkpoint that restore trusts;
  * async save — device_get + file IO run on a background thread so the
    training loop only blocks for the on-device snapshot;
  * elastic restore — arrays are loaded as full logical tensors and
    re-placed with jax.device_put under *whatever mesh the restore-time
    ParallelCtx provides*, so restarting on a different pod count /
    topology (elastic scaling) is a no-op for the caller;
  * retention — keep the most recent `keep` checkpoints.
"""
from __future__ import annotations

import json
import os
import re
import shutil
import threading

import jax
import numpy as np


def _flatten_with_names(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    names, leaves = [], []
    for path, leaf in flat:
        name = re.sub(r"[^A-Za-z0-9_.\-]", "_", jax.tree_util.keystr(path))
        names.append(name)
        leaves.append(leaf)
    return names, leaves, jax.tree_util.tree_structure(tree)


def save_checkpoint(ckpt_dir: str, step: int, tree, keep: int = 3,
                    process_index: int | None = None) -> str:
    """Synchronous atomic save.  Returns the committed directory."""
    pidx = jax.process_index() if process_index is None else process_index
    names, leaves, treedef = _flatten_with_names(tree)
    final = os.path.join(ckpt_dir, f"step_{step:08d}")
    tmp = final + f".tmp{pidx}"
    os.makedirs(tmp, exist_ok=True)
    meta = {"step": step, "treedef": str(treedef), "leaves": {}}
    for name, leaf in zip(names, leaves):
        arr = np.asarray(jax.device_get(leaf))
        fn = f"{name}__p{pidx}.npy"
        np.save(os.path.join(tmp, fn), arr)
        meta["leaves"][name] = {"file": fn, "shape": list(arr.shape),
                                "dtype": str(arr.dtype)}
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(meta, f)
        f.flush()
        os.fsync(f.fileno())
    os.replace(tmp, final) if not os.path.exists(final) else \
        _merge_into(tmp, final)
    _prune(ckpt_dir, keep)
    return final


def _merge_into(tmp, final):
    for fn in os.listdir(tmp):
        os.replace(os.path.join(tmp, fn), os.path.join(final, fn))
    shutil.rmtree(tmp, ignore_errors=True)


def _prune(ckpt_dir: str, keep: int):
    steps = sorted(
        d for d in os.listdir(ckpt_dir)
        if d.startswith("step_") and not d.endswith(".tmp"))
    for d in steps[:-keep]:
        shutil.rmtree(os.path.join(ckpt_dir, d), ignore_errors=True)


def latest_step(ckpt_dir: str) -> int | None:
    if not os.path.isdir(ckpt_dir):
        return None
    steps = [int(d.split("_")[1]) for d in os.listdir(ckpt_dir)
             if d.startswith("step_") and not d.endswith(".tmp")
             and os.path.exists(os.path.join(ckpt_dir, d,
                                             "manifest.json"))]
    return max(steps) if steps else None


def restore_checkpoint(ckpt_dir: str, step: int, target_tree,
                       shardings=None):
    """Restore into the structure of `target_tree`.

    With `shardings` (a matching tree of NamedShardings, possibly built
    from a *different* mesh than the one that saved), each array is
    re-placed accordingly — this is the elastic-rescale path.
    """
    d = os.path.join(ckpt_dir, f"step_{step:08d}")
    names, leaves, treedef = _flatten_with_names(target_tree)
    with open(os.path.join(d, "manifest.json")) as f:
        meta = json.load(f)
    out = []
    shard_leaves = (jax.tree_util.tree_leaves(
        shardings, is_leaf=lambda x: hasattr(x, "mesh"))
        if shardings is not None else [None] * len(names))
    for name, ref_leaf, shard in zip(names, leaves, shard_leaves):
        info = meta["leaves"][name]
        arr = np.load(os.path.join(d, info["file"]))
        if shard is not None:
            arr = jax.device_put(arr, shard)
        out.append(arr)
    return jax.tree_util.tree_unflatten(treedef, out)


class AsyncCheckpointer:
    """Snapshot on-device state synchronously, write asynchronously."""

    def __init__(self, ckpt_dir: str, keep: int = 3):
        self.ckpt_dir = ckpt_dir
        self.keep = keep
        self._thread: threading.Thread | None = None
        self.last_error: Exception | None = None

    def save(self, step: int, tree):
        self.wait()
        # snapshot: device_get here (blocking) keeps a consistent view;
        # the file IO happens on the worker thread.
        host_tree = jax.tree.map(lambda x: np.asarray(jax.device_get(x)),
                                 tree)

        def work():
            try:
                save_checkpoint(self.ckpt_dir, step, host_tree,
                                keep=self.keep)
            except Exception as e:  # noqa: BLE001
                self.last_error = e

        self._thread = threading.Thread(target=work, daemon=True)
        self._thread.start()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self.last_error is not None:
            err, self.last_error = self.last_error, None
            raise err
