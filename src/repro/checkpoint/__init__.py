"""Checkpoint save/restore with async host offload for the training stack."""
from .checkpoint import (save_checkpoint, restore_checkpoint,  # noqa
                         latest_step, AsyncCheckpointer)
