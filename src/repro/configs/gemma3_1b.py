"""gemma3-1b [dense] — 26L d_model=1152 4H (GQA kv=1) d_ff=6912
vocab=262144; 5 local : 1 global sliding-window attention, 128k context.
[hf:google/gemma-3-1b-pt; unverified]"""
from repro.models.model import ModelConfig

CONFIG = ModelConfig(
    name="gemma3-1b", n_layers=26, d_model=1152, n_heads=4,
    n_kv_heads=1, head_dim=256, d_ff=6912, vocab=262144,
    attn_kind="gqa", qk_norm=True, rope_theta=1e6,
    window=1024, global_every=6)

SMOKE = ModelConfig(
    name="gemma3-smoke", n_layers=6, d_model=64, n_heads=2, n_kv_heads=1,
    head_dim=32, d_ff=128, vocab=512, attn_kind="gqa", qk_norm=True,
    window=8, global_every=3)
