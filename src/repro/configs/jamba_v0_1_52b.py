"""jamba-v0.1-52b [hybrid] — 32L d_model=4096 32H (GQA kv=8) d_ff=14336
vocab=65536, MoE 16 experts top-2 every other layer, Mamba:attention 7:1
(one attention layer per 8-layer block).  The Mamba blocks use the SSD
(mamba2) chunked form — the TPU-friendly adaptation (DESIGN.md §4).
[arXiv:2403.19887; hf]"""
from repro.models.model import ModelConfig

CONFIG = ModelConfig(
    name="jamba-v0.1-52b", n_layers=32, d_model=4096, n_heads=32,
    n_kv_heads=8, head_dim=128, d_ff=14336, vocab=65536,
    attn_kind="gqa", rope_theta=1e4,
    n_experts=16, top_k=2, moe_every=2, attn_every=8,
    ssm_state=64, ssm_expand=2, ssm_head_dim=64)

SMOKE = ModelConfig(
    name="jamba-smoke", n_layers=4, d_model=64, n_heads=4, n_kv_heads=2,
    head_dim=16, d_ff=128, vocab=512, attn_kind="gqa",
    n_experts=4, top_k=2, moe_every=2, attn_every=4,
    ssm_state=16, ssm_expand=2, ssm_head_dim=16, ssm_chunk=8)
