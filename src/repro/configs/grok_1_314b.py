"""grok-1-314b [moe] — 64L d_model=6144 48H (GQA kv=8) d_ff=32768
vocab=131072, MoE 8 experts top-2.  Experts are split into 2 virtual
half-d_ff experts so the 8-expert dimension tiles the 16-way model mesh
axis (see models/layers.moe_ep_local).  [hf:xai-org/grok-1; unverified]"""
from repro.models.model import ModelConfig

CONFIG = ModelConfig(
    name="grok-1-314b", n_layers=64, d_model=6144, n_heads=48,
    n_kv_heads=8, head_dim=128, d_ff=32768, vocab=131072,
    attn_kind="gqa", rope_theta=1e4,
    n_experts=8, top_k=2, moe_every=1, moe_virtual_split=2)

SMOKE = ModelConfig(
    name="grok-smoke", n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
    head_dim=16, d_ff=64, vocab=512, attn_kind="gqa",
    n_experts=4, top_k=2, moe_every=1, moe_virtual_split=2)
