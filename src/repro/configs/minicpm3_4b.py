"""minicpm3-4b [dense] — 62L d_model=2560 40H d_ff=6400 vocab=73448;
MLA (multi-head latent attention): q_lora=768, kv_lora=256,
nope/rope head dims 64/32.  [hf:openbmb/MiniCPM3-4B; hf]"""
from repro.models.model import ModelConfig

CONFIG = ModelConfig(
    name="minicpm3-4b", n_layers=62, d_model=2560, n_heads=40,
    n_kv_heads=40, head_dim=64, d_ff=6400, vocab=73448,
    attn_kind="mla", q_lora_rank=768, kv_lora_rank=256,
    mla_nope_dim=64, mla_rope_dim=32, rope_theta=1e4)

SMOKE = ModelConfig(
    name="minicpm3-smoke", n_layers=2, d_model=64, n_heads=4,
    n_kv_heads=4, head_dim=16, d_ff=128, vocab=512,
    attn_kind="mla", q_lora_rank=32, kv_lora_rank=16,
    mla_nope_dim=16, mla_rope_dim=8)
