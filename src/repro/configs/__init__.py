"""Assigned-architecture registry: 10 archs x 4 input shapes.

Each arch module defines CONFIG (the exact published configuration) and
SMOKE (a reduced same-family config for CPU smoke tests).  Shapes follow
the assignment:

    train_4k     seq 4096,   global_batch 256   (train_step)
    prefill_32k  seq 32768,  global_batch 32    (serve prefill)
    decode_32k   seq 32768,  global_batch 128   (serve_step, 1 new token)
    long_500k    seq 524288, global_batch 1     (long-context decode;
                 only sub-quadratic archs — see DESIGN.md)
"""
from __future__ import annotations

import dataclasses
import importlib

SHAPES = {
    "train_4k": dict(seq_len=4096, global_batch=256, mode="train"),
    "prefill_32k": dict(seq_len=32768, global_batch=32, mode="prefill"),
    "decode_32k": dict(seq_len=32768, global_batch=128, mode="decode"),
    "long_500k": dict(seq_len=524288, global_batch=1, mode="decode"),
}

ARCHS = [
    "qwen3_1_7b",
    "gemma3_1b",
    "starcoder2_3b",
    "minicpm3_4b",
    "seamless_m4t_medium",
    "qwen3_moe_235b_a22b",
    "grok_1_314b",
    "chameleon_34b",
    "jamba_v0_1_52b",
    "mamba2_1_3b",
]

# archs that can run 524288-token decode sub-quadratically (SSM / hybrid /
# mostly-local attention).  Pure full-attention archs skip long_500k.
LONG_CONTEXT_OK = {"mamba2_1_3b", "jamba_v0_1_52b", "gemma3_1b"}


def canon(name: str) -> str:
    return name.replace("-", "_").replace(".", "_")


def get_config(arch: str, smoke: bool = False):
    mod = importlib.import_module(f"repro.configs.{canon(arch)}")
    return mod.SMOKE if smoke else mod.CONFIG


def cells():
    """All runnable (arch, shape) dry-run cells."""
    out = []
    for a in ARCHS:
        for s in SHAPES:
            if s == "long_500k" and a not in LONG_CONTEXT_OK:
                continue
            out.append((a, s))
    return out
