"""starcoder2-3b [dense] — 30L d_model=3072 24H (GQA kv=2) d_ff=12288
vocab=49152; GQA + RoPE. [arXiv:2402.19173; hf]"""
from repro.models.model import ModelConfig

CONFIG = ModelConfig(
    name="starcoder2-3b", n_layers=30, d_model=3072, n_heads=24,
    n_kv_heads=2, head_dim=128, d_ff=12288, vocab=49152,
    attn_kind="gqa", rope_theta=999999.0)

SMOKE = ModelConfig(
    name="starcoder2-smoke", n_layers=2, d_model=64, n_heads=4,
    n_kv_heads=2, head_dim=16, d_ff=128, vocab=512, attn_kind="gqa")
