"""mamba2-1.3b [ssm] — 48L d_model=2048 attention-free, vocab=50280,
ssm_state=128; SSD (state-space duality) chunked form.
[arXiv:2405.21060; unverified]"""
from repro.models.model import ModelConfig

CONFIG = ModelConfig(
    name="mamba2-1.3b", n_layers=48, d_model=2048, n_heads=1,
    n_kv_heads=1, head_dim=64, d_ff=0, vocab=50280,
    attn_kind="none", ssm_state=128, ssm_expand=2, ssm_head_dim=64,
    ssm_chunk=256)

SMOKE = ModelConfig(
    name="mamba2-smoke", n_layers=2, d_model=64, n_heads=1, n_kv_heads=1,
    head_dim=16, d_ff=0, vocab=512, attn_kind="none", ssm_state=16,
    ssm_expand=2, ssm_head_dim=16, ssm_chunk=8)
