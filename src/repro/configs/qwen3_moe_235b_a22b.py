"""qwen3-moe-235b-a22b [moe] — 94L d_model=4096 64H (GQA kv=4)
expert d_ff=1536 vocab=151936, MoE 128 experts top-8, qk_norm.
[hf:Qwen/Qwen3-30B-A3B family; hf]"""
from repro.models.model import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-moe-235b-a22b", n_layers=94, d_model=4096, n_heads=64,
    n_kv_heads=4, head_dim=128, d_ff=1536, vocab=151936,
    attn_kind="gqa", qk_norm=True, rope_theta=1e6,
    n_experts=128, top_k=8, moe_every=1, capacity_factor=1.25)

SMOKE = ModelConfig(
    name="qwen3-moe-smoke", n_layers=2, d_model=64, n_heads=4,
    n_kv_heads=2, head_dim=16, d_ff=64, vocab=512, attn_kind="gqa",
    qk_norm=True, n_experts=8, top_k=2, moe_every=1)
