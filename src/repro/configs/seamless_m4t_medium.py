"""seamless-m4t-medium [audio] — enc-dec transformer backbone:
12L encoder + 12L decoder, d_model=1024 16H d_ff=4096 vocab=256206.
The speech frontend is a STUB: input_specs() supplies precomputed frame
embeddings [B, T, d_model].  [arXiv:2308.11596; hf]"""
from repro.models.model import ModelConfig

CONFIG = ModelConfig(
    name="seamless-m4t-medium", n_layers=12, n_enc_layers=12,
    d_model=1024, n_heads=16, n_kv_heads=16, head_dim=64, d_ff=4096,
    vocab=256206, attn_kind="gqa", arch_kind="encdec",
    frontend="audio_frames", rope_theta=1e4)

SMOKE = ModelConfig(
    name="seamless-smoke", n_layers=2, n_enc_layers=2, d_model=64,
    n_heads=4, n_kv_heads=4, head_dim=16, d_ff=128, vocab=512,
    attn_kind="gqa", arch_kind="encdec", frontend="audio_frames")
