"""chameleon-34b [vlm] — 48L d_model=8192 64H (GQA kv=8) d_ff=22016
vocab=65536; early-fusion: VQ image tokens share the text vocabulary, so
the modality frontend is the tokenizer itself (stub — input_specs()
supplies token ids that may be image codes).  qk-norm per the paper.
[arXiv:2405.09818; unverified]"""
from repro.models.model import ModelConfig

CONFIG = ModelConfig(
    name="chameleon-34b", n_layers=48, d_model=8192, n_heads=64,
    n_kv_heads=8, head_dim=128, d_ff=22016, vocab=65536,
    attn_kind="gqa", qk_norm=True, rope_theta=1e4)

SMOKE = ModelConfig(
    name="chameleon-smoke", n_layers=2, d_model=64, n_heads=4,
    n_kv_heads=2, head_dim=16, d_ff=128, vocab=512, attn_kind="gqa",
    qk_norm=True)
