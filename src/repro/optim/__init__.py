from .adamw import AdamWConfig, adamw_init, adamw_update, clip_by_global_norm  # noqa
from .schedule import warmup_cosine  # noqa
