"""Optimizers and LR schedules (AdamW + warmup-cosine) for the training stack."""
from .adamw import AdamWConfig, adamw_init, adamw_update, clip_by_global_norm  # noqa
from .schedule import warmup_cosine  # noqa
