"""AdamW with global-norm clipping (pure JAX, optimizer state shards like
the parameters — ZeRO-style since params are FSDP-sharded over "data").

Gradient "compression": the backward pass runs in bf16 (compute dtype),
so every gradient collective the partitioner inserts moves bf16, not
fp32 — the 2x wire-compression falls out of the mixed-precision design
rather than a bolt-on cast (DESIGN.md §4).  An optional stochastic-
rounding-free fp32 accumulation happens here at the master update.
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0


class AdamWState(NamedTuple):
    step: jnp.ndarray
    m: dict
    v: dict


def adamw_init(params) -> AdamWState:
    z = jax.tree.map(lambda p: jnp.zeros_like(p, dtype=jnp.float32), params)
    return AdamWState(step=jnp.zeros((), jnp.int32), m=z,
                      v=jax.tree.map(jnp.copy, z))


def clip_by_global_norm(grads, max_norm: float):
    leaves = jax.tree.leaves(grads)
    gn = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                      for g in leaves))
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(gn, 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale), grads), gn


def adamw_update(cfg: AdamWConfig, params, grads, state: AdamWState,
                 lr_scale=1.0):
    grads, gnorm = clip_by_global_norm(grads, cfg.clip_norm)
    step = state.step + 1
    b1c = 1 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1 - cfg.b2 ** step.astype(jnp.float32)
    lr = cfg.lr * lr_scale

    def upd(p, g, m, v):
        m = cfg.b1 * m + (1 - cfg.b1) * g
        v = cfg.b2 * v + (1 - cfg.b2) * (g * g)
        mh = m / b1c
        vh = v / b2c
        p32 = p.astype(jnp.float32)
        p32 = p32 - lr * (mh / (jnp.sqrt(vh) + cfg.eps)
                          + cfg.weight_decay * p32)
        return p32.astype(p.dtype), m, v

    p_flat, tdef = jax.tree.flatten(params)
    g_flat = jax.tree.leaves(grads)
    m_flat = jax.tree.leaves(state.m)
    v_flat = jax.tree.leaves(state.v)
    res = [upd(p, g, m, v) for p, g, m, v in
           zip(p_flat, g_flat, m_flat, v_flat)]
    new_p = jax.tree.unflatten(tdef, [r[0] for r in res])
    new_m = jax.tree.unflatten(tdef, [r[1] for r in res])
    new_v = jax.tree.unflatten(tdef, [r[2] for r in res])
    return new_p, AdamWState(step=step, m=new_m, v=new_v), gnorm
