"""Two-stage candidate evaluation (DESIGN.md §11).

Stage 1 (**analytic**, cheap): the channel-load saturation bound of
the shared deadlock-free routing (`routing_for`, structural-hash
cached) feeds the paper's §V-B cost model — absolute Tb/s through the
substrate wires, zero-load latency, wire cost.  This ranks thousands
of candidates without a single simulated cycle.

Stage 2 (**cycle-accurate**, expensive): the top slice is packed into
`repro.experiments` scenarios — `Scenario` carrying the synthesized
`Topology` objects directly — and executed as padded `SweepEngine`
batches, replacing the analytic saturation with the simulated plateau.
The Pareto objectives stay comparable across stages: only the
throughput coordinate changes backend; zero-load latency and wire
cost are analytic by definition.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core import costmodel as cm
from repro.core import traffic as TR
from repro.core.routing import routing_for
from repro.core.simulator import SimConfig, zero_load_latency
from repro.core.topology import Topology, make_topology

#: Pareto objectives: (metrics key, maximize?)
OBJECTIVES = (("abs_throughput_gbps", True),
              ("zero_load_latency_ns", False),
              ("wire_cost_mm", False))
MAXIMIZE = tuple(mx for _, mx in OBJECTIVES)


@dataclasses.dataclass
class Candidate:
    """One design-space point: a topology plus its evaluation record."""
    topo: Topology
    origin: str                     # registry | fold_mask | random | perturb
    parent: str = ""
    reasons: tuple = ()             # infeasibility reasons; () == feasible
    analytic: dict | None = None    # stage-1 metrics
    sim: dict | None = None         # stage-2 metrics (adds sim_saturation)

    @property
    def feasible(self) -> bool:
        return not self.reasons

    @property
    def simulated(self) -> bool:
        return self.sim is not None

    @property
    def metrics(self) -> dict | None:
        return self.sim if self.sim is not None else self.analytic

    def objectives(self) -> np.ndarray:
        """[K] objective vector (NaN until stage-1 evaluated)."""
        m = self.metrics
        if m is None:
            return np.full(len(OBJECTIVES), np.nan)
        return np.array([m[k] for k, _ in OBJECTIVES], np.float64)

    # ---- JSON round-trip (SearchState serialization) ------------------
    def to_dict(self) -> dict:
        t = self.topo
        return dict(name=t.name, n=t.n, substrate=t.substrate,
                    area=t.chiplet_area_mm2,
                    pos=np.asarray(t.pos, float).tolist(),
                    edges=np.asarray(t.edges, int).tolist(),
                    origin=self.origin, parent=self.parent,
                    reasons=list(self.reasons),
                    analytic=self.analytic, sim=self.sim)

    @classmethod
    def from_dict(cls, d: dict) -> "Candidate":
        topo = make_topology(d["name"], np.asarray(d["pos"]),
                             np.asarray(d["edges"], np.int64),
                             substrate=d["substrate"],
                             chiplet_area_mm2=d["area"])
        return cls(topo=topo, origin=d["origin"], parent=d["parent"],
                   reasons=tuple(d["reasons"]),
                   analytic=d["analytic"], sim=d["sim"])


def objective_matrix(cands) -> np.ndarray:
    return np.stack([c.objectives() for c in cands]) if cands else \
        np.zeros((0, len(OBJECTIVES)))


def analytic_metrics(topo: Topology, traffic: str = "uniform") -> dict:
    """Stage-1 metrics: analytic saturation -> §V-B cost model."""
    r = routing_for(topo)
    tm = TR.PATTERNS[traffic](topo)
    sat = r.saturation_rate(tm)
    # one all-pairs pass covers diameter + avg hops (candidates are
    # validated connected, so no inf rows); the properties would run it
    # twice per candidate in the hot analytic loop
    h = topo.hop_matrix()
    n = topo.n
    return dict(
        analytic_saturation=float(sat),
        abs_throughput_gbps=cm.absolute_throughput_gbps(topo, sat),
        zero_load_latency_ns=float(zero_load_latency(r, tm)),
        wire_cost_mm=cm.wire_cost_mm(topo),
        radix=int(topo.radix), diameter=int(h.max()),
        avg_hops=float(h.sum() / (n * (n - 1))),
        n_links=int(len(topo.edges)),
        max_link_mm=float(topo.max_link_length_mm()))


def evaluate_analytic(cands, traffic: str = "uniform") -> None:
    """Attach stage-1 metrics to every candidate lacking them."""
    for c in cands:
        if c.analytic is None:
            c.analytic = analytic_metrics(c.topo, traffic)


def simulate_candidates(cands, traffic: str = "uniform",
                        cfg: SimConfig = SimConfig(), n_rates: int = 4,
                        chunk_size: int | None = None,
                        single_program: bool = False):
    """Stage 2: cycle-accurate saturation for `cands`, batched.

    Lowers the candidates onto the declarative experiment pipeline —
    one `Scenario` per candidate carrying its `Topology` object — so
    the padded `SweepEngine` batches, executable caching and
    failure-isolation all apply.  Each candidate's `sim` metrics
    replace the analytic throughput with the simulated one; the
    returned `ResultFrame` keeps the full rate sweeps.
    """
    import repro.experiments as X
    # substrate/area inherit from each candidate's Topology (the
    # Scenario None-default), so glass candidates stay glass
    scens = [X.Scenario(topology=c.topo, n=c.topo.n, traffic=traffic,
                        rates=X.SaturationGrid(n_rates))
             for c in cands]
    frame = X.run(X.Experiment(scens, cfg=cfg, name="synth_sim"),
                  chunk_size=chunk_size, single_program=single_program)
    for c, row in zip(cands, frame.rows):
        if row["status"] != "ok":
            continue
        c.sim = dict(c.analytic,
                     sim_saturation=float(row["sim_saturation"]),
                     abs_throughput_gbps=float(row["abs_throughput_gbps"]),
                     latency_at_sat_ns=float(row["latency_ns"]))
    return frame
