"""Parametric topology design space (DESIGN.md §11).

Three candidate families, all emitting first-class validated
`Topology` objects over the existing placement rasters:

  * **fold-mask variants** — the generalization the paper's Table III
    is a few points of: every physical chain family of a raster (grid
    rows/columns, grid diagonals, brick-wall rows/diagonals) gets an
    independent wiring mode from {path, ring, folded}.  Mesh is
    all-path, Torus all-ring, FoldedTorus all-folded on the grid;
    HexaMesh is all-path and FoldedHexaTorus all-folded on the brick
    raster — and the space contains every mixed variant in between
    (e.g. folded rows + path columns).
  * **degree-bounded random geometric graphs** — a random spanning
    tree plus random extra edges over the pairs within a link-range
    budget, the unstructured half of the space (PlaceIT-style
    generation without the placement search).
  * **perturbation moves** — add / remove / rewire one edge of an
    existing candidate, the neighbourhood the evolutionary driver
    (repro.synth.search) walks.

Randomness is seeded through JAX PRNG keys at the driver level
(`key_seeds`); the graph construction itself runs on numpy Generators
fed those seeds, so candidates are reproducible and resumable.
"""
from __future__ import annotations

import itertools

import numpy as np

from repro.core import placement as pl
from repro.core.linkmodel import CHIPLET_AREA_MM2
from repro.core.topology import (Topology, fold_chain,
                                 link_range_from_pitch, make_topology,
                                 _brick_chains, _diag_chains,
                                 _grid_chains_cols, _grid_chains_rows)

#: per-axis wiring modes; single-letter codes name the variants
AXIS_MODES = ("path", "ring", "folded")
_MODE_CODE = {"path": "p", "ring": "r", "folded": "f"}


def key_seeds(key, n: int) -> np.ndarray:
    """Derive `n` independent int32 seeds from a JAX PRNG key.

    The search driver threads `jax.random` keys (split / fold_in per
    generation); numpy Generators do the graph work on the derived
    seeds.
    """
    import jax
    return np.asarray(jax.random.randint(key, (n,), 0,
                                         np.iinfo(np.int32).max))


def _axis_edges(chain: list[int], mode: str) -> list[tuple[int, int]]:
    """Wire one physical chain as a path, a ring, or a folded ring."""
    if mode == "path":
        return list(zip(chain[:-1], chain[1:]))
    if mode == "ring":
        e = list(zip(chain[:-1], chain[1:]))
        if len(chain) > 2:
            e.append((chain[0], chain[-1]))
        return e
    if mode == "folded":
        return fold_chain(chain)
    raise ValueError(f"unknown axis mode {mode!r}; choose from {AXIS_MODES}")


#: family -> (placement kwargs, ordered chain-group builders)
_FAMILIES = {
    "grid": ((False,), (
        lambda r, c: _grid_chains_rows(r, c),
        lambda r, c: _grid_chains_cols(r, c))),
    "grid_diag": ((False,), (
        lambda r, c: _grid_chains_rows(r, c),
        lambda r, c: _grid_chains_cols(r, c),
        lambda r, c: _diag_chains(r, c, +1) + _diag_chains(r, c, -1))),
    "brick": ((True,), (
        lambda r, c: _grid_chains_rows(r, c),
        lambda r, c: _brick_chains(r, c, "dr"),
        lambda r, c: _brick_chains(r, c, "dl"))),
}


def fold_mask_topology(n: int, family: str, modes: tuple,
                       substrate: str = "organic",
                       area: float = CHIPLET_AREA_MM2) -> Topology:
    """One fold-mask variant: `modes[i]` wires the family's i-th chain
    group.  Raises ValueError if the combination is disconnected."""
    if family not in _FAMILIES:
        raise KeyError(f"unknown family {family!r}; "
                       f"choose from {sorted(_FAMILIES)}")
    (brick,), groups = _FAMILIES[family]
    if len(modes) != len(groups):
        raise ValueError(f"{family} has {len(groups)} chain groups, "
                         f"got {len(modes)} modes")
    rows, cols = pl.grid_dims(n)
    pos = pl.grid_positions(rows, cols, brick=brick)
    edges: list = []
    for mode, group in zip(modes, groups):
        for chain in group(rows, cols):
            edges += _axis_edges(chain, mode)
    # dedupe before validation (axis groups can share end links)
    edges = sorted({(min(a, b), max(a, b)) for a, b in edges if a != b})
    name = f"fm_{family}_" + "".join(_MODE_CODE[m] for m in modes)
    return make_topology(name, pos, edges, substrate=substrate,
                         chiplet_area_mm2=area)


def fold_mask_variants(n: int,
                       families: tuple = ("grid", "brick", "grid_diag"),
                       substrate: str = "organic",
                       area: float = CHIPLET_AREA_MM2) -> list[Topology]:
    """Enumerate every per-axis mode assignment of the given families.

    Disconnected combinations (none on the standard rasters, but
    possible at degenerate dims) are skipped, not raised."""
    out = []
    for family in families:
        _, groups = _FAMILIES[family]
        for modes in itertools.product(AXIS_MODES, repeat=len(groups)):
            try:
                out.append(fold_mask_topology(n, family, modes,
                                              substrate=substrate,
                                              area=area))
            except ValueError:
                continue
    return out


# ---------------------------------------------------------------------
# degree-bounded random geometric graphs
# ---------------------------------------------------------------------

def _range_matrix(pos: np.ndarray) -> np.ndarray:
    """Pairwise link-range over raster positions (pitch units) — the
    one `topology.link_range_from_pitch` convention, so generated
    candidates and the feasibility filter agree on the budget."""
    d = np.sqrt(((pos[:, None, :] - pos[None, :, :]) ** 2).sum(-1))
    return link_range_from_pitch(d)


def candidate_pairs(pos: np.ndarray, max_range: int) -> np.ndarray:
    """[M, 2] node pairs (i < j) whose link-range is within budget."""
    rng = _range_matrix(pos)
    i, j = np.triu_indices(len(pos), k=1)
    ok = rng[i, j] <= max_range
    return np.stack([i[ok], j[ok]], axis=1)


def random_geometric(n: int, seed: int, family: str = "grid",
                     max_degree: int = 6, max_range: int = 1,
                     extra_frac: float | None = None,
                     substrate: str = "organic",
                     area: float = CHIPLET_AREA_MM2,
                     name: str | None = None,
                     max_tries: int = 8) -> Topology | None:
    """Random connected degree-bounded graph over a placement raster.

    A shuffled Kruskal pass builds a spanning tree from the pairs
    within `max_range` (respecting `max_degree`); a second pass adds
    random extra edges until `extra_frac` of the remaining degree
    budget is spent (drawn U[0.2, 0.9] when None).  Returns None when
    `max_tries` shuffles cannot connect the raster under the degree
    bound (only plausible for tiny max_degree).
    """
    rows, cols = pl.grid_dims(n)
    pos = pl.grid_positions(rows, cols, brick=(family == "brick"))
    pairs = candidate_pairs(pos, max_range)
    rng = np.random.default_rng(seed)
    for _ in range(max_tries):
        order = rng.permutation(len(pairs))
        deg = np.zeros(n, dtype=int)
        parent = np.arange(n)

        def find(x):
            while parent[x] != x:
                parent[x] = parent[parent[x]]
                x = parent[x]
            return x

        tree, extra = [], []
        for idx in order:
            a, b = pairs[idx]
            if deg[a] >= max_degree or deg[b] >= max_degree:
                continue
            ra, rb = find(a), find(b)
            if ra == rb:
                extra.append((int(a), int(b)))
                continue
            parent[ra] = rb
            deg[a] += 1
            deg[b] += 1
            tree.append((int(a), int(b)))
        if len(tree) != n - 1:
            continue                     # unlucky shuffle; retry
        frac = float(rng.uniform(0.2, 0.9)) if extra_frac is None \
            else extra_frac
        budget = int(frac * (max_degree * n // 2 - (n - 1)))
        edges = list(tree)
        for a, b in extra:
            if budget <= 0:
                break
            if deg[a] >= max_degree or deg[b] >= max_degree:
                continue
            deg[a] += 1
            deg[b] += 1
            edges.append((a, b))
            budget -= 1
        label = name or f"rg_{family}_{seed & 0xffffffff:08x}"
        return make_topology(label, pos, edges, substrate=substrate,
                             chiplet_area_mm2=area)
    return None


# ---------------------------------------------------------------------
# perturbation moves (the evolutionary neighbourhood)
# ---------------------------------------------------------------------

def perturb(topo: Topology, seed: int, max_degree: int = 6,
            max_range: int = 1, n_moves: int = 1,
            name: str | None = None,
            max_tries: int = 16) -> Topology | None:
    """Apply `n_moves` random add/remove/rewire edge moves.

    Every move preserves the invariants the feasibility filter and
    `make_topology` enforce: connectivity, the degree bound, and the
    link-range budget.  Returns None if no valid move sequence is
    found in `max_tries` attempts (e.g. a tree with a saturated degree
    budget).
    """
    rng = np.random.default_rng(seed)
    pairs = {(int(a), int(b)) for a, b in candidate_pairs(topo.pos,
                                                          max_range)}
    base = {(min(int(a), int(b)), max(int(a), int(b)))
            for a, b in topo.edges}
    n = topo.n
    for _ in range(max_tries):
        edges = set(base)
        deg = np.zeros(n, dtype=int)
        for a, b in edges:
            deg[a] += 1
            deg[b] += 1
        ok = True
        for _m in range(n_moves):
            op = rng.choice(("add", "remove", "rewire"))
            if not _one_move(edges, deg, pairs, rng, op, max_degree, n):
                ok = False
                break
        if not ok or edges == base:
            continue
        label = name or f"{topo.name}~{seed & 0xffff:04x}"
        try:
            return make_topology(label, topo.pos, sorted(edges),
                                 substrate=topo.substrate,
                                 chiplet_area_mm2=topo.chiplet_area_mm2)
        except ValueError:
            continue                     # move disconnected the graph
    return None


def _removable(edges: set, n: int) -> list:
    """Edges whose removal keeps the graph connected (not bridges)."""
    out = []
    for e in edges:
        rest = edges - {e}
        if _connected(rest, n):
            out.append(e)
    return out


def _connected(edges: set, n: int) -> bool:
    parent = list(range(n))

    def find(x):
        while parent[x] != x:
            parent[x] = parent[parent[x]]
            x = parent[x]
        return x

    comp = n
    for a, b in edges:
        ra, rb = find(a), find(b)
        if ra != rb:
            parent[ra] = rb
            comp -= 1
    return comp == 1


def _one_move(edges: set, deg: np.ndarray, pairs: set, rng, op: str,
              max_degree: int, n: int) -> bool:
    """Mutate (edges, deg) in place with one move; False if impossible."""
    if op in ("remove", "rewire"):
        cand = _removable(edges, n)
        if not cand:
            return False
        e = cand[rng.integers(len(cand))]
        edges.discard(e)
        deg[e[0]] -= 1
        deg[e[1]] -= 1
        if op == "remove":
            return True
    addable = [p for p in pairs
               if p not in edges
               and deg[p[0]] < max_degree and deg[p[1]] < max_degree]
    if not addable:
        return False
    e = addable[rng.integers(len(addable))]
    edges.add(e)
    deg[e[0]] += 1
    deg[e[1]] += 1
    return True
