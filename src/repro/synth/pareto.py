"""Pareto-front utilities for the synthesis engine (DESIGN.md §11).

Small, dependency-free multi-objective helpers over an [M, K] matrix
of objective values with per-column directions (True = maximize).
`eps` relaxation is multiplicative ε-efficiency (the ε-approximate
Pareto set of Papadimitriou & Yannakakis): a point is *within eps of
the front* iff no rival is better by more than a factor (1+eps) in
EVERY objective — equivalently, boosting all its objectives by (1+eps)
toward the good direction makes it non-dominated.  Note the
consequence: a candidate that ties the front's best value in one
objective is ε-efficient regardless of the others (it holds an edge of
the front), which is the intended "on or within 5 %" reading.
"""
from __future__ import annotations

import numpy as np


def _boost(points: np.ndarray, maximize, eps: float) -> np.ndarray:
    pts = np.asarray(points, np.float64).copy()
    for k, mx in enumerate(maximize):
        pts[:, k] = pts[:, k] * (1.0 + eps) if mx \
            else pts[:, k] / (1.0 + eps)
    return pts


def dominates(a, b, maximize) -> bool:
    """True if `a` weakly improves on `b` everywhere, strictly once."""
    a, b = np.asarray(a, np.float64), np.asarray(b, np.float64)
    ge = np.where(maximize, a >= b, a <= b)
    gt = np.where(maximize, a > b, a < b)
    return bool(ge.all() and gt.any())


def pareto_mask(points, maximize, eps: float = 0.0) -> np.ndarray:
    """[M] bool: point m is on (eps=0) or within eps of the front.

    NaN rows (unevaluated candidates) are never on the front and never
    dominate anyone.  One broadcast dominance check — this runs over
    the whole pool every search generation, so no per-pair Python.
    """
    pts = np.asarray(points, np.float64)
    m = len(pts)
    maximize = np.asarray(maximize, bool)
    if m == 0:
        return np.zeros(0, dtype=bool)
    boosted = _boost(pts, maximize, eps)
    valid = ~np.isnan(pts).any(axis=1)
    # orient so every objective is "bigger is better"
    sign = np.where(maximize, 1.0, -1.0)
    a = pts * sign                       # [M, K] candidates as dominators
    b = boosted * sign                   # [M, K] candidates as targets
    ge = a[:, None, :] >= b[None, :, :]  # [j, i, k]
    gt = a[:, None, :] > b[None, :, :]
    dom = ge.all(-1) & gt.any(-1) & valid[:, None]   # j dominates i
    np.fill_diagonal(dom, False)
    return valid & ~dom.any(axis=0)


def pareto_front(points, maximize) -> np.ndarray:
    """Indices of the exact Pareto front, in input order."""
    return np.flatnonzero(pareto_mask(points, maximize, eps=0.0))
