"""repro.synth — topology design-space exploration (DESIGN.md §11).

The paper's deepest contribution is not one topology but the design
principles that produced it; this package turns those principles into
a search:

    from repro.synth import SearchConfig, run_search
    res = run_search(SearchConfig(n=48, substrate="organic", seed=0))
    print(res.prefilter_ratio)            # sims saved by the prefilter
    for c in res.front():                 # (Tb/s, latency, wire cost)
        print(c.topo.name, c.metrics["abs_throughput_gbps"])
    assert res.on_front("folded_hexa_torus", eps=0.05)

Layers: `space` (fold-mask variants, degree-bounded random geometric
graphs, perturbation moves — all first-class `Topology` objects),
`feasibility` (the three design principles as prefilter checks),
`evaluate` (analytic rank, then cycle-accurate verification through
the batched experiment pipeline), `pareto` (ε-dominance utilities) and
`search` (the seeded, resumable evolutionary driver).
"""
from .evaluate import (Candidate, MAXIMIZE, OBJECTIVES, analytic_metrics,
                       evaluate_analytic, objective_matrix,
                       simulate_candidates)
from .feasibility import (FeasibilityCriteria, check, filter_feasible,
                          max_feasible_link_mm)
from .pareto import dominates, pareto_front, pareto_mask
from .search import (DEFAULT_ANCHORS, SearchConfig, SearchResult,
                     SearchState, run_search)
from .space import (AXIS_MODES, candidate_pairs, fold_mask_topology,
                    fold_mask_variants, key_seeds, perturb,
                    random_geometric)

__all__ = [
    "SearchConfig", "SearchState", "SearchResult", "run_search",
    "DEFAULT_ANCHORS",
    "Candidate", "OBJECTIVES", "MAXIMIZE", "analytic_metrics",
    "evaluate_analytic", "objective_matrix", "simulate_candidates",
    "FeasibilityCriteria", "check", "filter_feasible",
    "max_feasible_link_mm",
    "pareto_mask", "pareto_front", "dominates",
    "fold_mask_variants", "fold_mask_topology", "random_geometric",
    "perturb", "candidate_pairs", "key_seeds", "AXIS_MODES",
]
