"""Feasibility filter (DESIGN.md §11): the paper's design principles
as candidate checks, applied before any routing or simulation.

The canonical implementation moved to `repro.analysis.principles`
(DESIGN.md §14) so the synth prefilter, the experiment planner and the
`python -m repro.analysis` CLI all emit the *same* diagnostic codes
(DP001–DP005) instead of three divergent string sets.  This module is
a compatibility shim: `FeasibilityCriteria` is the same class, and
`check` returns exactly the legacy reason strings — they are the
`message` fields of the structured diagnostics, in the same order, so
the synth rejection ledger is byte-identical to pre-refactor runs.

  * **Principle 2 — link-range budget** (DP001): every link spans at
    most `max_link_range` intermediate chiplets;
  * **substrate rate floor** (DP002): the longest link must retain at
    least `min_rate_fraction` of the maximum per-wire rate on this
    substrate's Fig.-2 curve — the mechanism that zeroes
    Torus/ClusCross-style wrap links at scale;
  * **Principle 3 — wire budget** (DP003/DP004/DP005): the radix must
    leave a positive per-link data-wire budget after the UCIe overhead,
    optionally capped (`max_radix`), and the total substrate wire cost
    may be bounded (`max_wire_cost_mm`).

Connectivity / well-formedness is not re-checked here — `make_topology`
and `topology.build` already enforce it at construction time.
"""
from __future__ import annotations

from repro.analysis.principles import (FeasibilityCriteria, diagnose,
                                       max_feasible_link_mm)
from repro.core.topology import Topology

__all__ = ["FeasibilityCriteria", "max_feasible_link_mm", "check",
           "check_diagnostics", "filter_feasible"]


def check(topo: Topology,
          crit: FeasibilityCriteria = FeasibilityCriteria()) -> list[str]:
    """Reasons this candidate is infeasible; empty list == feasible."""
    return [d.message for d in diagnose(topo, crit)]


def check_diagnostics(topo: Topology,
                      crit: FeasibilityCriteria = FeasibilityCriteria()):
    """The same checks as structured diagnostics (DP codes + witness)."""
    return diagnose(topo, crit)


def filter_feasible(topos, crit: FeasibilityCriteria = FeasibilityCriteria()
                    ) -> tuple[list, list]:
    """Split candidates into (feasible, [(topo, reasons), ...])."""
    feasible, rejected = [], []
    for t in topos:
        reasons = check(t, crit)
        (feasible.append(t) if not reasons
         else rejected.append((t, reasons)))
    return feasible, rejected
