"""Feasibility filter (DESIGN.md §11): the paper's design principles
as candidate checks, applied before any routing or simulation.

The paper distils FoldedHexaTorus from three principles.  Principle 1
(low diameter) is an *objective* — the Pareto front rewards it via
zero-load latency — but Principles 2 and 3 are *constraints* a
substrate either meets or does not, so they prune the design space:

  * **Principle 2 — link-range budget**: every link spans at most
    `max_link_range` intermediate chiplets (the paper argues range > 1
    both slows the link and congests the wiring layers);
  * **substrate rate floor**: the longest link must retain at least
    `min_rate_fraction` of the maximum per-wire rate on this
    substrate's Fig.-2 curve (`linkmodel.rate_fraction`) — the
    mechanism that zeroes Torus/ClusCross-style wrap links at scale;
  * **Principle 3 — wire budget**: the radix must leave a positive
    per-link data-wire budget after the UCIe overhead
    (`costmodel.data_wires`), optionally capped (`max_radix`), and the
    total substrate wire cost may be bounded (`max_wire_cost_mm`).

Connectivity / well-formedness is not re-checked here — `make_topology`
and `topology.build` already enforce it at construction time.
"""
from __future__ import annotations

import dataclasses
import functools

import numpy as np

from repro.core import costmodel as cm
from repro.core import linkmodel as lm
from repro.core.topology import Topology


@dataclasses.dataclass(frozen=True)
class FeasibilityCriteria:
    max_link_range: int = 1          # Principle 2
    min_rate_fraction: float = 0.25  # substrate floor on the Fig.-2 curve
    max_radix: int | None = 8        # Principle 3: per-chiplet PHY budget
    min_data_wires: int = 1          # Principle 3: wires left per link
    max_wire_cost_mm: float | None = None

    def max_link_mm(self, substrate: str) -> float:
        return max_feasible_link_mm(substrate, self.min_rate_fraction)


@functools.lru_cache(maxsize=64)
def max_feasible_link_mm(substrate: str,
                         min_rate_fraction: float) -> float:
    """Longest link (mm) that still meets the rate floor on this
    substrate — the inverse of the monotone tail of the Fig.-2 curve,
    read off a fine grid (cached: `check` calls this once per
    generated candidate)."""
    grid = np.linspace(0.0, lm.MAX_LINK_LENGTH_MM, 7001)
    ok = grid[lm.rate_fraction(grid, substrate) >= min_rate_fraction]
    return float(ok.max()) if len(ok) else 0.0


def check(topo: Topology,
          crit: FeasibilityCriteria = FeasibilityCriteria()) -> list[str]:
    """Reasons this candidate is infeasible; empty list == feasible."""
    reasons = []
    ranges = topo.link_ranges()
    if len(ranges) and int(ranges.max()) > crit.max_link_range:
        reasons.append(f"link-range {int(ranges.max())} > "
                       f"{crit.max_link_range} (Principle 2)")
    cap = crit.max_link_mm(topo.substrate)
    lmax = topo.max_link_length_mm()
    if lmax > cap + 1e-9:
        reasons.append(f"max link {lmax:.1f} mm > {cap:.1f} mm "
                       f"({topo.substrate} rate floor "
                       f"{crit.min_rate_fraction:g})")
    if crit.max_radix is not None and topo.radix > crit.max_radix:
        reasons.append(f"radix {topo.radix} > {crit.max_radix} "
                       "(Principle 3)")
    if cm.data_wires(topo) < crit.min_data_wires:
        reasons.append(f"data wires {cm.data_wires(topo)} < "
                       f"{crit.min_data_wires} at radix {topo.radix} "
                       "(Principle 3)")
    if crit.max_wire_cost_mm is not None and \
            cm.wire_cost_mm(topo) > crit.max_wire_cost_mm:
        reasons.append(f"wire cost {cm.wire_cost_mm(topo):.0f} wire-mm "
                       f"> {crit.max_wire_cost_mm:.0f}")
    return reasons


def filter_feasible(topos, crit: FeasibilityCriteria = FeasibilityCriteria()
                    ) -> tuple[list, list]:
    """Split candidates into (feasible, [(topo, reasons), ...])."""
    feasible, rejected = [], []
    for t in topos:
        reasons = check(t, crit)
        (feasible.append(t) if not reasons
         else rejected.append((t, reasons)))
    return feasible, rejected
