"""Topology synthesis driver (DESIGN.md §11).

    generate -> feasibility filter -> analytic rank -> sim verify -> Pareto

`run_search` seeds a candidate pool (Table-III registry anchors +
fold-mask variants + degree-bounded random geometric graphs), prunes
it with the design-principle feasibility filter, ranks survivors with
the analytic channel-load bound, then walks `generations` rounds of
evolutionary perturbation moves (parents = the analytic ε-Pareto
front) before promoting the top slice to cycle-accurate verification
through the batched experiment pipeline.  The result is a Pareto front
over (absolute Tb/s, zero-load latency, wire cost) — which is how the
repo checks that FoldedHexaTorus actually sits on the frontier its own
simulator produces, not just against hand-picked baselines.

Randomness flows through JAX PRNG keys: generation g derives its move
seeds from `fold_in(key(seed), g)`, so a `SearchState` serialized
mid-search and resumed produces the identical pool as an uninterrupted
run.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core import topology as T
from repro.core.simulator import SimConfig
from repro.experiments import io as xio
from repro.obs.metrics import metrics
from repro.obs.trace import trace

from .evaluate import (Candidate, MAXIMIZE, evaluate_analytic,
                       objective_matrix, simulate_candidates)
from .feasibility import FeasibilityCriteria, check_diagnostics
from .pareto import pareto_mask
from .space import fold_mask_variants, key_seeds, perturb, random_geometric

#: registry names seeded as anchors (all Table-III families that exist
#: at arbitrary N; constrained ones are skipped via N_CONSTRAINTS)
DEFAULT_ANCHORS = ("mesh", "torus", "folded_torus", "hexamesh",
                   "folded_hexa_torus", "octamesh", "folded_octa_torus",
                   "honeycomb_mesh", "sid_mesh", "kite_large")


@dataclasses.dataclass(frozen=True)
class SearchConfig:
    n: int = 48
    substrate: str = "organic"
    traffic: str = "uniform"
    seed: int = 0
    area: float = 74.0
    anchors: tuple = DEFAULT_ANCHORS
    families: tuple = ("grid", "brick", "grid_diag")
    n_random: int = 32
    generations: int = 3
    offspring: int = 16              # perturbation moves per generation
    parents: int = 10                # ε-front slice used as parents
    max_degree: int = 8
    max_link_range: int = 1
    min_rate_fraction: float = 0.25
    sim_top: int = 8                 # stage-2 budget beyond the anchors
    n_rates: int = 4
    cfg: SimConfig = SimConfig(cycles=1500, warmup=500)

    @property
    def criteria(self) -> FeasibilityCriteria:
        return FeasibilityCriteria(max_link_range=self.max_link_range,
                                   min_rate_fraction=self.min_rate_fraction,
                                   max_radix=self.max_degree)

    def to_dict(self) -> dict:
        d = dataclasses.asdict(self)
        d["cfg"] = list(self.cfg)
        return d

    @classmethod
    def from_dict(cls, d: dict) -> "SearchConfig":
        d = dict(d)
        d["cfg"] = SimConfig(*d["cfg"])
        for k in ("anchors", "families"):
            d[k] = tuple(d[k])
        return cls(**d)


@dataclasses.dataclass
class SearchState:
    """Serializable search progress: the feasible pool, dedupe set,
    rejection ledger and counters.  JSON round-trips via
    `experiments.io` (schema-stamped), so a search can be stopped
    after any generation and resumed elsewhere."""
    config: SearchConfig
    generation: int = 0
    pool: list = dataclasses.field(default_factory=list)   # [Candidate]
    seen: set = dataclasses.field(default_factory=set)     # structural hashes
    rejected: list = dataclasses.field(default_factory=list)
    stats: dict = dataclasses.field(default_factory=lambda: dict(
        n_generated=0, n_duplicate=0, n_infeasible=0, n_feasible=0,
        n_simulated=0))

    # ---- pool growth ---------------------------------------------------
    def admit(self, topo, origin: str, parent: str = "") -> bool:
        """Dedupe -> validate feasibility -> pool; returns admitted?"""
        self.stats["n_generated"] += 1
        h = topo.structural_hash()
        if h in self.seen:
            self.stats["n_duplicate"] += 1
            return False
        self.seen.add(h)
        diags = check_diagnostics(topo, self.config.criteria)
        if diags:
            self.stats["n_infeasible"] += 1
            # reason strings stay byte-identical to the legacy ledger
            # (d.message IS the legacy string); codes ride alongside so
            # rejections are machine-groupable (DESIGN.md §14)
            self.rejected.append(dict(
                name=topo.name, origin=origin,
                reasons=[d.message for d in diags],
                diag_codes=[d.code for d in diags]))
            return False
        self.stats["n_feasible"] += 1
        self.pool.append(Candidate(topo=topo, origin=origin, parent=parent))
        return True

    # ---- serialization -------------------------------------------------
    def to_json(self, path: str) -> None:
        xio.write_json(path, [c.to_dict() for c in self.pool],
                       meta=dict(kind="synth_search_state",
                                 config=self.config.to_dict(),
                                 generation=self.generation,
                                 seen=sorted(self.seen),
                                 rejected=self.rejected,
                                 stats=self.stats))

    @classmethod
    def from_json(cls, path: str) -> "SearchState":
        doc = xio.read_json(path)
        if doc.get("kind") != "synth_search_state":
            raise ValueError(f"{path}: not a synth search state")
        return cls(config=SearchConfig.from_dict(doc["config"]),
                   generation=int(doc["generation"]),
                   pool=[Candidate.from_dict(d) for d in doc["rows"]],
                   seen=set(doc["seen"]), rejected=list(doc["rejected"]),
                   stats=dict(doc["stats"]))


@dataclasses.dataclass
class SearchResult:
    state: SearchState
    simulated: list                  # stage-2 Candidates, rank order
    frame: object                    # stage-2 ResultFrame (rate sweeps)

    @property
    def stats(self) -> dict:
        return self.state.stats

    @property
    def prefilter_ratio(self) -> float:
        """Feasible candidates per cycle-sim evaluation — how much the
        analytic prefilter cut the simulation bill."""
        return self.stats["n_feasible"] / max(self.stats["n_simulated"], 1)

    def front_mask(self, eps: float = 0.0) -> np.ndarray:
        """[len(simulated)] mask: on (or within eps of) the Pareto
        front over the sim-verified objective vectors."""
        return pareto_mask(objective_matrix(self.simulated), MAXIMIZE,
                           eps=eps)

    def front(self, eps: float = 0.0) -> list:
        m = self.front_mask(eps)
        return [c for c, on in zip(self.simulated, m) if on]

    def on_front(self, name: str, eps: float = 0.0) -> bool:
        """Is the named candidate on (or within eps of) the front?"""
        m = self.front_mask(eps)
        return any(on for c, on in zip(self.simulated, m)
                   if c.topo.name == name)

    def rows(self) -> list:
        """Tidy rows (pool + rejections) for the versioned writers."""
        front = {id(c) for c in self.front(0.0)}
        eps_front = {id(c) for c in self.front(0.05)}
        out = []
        for c in sorted(self.state.pool,
                        key=lambda c: -(c.metrics or {}).get(
                            "abs_throughput_gbps", 0.0)):
            m = c.metrics or {}
            out.append(dict(
                name=c.topo.name, origin=c.origin, parent=c.parent,
                n=c.topo.n, substrate=c.topo.substrate, status="ok",
                stage="sim" if c.simulated else "analytic",
                on_front=id(c) in front, within_5pct=id(c) in eps_front,
                **{k: m.get(k) for k in (
                    "abs_throughput_gbps", "zero_load_latency_ns",
                    "wire_cost_mm", "analytic_saturation",
                    "sim_saturation", "radix", "diameter", "avg_hops",
                    "n_links", "max_link_mm")}))
        for r in self.state.rejected:
            out.append(dict(name=r["name"], origin=r["origin"],
                            n=self.state.config.n,
                            substrate=self.state.config.substrate,
                            status="infeasible",
                            error="; ".join(r["reasons"]),
                            diag_code=";".join(r.get("diag_codes", []))))
        return out


# ---------------------------------------------------------------------
# driver
# ---------------------------------------------------------------------

def _seed_pool(state: SearchState) -> None:
    cfg = state.config
    for name in cfg.anchors:
        if not T.valid_n(name, cfg.n):
            continue
        topo = T.build(name, cfg.n, substrate=cfg.substrate,
                       chiplet_area_mm2=cfg.area)
        state.admit(topo, origin="registry")
    for topo in fold_mask_variants(cfg.n, families=cfg.families,
                                   substrate=cfg.substrate, area=cfg.area):
        state.admit(topo, origin="fold_mask")
    import jax
    seeds = key_seeds(jax.random.fold_in(jax.random.key(cfg.seed), 0),
                      cfg.n_random)
    for i, s in enumerate(seeds):
        family = cfg.families[i % len(cfg.families)]
        family = "brick" if family == "brick" else "grid"
        topo = random_geometric(cfg.n, int(s), family=family,
                                max_degree=cfg.max_degree,
                                max_range=cfg.max_link_range,
                                substrate=cfg.substrate, area=cfg.area)
        if topo is not None:
            state.admit(topo, origin="random")


def _select_parents(state: SearchState) -> list:
    cfg = state.config
    cands = [c for c in state.pool if c.analytic is not None]
    if not cands:
        return []
    mask = pareto_mask(objective_matrix(cands), MAXIMIZE, eps=0.05)
    ranked = sorted(
        range(len(cands)),
        key=lambda i: (not mask[i],
                       -cands[i].analytic["abs_throughput_gbps"]))
    return [cands[i] for i in ranked[:cfg.parents]]


def _evolve(state: SearchState, generation: int) -> None:
    import jax
    cfg = state.config
    parents = _select_parents(state)
    if not parents:
        return
    seeds = key_seeds(jax.random.fold_in(jax.random.key(cfg.seed),
                                         generation), cfg.offspring)
    for i, s in enumerate(seeds):
        parent = parents[i % len(parents)]
        child = perturb(parent.topo, int(s), max_degree=cfg.max_degree,
                        max_range=cfg.max_link_range,
                        n_moves=1 + i % 2)
        if child is not None:
            state.admit(child, origin="perturb", parent=parent.topo.name)


def _sim_slice(state: SearchState) -> list:
    """Stage-2 selection: every feasible registry anchor (so the
    paper's own topologies are always verified, FHT included) plus the
    `sim_top` best non-anchors — analytic Pareto-front members first,
    then by analytic throughput."""
    cfg = state.config
    anchors = [c for c in state.pool if c.origin == "registry"]
    rest = [c for c in state.pool if c.origin != "registry"]
    mask = pareto_mask(objective_matrix(rest), MAXIMIZE, eps=0.0) \
        if rest else np.zeros(0, bool)
    ranked = sorted(
        range(len(rest)),
        key=lambda i: (not mask[i],
                       -rest[i].analytic["abs_throughput_gbps"]))
    return anchors + [rest[i] for i in ranked[:cfg.sim_top]]


def run_search(config: SearchConfig | None = None,
               state: SearchState | None = None,
               progress=None,
               pause_after: int | None = None) -> SearchResult:
    """Run (or resume) a synthesis search; see the module docstring.

    Pass a saved `SearchState` to resume: completed generations are
    not re-run, and PRNG keys are derived per generation
    (`fold_in(key(seed), g)`), so resumed and uninterrupted runs
    produce the identical pool.  `pause_after=g` stops after
    generation min(g, generations) and always skips the stage-2
    simulation (the result carries an empty `simulated` slice) —
    serialize `result.state` and pass it back to continue.
    """
    if state is None:
        state = SearchState(config=config or SearchConfig())
    elif config is not None and config != state.config:
        raise ValueError("resume state carries a different SearchConfig")
    cfg = state.config
    if not state.pool and state.generation == 0:
        with trace("synth.seed", cat="synth", n=cfg.n,
                   substrate=cfg.substrate):
            _seed_pool(state)
    with trace("synth.analytic", cat="synth", pool=len(state.pool)):
        evaluate_analytic(state.pool, cfg.traffic)
    target = cfg.generations if pause_after is None \
        else min(pause_after, cfg.generations)
    while state.generation < target:
        g = state.generation + 1
        with trace("synth.generation", cat="synth", generation=g,
                   pool=len(state.pool)):
            _evolve(state, g)
            evaluate_analytic(state.pool, cfg.traffic)
        state.generation = g
        metrics.inc("synth.generations")
        if progress is not None:
            progress(g, cfg.generations, state.stats)
    metrics.inc("synth.candidates", state.stats["n_generated"])
    if pause_after is not None:           # paused: no stage-2 this call
        return SearchResult(state=state, simulated=[], frame=None)
    sim = _sim_slice(state)
    with trace("synth.simulate", cat="synth", candidates=len(sim)):
        frame = simulate_candidates(sim, traffic=cfg.traffic, cfg=cfg.cfg,
                                    n_rates=cfg.n_rates)
    state.stats["n_simulated"] = sum(1 for c in sim if c.simulated)
    metrics.inc("synth.simulated", state.stats["n_simulated"])
    return SearchResult(state=state, simulated=[c for c in sim
                                               if c.simulated],
                        frame=frame)
