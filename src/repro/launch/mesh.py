"""Production meshes.

Single pod: 16 x 16 = 256 chips, axes ("data", "model").
Multi-pod:  2 x 16 x 16 = 512 chips, axes ("pod", "data", "model") — the
"pod" axis is pure data parallelism across pods (gradient all-reduce
crosses the inter-pod links only once per step).

Defined as functions so importing this module never touches jax device
state; the dry-run sets XLA_FLAGS before any jax import.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh():
    """A trivial 1x1 mesh for CPU smoke tests of the sharded code paths."""
    return jax.make_mesh((1, 1), ("data", "model"))
