"""End-to-end training driver.

    PYTHONPATH=src python -m repro.launch.train --arch qwen3-1.7b \
        --smoke --steps 200 --batch 8 --seq 128 --ckpt-dir /tmp/ck

Integrates the full production loop: synthetic data pipeline, AdamW with
warmup-cosine, microbatch accumulation, async checkpointing with resume,
step watchdog (straggler flagging) and heartbeat.  `--smoke` selects the
reduced config so a ~100M-class run fits a CPU box; on real hardware the
same driver takes the full config + production mesh.
"""
from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.checkpoint import AsyncCheckpointer, latest_step, \
    restore_checkpoint
from repro.data import SyntheticLMData
from repro.launch import steps as St
from repro.models import Model, unbox
from repro.optim import adamw_init
from repro.runtime import Heartbeat, StepWatchdog


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-1.7b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--d-model", type=int, default=None,
                    help="override width (e.g. ~100M-param variant)")
    ap.add_argument("--layers", type=int, default=None)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch, smoke=args.smoke)
    over = {}
    if args.d_model:
        over.update(d_model=args.d_model,
                    d_ff=args.d_model * 4,
                    head_dim=args.d_model // cfg.n_heads)
    if args.layers:
        over.update(n_layers=args.layers)
    if over:
        cfg = dataclasses.replace(cfg, **over)

    model = Model(cfg)
    params, _ = unbox(model.init(jax.random.PRNGKey(args.seed)))
    n_params = sum(int(np.prod(p.shape))
                   for p in jax.tree.leaves(params))
    print(f"[train] arch={cfg.name} params={n_params/1e6:.1f}M "
          f"steps={args.steps} batch={args.batch}x{args.seq}")

    from repro.optim.adamw import AdamWConfig
    tcfg = St.TrainConfig(
        opt=AdamWConfig(lr=args.lr),
        microbatches=args.microbatches,
        total_steps=args.steps, warmup_steps=max(args.steps // 20, 5))
    step_fn = jax.jit(St.make_train_step(model, tcfg), donate_argnums=(0, 1))
    opt_state = adamw_init(params)

    start = 0
    ck = None
    if args.ckpt_dir:
        ck = AsyncCheckpointer(args.ckpt_dir)
        last = latest_step(args.ckpt_dir)
        if last is not None:
            state = restore_checkpoint(
                args.ckpt_dir, last,
                {"params": params, "opt": opt_state})
            params, opt_state = state["params"], state["opt"]
            start = last
            print(f"[train] resumed from step {start}")

    data = SyntheticLMData(vocab=cfg.vocab, seq_len=args.seq,
                           global_batch=args.batch, seed=args.seed)
    wd = StepWatchdog()
    hb = Heartbeat((args.ckpt_dir or "/tmp") + "/heartbeat.json",
                   interval_s=30).start() if args.ckpt_dir else None

    losses = []
    for step in range(start, args.steps):
        batch = {k: jnp.asarray(v) for k, v in data.batch(step).items()}
        if cfg.arch_kind == "encdec":
            rngf = np.random.default_rng(step)
            batch["frames"] = jnp.asarray(
                rngf.normal(0, 0.02, (args.batch, args.seq, cfg.d_model)),
                jnp.float32)
        t0 = time.time()
        params, opt_state, metrics = step_fn(params, opt_state, batch)
        loss = float(metrics["loss"])
        dt = time.time() - t0
        slow = wd.observe(dt)
        losses.append(loss)
        if step % args.log_every == 0 or step == args.steps - 1:
            print(f"[train] step={step:5d} loss={loss:.4f} "
                  f"gnorm={float(metrics['grad_norm']):.3f} "
                  f"dt={dt*1e3:.0f}ms{' STRAGGLER' if slow else ''}")
        if ck and step and step % args.ckpt_every == 0:
            ck.save(step, {"params": params, "opt": opt_state})
    if ck:
        ck.save(args.steps, {"params": params, "opt": opt_state})
        ck.wait()
    if hb:
        hb.stop()
    print(f"[train] done: first-10 avg {np.mean(losses[:10]):.4f} -> "
          f"last-10 avg {np.mean(losses[-10:]):.4f}")
    return losses


if __name__ == "__main__":
    main()
