"""Step builders + input/parameter specs for training and serving.

Everything here works on ShapeDtypeStructs as well as real arrays, so the
multi-pod dry-run lowers the exact production step functions without
allocating anything.
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.models import Model, ModelConfig, ParallelCtx, unbox
from repro.models.model import DecodeDims
from repro.models.sharding import tree_pspecs, batch_spec
from repro.optim import AdamWConfig, adamw_init, adamw_update
from repro.optim.schedule import warmup_cosine


def build_ctx(mesh: Mesh) -> ParallelCtx:
    names = mesh.axis_names
    batch_axes = tuple(n for n in names if n in ("pod", "data"))
    return ParallelCtx(mesh=mesh, batch_axes=batch_axes,
                       model_axis="model", fsdp_axes=("data",))


# ---------------------------------------------------------------------
# specs
# ---------------------------------------------------------------------

def param_shapes_and_axes(model: Model):
    boxed = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    return unbox(boxed)           # (ShapeDtypeStruct tree, axes tree)


def param_shardings(model: Model, ctx: ParallelCtx,
                    serving_mode: str = "train"):
    shapes, axes = param_shapes_and_axes(model)
    if serving_mode == "decode":
        # weight-stationary serving: no FSDP (embed unsharded over data);
        # instead the *output* dims (mlp/d_ff) shard over "data", so
        # per-layer weight all-gathers become tiny activation psums, and
        # MoE experts match moe_ep_stationary's (model, data) layout.
        ctx = dataclasses.replace(ctx,
                                  extra_rules={"embed": (),
                                               "mlp": ("data",)})
    elif model.cfg.seq_parallel:
        # sequence-parallel archs keep activations seq-sharded on the
        # model axis end-to-end; tensor-parallel MLP sharding would force
        # an all-gather/reduce-scatter pair at every layer boundary, so
        # the (small) MLP weights are replicated over "model" instead
        # and remain FSDP-sharded over "data".
        ctx = dataclasses.replace(ctx, extra_rules={"mlp": ()})
    specs = tree_pspecs(axes, shapes, ctx, for_weights=True)
    shard = jax.tree.map(lambda s: NamedSharding(ctx.mesh, s), specs,
                         is_leaf=lambda x: isinstance(x, P))
    return shapes, shard


def batch_specs(cfg: ModelConfig, shape: dict, ctx: ParallelCtx | None):
    b, t = shape["global_batch"], shape["seq_len"]
    mode = shape["mode"]
    sds = jax.ShapeDtypeStruct
    if mode == "train":
        batch = {"tokens": sds((b, t), jnp.int32),
                 "labels": sds((b, t), jnp.int32)}
        if cfg.arch_kind == "encdec":
            batch["frames"] = sds((b, t, cfg.d_model), jnp.float32)
    elif mode == "prefill":
        batch = {"tokens": sds((b, t), jnp.int32)}
        if cfg.arch_kind == "encdec":
            batch["frames"] = sds((b, t, cfg.d_model), jnp.float32)
    else:                          # decode
        batch = {"tokens": sds((b, 1), jnp.int32)}
    if ctx is None:
        return batch, None
    shard = {k: NamedSharding(ctx.mesh, batch_spec(ctx, b, v.ndim))
             for k, v in batch.items()}
    return batch, shard


def cache_specs(model: Model, dims: DecodeDims, ctx: ParallelCtx | None):
    shapes = jax.eval_shape(lambda: model.init_cache(dims))
    if ctx is None:
        return shapes, None
    axes = model.cache_logical_axes(dims)
    cfg = model.cfg
    msize = ctx.mesh.shape[ctx.model_axis]
    # prefer kv-head sharding; fall back to sequence sharding (distributed
    # softmax) when the arch's kv head count cannot tile the model axis
    if cfg.attn_kind == "gqa" and cfg.n_kv_heads % msize == 0:
        extra = {"seq": (), "kv": (ctx.model_axis,)}
    else:
        extra = {"seq": (ctx.model_axis,), "kv": ()}
    ctx2 = dataclasses.replace(ctx, extra_rules=extra)
    specs = tree_pspecs(axes, shapes, ctx2, for_weights=False)
    shard = jax.tree.map(lambda s: NamedSharding(ctx.mesh, s), specs,
                         is_leaf=lambda x: isinstance(x, P))
    return shapes, shard


# ---------------------------------------------------------------------
# steps
# ---------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class TrainConfig:
    opt: AdamWConfig = AdamWConfig()
    microbatches: int = 1
    total_steps: int = 10000
    warmup_steps: int = 100


def make_train_step(model: Model, tcfg: TrainConfig):
    """(params, opt_state, batch) -> (params, opt_state, metrics).

    With microbatches > 1, the batch is split along dim 0 and gradients
    are accumulated in a lax.scan (activation memory / pipeline knob).
    """
    def loss_fn(p, b):
        return model.loss_fn(p, b)

    def train_step(params, opt_state, batch):
        k = tcfg.microbatches
        # Cast the fp32 masters to bf16 ONCE per step, before any use:
        # the FSDP weight all-gathers the partitioner inserts then move
        # bf16 (half the wire bytes) and are loop-invariant w.r.t. the
        # microbatch scan.  Gradients flow to the bf16 copies and are
        # accumulated in fp32 (standard mixed precision).
        params_c = model._cast(params)
        if k > 1:
            def micro(carry, mb):
                acc = carry
                l, g = jax.value_and_grad(loss_fn)(params_c, mb)
                acc = jax.tree.map(
                    lambda a, x: a + x.astype(jnp.float32), acc, g)
                return acc, l
            mbs = jax.tree.map(
                lambda x: x.reshape((k, x.shape[0] // k) + x.shape[1:]),
                batch)
            zero = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            grads, losses = jax.lax.scan(micro, zero, mbs)
            grads = jax.tree.map(lambda g: g / k, grads)
            loss = losses.mean()
        else:
            loss, grads = jax.value_and_grad(loss_fn)(params_c, batch)
        lr_scale = warmup_cosine(opt_state.step + 1,
                                 warmup=tcfg.warmup_steps,
                                 total=tcfg.total_steps)
        params, opt_state, gnorm = adamw_update(
            tcfg.opt, params, grads, opt_state, lr_scale)
        return params, opt_state, {"loss": loss, "grad_norm": gnorm}

    return train_step


def make_prefill_step(model: Model):
    def prefill_step(params, batch):
        return model.prefill(params, batch)
    return prefill_step


def make_decode_step(model: Model):
    def decode_step(params, caches, tokens, pos):
        return model.decode_step(params, caches, tokens, pos)
    return decode_step
