"""Batched serving driver: prefill a batch of prompts, then decode.

    PYTHONPATH=src python -m repro.launch.serve --arch mamba2-1.3b \
        --smoke --batch 4 --prompt-len 64 --gen 32
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.models import Model, unbox
from repro.models.model import DecodeDims


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-1.7b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch, smoke=args.smoke)
    model = Model(cfg)
    params, _ = unbox(model.init(jax.random.PRNGKey(args.seed)))

    rng = np.random.default_rng(args.seed)
    b, t = args.batch, args.prompt_len
    prompts = jnp.asarray(rng.integers(0, cfg.vocab, (b, t)), jnp.int32)
    batch = {"tokens": prompts}
    if cfg.arch_kind == "encdec":
        batch["frames"] = jnp.asarray(
            rng.normal(0, 0.02, (b, t, cfg.d_model)), jnp.float32)

    prefill = jax.jit(model.prefill)
    decode = jax.jit(model.decode_step)

    t0 = time.time()
    logits, caches = prefill(params, batch)
    logits.block_until_ready()
    t_prefill = time.time() - t0
    print(f"[serve] prefill {b}x{t}: {t_prefill*1e3:.0f}ms")

    # Decode uses ring-buffer caches: generating past the prompt length
    # overwrites the oldest prompt entries (sliding-window semantics for
    # attention caches; SSM state is exact regardless).  For gen <=
    # prompt_len this demo stays well inside the window.

    tok = jnp.argmax(logits, -1).astype(jnp.int32)[:, None]
    out = [tok]
    t0 = time.time()
    for i in range(args.gen):
        logits, caches = decode(params, caches, tok, jnp.int32(t + i))
        tok = jnp.argmax(logits[:, -1], -1).astype(jnp.int32)[:, None]
        out.append(tok)
    jax.block_until_ready(tok)
    dt = time.time() - t0
    toks = jnp.concatenate(out, axis=1)
    print(f"[serve] decoded {args.gen} tokens/seq x {b} seqs in "
          f"{dt*1e3:.0f}ms ({args.gen*b/max(dt,1e-9):.1f} tok/s)")
    print("[serve] sample:", np.asarray(toks[0][:16]))
    return toks


if __name__ == "__main__":
    main()
