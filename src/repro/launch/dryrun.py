"""Multi-pod dry-run: lower + compile every (arch x shape) cell on the
production meshes and extract roofline inputs from the compiled artifact.

    PYTHONPATH=src python -m repro.launch.dryrun --arch qwen3-1.7b \
        --shape train_4k [--multi-pod] [--out results/dryrun]

The compiled module is one SPMD partition, so cost_analysis() FLOPs /
bytes and memory_analysis() are *per chip*; collective bytes are summed
from the post-partitioning HLO (output shapes of all-reduce / all-gather
/ reduce-scatter / all-to-all / collective-permute ops).
"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

import argparse   # noqa: E402
import dataclasses  # noqa: E402
import json       # noqa: E402
import re         # noqa: E402
import time       # noqa: E402
import traceback  # noqa: E402

import jax        # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402
from jax.sharding import NamedSharding, PartitionSpec as P  # noqa: E402

from repro.configs import SHAPES, ARCHS, LONG_CONTEXT_OK, canon, \
    get_config, cells  # noqa: E402
from repro.launch.mesh import make_production_mesh  # noqa: E402
from repro.launch import steps as St  # noqa: E402
from repro.models import Model  # noqa: E402
from repro.models.model import DecodeDims  # noqa: E402
from repro.optim import adamw_init  # noqa: E402

_DTYPE_BYTES = {"pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2,
                "bf16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8,
                "u64": 8, "f64": 8, "c64": 8, "c128": 16}

_COLL_RE = re.compile(
    r"^\s*%?[\w.\-]+ = (\([^)]*\)|[a-z0-9]+\[[0-9,]*\][^ ]*) "
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"[\w.\-]*\(", re.M)
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")


def _shape_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def parse_collectives(hlo_text: str) -> dict:
    out = {}
    for type_str, kind in _COLL_RE.findall(hlo_text):
        b = _shape_bytes(type_str)
        d = out.setdefault(kind, {"count": 0, "bytes": 0})
        d["count"] += 1
        d["bytes"] += b
    return out


def build_lowered(arch: str, shape_name: str, multi_pod: bool,
                  microbatches: int = 1, remat: str | None = None,
                  fsdp_pod: bool = False, extra_cfg: dict | None = None):
    cfg = get_config(arch)
    if remat:
        cfg = dataclasses.replace(cfg, remat=remat)
    if extra_cfg:
        cfg = dataclasses.replace(cfg, **extra_cfg)
    shape = SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    ctx = St.build_ctx(mesh)
    if fsdp_pod and multi_pod:
        ctx = dataclasses.replace(ctx, fsdp_axes=("pod", "data"))
    model = Model(cfg, ctx=ctx)

    mode = shape["mode"]
    p_shapes, p_shard = St.param_shardings(model, ctx, serving_mode=mode)
    b_shapes, b_shard = St.batch_specs(cfg, shape, ctx)
    if mode in ("prefill", "decode"):
        # serving holds bf16 weights (the fp32 masters live with the
        # trainer): halves weight-gather bytes and per-chip HBM
        p_shapes = jax.tree.map(
            lambda s: jax.ShapeDtypeStruct(s.shape, jnp.bfloat16)
            if s.dtype == jnp.float32 else s, p_shapes)

    with mesh:
        if mode == "train":
            tcfg = St.TrainConfig(microbatches=microbatches)
            step = St.make_train_step(model, tcfg)
            o_shapes = jax.eval_shape(adamw_init, p_shapes)
            o_shard = type(o_shapes)(
                step=NamedSharding(mesh, P()),
                m=p_shard, v=jax.tree.map(lambda s: s, p_shard))
            fn = jax.jit(step, in_shardings=(p_shard, o_shard, b_shard),
                         donate_argnums=(0, 1))
            lowered = fn.lower(p_shapes, o_shapes, b_shapes)
        elif mode == "prefill":
            step = St.make_prefill_step(model)
            fn = jax.jit(step, in_shardings=(p_shard, b_shard))
            lowered = fn.lower(p_shapes, b_shapes)
        else:
            step = St.make_decode_step(model)
            dims = DecodeDims(batch=shape["global_batch"],
                              seq=shape["seq_len"])
            c_shapes, c_shard = St.cache_specs(model, dims, ctx)
            pos_shard = NamedSharding(mesh, P())
            fn = jax.jit(step, in_shardings=(
                p_shard, c_shard, b_shard["tokens"], pos_shard),
                donate_argnums=(1,))      # serving loop donates the cache
            lowered = fn.lower(p_shapes, c_shapes,
                               b_shapes["tokens"],
                               jax.ShapeDtypeStruct((), jnp.int32))
    return lowered, mesh, cfg


def _extract(compiled):
    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    cost = cost[0] if isinstance(cost, (list, tuple)) else cost
    coll = parse_collectives(compiled.as_text())
    return dict(
        flops=float(cost.get("flops", -1)),
        bytes_accessed=float(cost.get("bytes accessed", -1)),
        peak_bytes=int(getattr(mem, "temp_size_in_bytes", -1)),
        argument_bytes=int(getattr(mem, "argument_size_in_bytes", -1)),
        output_bytes=int(getattr(mem, "output_size_in_bytes", -1)),
        collectives=coll,
        collective_bytes=sum(v["bytes"] for v in coll.values()),
    )


def _scan_reps(cfg) -> int:
    _, n_rep, _ = cfg.pattern()
    return n_rep


# per-arch microbatch tuning (see EXPERIMENTS.md §Perf): fewer
# microbatches -> fewer FSDP weight gathers per step, as long as the
# activation peak still fits 16 GB HBM.
MICROBATCH_DEFAULTS = {"starcoder2_3b": 1, "gemma3_1b": 2}


def run_cell(arch: str, shape_name: str, multi_pod: bool, out_dir: str,
             microbatches: int = 1, **kw) -> dict:
    """Compile the production step (memory check) plus an unroll=2 variant
    whose static HLO-cost delta gives the per-layer cost, so scan-hidden
    FLOPs/bytes/collective-bytes extrapolate to true per-step totals:

        S(u) = const + u * per_layer   =>   total = k*(S1 + (R-1)*(S2-S1))
    """
    t0 = time.time()
    arch = canon(arch)
    tag = f"{arch}__{shape_name}__{'pod2' if multi_pod else 'pod1'}"
    rec = dict(arch=arch, shape=shape_name,
               mesh="2x16x16" if multi_pod else "16x16", tag=tag)
    if SHAPES[shape_name]["mode"] == "train":
        k = MICROBATCH_DEFAULTS.get(arch, microbatches)
    else:
        k = 1
    try:
        lowered, mesh, cfg = build_lowered(
            arch, shape_name, multi_pod, microbatches=k, **kw)
        t1 = time.time()
        compiled = lowered.compile()
        t2 = time.time()
        m1 = _extract(compiled)
        del compiled, lowered

        # unroll=2 variant for per-layer extrapolation
        kw2 = dict(kw)
        kw2.setdefault("extra_cfg", {})
        kw2["extra_cfg"] = dict(kw2["extra_cfg"] or {}, scan_unroll=2)
        lowered2, _, _ = build_lowered(
            arch, shape_name, multi_pod, microbatches=k, **kw2)
        m2 = _extract(lowered2.compile())
        t3 = time.time()

        n_rep = _scan_reps(get_config(arch))
        def extr(key):
            per_layer = max(m2[key] - m1[key], 0.0)
            return k * (m1[key] + (n_rep - 1) * per_layer)
        coll_total = {}
        for kind in set(m1["collectives"]) | set(m2["collectives"]):
            c1 = m1["collectives"].get(kind, {"count": 0, "bytes": 0})
            c2 = m2["collectives"].get(kind, {"count": 0, "bytes": 0})
            coll_total[kind] = {
                "count": int(k * (c1["count"] + (n_rep - 1) *
                                  max(c2["count"] - c1["count"], 0))),
                "bytes": int(k * (c1["bytes"] + (n_rep - 1) *
                                  max(c2["bytes"] - c1["bytes"], 0)))}
        rec.update(
            ok=True, microbatches=k, scan_reps=n_rep,
            lower_s=round(t1 - t0, 1), compile_s=round(t2 - t1, 1),
            unroll2_s=round(t3 - t2, 1),
            flops_per_chip=extr("flops"),
            bytes_accessed_per_chip=extr("bytes_accessed"),
            peak_bytes_per_chip=m1["peak_bytes"],
            argument_bytes_per_chip=m1["argument_bytes"],
            output_bytes_per_chip=m1["output_bytes"],
            collectives=coll_total,
            collective_bytes_per_chip=sum(v["bytes"]
                                          for v in coll_total.values()),
            raw_static=dict(u1=m1, u2={kk: m2[kk] for kk in
                                       ("flops", "bytes_accessed")}),
        )
        print(f"[dryrun] {tag}: OK  compile={rec['compile_s']}s "
              f"flops/chip={rec['flops_per_chip']:.3e} "
              f"peak={rec['peak_bytes_per_chip']/2**30:.2f}GiB "
              f"coll={rec['collective_bytes_per_chip']/2**20:.1f}MiB")
    except Exception as e:  # noqa: BLE001
        rec.update(ok=False, error=f"{type(e).__name__}: {e}",
                   traceback=traceback.format_exc()[-4000:])
        print(f"[dryrun] {tag}: FAIL {type(e).__name__}: {str(e)[:200]}")
    os.makedirs(out_dir, exist_ok=True)
    with open(os.path.join(out_dir, tag + ".json"), "w") as f:
        json.dump(rec, f, indent=1)
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default="results/dryrun")
    ap.add_argument("--skip-existing", action="store_true")
    ap.add_argument("--microbatches", type=int, default=4)
    ap.add_argument("--remat", default=None)
    args = ap.parse_args()

    if args.all:
        todo = [(a, s, mp) for (a, s) in cells()
                for mp in ((False, True) if args.both_meshes
                           else (args.multi_pod,))]
    else:
        assert args.arch and args.shape
        todo = [(args.arch, args.shape, mp)
                for mp in ((False, True) if args.both_meshes
                           else (args.multi_pod,))]

    n_ok = 0
    for arch, shape, mp in todo:
        tag = f"{canon(arch)}__{shape}__{'pod2' if mp else 'pod1'}"
        path = os.path.join(args.out, tag + ".json")
        if args.skip_existing and os.path.exists(path):
            with open(path) as f:
                if json.load(f).get("ok"):
                    print(f"[dryrun] {tag}: cached OK")
                    n_ok += 1
                    continue
        rec = run_cell(arch, shape, mp, args.out,
                       microbatches=args.microbatches, remat=args.remat)
        n_ok += bool(rec.get("ok"))
    print(f"[dryrun] {n_ok}/{len(todo)} cells OK")


if __name__ == "__main__":
    main()
