"""Blockwise flash attention (forward) as a Pallas TPU kernel.

Grid: (batch*heads, q_blocks).  Each program holds one q tile
[BQ, hd] in VMEM plus the full k/v stripes for its (batch, head) —
[T_k, hd] each, bf16, which fits VMEM for T_k <= 32k at hd=128 — and
iterates over k tiles with the online-softmax running (m, l, acc)
recurrence.  Causal and sliding-window masks are applied per tile, and
fully-masked tiles are skipped via the loop bounds (causal ⇒ only tiles
with k_start <= q_end; window ⇒ only tiles with k_end > q_start-window).

MXU alignment: BQ = BK = 128, hd padded to a multiple of 128 by the
wrapper when needed.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

DEFAULT_BQ = 128
DEFAULT_BK = 128
NEG_INF = -1e30


def _fa_kernel(q_ref, k_ref, v_ref, o_ref, *, bq: int, bk: int,
               seq_k: int, causal: bool, window: int | None,
               sm_scale: float):
    qi = pl.program_id(1)
    q = q_ref[...].astype(jnp.float32) * sm_scale        # [bq, hd]
    hd = q.shape[-1]
    q_start = qi * bq
    q_pos = q_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)

    n_k = seq_k // bk
    if causal:
        # highest k tile that any of our queries can see
        hi = jnp.minimum((q_start + bq + bk - 1) // bk, n_k)
    else:
        hi = n_k
    if window is not None:
        lo = jnp.maximum((q_start - window) // bk, 0)
    else:
        lo = 0

    def body(ki, carry):
        m_prev, l_prev, acc = carry
        k = pl.load(k_ref, (pl.ds(ki * bk, bk), slice(None))
                    ).astype(jnp.float32)                 # [bk, hd]
        v = pl.load(v_ref, (pl.ds(ki * bk, bk), slice(None))
                    ).astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
        k_pos = ki * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        mask = jnp.ones((bq, bk), jnp.bool_)
        if causal:
            mask &= q_pos >= k_pos
        if window is not None:
            mask &= (q_pos - k_pos) < window
        s = jnp.where(mask, s, NEG_INF)

        m_cur = jnp.maximum(m_prev, s.max(axis=1))        # [bq]
        alpha = jnp.exp(m_prev - m_cur)
        p = jnp.exp(s - m_cur[:, None])
        l_cur = l_prev * alpha + p.sum(axis=1)
        acc = acc * alpha[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        return m_cur, l_cur, acc

    m0 = jnp.full((bq,), NEG_INF, jnp.float32)
    l0 = jnp.zeros((bq,), jnp.float32)
    a0 = jnp.zeros((bq, hd), jnp.float32)
    m, l, acc = jax.lax.fori_loop(lo, hi, body, (m0, l0, a0))
    o_ref[...] = (acc / jnp.maximum(l, 1e-30)[:, None]).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("causal", "window", "bq",
                                             "bk", "interpret"))
def flash_attention_bhsd(q, k, v, *, causal=True, window=None,
                         bq=DEFAULT_BQ, bk=DEFAULT_BK, interpret=False):
    """q: [BH, Tq, hd], k/v: [BH, Tk, hd] (kv already head-broadcast)."""
    bh, tq, hd = q.shape
    tk = k.shape[1]
    assert tq % bq == 0 and tk % bk == 0, (tq, tk, bq, bk)
    sm_scale = 1.0 / np.sqrt(hd)
    kern = functools.partial(_fa_kernel, bq=bq, bk=bk, seq_k=tk,
                             causal=causal, window=window,
                             sm_scale=sm_scale)
    grid = (bh, tq // bq)
    return pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((None, bq, hd), lambda b, i: (b, i, 0)),
            pl.BlockSpec((None, tk, hd), lambda b, i: (b, 0, 0)),
            pl.BlockSpec((None, tk, hd), lambda b, i: (b, 0, 0)),
        ],
        out_specs=pl.BlockSpec((None, bq, hd), lambda b, i: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, tq, hd), q.dtype),
        interpret=interpret,
    )(q, k, v)
