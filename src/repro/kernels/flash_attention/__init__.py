"""Flash-attention Pallas kernel with a pure-jnp reference oracle."""
from . import ops, ref  # noqa
