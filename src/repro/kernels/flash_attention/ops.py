"""Public wrapper: [B, T, H, hd] attention -> Pallas flash kernel.

interpret=True on CPU (validation); compiled Mosaic path on TPU.
"""
import jax
import jax.numpy as jnp

from .flash_attention import flash_attention_bhsd


def _interpret() -> bool:
    return jax.default_backend() == "cpu"


def flash_attention(q, k, v, *, causal=True, window=None):
    """q: [B,Tq,H,hd]; k,v: [B,Tk,KV,hd] — GQA broadcast then kernel."""
    b, tq, h, hd = q.shape
    kvh = k.shape[2]
    if kvh != h:
        k = jnp.repeat(k, h // kvh, axis=2)
        v = jnp.repeat(v, h // kvh, axis=2)
    tk = k.shape[1]
    qb = q.transpose(0, 2, 1, 3).reshape(b * h, tq, hd)
    kb = k.transpose(0, 2, 1, 3).reshape(b * h, tk, hd)
    vb = v.transpose(0, 2, 1, 3).reshape(b * h, tk, hd)
    ob = flash_attention_bhsd(qb, kb, vb, causal=causal, window=window,
                              interpret=_interpret())
    return ob.reshape(b, h, tq, hd).transpose(0, 2, 1, 3)
