"""Pure-jnp oracle for the flash-attention kernel."""
import jax
import jax.numpy as jnp
import numpy as np


def attention_ref(q, k, v, *, causal=True, window=None):
    """q: [BH, Tq, hd], k/v: [BH, Tk, hd] — exact softmax attention."""
    bh, tq, hd = q.shape
    tk = k.shape[1]
    s = jnp.einsum("bqd,bkd->bqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) / np.sqrt(hd)
    qp = jnp.arange(tq)[:, None]
    kp = jnp.arange(tk)[None, :]
    mask = jnp.ones((tq, tk), bool)
    if causal:
        mask &= qp >= kp
    if window is not None:
        mask &= (qp - kp) < window
    s = jnp.where(mask[None], s, -1e30)
    w = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bqk,bkd->bqd", w,
                      v.astype(jnp.float32)).astype(q.dtype)
