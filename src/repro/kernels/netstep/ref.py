"""Oracle for the netstep kernel — mirrors the allocation arithmetic of
repro.core.simulator on pre-computed (op_slot, eligible).  `rr` is a
scalar, or an (rr_vc, rr_port) pair rotating the two phases separately
(the batched simulator's convention, DESIGN.md §6).

Like the kernel it checks, the oracle is telemetry-neutral: the flight
recorder (DESIGN.md §13) consumes allocation outputs downstream and
never alters this arithmetic."""
import jax
import jax.numpy as jnp

INF = jnp.int32(2 ** 30)


def netstep_ref(op_slot, eligible, rr):
    if isinstance(rr, tuple):
        rr_vc, rr_port = rr
    else:
        rr_vc = rr_port = rr
    n, pi, v = op_slot.shape
    vcs = jnp.arange(v)[None, None, :]
    vc_score = jnp.where(eligible, (vcs - rr_vc) % v, INF)
    vc_choice = jnp.argmin(vc_score, axis=2).astype(jnp.int32)
    port_ok = jnp.min(vc_score, axis=2) < INF
    sel = jax.nn.one_hot(vc_choice, v, dtype=jnp.bool_)
    out_req = jnp.where(port_ok,
                        jnp.take_along_axis(op_slot,
                                            vc_choice[..., None],
                                            axis=2)[..., 0], -1)
    p_score = (jnp.arange(pi)[None, :] - rr_port) % pi
    win = jnp.zeros((n, pi), jnp.bool_)
    for o in range(pi):
        req_o = out_req == o
        score_o = jnp.where(req_o, p_score, INF)
        m = jnp.min(score_o, axis=1, keepdims=True)
        win_o = req_o & (score_o == m) & (m < INF)
        first = jnp.cumsum(win_o.astype(jnp.int32), axis=1)
        win_o &= first == 1
        win |= win_o
    win_mask = sel & eligible & win[:, :, None]
    return win_mask, vc_choice, out_req
