"""The ICI simulator's switch-allocation step as a Pallas TPU kernel —
the paper-specific hot loop (repro.core.simulator executes this every
simulated cycle for every router).

Two-phase separable allocation over a tile of routers:
  phase a — each input port picks its best eligible VC (rotating
            priority argmin over the V lane),
  phase b — each output slot picks one requesting input port.

Inputs per router tile [BN, PI, V]: op_slot (requested output slot per
head flit, -1 if none) and eligible (credit/validity mask); plus the
scalar rotating-priority counter.  Outputs: win_mask [BN, PI, V] and the
chosen vc / out-slot per port.  Pure vector ops (masked min/argmin,
one-hot compares) — VPU work, no MXU — tiled so a router block's state
fits VMEM even for radix-31 topologies (FlattenedButterfly at N=256).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

INF = 2 ** 30   # python literal: jnp constants would be captured consts


def _netstep_kernel(op_slot_ref, eligible_ref, rr_ref, win_ref, vc_ref,
                    req_ref, *, n_out: int):
    op_slot = op_slot_ref[...]                 # [BN, PI, V] int32
    eligible = eligible_ref[...]               # [BN, PI, V] bool
    rr_vc = rr_ref[0]                          # VC-phase rotating counter
    rr_port = rr_ref[1]                        # port-phase rotating counter
    bn, pi, v = op_slot.shape

    # phase a: rotating-priority VC choice per input port
    vcs = jax.lax.broadcasted_iota(jnp.int32, (bn, pi, v), 2)
    vc_score = jnp.where(eligible, (vcs - rr_vc) % v, INF)
    best = jnp.min(vc_score, axis=2)                      # [BN, PI]
    vc_choice = jnp.argmin(vc_score, axis=2).astype(jnp.int32)
    port_ok = best < INF
    sel = jax.nn.one_hot(vc_choice, v, dtype=jnp.bool_)
    out_req = jnp.where(
        port_ok,
        jnp.sum(jnp.where(sel, op_slot, 0), axis=2), -1)  # [BN, PI]

    # phase b: each output slot takes the lowest-priority-score requester
    ports = jax.lax.broadcasted_iota(jnp.int32, (bn, pi), 1)
    p_score = (ports - rr_port) % pi                      # [BN, PI]
    win = jnp.zeros((bn, pi), jnp.bool_)
    for o in range(n_out):                                # static radix
        req_o = out_req == o
        score_o = jnp.where(req_o, p_score, INF)
        m = jnp.min(score_o, axis=1, keepdims=True)
        win_o = req_o & (score_o == m) & (m < INF)
        # strict one-winner: lowest port index among score ties
        first = jnp.cumsum(win_o.astype(jnp.int32), axis=1)
        win_o &= first == 1
        win |= win_o
    win_mask = sel & eligible & win[:, :, None]
    win_ref[...] = win_mask
    vc_ref[...] = vc_choice
    req_ref[...] = out_req


@functools.partial(jax.jit, static_argnames=("block", "interpret"))
def netstep_pallas(op_slot, eligible, rr, *, block: int = 64,
                   interpret: bool = False):
    """op_slot: [N, PI, V] int32 (requested out slot, -1 none);
    eligible: [N, PI, V] bool; rr: scalar int32 — or an (rr_vc, rr_port)
    pair to rotate the VC and port phases with different periods, as the
    batched simulator requires (DESIGN.md §6).
    Returns (win_mask [N,PI,V], vc_choice [N,PI], out_req [N,PI])."""
    if isinstance(rr, tuple):
        rr_vc, rr_port = rr
    else:
        rr_vc = rr_port = rr
    rr2 = jnp.stack([jnp.asarray(rr_vc, jnp.int32),
                     jnp.asarray(rr_port, jnp.int32)])
    n, pi, v = op_slot.shape
    pad = (-n) % block
    if pad:
        op_slot = jnp.pad(op_slot, ((0, pad), (0, 0), (0, 0)),
                          constant_values=-1)
        eligible = jnp.pad(eligible, ((0, pad), (0, 0), (0, 0)))
    np_ = op_slot.shape[0]
    kern = functools.partial(_netstep_kernel, n_out=pi)
    win, vc, req = pl.pallas_call(
        kern,
        grid=(np_ // block,),
        in_specs=[
            pl.BlockSpec((block, pi, v), lambda i: (i, 0, 0)),
            pl.BlockSpec((block, pi, v), lambda i: (i, 0, 0)),
            pl.BlockSpec((2,), lambda i: (0,)),
        ],
        out_specs=[
            pl.BlockSpec((block, pi, v), lambda i: (i, 0, 0)),
            pl.BlockSpec((block, pi), lambda i: (i, 0)),
            pl.BlockSpec((block, pi), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((np_, pi, v), jnp.bool_),
            jax.ShapeDtypeStruct((np_, pi), jnp.int32),
            jax.ShapeDtypeStruct((np_, pi), jnp.int32),
        ],
        interpret=interpret,
    )(op_slot, eligible, rr2)
    return win[:n], vc[:n], req[:n]
