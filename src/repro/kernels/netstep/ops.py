"""Public wrapper for the netstep Pallas kernel."""
import jax

from .netstep import netstep_pallas


def _interpret() -> bool:
    return jax.default_backend() == "cpu"


def netstep(op_slot, eligible, rr, *, block: int = 64):
    return netstep_pallas(op_slot, eligible, rr, block=block,
                          interpret=_interpret())
