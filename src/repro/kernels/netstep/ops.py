"""Public wrapper for the netstep Pallas kernel.

`netstep` is the allocation hot loop the batched simulator dispatches to
when `SimConfig.alloc` resolves to "pallas" (auto on TPU).  On CPU the
kernel runs in interpret mode — correct but slow, so the simulator
defaults to the pure-jnp oracle there.

Telemetry neutrality (DESIGN.md §13): the flight recorder observes the
allocation *outputs* (win_mask and the masks the step derives from it)
— it never reaches into the kernel, so `SimConfig(telemetry=...)` can
not change which impl runs or what it computes, and the kernel needs no
recompile when telemetry toggles."""
import jax

from .netstep import netstep_pallas


def _interpret() -> bool:
    return jax.default_backend() == "cpu"


def is_available() -> bool:
    """True when the kernel compiles natively (non-interpreted)."""
    return jax.default_backend() == "tpu"


def netstep(op_slot, eligible, rr, *, block: int = 64):
    return netstep_pallas(op_slot, eligible, rr, block=block,
                          interpret=_interpret())
