"""ICI switch-allocation (netstep) Pallas kernel - the simulator hot loop."""
from . import ops, ref  # noqa
