"""Mamba-2 SSD chunked-scan Pallas kernel with pure-jnp oracles."""
from . import ops, ref  # noqa
