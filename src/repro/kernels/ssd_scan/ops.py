"""Public wrapper for the SSD Pallas kernel."""
import jax

from .ssd_scan import ssd_scan_pallas


def _interpret() -> bool:
    return jax.default_backend() == "cpu"


def ssd_scan(x, dt, a, b_mat, c_mat, *, chunk: int = 128):
    return ssd_scan_pallas(x, dt, a, b_mat, c_mat, chunk=chunk,
                           interpret=_interpret())
