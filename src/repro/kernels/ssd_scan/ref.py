"""Oracle for the SSD kernel: the pure-jnp chunked core (itself validated
against a naive per-token recurrence in tests/test_kernels.py)."""
from repro.models.ssm import ssd_chunked_core  # noqa: F401


def ssd_ref(x, dt, a, b_mat, c_mat, chunk):
    return ssd_chunked_core(x, dt, a, b_mat, c_mat, chunk)


def ssd_naive(x, dt, a, b_mat, c_mat):
    """Per-token recurrence (the mathematical definition)."""
    import jax.numpy as jnp
    import jax

    def step(s, inp):
        xt, dtt, bt, ct = inp
        da = jnp.exp(dtt * a[None, :])                    # [B,H]
        upd = jnp.einsum("bn,bhp,bh->bhnp", bt, xt, dtt)
        s = s * da[:, :, None, None] + upd
        y = jnp.einsum("bn,bhnp->bhp", ct, s)
        return s, y

    bsz, t, h, p = x.shape
    n = b_mat.shape[-1]
    s0 = jnp.zeros((bsz, h, n, p), jnp.float32)
    xs = (jnp.moveaxis(x.astype(jnp.float32), 1, 0),
          jnp.moveaxis(dt.astype(jnp.float32), 1, 0),
          jnp.moveaxis(b_mat.astype(jnp.float32), 1, 0),
          jnp.moveaxis(c_mat.astype(jnp.float32), 1, 0))
    s, ys = jax.lax.scan(step, s0, xs)
    return jnp.moveaxis(ys, 0, 1).astype(x.dtype), s
