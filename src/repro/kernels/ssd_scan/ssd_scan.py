"""Mamba2 SSD chunked scan as a Pallas TPU kernel.

Grid: (B, n_chunks) — the chunk axis is the minor grid dimension, which
TPU executes sequentially, so the inter-chunk recurrent state lives in a
VMEM scratch accumulator that persists across grid steps (reset at
chunk 0, flushed to the final-state output at the last chunk).

Per program: one chunk [Q, H, P] of inputs.  All within-chunk terms are
expressed as matmuls (MXU): the inclusive cumulative sum of decay rates
is a lower-triangular-ones matmul, the within-chunk "attention" term is
(C Bᵀ ∘ L) X, and the chunk state summary is Bᵀ (decay·dt·X).

VMEM at Q=128, H=64, P=64, N=128 (mamba2-1.3b): x/y tiles 2 MiB (f32),
L matrix Q²H = 4 MiB — under the 16 MiB budget.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _ssd_kernel(x_ref, dt_ref, a_ref, b_ref, c_ref, y_ref, s_out_ref,
                state_ref, *, n_chunks: int):
    ci = pl.program_id(1)
    x = x_ref[...].astype(jnp.float32)        # [Q, H, P]
    dt = dt_ref[...].astype(jnp.float32)      # [Q, H]
    a = a_ref[...].astype(jnp.float32)        # [H]
    bm = b_ref[...].astype(jnp.float32)       # [Q, N]
    cm = c_ref[...].astype(jnp.float32)       # [Q, N]
    q, h, p = x.shape
    n = bm.shape[-1]

    @pl.when(ci == 0)
    def _init():
        state_ref[...] = jnp.zeros_like(state_ref)

    da = dt * a[None, :]                                        # [Q, H]
    tril = jnp.tril(jnp.ones((q, q), jnp.float32))
    cum = jax.lax.dot_general(tril, da, (((1,), (0,)), ((), ())),
                              preferred_element_type=jnp.float32)
    seg = cum[:, None, :] - cum[None, :, :]                     # [Q,Q,H]
    mask = jnp.tril(jnp.ones((q, q), jnp.bool_))
    l_mat = jnp.where(mask[:, :, None], jnp.exp(seg), 0.0)

    cb = jax.lax.dot_general(cm, bm, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)  # [Q,Q]
    xdt = x * dt[:, :, None]                                    # [Q,H,P]
    m = (cb[:, :, None] * l_mat)                                # [Q,Q,H]
    # y_diag[q,h,p] = sum_k m[q,k,h] xdt[k,h,p]  (batched over h)
    mt = jnp.transpose(m, (2, 0, 1))                            # [H,Q,Q]
    xt = jnp.transpose(xdt, (1, 0, 2))                          # [H,Q,P]
    y_diag = jax.lax.dot_general(
        mt, xt, (((2,), (1,)), ((0,), (0,))),
        preferred_element_type=jnp.float32)                     # [H,Q,P]
    y_diag = jnp.transpose(y_diag, (1, 0, 2))                   # [Q,H,P]

    # inter-chunk term from the carried state
    s_in = state_ref[...].astype(jnp.float32)                   # [H,N,P]
    s_flat = jnp.transpose(s_in, (1, 0, 2)).reshape(n, h * p)   # [N,HP]
    y_int = jax.lax.dot_general(cm, s_flat, (((1,), (0,)), ((), ())),
                                preferred_element_type=jnp.float32)
    y_int = y_int.reshape(q, h, p) * jnp.exp(cum)[:, :, None]   # [Q,H,P]
    y_ref[...] = (y_diag + y_int).astype(y_ref.dtype)

    # state update: S = S * exp(cum[-1]) + B^T (exp(cum[-1]-cum)*dt*X)
    decay_tail = jnp.exp(cum[-1:, :] - cum)                     # [Q,H]
    w = x * (decay_tail * dt)[:, :, None]                       # [Q,H,P]
    w2 = w.reshape(q, h * p)
    s_new = jax.lax.dot_general(bm, w2, (((0,), (0,)), ((), ())),
                                preferred_element_type=jnp.float32)
    s_new = jnp.transpose(s_new.reshape(n, h, p), (1, 0, 2))    # [H,N,P]
    chunk_decay = jnp.exp(cum[-1, :])                           # [H]
    s_next = s_in * chunk_decay[:, None, None] + s_new
    state_ref[...] = s_next

    @pl.when(ci == n_chunks - 1)
    def _flush():
        s_out_ref[...] = s_next.astype(s_out_ref.dtype)


@functools.partial(jax.jit,
                   static_argnames=("chunk", "interpret"))
def ssd_scan_pallas(x, dt, a, b_mat, c_mat, *, chunk: int = 128,
                    interpret: bool = False):
    """x: [B,T,H,P]; dt: [B,T,H]; a: [H]; b/c: [B,T,N].

    Returns (y [B,T,H,P], final_state [B,H,N,P] float32).
    """
    bsz, t, h, p = x.shape
    n = b_mat.shape[-1]
    assert t % chunk == 0, (t, chunk)
    nc = t // chunk
    kern = functools.partial(_ssd_kernel, n_chunks=nc)
    grid = (bsz, nc)
    y, s = pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((None, chunk, h, p), lambda b, c: (b, c, 0, 0)),
            pl.BlockSpec((None, chunk, h), lambda b, c: (b, c, 0)),
            pl.BlockSpec((h,), lambda b, c: (0,)),
            pl.BlockSpec((None, chunk, n), lambda b, c: (b, c, 0)),
            pl.BlockSpec((None, chunk, n), lambda b, c: (b, c, 0)),
        ],
        out_specs=[
            pl.BlockSpec((None, chunk, h, p), lambda b, c: (b, c, 0, 0)),
            pl.BlockSpec((None, h, n, p), lambda b, c: (b, 0, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bsz, t, h, p), x.dtype),
            jax.ShapeDtypeStruct((bsz, h, n, p), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((h, n, p), jnp.float32)],
        interpret=interpret,
    )(x, dt, a, b_mat, c_mat)
    return y, s
