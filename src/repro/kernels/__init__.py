"""Pallas TPU kernels for the framework's compute hot spots.

Each kernel directory has:
    <name>.py — pl.pallas_call + explicit BlockSpec VMEM tiling
    ops.py    — jit'd public wrapper (interpret=True on CPU)
    ref.py    — pure-jnp oracle used by the allclose test sweeps

Kernels:
    flash_attention — blockwise causal/sliding-window attention with an
        online softmax (the quadratic-memory hot spot of every attention
        arch at train_4k/prefill_32k).
    ssd_scan — Mamba2 SSD chunked scan; the sequential inter-chunk
        recurrence is carried across the TPU grid's sequential minor axis
        in a VMEM scratch accumulator.
    netstep — the paper-specific hot loop: the ICI simulator's two-phase
        separable switch allocation, tiled over router blocks.
"""
