"""Minimal-adaptive routing subsystem (DESIGN.md §15).

One front door for the adaptive-routing pieces that live across the
layers they extend:

  * the **productive-ports mask** (`repro.core.routing.productive_ports`)
    — `[N_dst, N, P]` bool, every escape-safe minimal next hop per
    (destination, node);
  * the **VC partition** in the batched simulator
    (`SimConfig(routing="adaptive")`): VC 0 is the escape class driven
    by the certified-acyclic static up*/down* table, VCs 1..V-1 are the
    adaptive class whose output port is chosen by downstream credit
    count among productive ports;
  * the **escape certification** (`repro.analysis.routing_verify
    .check_escape`, diagnostic RT005): every adaptive choice retains a
    deliverable escape path and the escape-class channel-dependency
    graph stays acyclic.

`routing="static"` is bitwise identical to the pre-adaptive simulator
(pinned in tests/test_simulator.py), so this module is purely additive.

Quickstart (see also examples/adaptive_quickstart.py):

    import repro.adaptive as A
    from repro.core import topology as T, traffic as TR
    from repro.core.routing import build_routing

    r = build_routing(T.build("folded_hexa_torus", 36))
    out = A.compare_saturation(r, TR.uniform(r.topo), A.adaptive_config())
    print(out["static"], out["adaptive"], out["gain"])
"""
from __future__ import annotations

import numpy as np

from repro.analysis.routing_verify import check_escape
from repro.core.routing import Routing, productive_ports
from repro.core.simulator import (ADAPTIVE_HEADROOM, STATIC_HEADROOM,
                                  SimConfig, routing_headroom,
                                  saturation_throughput)

__all__ = [
    "ADAPTIVE_HEADROOM", "STATIC_HEADROOM", "adaptive_config",
    "check_escape", "compare_saturation", "productive_ports",
    "routing_headroom",
]


def adaptive_config(cfg: SimConfig | None = None,
                    n_vcs: int | None = None) -> SimConfig:
    """A SimConfig running the minimal-adaptive mode.

    Starts from `cfg` (default: the stock SimConfig), switches
    `routing="adaptive"` and — because the mode needs VC 0 escape plus
    at least one adaptive VC — raises `n_vcs` to 2 if the base config
    has fewer.  Pass `n_vcs` to pick the VC count explicitly.
    """
    cfg = cfg or SimConfig()
    if n_vcs is None:
        n_vcs = max(cfg.n_vcs, 2)
    return cfg._replace(routing="adaptive", n_vcs=n_vcs)


def compare_saturation(routing: Routing, traffic: np.ndarray,
                       cfg: SimConfig | None = None,
                       n_rates: int = 6) -> dict:
    """Static-vs-adaptive saturation for one (routing, traffic) cell.

    Runs `simulator.saturation_throughput` once per mode (each with its
    own routing-aware rate-grid headroom) and reports the relative
    gain.  `cfg` may be either mode; both variants are derived from it.
    """
    cfg = cfg or SimConfig()
    st = saturation_throughput(routing, traffic,
                               cfg._replace(routing="static"), n_rates)
    ad = saturation_throughput(routing, traffic, adaptive_config(cfg),
                               n_rates)
    s, a = st["sim_saturation"], ad["sim_saturation"]
    return dict(static=s, adaptive=a,
                gain=a / s - 1.0 if s > 0 else float("nan"),
                analytic=st["analytic_saturation"],
                static_sweep=st, adaptive_sweep=ad)
