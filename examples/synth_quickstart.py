"""Quickstart: synthesize custom topologies and search the design
space (DESIGN.md §11).

    PYTHONPATH=src python examples/synth_quickstart.py

Shows the three layers of `repro.synth`: (1) custom topologies as
first-class citizens — build one from raw edges, register a generator,
evaluate both through the ordinary experiment API; (2) the design
space and feasibility filter; (3) a small seeded search producing a
Pareto front with save/resume.
"""
import os

import repro.experiments as X
from repro.core import topology as T
from repro.core.simulator import SimConfig
from repro.synth import (FeasibilityCriteria, SearchConfig, SearchState,
                         check, fold_mask_variants, random_geometric,
                         run_search)

RESULTS = os.path.join(os.path.dirname(__file__), "..", "results")


def main():
    print("=== custom topologies are first-class ===")
    # a Topology built from raw arrays (validated: no self-loops,
    # duplicates or disconnection), evaluated like any registry name
    base = T.build("mesh", 16)
    ring = T.make_topology("ring16", base.pos,
                           [(i, (i + 1) % 16) for i in range(16)])
    # ... or a registered generator, resolvable by name everywhere
    T.register_topology(
        "double_ring", lambda n: ("double_ring", base.pos,
                                  [(i, (i + 1) % n) for i in range(n)]
                                  + [(i, (i + 2) % n) for i in range(n)]),
        overwrite=True)
    exp = X.Experiment([X.Scenario(ring, 16), X.Scenario("double_ring", 16),
                        X.Scenario("folded_hexa_torus", 16)],
                       backend="analytic", name="custom_demo")
    for row in X.run(exp).ok():
        print(f"  {row['topology']:18s} analytic T_r="
              f"{row['analytic_saturation']:.3f} "
              f"radix={row['radix']}")

    print("\n=== the design space + feasibility filter ===")
    crit = FeasibilityCriteria()          # the paper's three principles
    variants = fold_mask_variants(16, families=("grid", "brick"))
    feasible = [t for t in variants if not check(t, crit)]
    print(f"  {len(variants)} fold-mask variants, "
          f"{len(feasible)} substrate-feasible")
    rg = random_geometric(16, seed=7, max_degree=6, max_range=1)
    print(f"  random geometric: {rg.name} radix={rg.radix} "
          f"links={len(rg.edges)} feasible={not check(rg, crit)}")

    print("\n=== a small seeded search (save + resume) ===")
    cfg = SearchConfig(n=16, n_random=8, generations=1, offspring=8,
                       sim_top=4, n_rates=3,
                       cfg=SimConfig(cycles=360, warmup=120))
    res = run_search(cfg)
    path = os.path.join(RESULTS, "synth_state_demo.json")
    res.state.to_json(path)                     # serializable SearchState
    SearchState.from_json(path)                 # ... and back
    print(f"  {res.stats['n_feasible']} feasible candidates, "
          f"{res.stats['n_simulated']} cycle-simulated "
          f"(prefilter {res.prefilter_ratio:.1f}x)")
    for c in res.front():
        m = c.metrics
        print(f"  front: {c.topo.name:24s} "
              f"{m['abs_throughput_gbps']:7.1f} Gb/s  "
              f"{m['zero_load_latency_ns']:5.1f} ns  "
              f"{m['wire_cost_mm']:8.0f} wire-mm")
    print("  folded_hexa_torus within 5% of front:",
          res.on_front("folded_hexa_torus", eps=0.05))


if __name__ == "__main__":
    main()
