"""Paper -> framework bridge: what a training step's collectives cost
under different chiplet-ICI topologies.

Reads a dry-run artifact (all-reduce/all-gather bytes of the compiled
sharded train step) and prices it under each ICI topology using the
paper's saturation-throughput results.

    PYTHONPATH=src python examples/topology_collectives.py \
        [results/dryrun/qwen3_1_7b__train_4k__pod1.json]
"""
import glob
import json
import sys

from repro.core.collectives import build_ici_model


def main():
    paths = sys.argv[1:] or sorted(
        glob.glob("results/dryrun/*train_4k__pod1.json"))
    if not paths:
        print("no dry-run artifacts found — run repro.launch.dryrun first")
        return
    for path in paths[:4]:
        rec = json.load(open(path))
        if not rec.get("ok"):
            continue
        print(f"\n=== {rec['tag']} ===")
        print(f"collective bytes/chip/step: "
              f"{rec['collective_bytes_per_chip']/2**30:.2f} GiB")
        for topo in ("mesh", "hexamesh", "folded_torus",
                     "folded_hexa_torus"):
            m = build_ici_model(topo, 64, "organic")
            t = sum(m.collective_time_s(kind.replace("-", "_"),
                                        v["bytes"])
                    for kind, v in rec["collectives"].items())
            print(f"  {topo:20s} B_eff={m.b_eff_gbps/1e3:6.2f} Tb/s  "
                  f"step collective time ~ {t*1e3:8.2f} ms")


if __name__ == "__main__":
    main()
