"""End-to-end driver: train a ~100M-param qwen3-family model for a few
hundred steps on the synthetic pipeline (CPU-sized by default; pass
--steps/--batch/--seq to scale up; on TPU the same driver takes the full
config + production mesh).

    PYTHONPATH=src python examples/train_lm.py            # quick demo
    PYTHONPATH=src python examples/train_lm.py --steps 300 --d-model 512 \
        --layers 12 --batch 8 --seq 512                    # ~100M params
"""
import sys

from repro.launch.train import main


if __name__ == "__main__":
    argv = sys.argv[1:]
    if not argv:
        argv = ["--arch", "qwen3-1.7b", "--smoke", "--steps", "60",
                "--batch", "8", "--seq", "128", "--log-every", "10"]
    main(argv)
