"""Quickstart: build FoldedHexaTorus, route it, simulate it, cost it.

    PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.core import topology as T, traffic as TR, costmodel as cm
from repro.core.routing import build_routing, dependency_graph_is_acyclic
from repro.core.simulator import SimConfig, saturation_throughput, \
    zero_load_latency


def main():
    print("=== FoldedHexaTorus vs Mesh, 64 chiplets, organic substrate ===")
    for name in ("mesh", "hexamesh", "folded_torus", "folded_hexa_torus"):
        topo = T.build(name, 64, substrate="organic")
        routing = build_routing(topo)
        assert dependency_graph_is_acyclic(routing)
        u = TR.uniform(topo)
        t_r = routing.saturation_rate(u)
        lat = zero_load_latency(routing, u)
        _, hops, _ = routing.paths_channel_loads(u)
        t_a = cm.absolute_throughput_gbps(topo, t_r)
        print(f"{name:20s} diam={topo.diameter:2d} radix={topo.radix} "
              f"maxlink={topo.max_link_length_mm():5.1f}mm "
              f"T_r={t_r:.3f} flits/node/cyc  T_a={t_a/1e3:7.2f} Tb/s "
              f"lat={lat:5.1f}ns")

    print("\n=== cycle-accurate check (16 chiplets) ===")
    topo = T.build("folded_hexa_torus", 16)
    routing = build_routing(topo)
    out = saturation_throughput(routing, TR.uniform(topo),
                                SimConfig(cycles=1500, warmup=500),
                                n_rates=5)
    print(f"simulated saturation {out['sim_saturation']:.3f} "
          f"(analytic bound {out['analytic_saturation']:.3f}), "
          f"latency@sat {out['latency_at_sat']:.1f} cycles")


if __name__ == "__main__":
    main()
