"""Quickstart: build FoldedHexaTorus, route it, then evaluate a whole
topology grid through the declarative experiment API (DESIGN.md §10).

    PYTHONPATH=src python examples/quickstart.py
"""
import os

import repro.experiments as X
from repro.core import topology as T, traffic as TR
from repro.core.routing import build_routing, dependency_graph_is_acyclic
from repro.core.simulator import SimConfig

RESULTS = os.path.join(os.path.dirname(__file__), "..", "results")


def main():
    print("=== the core layer: one topology, routed and checked ===")
    topo = T.build("folded_hexa_torus", 64, substrate="organic")
    routing = build_routing(topo)
    assert dependency_graph_is_acyclic(routing)
    u = TR.uniform(topo)
    print(f"folded_hexa_torus    diam={topo.diameter:2d} "
          f"radix={topo.radix} "
          f"maxlink={topo.max_link_length_mm():5.1f}mm "
          f"analytic T_r={routing.saturation_rate(u):.3f}")

    print("\n=== the experiment API: a grid through one front door ===")
    exp = X.Experiment.grid(
        topologies=["mesh", "hexamesh", "folded_torus",
                    "folded_hexa_torus"],
        sizes=[64], name="quickstart", backend="analytic")
    frame = X.run(exp)
    for r in frame.ok():
        print(f"{r['topology']:20s} T_r={r['rel_throughput']:.3f} "
              f"flits/node/cyc  T_a={r['abs_throughput_gbps']/1e3:7.2f} "
              f"Tb/s  lat={r['latency_ns']:5.1f}ns")
    frame.to_csv(os.path.join(RESULTS, "quickstart.csv"))

    print("\n=== cycle-accurate check (16 chiplets, simulated) ===")
    sim_exp = X.Experiment(
        [X.Scenario("folded_hexa_torus", 16,
                    rates=X.SaturationGrid(5))],
        cfg=SimConfig(cycles=1500, warmup=500), name="quickstart_sim")
    res = X.run(sim_exp).case_result(0)
    print(f"simulated saturation {res['sim_saturation']:.3f} "
          f"(analytic bound {res['analytic_saturation']:.3f}), "
          f"latency@sat {res['latency_at_sat']:.1f} cycles")


if __name__ == "__main__":
    main()
