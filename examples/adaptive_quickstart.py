"""Adaptive-routing quickstart: minimal-adaptive with escape VCs
(DESIGN.md §15).

    PYTHONPATH=src python examples/adaptive_quickstart.py

Walks the three layers of the adaptive subsystem on a drifting-hotspot
workload — the traffic adaptivity is built for:

  1. the productive-ports mask and its RT005 escape certification:
     every adaptive choice keeps a deliverable escape path and the
     escape-class channel-dependency graph stays acyclic;
  2. a static-vs-adaptive saturation comparison through the
     `repro.adaptive` facade (one call, both modes, routing-aware
     rate-grid headroom);
  3. the same comparison through `repro.experiments` — the routing
     mode rides in `Scenario(routing=...)`, so one declarative
     experiment runs both modes and the frame carries a `routing`
     column.
"""
import numpy as np

import repro.adaptive as A
import repro.experiments as X
import repro.workloads as W
from repro.analysis.routing_verify import certify_routing
from repro.core import topology as T, traffic as TR
from repro.core.routing import build_routing
from repro.core.simulator import SimConfig


def main():
    n = 36
    r = build_routing(T.build("mesh", n))

    print("=== 1. productive ports + escape certification (RT005) ===")
    prod = A.productive_ports(r)
    cert = certify_routing(r)
    print(f"  mask [N_dst, N, P] = {prod.shape}, "
          f"{int(prod.sum())} productive entries")
    print(f"  certificate: ok={cert.ok} escape_safe={cert.escape_safe} "
          f"adaptive_choices={cert.n_adaptive_choices}")
    assert cert.ok, "escape certification must pass for Table III"

    print("\n=== 2. static vs adaptive under a drifting hotspot ===")
    cfg = SimConfig(cycles=1000, warmup=300)
    sched = W.hotspot_drift(r.topo, n_phases=4, dwell=250,
                            seed=2).fit(cfg.cycles).compile()
    from repro.core.simulator import make_spec, run_batch
    spec = make_spec(r, TR.uniform(r.topo))
    rates = np.linspace(0.05, 0.9, 6).astype(np.float32)[None, :]
    st = run_batch([spec], rates, cfg, schedules=[sched])[0]
    ad = run_batch([spec], rates, A.adaptive_config(cfg),
                   schedules=[sched])[0]
    s = float(np.max(np.asarray(st["throughput"])))
    a = float(np.max(np.asarray(ad["throughput"])))
    print(f"  mesh{n}, hotspot_drift: static {s:.3f} "
          f"adaptive {a:.3f}  gain {a / s - 1.0:+.1%}")

    print("\n=== 3. the same thing declaratively, via Scenario(routing) "
          "===")
    wl = W.Workload("hotspot_drift",
                    lambda topo: W.hotspot_drift(topo, n_phases=4,
                                                 dwell=250, seed=2))
    exp = X.Experiment(
        [X.Scenario("folded_hexa_torus", n, traffic=wl, routing=mode,
                    rates=X.SaturationGrid(4))
         for mode in ("static", "adaptive")],
        cfg=cfg, name="adaptive_quickstart")
    frame = X.run(exp)
    for row in frame.rows:
        print(f"  {row['topology']:18s} routing={row['routing']:8s} "
              f"sim_saturation={row['sim_saturation']:.3f}")
    print("  -> FHT's static channel load is already flat, so its "
          "adaptive margin is small; see results/adaptive_gain.csv")


if __name__ == "__main__":
    main()
