"""Batched serving example: prefill + decode with KV/SSM caches.

    PYTHONPATH=src python examples/serve_lm.py [--arch mamba2-1.3b]
"""
import sys

from repro.launch.serve import main


if __name__ == "__main__":
    argv = sys.argv[1:]
    if not argv:
        argv = ["--arch", "qwen3-1.7b", "--smoke", "--batch", "4",
                "--prompt-len", "32", "--gen", "16"]
    main(argv)
