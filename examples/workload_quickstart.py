"""Workload-engine quickstart: time-varying traffic through the
declarative experiment API (DESIGN.md §9 + §10).

    PYTHONPATH=src python examples/workload_quickstart.py

Builds three workloads — a qwen3-style LLM-training collective
schedule, a replayed fluidanimate trace with ON/OFF bursts, and an
adversarial tornado<->uniform alternation — crosses them with Mesh vs
FoldedHexaTorus in ONE `Experiment`, and runs the grid through
`repro.experiments.run` (the workloads ride in each Scenario's
`traffic` field; the planner lowers them onto batched engine programs).
"""
import os
from functools import partial

import numpy as np

import repro.experiments as X
import repro.workloads as W
from repro.configs import get_config
from repro.core.simulator import SimConfig


def main():
    cfg = get_config("qwen3_1_7b")
    workloads = [
        W.Workload(f"collective:{cfg.name}",
                   partial(W.collective_workload, cfg)),
        W.Workload("trace:fluidanimate",
                   partial(W.trace_workload, trace="fluidanimate")),
        W.Workload("alt:tornado-uniform", W.phase_alternating),
    ]
    exp = X.Experiment(
        [X.Scenario(name, 16, traffic=wl, roles="hetero_cmi",
                    rates=X.SaturationGrid(4))
         for name in ("mesh", "folded_hexa_torus") for wl in workloads],
        cfg=SimConfig(cycles=800, warmup=300), name="workload_quickstart")
    frame = X.run(exp)
    print("=== workloads x topologies, one declarative experiment ===")
    for i, row in enumerate(frame.rows):
        if row["status"] != "ok":
            continue
        res = frame.workload_result(i)
        phases = ", ".join(
            f"{lbl}={thr:.3f}" for lbl, thr in
            zip(res["phase_labels"], res["throughput_ph"]))
        print(f"{row['topology']:18s} {res['workload']:24s} "
              f"sat={res['sim_saturation']:.3f} "
              f"lat={res['latency_at_sat']:5.1f}cy  per-phase [{phases}]")
    frame.to_csv(os.path.join(os.path.dirname(__file__), "..",
                              "results", "workload_quickstart.csv"))

    print("\n=== anatomy of the collective schedule on FHT-16 ===")
    from repro.core.topology import build
    topo = build("folded_hexa_torus", 16)
    sched = W.collective_workload(cfg, topo)
    for p in sched.phases:
        burst = f" burst {p.burst_on}/{p.burst_off}" if p.burst_on else ""
        print(f"  {p.label:12s} {p.duration:4d}cy intensity="
              f"{p.intensity:.3f}{burst} peak-row="
              f"{np.asarray(p.traffic).sum(1).max():.3g} bytes")


if __name__ == "__main__":
    main()
