"""Workload-engine quickstart: time-varying traffic through the sweep.

    PYTHONPATH=src python examples/workload_quickstart.py

Builds three workloads — a qwen3-style LLM-training collective
schedule, a replayed fluidanimate trace with ON/OFF bursts, and an
adversarial tornado<->uniform alternation — and evaluates Mesh vs
FoldedHexaTorus under all of them in one batched engine call
(DESIGN.md §9).
"""
from functools import partial

import numpy as np

import repro.workloads as W
from repro.configs import get_config
from repro.core.simulator import SimConfig
from repro.sweep.engine import SweepCase, SweepEngine


def main():
    cfg = get_config("qwen3_1_7b")
    workloads = [
        W.Workload(f"collective:{cfg.name}",
                   partial(W.collective_workload, cfg)),
        W.Workload("trace:fluidanimate",
                   partial(W.trace_workload, trace="fluidanimate")),
        W.Workload("alt:tornado-uniform", W.phase_alternating),
    ]
    cases = [SweepCase(name, 16, roles="hetero_cmi")
             for name in ("mesh", "folded_hexa_torus")]
    engine = SweepEngine(cfg=SimConfig(cycles=800, warmup=300))
    print("=== workloads x topologies, one batched sweep ===")
    for res in engine.evaluate_workload_cases(cases, workloads, n_rates=4):
        phases = ", ".join(
            f"{lbl}={thr:.3f}" for lbl, thr in
            zip(res["phase_labels"], res["throughput_ph"]))
        print(f"{res['case'].name:18s} {res['workload']:24s} "
              f"sat={res['sim_saturation']:.3f} "
              f"lat={res['latency_at_sat']:5.1f}cy  per-phase [{phases}]")

    print("\n=== anatomy of the collective schedule on FHT-16 ===")
    from repro.core.topology import build
    topo = build("folded_hexa_torus", 16)
    sched = W.collective_workload(cfg, topo)
    for p in sched.phases:
        burst = f" burst {p.burst_on}/{p.burst_off}" if p.burst_on else ""
        print(f"  {p.label:12s} {p.duration:4d}cy intensity="
              f"{p.intensity:.3f}{burst} peak-row="
              f"{np.asarray(p.traffic).sum(1).max():.3g} bytes")


if __name__ == "__main__":
    main()
