"""Observability quickstart: flight recorder + span tracing
(DESIGN.md §13).

    PYTHONPATH=src python examples/obs_quickstart.py

Runs a tiny Mesh vs FoldedHexaTorus experiment with the in-sim flight
recorder on (`SimConfig(telemetry=True)`) and host-side span tracing
enabled, then shows the three things the telemetry layer gives you:

  1. per-link load — which directed channels carry the traffic, how
     unevenly (p95/max utilization, Gini imbalance), and why folding
     wins: its channel-load histogram is flatter at equal throughput;
  2. exact conservation — the per-node injection/ejection counters
     reconcile bitwise with the aggregate counters the simulator
     already reported, so the flight data is trustworthy, not sampled;
  3. where the wall-clock went — a Chrome-trace/Perfetto JSON of the
     plan -> execute -> dispatch/wait span tree with the compile-vs-run
     split (load results/obs_quickstart.trace.json in ui.perfetto.dev);
  4. load over TIME — `SimConfig(telemetry_windows=W)` bins the same
     counters into W time windows (DESIGN.md §16), so a drifting
     hotspot on FHT36 becomes visible as per-window Gini churn in
     results/obs_quickstart_windows.csv instead of averaging away.
"""
import os

import numpy as np

import repro.experiments as X
import repro.workloads as W
from repro.core.simulator import SimConfig
from repro.obs import metrics
from repro.obs.report import gini, link_load_summary, window_summary
from repro.obs.trace import (disable_tracing, enable_tracing,
                             save_chrome_trace)


def main():
    cfg = SimConfig(cycles=600, warmup=200, telemetry=True)
    exp = X.Experiment(
        [X.Scenario(name, 16, rates=X.SaturationGrid(4))
         for name in ("mesh", "folded_hexa_torus")],
        cfg=cfg, name="obs_quickstart")

    enable_tracing()
    frame = X.run(exp)
    disable_tracing()

    print("=== 1. per-link load at saturation (the paper's mechanism) ===")
    for cell in link_load_summary(frame.all_link_rows()):
        print(f"  {cell['topology']:18s} links={cell['n_links']:3d} "
              f"p50={cell['util_p50']:.3f} p95={cell['util_p95']:.3f} "
              f"max={cell['util_max']:.3f} gini={cell['gini']:.3f}")
    mesh, fht = frame.rows[0], frame.rows[1]
    print(f"  -> folding flattens the load: FHT gini "
          f"{fht['link_gini']:.3f} vs mesh {mesh['link_gini']:.3f}")

    print("\n=== 2. conservation: flight counters == aggregate counters "
          "===")
    for i, row in enumerate(frame.rows):
        res = frame.results[i]
        if row["status"] != "ok" or res is None:
            continue
        np.testing.assert_array_equal(res["inj_node"].sum(axis=1),
                                      res["accepted_n"])
        np.testing.assert_array_equal(res["eject_node"].sum(axis=1),
                                      res["delivered"])
        np.testing.assert_array_equal(res["lat_hist"].sum(axis=1),
                                      res["delivered"])
        print(f"  {row['topology']:18s} sum(inj)==accepted, "
              f"sum(eject)==delivered, sum(hist)==delivered  [exact]")

    print("\n=== 3. where the wall-clock went ===")
    results = os.path.join(os.path.dirname(__file__), "..", "results")
    save_chrome_trace(os.path.join(results, "obs_quickstart.trace.json"),
                      metadata=dict(example="obs_quickstart"))
    snap = metrics.snapshot()
    print(f"  sweep runs={snap.get('sweep.runs', 0):.0f} "
          f"compiles={snap.get('sweep.compiles', 0):.0f} "
          f"runner cache misses={snap['cache.runner.misses']} "
          f"hits={snap['cache.runner.hits']}")
    print("  open results/obs_quickstart.trace.json in ui.perfetto.dev "
          "for the span tree")

    frame.to_link_csv(os.path.join(results, "obs_quickstart_links.csv"))

    print("\n=== 4. windowed time-heatmap: a hotspot drifting across "
          "FHT36 ===")
    wcfg = SimConfig(cycles=900, warmup=300, telemetry=True,
                     telemetry_windows=6)
    drift = W.Workload("hotspot_drift",
                       lambda topo: W.hotspot_drift(topo, n_phases=6,
                                                    dwell=100))
    wexp = X.Experiment(
        [X.Scenario("folded_hexa_torus", 36, traffic=drift,
                    rates=X.SaturationGrid(3))],
        cfg=wcfg, name="obs_quickstart_windows")
    wframe = X.run(wexp)
    wframe.to_window_csv(
        os.path.join(results, "obs_quickstart_windows.csv"))
    print("  per-window channel-load imbalance (gini) and the "
          "escape/adaptive occupancy split:")
    for s in window_summary(wframe.all_window_rows()):
        print(f"  window {s['window']} "
              f"[t={s['t_start']:4d}..{s['t_end']:4d}) "
              f"util_p95={s['util_p95']:.3f} gini={s['gini']:.3f} "
              f"occ_esc={s['occ_escape_mean']:.3f} "
              f"occ_adapt={s['occ_adaptive_mean']:.3f}")
    print("  -> each window's hot channels move with the hotspot; the "
          "aggregate heatmap above averages this away")


if __name__ == "__main__":
    main()
