"""Fault-injection quickstart: serve traffic through dead links
(DESIGN.md §12).

    PYTHONPATH=src python examples/fault_quickstart.py

Draws seeded link faults on FoldedHexaTorus-36, shows the degraded
topology re-routing deadlock-free through the experiment pipeline,
compares the degradation against Mesh, and runs a mixed-tenant
schedule (serving traffic superimposed on a training step) through the
same fault masks.  A disconnecting fault set is shown being rejected.
"""
import os

import repro.experiments as X
import repro.faults as F
import repro.workloads as W
from repro.configs import get_config
from repro.core.simulator import SimConfig
from repro.core.topology import build


def main():
    cfg = SimConfig(cycles=800, warmup=300)
    names = ("mesh", "folded_hexa_torus")
    ks = (0, 1, 2, 4)

    print("=== uniform-traffic degradation, N=36 organic ===")
    scenarios = []
    for name in names:
        topo = build(name, 36)
        for k in ks:
            fs = F.sample_faults(topo, k, "random", seed=0) if k else None
            scenarios.append(X.Scenario(
                name, 36, faults=fs, rates=X.SaturationGrid(4),
                tags=(("k_failed", k),)))
    frame = X.run(X.Experiment(scenarios, cfg=cfg,
                               name="fault_quickstart"))
    for row in frame.ok():
        print(f"  {row['topology']:18s} k={row['k_failed']} "
              f"faults={row['faults']:16s} "
              f"sat={row['sim_saturation']:.3f} "
              f"abs={row['abs_throughput_gbps'] / 1e3:.2f} Tb/s")

    print("\n=== mixed tenant (train collectives + 30% serving) "
          "through the same masks ===")
    mixed = W.mixed_tenant(get_config("qwen3_1_7b"), serve_frac=0.3)
    topo = build("folded_hexa_torus", 36)
    scenarios = [X.Scenario("folded_hexa_torus", 36, traffic=mixed,
                            faults=F.sample_faults(topo, k, "random",
                                                   seed=0) if k else None,
                            rates=X.SaturationGrid(3),
                            tags=(("k_failed", k),))
                 for k in (0, 2)]
    mf = X.run(X.Experiment(scenarios, cfg=cfg, name="fault_mixed"))
    for i, row in enumerate(mf.ok()):
        res = mf.workload_result(i)
        print(f"  k={row['k_failed']} sat={res['sim_saturation']:.3f} "
              f"lat={res['latency_at_sat']:.1f}cy "
              f"({len(res['phase_labels'])} phases)")

    print("\n=== partitioned packages are outages, not data points ===")
    import numpy as np
    mesh = build("mesh", 16)
    e = np.sort(np.asarray(mesh.edges), axis=1)
    cut = tuple(tuple(int(x) for x in lk) for lk in e[(e == 0).any(1)])
    try:
        F.FaultSet(links=cut).apply(mesh)
    except F.DisconnectedFaultError as err:
        print(f"  rejected: {err}")

    frame.to_csv(os.path.join(os.path.dirname(__file__), "..",
                              "results", "fault_quickstart.csv"))


if __name__ == "__main__":
    main()
